//! Micro-intrusive Begin/End API demo: a "training script" talks to the
//! GPOEO daemon over a Unix socket, exactly like the paper's two-call
//! instrumentation (§2.2.2) — through the control-plane v1 client
//! (`GpoeoClient`, DESIGN.md §9). The legacy line protocol still works
//! on the same socket (`LegacyClient`), shown at the end.
//!
//!     cargo run --release --example daemon_client

use gpoeo::api::{GpoeoClient, LegacyClient};
use gpoeo::coordinator::daemon::Daemon;
use gpoeo::sim::Spec;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let sock = std::env::temp_dir().join(format!("gpoeo-demo-{}.sock", std::process::id()));
    let spec = Arc::new(Spec::load_default()?);
    let daemon = Daemon::new(spec, 2);
    let sock_srv = sock.clone();
    std::thread::spawn(move || {
        let _ = daemon.serve(&sock_srv);
    });
    while !sock.exists() {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // --- the "training script" side (protocol v1) ---------------------
    let mut c = GpoeoClient::connect(&sock)?; // hello handshake inside
    let id = c.begin("AI_OBJ", Some(300), None, None)?; // Begin API
    println!("daemon: session {id} started");

    for i in 0..8 {
        let st = c.status(&id)?; // drives a slice, reports telemetry
        println!(
            "poll {i}: iter {:>4}/{}  t={:>8.3}s  E={:>10.1}J  clocks=({}, {})",
            st.iterations, st.target_iters, st.time_s, st.energy_j, st.sm_gear, st.mem_gear
        );
    }

    let r = c.end(&id)?; // End API
    println!(
        "daemon: RESULT energy {:.1} J  time {:.3} s  {} iterations",
        r.energy_j, r.time_s, r.iterations
    );

    // --- the same contract over the legacy line protocol --------------
    let mut l = LegacyClient::connect(&sock)?;
    l.begin("AI_OBJ", Some(300))?;
    let r2 = l.end()?;
    l.quit();
    println!(
        "legacy: RESULT energy {:.1} J  time {:.3} s  (bit-identical: {})",
        r2.energy_j,
        r2.time_s,
        (r2.energy_j - r.energy_j).abs() < 0.05 && (r2.time_s - r.time_s).abs() < 0.0005
    );
    Ok(())
}
