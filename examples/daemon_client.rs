//! Micro-intrusive Begin/End API demo: a "training script" talks to the
//! GPOEO daemon over a Unix socket, exactly like the paper's two-call
//! instrumentation (§2.2.2).
//!
//!     cargo run --release --example daemon_client

use gpoeo::coordinator::daemon::Daemon;
use gpoeo::sim::Spec;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let sock = std::env::temp_dir().join(format!("gpoeo-demo-{}.sock", std::process::id()));
    let spec = Arc::new(Spec::load_default()?);
    let daemon = Daemon::new(spec, 2);
    let sock_srv = sock.clone();
    std::thread::spawn(move || {
        let _ = daemon.serve(&sock_srv);
    });
    while !sock.exists() {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    // --- the "training script" side -----------------------------------
    let stream = UnixStream::connect(&sock)?;
    let mut w = stream.try_clone()?;
    let mut r = BufReader::new(stream);
    let mut line = String::new();

    writeln!(w, "BEGIN AI_OBJ 300")?; // Begin API at the training region
    r.read_line(&mut line)?;
    print!("daemon: {line}");

    for i in 0..8 {
        line.clear();
        writeln!(w, "STATUS")?;
        r.read_line(&mut line)?;
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() >= 6 {
            println!(
                "poll {i}: iter {:>4}  t={:>7}s  E={:>9}J  clocks=({}, {})",
                f[1], f[2], f[3], f[4], f[5]
            );
        }
    }

    line.clear();
    writeln!(w, "END")?; // End API
    r.read_line(&mut line)?;
    print!("daemon: {line}");
    writeln!(w, "QUIT")?;
    Ok(())
}
