//! End-to-end driver: the full GPOEO system on the paper's entire 71-app
//! evaluation (AIBench + ThunderSVM/GBM + benchmarking-gnns), producing
//! the headline metric of §1/§7 — recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example end_to_end [--quick]
//!
//! All three layers compose here: the L3 controller drives the simulated
//! device; period detection runs the AOT-compiled Pallas periodogram via
//! PJRT; gear prediction runs the AOT-compiled GBT ensembles via PJRT.

use gpoeo::experiments::online;
use gpoeo::model::Predictor;
use gpoeo::sim::Spec;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let spec = Arc::new(Spec::load_default()?);
    let predictor = Arc::new(Predictor::load_best()?);
    println!("prediction backend: {}", predictor.backend_name());

    let t0 = std::time::Instant::now();
    let medium = online::fig13(&spec, &predictor, quick);
    print!("{}", medium.table.to_text());
    medium.print_summary("paper: 14.7% / 4.6% / 6.8%");

    let gnns = online::fig14(&spec, &predictor, quick);
    print!("{}", gnns.table.to_text());
    gnns.print_summary("paper: 16.6% / 5.2% / 7.8%");

    let n = medium.n + gnns.n;
    let saving = (medium.gpoeo_mean_saving * medium.n as f64
        + gnns.gpoeo_mean_saving * gnns.n as f64)
        / n as f64;
    let slow = (medium.gpoeo_mean_slowdown * medium.n as f64
        + gnns.gpoeo_mean_slowdown * gnns.n as f64)
        / n as f64;
    println!(
        "\n=== HEADLINE: {} apps, mean energy saving {:.1}% (paper 16.2%), mean slowdown {:.1}% (paper 5.1%) ===",
        n,
        saving * 100.0,
        slow * 100.0
    );
    println!("wall time: {:.1}s (simulating {} training runs)", t0.elapsed().as_secs_f64(), 3 * n);
    Ok(())
}
