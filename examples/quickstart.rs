//! Quickstart: attach GPOEO to one training workload and report the
//! energy saving against the NVIDIA default scheduling strategy.
//!
//!     cargo run --release --example quickstart [APP]
//!
//! Requires `make artifacts` (AOT-compiled prediction models); without
//! them the controller transparently falls back to native GBT inference.

use gpoeo::coordinator::{run_sim, savings, DefaultPolicy, Gpoeo, GpoeoCfg};
use gpoeo::model::Predictor;
use gpoeo::sim::{find_app, Spec};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let app_name = std::env::args().nth(1).unwrap_or_else(|| "AI_I2T".into());
    let spec = Arc::new(Spec::load_default()?);
    let app = find_app(&spec, &app_name)?;
    let predictor = Arc::new(Predictor::load_best()?);
    println!("prediction backend: {}", predictor.backend_name());

    let n_iters = 400;
    let base = run_sim(&spec, &app, &mut DefaultPolicy { ts: 0.025 }, n_iters);
    let mut controller = Gpoeo::new(GpoeoCfg::default(), predictor);
    let run = run_sim(&spec, &app, &mut controller, n_iters);
    let s = savings(&base, &run);

    println!(
        "{app_name}: {} iterations  energy {:.0} J -> {:.0} J  time {:.0} s -> {:.0} s",
        n_iters, base.energy_j, run.energy_j, base.time_s, run.time_s
    );
    println!(
        "energy saving {:+.1}%  slowdown {:+.1}%  ED2P saving {:+.1}%",
        s.energy_saving * 100.0,
        s.slowdown * 100.0,
        s.ed2p_saving * 100.0
    );
    println!(
        "final clocks: SM {} MHz, mem {} MHz  (period detected {:.3} s, true {:.3} s)",
        spec.gears.sm_mhz(run.final_sm_gear),
        spec.gears.mem_mhz_of(run.final_mem_gear),
        controller.stats.detected_period_s,
        controller.stats.true_period_s,
    );
    Ok(())
}
