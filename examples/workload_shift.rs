//! Adaptivity demo: the workload changes mid-run (a new training job
//! takes the GPU). The controller's energy-characteristic monitor
//! (Fig. 4 step ⑧) detects the fluctuation, resets to default clocks and
//! re-optimizes for the new workload.
//!
//!     cargo run --release --example workload_shift

use gpoeo::coordinator::{Gpoeo, GpoeoCfg, Policy};
use gpoeo::model::Predictor;
use gpoeo::sim::{find_app, SimGpu, Spec};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let predictor = Arc::new(Predictor::load_best()?);
    let first = find_app(&spec, "SBM_GIN")?; // compute-bound GNN
    let second = find_app(&spec, "CLB_MLP")?; // memory-bound MLP

    let mut gpu = SimGpu::new(spec.clone(), first);
    let mut ctl = Gpoeo::new(GpoeoCfg::default(), predictor);

    // Phase 1: optimize the first workload.
    while gpu.time_s() < 120.0 {
        ctl.tick(&mut gpu);
    }
    println!(
        "t=120s  app=SBM_GIN     SM {} MHz, mem {} MHz (reoptimizations: {})",
        spec.gears.sm_mhz(gpu.sm_gear()),
        spec.gears.mem_mhz_of(gpu.mem_gear()),
        ctl.stats.reoptimizations
    );
    let gear_first = gpu.sm_gear();

    // Phase 2: the workload changes under the controller's feet.
    gpu.swap_app(second);
    println!("t=120s  >>> workload swapped to CLB_MLP <<<");
    while gpu.time_s() < 300.0 {
        ctl.tick(&mut gpu);
    }
    println!(
        "t=300s  app=CLB_MLP     SM {} MHz, mem {} MHz (reoptimizations: {})",
        spec.gears.sm_mhz(gpu.sm_gear()),
        spec.gears.mem_mhz_of(gpu.mem_gear()),
        ctl.stats.reoptimizations
    );
    assert!(
        ctl.stats.reoptimizations >= 1,
        "monitor must trigger a re-optimization after the swap"
    );
    assert_ne!(gear_first, gpu.sm_gear(), "new workload, new operating point");
    println!("monitor correctly re-optimized after the workload shift ✓");
    Ok(())
}
