"""AOT pipeline: train the four GBT models, lower the L2 graphs to HLO
text, and write every artifact the Rust runtime consumes.

Run via ``make artifacts`` (idempotent — re-trains only when inputs are
newer or ``--force`` is given):

  artifacts/
    periodogram_1024.hlo.txt   f32[1024] -> (f32[512],)
    predictor_sm.hlo.txt       f32[16] -> (f32[99] eng, f32[99] time)
    predictor_mem.hlo.txt      f32[16] -> (f32[5]  eng, f32[5]  time)
    gbt_sm_eng.json / gbt_sm_time.json / gbt_mem_eng.json / gbt_mem_time.json
    meta.json                  gear tables, feature names, val errors
    crosscheck.json            Python-vs-Rust ground-truth pinning data

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import gbt, simdata  # noqa: E402
from compile.model import make_predictor, periodogram_1024  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default elides array literals as "{...}",
    # which the 0.5.1 text parser silently reads back as zeros/NaN. Every
    # baked tree tensor rides as a large constant, so this flag is load-
    # bearing (rust/examples/probe_hlo.rs documents the failure mode).
    return comp.as_hlo_text(print_large_constants=True)


def train_models(spec: simdata.Spec, out_dir: str, force: bool) -> dict:
    """Train (or load cached) eng/time models for SM and memory clocks."""
    names = ["sm_eng", "sm_time", "mem_eng", "mem_time"]
    paths = {n: os.path.join(out_dir, f"gbt_{n}.json") for n in names}
    if not force and all(os.path.exists(p) for p in paths.values()):
        models = {}
        for n in names:
            with open(paths[n]) as f:
                models[n] = gbt.GbtModel.from_json(json.load(f))
        print("gbt: loaded cached models")
        return models

    print("gbt: generating training data from the analytic ground truth ...")
    t0 = time.time()
    data = simdata.training_data(spec, noise_replicas=2)
    print(f"gbt: data ready in {time.time() - t0:.1f}s "
          f"(sm rows={len(data['sm_eng'][1])}, mem rows={len(data['mem_eng'][1])})")

    # The paper tunes hyper-parameters by grid search (§4.3.3). The memory
    # models are tiny, so they get the full grid; the SM models use a
    # two-point grid to keep `make artifacts` fast.
    grid_small = [
        dict(n_trees=90, max_depth=5, lr=0.12, min_child=8),
        dict(n_trees=60, max_depth=6, lr=0.15, min_child=8),
    ]
    grid_mem = grid_small + [
        dict(n_trees=120, max_depth=4, lr=0.10, min_child=4),
        dict(n_trees=60, max_depth=4, lr=0.20, min_child=4),
    ]
    models = {}
    for n in names:
        X, y = data[n]
        grid = grid_mem if n.startswith("mem") else grid_small
        t0 = time.time()
        params, val_err = gbt.grid_search(X, y, grid)
        m = gbt.train(X, y, meta={"target": n, "val_mae": val_err, "params": params}, **params)
        m.save(paths[n])
        print(f"gbt: {n}: params={params} val_mae={val_err:.4f} ({time.time() - t0:.1f}s)")
        models[n] = m
    return models


def self_check(spec: simdata.Spec, models: dict) -> dict:
    """Kernel-vs-ref and predictor-vs-model assertions, plus held-out
    accuracy on the *test* suites (the paper's Figs. 9-12 preview)."""
    import jax.numpy as jnp

    from compile.kernels.ref import gbt_eval_ref, periodogram_ref

    # Periodogram kernel vs oracle.
    x = np.sin(np.arange(1024) * 0.37) + 0.2 * np.cos(np.arange(1024) * 1.1)
    a = np.asarray(periodogram_1024(jnp.asarray(x, jnp.float32))[0])
    b = np.asarray(periodogram_ref(jnp.asarray(x, jnp.float32)))
    per_err = float(np.max(np.abs(a - b)) / np.max(b))
    assert per_err < 1e-3, f"periodogram kernel mismatch: {per_err}"

    # Predictor (pallas path) vs plain model on one app.
    app = simdata.materialize_suite(spec, "aibench")[0]
    sm_norms = np.array([simdata.gear_norm_sm(spec, g) for g in spec.sm_gears()])
    pred = make_predictor(models["sm_eng"], models["sm_time"], sm_norms)
    eng, tim = pred(jnp.asarray(app.features, jnp.float32))
    X = np.concatenate([sm_norms[:, None], np.tile(app.features, (len(sm_norms), 1))], axis=1)
    eng_np = models["sm_eng"].predict(X)
    tim_np = models["sm_time"].predict(X)
    assert float(np.max(np.abs(np.asarray(eng) - eng_np))) < 1e-4
    assert float(np.max(np.abs(np.asarray(tim) - tim_np))) < 1e-4

    # Held-out accuracy (mean APE, clean features) over the test suites.
    errs = {"eng": [], "time": []}
    for suite in ("aibench", "gnns", "classical"):
        for app in simdata.materialize_suite(spec, suite):
            Xq = np.concatenate(
                [sm_norms[:, None], np.tile(app.features, (len(sm_norms), 1))], axis=1
            )
            pe = models["sm_eng"].predict(Xq)
            pt = models["sm_time"].predict(Xq)
            te = []
            tt = []
            for i, g in enumerate(spec.sm_gears()):
                e, t = app.ratios_vs_default(spec, g, spec.default_mem_gear)
                te.append(e)
                tt.append(t)
            errs["eng"].append(float(np.mean(np.abs(pe - te) / np.asarray(te))))
            errs["time"].append(float(np.mean(np.abs(pt - tt) / np.asarray(tt))))
    mape_eng = float(np.mean(errs["eng"]))
    mape_time = float(np.mean(errs["time"]))
    print(f"self-check: SM-model held-out MAPE eng={mape_eng:.3%} time={mape_time:.3%}")
    return {
        "periodogram_rel_err": per_err,
        "sm_holdout_mape_eng": mape_eng,
        "sm_holdout_mape_time": mape_time,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="artifact directory")
    ap.add_argument("--force", action="store_true", help="retrain models")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    out_dir = args.out or os.path.join(simdata.repo_root(), "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    spec = simdata.Spec.load()

    models = train_models(spec, out_dir, args.force)
    checks = self_check(spec, models)

    # --- Lower the three modules to HLO text. ---------------------------
    spec_1024 = jax.ShapeDtypeStruct((1024,), jnp.float32)
    lowered = jax.jit(periodogram_1024).lower(spec_1024)
    path = os.path.join(out_dir, "periodogram_1024.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    feat_spec = jax.ShapeDtypeStruct((simdata.NUM_FEATURES,), jnp.float32)
    sm_norms = np.array([simdata.gear_norm_sm(spec, g) for g in spec.sm_gears()])
    mem_norms = np.array([simdata.gear_norm_mem(spec, m) for m in range(len(spec.mem_mhz))])
    for name, (eng, tim, norms) in {
        "predictor_sm": (models["sm_eng"], models["sm_time"], sm_norms),
        "predictor_mem": (models["mem_eng"], models["mem_time"], mem_norms),
    }.items():
        predict = make_predictor(eng, tim, norms)
        lowered = jax.jit(predict).lower(feat_spec)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {path}")

    # --- meta.json + crosscheck.json. ------------------------------------
    meta = {
        "feature_names": spec.feature_names,
        "sm_gears": list(spec.sm_gears()),
        "sm_gear_norms": sm_norms.tolist(),
        "mem_gear_norms": mem_norms.tolist(),
        "mem_mhz": spec.mem_mhz,
        "checks": checks,
        "models": {n: m.meta for n, m in models.items()},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    with open(os.path.join(out_dir, "crosscheck.json"), "w") as f:
        json.dump(simdata.crosscheck_payload(spec), f, indent=2)
    print("wrote meta.json, crosscheck.json")


if __name__ == "__main__":
    main()
