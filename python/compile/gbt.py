"""Gradient-boosted regression trees, from scratch (numpy).

The offline environment has no xgboost; this is the paper's §4.3.3 model
family reimplemented: additive regression trees fit to squared loss with
shrinkage, depth-limited, greedy histogram splits — plus the grid-search
hyper-parameter tuning the paper describes.

The trained ensemble serializes to a dense-array JSON that both the
AOT-compiled Pallas kernel (``kernels/gbt_eval.py``) and the native Rust
inference path (``rust/src/model/gbt.rs``) consume: per tree, arrays
``feat`` (i32, -1 ⇒ leaf), ``thr`` (f32; leaf value when ``feat == -1``),
``left``/``right`` (i32 child node ids; leaves self-loop).
"""

from __future__ import annotations

import json
import math

import numpy as np


class Tree:
    """One regression tree in flattened-array form."""

    __slots__ = ("feat", "thr", "left", "right")

    def __init__(self):
        self.feat: list[int] = []
        self.thr: list[float] = []
        self.left: list[int] = []
        self.right: list[int] = []

    def add_leaf(self, value: float) -> int:
        i = len(self.feat)
        self.feat.append(-1)
        self.thr.append(float(value))
        self.left.append(i)
        self.right.append(i)
        return i

    def add_split(self, feature: int, threshold: float) -> int:
        i = len(self.feat)
        self.feat.append(int(feature))
        self.thr.append(float(threshold))
        self.left.append(-1)  # patched later
        self.right.append(-1)
        return i

    def predict(self, X: np.ndarray) -> np.ndarray:
        feat = np.asarray(self.feat)
        thr = np.asarray(self.thr)
        left = np.asarray(self.left)
        right = np.asarray(self.right)
        idx = np.zeros(len(X), dtype=np.int64)
        # Descend max-depth times; leaves self-loop so extra steps are no-ops.
        for _ in range(32):
            f = feat[idx]
            is_leaf = f < 0
            go_left = X[np.arange(len(X)), np.maximum(f, 0)] <= thr[idx]
            nxt = np.where(go_left, left[idx], right[idx])
            idx = np.where(is_leaf, idx, nxt)
            if np.all(feat[idx] < 0):
                break
        return thr[idx]


class GbtModel:
    """Trained ensemble: prediction = base + lr * Σ tree_k(x)."""

    def __init__(self, base: float, lr: float, trees: list[Tree], meta: dict | None = None):
        self.base = base
        self.lr = lr
        self.trees = trees
        self.meta = meta or {}

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.full(len(X), self.base)
        for t in self.trees:
            out += self.lr * t.predict(X)
        return out

    def to_json(self) -> dict:
        return {
            "base": self.base,
            "lr": self.lr,
            "meta": self.meta,
            "trees": [
                {"feat": t.feat, "thr": t.thr, "left": t.left, "right": t.right}
                for t in self.trees
            ],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @classmethod
    def from_json(cls, d: dict) -> "GbtModel":
        trees = []
        for td in d["trees"]:
            t = Tree()
            t.feat = list(td["feat"])
            t.thr = [float(x) for x in td["thr"]]
            t.left = list(td["left"])
            t.right = list(td["right"])
            trees.append(t)
        return cls(d["base"], d["lr"], trees, d.get("meta"))

    # Dense tensors for the Pallas kernel: [T, N] padded arrays.
    def to_dense(self):
        n = max(len(t.feat) for t in self.trees)
        T = len(self.trees)
        feat = np.full((T, n), -1, dtype=np.int32)
        thr = np.zeros((T, n), dtype=np.float32)
        left = np.zeros((T, n), dtype=np.int32)
        right = np.zeros((T, n), dtype=np.int32)
        for k, t in enumerate(self.trees):
            m = len(t.feat)
            feat[k, :m] = t.feat
            thr[k, :m] = t.thr
            left[k, :m] = t.left
            right[k, :m] = t.right
            # Padding nodes are self-looping zero leaves; point them at
            # themselves to keep gathers in range.
            for j in range(m, n):
                left[k, j] = j
                right[k, j] = j
        return feat, thr, left, right


def _best_split(Xb: np.ndarray, g: np.ndarray, node_rows: np.ndarray, n_bins: int,
                min_child: int, lam: float):
    """Greedy histogram split search on binned features.

    Returns (gain, feature, bin) or None. Squared loss: gain derives from
    sum/count statistics (variance reduction with L2 regularization lam).
    """
    nf = Xb.shape[1]
    gsum = g[node_rows].sum()
    cnt = len(node_rows)
    best = None
    parent_score = gsum * gsum / (cnt + lam)
    for f in range(nf):
        b = Xb[node_rows, f]
        hist_sum = np.bincount(b, weights=g[node_rows], minlength=n_bins)
        hist_cnt = np.bincount(b, minlength=n_bins)
        cs = np.cumsum(hist_sum)[:-1]
        cc = np.cumsum(hist_cnt)[:-1]
        valid = (cc >= min_child) & ((cnt - cc) >= min_child)
        if not valid.any():
            continue
        lscore = np.where(valid, cs * cs / (cc + lam), -np.inf)
        rs = gsum - cs
        rc = cnt - cc
        rscore = np.where(valid, rs * rs / (rc + lam), -np.inf)
        gains = lscore + rscore - parent_score
        k = int(np.argmax(gains))
        if gains[k] > 0 and (best is None or gains[k] > best[0]):
            best = (float(gains[k]), f, k)
    return best


def _fit_tree(Xb, g, bin_edges, n_bins, max_depth, min_child, lam):
    tree = Tree()
    # Recursive growth with explicit stack: (node_rows, depth, parent, side).
    root_rows = np.arange(len(Xb))
    stack = [(root_rows, 0, None, None)]
    while stack:
        rows, depth, parent, side = stack.pop()
        split = None
        if depth < max_depth and len(rows) >= 2 * min_child:
            split = _best_split(Xb, g, rows, n_bins, min_child, lam)
        if split is None:
            val = g[rows].sum() / (len(rows) + lam)
            node = tree.add_leaf(val)
        else:
            _, f, b = split
            thr = bin_edges[f][b]
            node = tree.add_split(f, thr)
            mask = Xb[rows, f] <= b
            stack.append((rows[mask], depth + 1, node, "left"))
            stack.append((rows[~mask], depth + 1, node, "right"))
        if parent is not None:
            if side == "left":
                tree.left[parent] = node
            else:
                tree.right[parent] = node
    return tree


def bin_features(X: np.ndarray, n_bins: int = 128):
    """Quantile-bin each feature; returns (binned int matrix, bin edges)."""
    n, nf = X.shape
    Xb = np.zeros((n, nf), dtype=np.int32)
    edges = []
    for f in range(nf):
        # Exactly n_bins-1 edges per feature (duplicates allowed: a split
        # on a duplicated edge simply yields zero gain), so bin indices are
        # uniformly 0..n_bins-1 across features.
        qs = np.quantile(X[:, f], np.linspace(0, 1, n_bins + 1)[1:-1])
        Xb[:, f] = np.searchsorted(qs, X[:, f], side="left")
        edges.append(qs)
    return Xb, edges


def train(
    X: np.ndarray,
    y: np.ndarray,
    n_trees: int = 80,
    max_depth: int = 5,
    lr: float = 0.15,
    min_child: int = 8,
    lam: float = 1.0,
    n_bins: int = 128,
    meta: dict | None = None,
) -> GbtModel:
    """Fit a squared-loss GBT ensemble."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    Xb, edges = bin_features(X, n_bins)
    base = float(y.mean())
    pred = np.full(len(y), base)
    trees: list[Tree] = []
    for _ in range(n_trees):
        resid = y - pred
        t = _fit_tree(Xb, resid, edges, n_bins, max_depth, min_child, lam)
        trees.append(t)
        pred += lr * t.predict(X)
    return GbtModel(base, lr, trees, meta)


def grid_search(
    X: np.ndarray,
    y: np.ndarray,
    param_grid: list[dict],
    val_frac: float = 0.2,
    seed: int = 1234,
) -> tuple[dict, float]:
    """The paper's hyper-parameter grid search (§4.3.3): hold out a
    validation split, return (best params, val MAE)."""
    rng = np.random.default_rng(seed)
    n = len(y)
    order = rng.permutation(n)
    n_val = max(1, int(n * val_frac))
    val, tr = order[:n_val], order[n_val:]
    best = None
    for params in param_grid:
        m = train(X[tr], y[tr], **params)
        err = float(np.abs(m.predict(X[val]) - y[val]).mean())
        if best is None or err < best[1]:
            best = (params, err)
    return best
