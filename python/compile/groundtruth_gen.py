"""Generator for ``data/groundtruth.json`` — the simulated-testbed spec.

The seed tree referenced ``data/groundtruth.json`` from both sides of the
cross-language contract (``rust/src/sim/spec.rs`` and ``simdata.py``) but
never shipped the file itself, so tier-1 could not run.  This script
regenerates it from first principles: an RTX3080Ti-like gear/power model
(99 SM gears at 210+15·g MHz, 5 memory gears, TDP 350 W), the Table-2
feature maps that drive the per-app analytic DVFS model, and the four
benchmark suites the paper evaluates (AIBench 14 + classical 2 +
benchmarking-gnns 55 = the 71 evaluation apps, plus a ``pytorch_train``
training corpus for the GBT models).

Calibration targets (checked by ``python/tests/test_groundtruth.py``,
which ports the Rust test-suite assertions):

* power strictly monotone in SM clock for every app at every mem gear;
* NVIDIA-default boost capped by TDP for hot apps, gear 114 for cool ones;
* an interior energy-optimal SM gear for typical apps (the paper premise);
* mean oracle saving under the 5% slowdown cap ≈ 16% over the 71 apps;
* aperiodic CSL/TU/classical apps with modest capped headroom.

Run:  python -m compile.groundtruth_gen   (from ``python/``)
"""

from __future__ import annotations

import json
import os

NUM_FEATURES = 16

# Table-2-style counter features, each normalized to (0, 1].
FEATURE_NAMES = [
    "sm_active",
    "sm_occupancy",
    "tensor_active",
    "fp32_active",
    "dram_read",
    "dram_write",
    "l2_hit_rate",
    "l1_hit_rate",
    "mem_busy",
    "issue_stall",
    "warp_eligible",
    "branch_efficiency",
    "shmem_util",
    "tex_util",
    "pcie_util",
    "achieved_ipc",
]


def _w(**kv: float) -> list[float]:
    """Sparse weight vector over FEATURE_NAMES."""
    v = [0.0] * NUM_FEATURES
    for name, val in kv.items():
        v[FEATURE_NAMES.index(name)] = val
    return v


def coeff_maps() -> dict:
    return {
        # Time decomposition: compute / memory / other raw weights
        # (normalized per app after hidden-coefficient jitter).
        "w_compute": {
            "bias": 0.08,
            "weights": _w(sm_active=0.35, tensor_active=0.18, fp32_active=0.20, achieved_ipc=0.15),
            "lo": 0.15,
            "hi": 0.95,
        },
        "w_memory": {
            "bias": 0.04,
            "weights": _w(dram_read=0.22, dram_write=0.15, mem_busy=0.30, issue_stall=0.10),
            "lo": 0.05,
            "hi": 0.90,
        },
        "w_other": {
            "bias": 0.10,
            "weights": _w(pcie_util=0.30),
            "lo": 0.05,
            "hi": 0.40,
        },
        # SM-clock scaling exponent of the compute term.
        "gamma_sm": {
            "bias": 0.30,
            "weights": _w(sm_active=0.25, achieved_ipc=0.30, fp32_active=0.15),
            "lo": 0.55,
            "hi": 1.00,
        },
        # Fraction of the memory term that scales with DRAM clock.
        "mem_sens": {
            "bias": 0.05,
            "weights": _w(mem_busy=0.60, dram_read=0.25, l2_hit_rate=-0.15),
            "lo": 0.05,
            "hi": 0.90,
        },
        # Power-model coefficients.
        "k_sm_power": {
            "bias": 0.40,
            "weights": _w(sm_active=0.45, tensor_active=0.20, fp32_active=0.15),
            "lo": 0.45,
            "hi": 1.50,
        },
        "k_mem_power": {
            "bias": 0.35,
            "weights": _w(dram_read=0.45, mem_busy=0.35, dram_write=0.20),
            "lo": 0.30,
            "hi": 1.40,
        },
        # Busy-fraction ceilings for the utilization channels.
        "sm_activity": {
            "bias": 0.45,
            "weights": _w(sm_active=0.50),
            "lo": 0.30,
            "hi": 0.98,
        },
        "mem_activity": {
            "bias": 0.25,
            "weights": _w(mem_busy=0.50, dram_read=0.20),
            "lo": 0.15,
            "hi": 0.95,
        },
    }


# ---------------------------------------------------------------------------
# Archetypes: features_mean drives the analytic model through the maps
# above; the phase/micro parameters drive the synthetic trace shape.
# Phases are (frac, cw, mw, pw): duration fraction at reference clocks,
# compute weight, memory weight, relative power level.
# ---------------------------------------------------------------------------

def archetypes() -> dict:
    def phases(*rows):
        return [{"frac": f, "cw": c, "mw": m, "pw": p} for (f, c, m, p) in rows]

    common = dict(abnormal_every=0, abnormal_scale=1.0)
    return {
        # Vision CNN training: data-load / forward / backward / optimizer.
        "cnn": dict(
            features_mean=[0.85, 0.60, 0.55, 0.70, 0.45, 0.35, 0.60, 0.75,
                           0.50, 0.35, 0.60, 0.90, 0.45, 0.50, 0.15, 0.60],
            features_std=0.06,
            period_s=[0.45, 1.60],
            trace_noise=0.05,
            micro_amp=0.06,
            micro_period_s=0.09,
            micro_jitter=0.10,
            phases=phases((0.12, 0.10, 0.30, 0.45), (0.30, 0.90, 0.50, 1.10),
                          (0.42, 0.95, 0.60, 1.22), (0.16, 0.35, 0.75, 0.62)),
            aperiodic=False,
            **common,
        ),
        # Attention/transformer training: long periods, hot tensor cores.
        "transformer": dict(
            features_mean=[0.80, 0.65, 0.72, 0.58, 0.50, 0.45, 0.55, 0.70,
                           0.55, 0.40, 0.55, 0.92, 0.35, 0.08, 0.10, 0.66],
            features_std=0.05,
            period_s=[1.20, 3.20],
            trace_noise=0.05,
            micro_amp=0.05,
            micro_period_s=0.12,
            micro_jitter=0.12,
            phases=phases((0.08, 0.15, 0.35, 0.50), (0.36, 0.92, 0.45, 1.12),
                          (0.40, 0.96, 0.55, 1.20), (0.16, 0.40, 0.70, 0.66)),
            aperiodic=False,
            **common,
        ),
        # Recurrent / sequence models: lower occupancy, kernel-launch bound.
        "rnn": dict(
            features_mean=[0.60, 0.45, 0.28, 0.55, 0.40, 0.30, 0.50, 0.60,
                           0.45, 0.50, 0.40, 0.85, 0.30, 0.05, 0.12, 0.45],
            features_std=0.06,
            period_s=[0.60, 2.00],
            trace_noise=0.07,
            micro_amp=0.10,
            micro_period_s=0.07,
            micro_jitter=0.18,
            phases=phases((0.15, 0.20, 0.30, 0.55), (0.45, 0.80, 0.45, 1.08),
                          (0.28, 0.88, 0.55, 1.18), (0.12, 0.30, 0.65, 0.62)),
            aperiodic=False,
            **common,
        ),
        # Generative models: two near-symmetric halves (G/D step) — the
        # 2nd-harmonic ambiguity case of §2.2.3.
        "gan": dict(
            features_mean=[0.80, 0.55, 0.50, 0.65, 0.50, 0.40, 0.55, 0.70,
                           0.55, 0.40, 0.55, 0.88, 0.40, 0.45, 0.18, 0.55],
            features_std=0.06,
            period_s=[0.80, 2.40],
            trace_noise=0.06,
            micro_amp=0.05,
            micro_period_s=0.10,
            micro_jitter=0.12,
            phases=phases((0.46, 0.92, 0.50, 1.14), (0.08, 0.25, 0.40, 0.55),
                          (0.38, 0.90, 0.55, 1.10), (0.08, 0.30, 0.60, 0.58)),
            aperiodic=False,
            **common,
        ),
        # Dense-graph GNNs (SBM node classification, COLLAB link pred.).
        "gnn_dense": dict(
            features_mean=[0.75, 0.50, 0.35, 0.60, 0.55, 0.45, 0.45, 0.60,
                           0.60, 0.45, 0.50, 0.80, 0.35, 0.05, 0.20, 0.50],
            features_std=0.07,
            period_s=[0.50, 1.80],
            trace_noise=0.07,
            micro_amp=0.08,
            micro_period_s=0.08,
            micro_jitter=0.15,
            phases=phases((0.14, 0.15, 0.45, 0.50), (0.34, 0.85, 0.60, 1.12),
                          (0.36, 0.90, 0.65, 1.18), (0.16, 0.35, 0.70, 0.60)),
            aperiodic=False,
            **common,
        ),
        # Sparse/molecular GNNs: memory-bound, stall-heavy.
        "gnn_sparse": dict(
            features_mean=[0.55, 0.40, 0.18, 0.45, 0.62, 0.50, 0.35, 0.50,
                           0.72, 0.60, 0.35, 0.75, 0.25, 0.05, 0.25, 0.35],
            features_std=0.07,
            period_s=[0.40, 1.40],
            trace_noise=0.08,
            micro_amp=0.09,
            micro_period_s=0.06,
            micro_jitter=0.20,
            phases=phases((0.16, 0.10, 0.55, 0.52), (0.36, 0.70, 0.75, 1.10),
                          (0.32, 0.75, 0.80, 1.16), (0.16, 0.30, 0.70, 0.62)),
            aperiodic=False,
            **common,
        ),
        # TSP-style GNNs: jittered micro-oscillations dominate the
        # spectrum (the paper's hardest periodic-detection case).
        "gnn_micro": dict(
            features_mean=[0.65, 0.45, 0.25, 0.50, 0.52, 0.42, 0.40, 0.55,
                           0.62, 0.50, 0.45, 0.78, 0.30, 0.05, 0.30, 0.42],
            features_std=0.06,
            period_s=[0.90, 2.60],
            trace_noise=0.06,
            micro_amp=0.22,
            micro_period_s=0.05,
            micro_jitter=0.25,
            phases=phases((0.12, 0.15, 0.45, 0.52), (0.40, 0.80, 0.65, 1.10),
                          (0.32, 0.85, 0.70, 1.16), (0.16, 0.30, 0.65, 0.60)),
            aperiodic=False,
            **common,
        ),
        # Small MLPs / tabular heads: short shallow periods.
        "mlp": dict(
            features_mean=[0.50, 0.35, 0.12, 0.50, 0.35, 0.30, 0.55, 0.65,
                           0.40, 0.30, 0.45, 0.95, 0.15, 0.02, 0.30, 0.50],
            features_std=0.06,
            period_s=[0.20, 0.70],
            trace_noise=0.06,
            micro_amp=0.07,
            micro_period_s=0.05,
            micro_jitter=0.15,
            phases=phases((0.18, 0.15, 0.35, 0.55), (0.40, 0.75, 0.45, 1.10),
                          (0.26, 0.82, 0.50, 1.16), (0.16, 0.25, 0.55, 0.60)),
            aperiodic=False,
            **common,
        ),
        # Aperiodic workloads (classical ML, CSL/TU graph datasets):
        # random segment walks with no usable period. High-ish compute
        # sensitivity → modest capped headroom (§5.4's hard cases).
        "aperiodic": dict(
            features_mean=[0.70, 0.40, 0.10, 0.75, 0.18, 0.14, 0.65, 0.70,
                           0.18, 0.25, 0.50, 0.90, 0.20, 0.02, 0.20, 0.78],
            features_std=0.07,
            period_s=[0.0, 0.0],
            trace_noise=0.10,
            micro_amp=0.12,
            micro_period_s=0.06,
            micro_jitter=0.30,
            phases=phases((0.25, 0.30, 0.35, 0.60), (0.25, 0.85, 0.45, 1.12),
                          (0.25, 0.90, 0.50, 1.20), (0.25, 0.45, 0.55, 0.75)),
            aperiodic=True,
            **common,
        ),
    }


# ---------------------------------------------------------------------------
# Suites.
# ---------------------------------------------------------------------------

GNN_MODELS = ["GCN", "GAT", "GraphSage", "GatedGCN", "GIN", "MoNet", "MLP", "3WLGNN", "RingGNN"]


def suites() -> dict:
    def app(name, arch, **over):
        d = {"name": name, "archetype": arch}
        d.update(over)
        return d

    # AIBench component benchmarks (paper Table 1: 14 tasks). Eval/
    # checkpoint every N iterations gives the abnormal-iteration spikes.
    aibench = [
        app("AI_IC", "cnn", abnormal_every=50, abnormal_scale=2.6),
        app("AI_IGEN", "gan"),
        app("AI_T2T", "transformer", abnormal_every=40, abnormal_scale=2.2),
        app("AI_I2T", "cnn", abnormal_every=60, abnormal_scale=2.4),
        app("AI_I2IC", "gan", abnormal_every=45, abnormal_scale=2.0),
        app("AI_S2T", "rnn"),
        app("AI_FE", "cnn", abnormal_every=35, abnormal_scale=2.8),
        app("AI_3DFR", "cnn"),
        app("AI_OBJ", "cnn", abnormal_every=55, abnormal_scale=2.2),
        app("AI_VP", "rnn", abnormal_every=30, abnormal_scale=1.8),
        app("AI_ICMP", "transformer"),
        app("AI_3DOR", "gan", abnormal_every=40, abnormal_scale=2.0),
        app("AI_TS", "rnn", abnormal_every=25, abnormal_scale=2.0),
        app("AI_L2R", "mlp"),
    ]

    classical = [app("TSVM", "aperiodic"), app("TGBM", "aperiodic")]

    # benchmarking-gnns: 5 periodic dataset families × 9 models + the
    # aperiodic CSL / TU families (paper: CSL and TU are non-periodical).
    gnns = []
    for ds, arch in [
        ("SBM", "gnn_dense"),
        ("SP", "gnn_sparse"),
        ("TSP", "gnn_micro"),
        ("MLC", "gnn_sparse"),
        ("CLB", "gnn_dense"),
    ]:
        for m in GNN_MODELS:
            gnns.append(app(f"{ds}_{m}", arch))
    for m in ["GCN", "GIN", "MLP", "GatedGCN", "RingGNN"]:
        gnns.append(app(f"CSL_{m}", "aperiodic"))
    for m in ["GCN", "GIN", "MLP", "GAT", "GatedGCN"]:
        gnns.append(app(f"TU_{m}", "aperiodic"))

    # Training corpus for the offline GBT models (disjoint from the
    # evaluation suites; §4.3.2 trains on a separate workload set).
    pt_archs = ["cnn", "transformer", "rnn", "gan", "gnn_dense", "gnn_sparse", "gnn_micro", "mlp"]
    pt_names = [
        "resnet50", "resnet18", "vgg16", "mobilenet_v2", "efficientnet_b0",
        "densenet121", "inception_v3", "bert_base", "bert_large", "gpt2_small",
        "t5_small", "roberta_base", "lstm_lm", "gru_seq2seq", "tacotron",
        "wavernn", "dcgan", "stylegan_lite", "pix2pix", "cyclegan",
        "vae_celeba", "unet_seg", "deeplab_v3", "fasterrcnn_fpn", "ssd300",
        "yolo_v3", "pointnet", "graphsage_ppi", "gcn_cora", "gat_citeseer",
        "gin_molhiv", "mpnn_qm9", "schnet_md17", "dlrm_tiny", "ncf_ml20m",
        "xdeepfm", "mlp_tabular", "wide_deep", "ft_transformer", "tabnet",
        "albert_tiny", "distilbert", "segformer_b0", "swin_tiny",
    ]
    pytorch_train = [
        app(f"PTB_{n}", pt_archs[i % len(pt_archs)]) for i, n in enumerate(pt_names)
    ]

    return {
        "aibench": {"seed_salt": 1101, "apps": aibench},
        "classical": {"seed_salt": 2202, "apps": classical},
        "gnns": {"seed_salt": 3303, "apps": gnns},
        "pytorch_train": {"seed_salt": 4404, "apps": pytorch_train},
    }


def build() -> dict:
    return {
        "global_seed": 20220116,
        "gears": {
            # Paper §3.1: 99 SM gears, f = 210 + 15·gear MHz, 450..1920.
            "sm_gear_min": 16,
            "sm_gear_max": 114,
            "sm_mhz_base": 210.0,
            "sm_mhz_step": 15.0,
            # RTX3080Ti memory P-states (MHz).
            "mem_mhz": [405.0, 810.0, 5001.0, 9251.0, 9501.0],
            "reference_sm_gear": 114,
            "reference_mem_gear": 4,
            "default_sm_gear": 114,
            "default_mem_gear": 4,
        },
        "power": {
            "p_idle_w": 36.0,
            # SM voltage curve: flat at v_min below the knee, superlinear
            # rise to v_max at f_max (boost-region inefficiency).
            "v_min": 0.712,
            "v_max": 1.081,
            "f_vknee_mhz": 960.0,
            "f_max_mhz": 1920.0,
            "c_sm_w_per_ghz_v2": 124.0,
            "c_mem_w_per_ghz": 9.2,
            "c_mem_static_w_per_ghz": 2.3,
            # Per-mem-gear V² proxy: lower P-states run at lower rail
            # voltage, so W/GHz shrinks with the gear index.
            "mem_v2_factor": [0.60, 0.64, 0.72, 0.88, 1.00],
            "thermal_tau_s": 0.65,
            "tdp_w": 350.0,
        },
        "time_model": {
            # DRAM-clock sensitivity exponent of the memory term.
            "mem_exponent": 0.85,
            # Floor on any single time-decomposition fraction.
            "min_frac": 0.05,
        },
        "noise": {
            "hidden_coeff_std": 0.12,
            "counter_meas_std": 0.035,
            "power_meas_std": 0.012,
            "iter_jitter_std": 0.02,
            "energy_meas_std": 0.004,
        },
        "profiling_tax": {
            "counter_time_mult": 1.11,
            "counter_power_mult": 1.08,
            "nvml_time_mult": 1.005,
        },
        "feature_names": FEATURE_NAMES,
        "coeff_maps": coeff_maps(),
        "archetypes": archetypes(),
        "suites": suites(),
    }


def main() -> None:
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    out = os.path.join(root, "data", "groundtruth.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(build(), f, indent=1)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
