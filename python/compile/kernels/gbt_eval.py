"""L1 Pallas kernel: gradient-boosted-tree ensemble inference.

Hardware adaptation (DESIGN.md §2): a GPU tree walk is warp-divergent;
on a vector unit we instead evaluate ALL (tree, gear) lanes in lockstep
as a fixed-depth chain of vectorized gathers/selects over dense node
tensors. Leaves self-loop, so the chain length is just the max depth.

Packing contract: the xla_extension-0.5.1 HLO text round-trip corrupts
every pallas operand after the first (rust/examples/probe_hlo.rs), so the
kernel takes ONE f32 vector: ``[X.ravel() | feat | thr | left | right]``.
Node-id/feature-id tensors ride as f32 (exact below 2^24) and are cast
back to i32 inside the kernel. The gear batch plus 60x127-node tree
tensors total ~130 KiB — a single VMEM-resident block, no grid needed.

``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _gbt_kernel(p_ref, out_ref, *, g: int, f: int, t: int, n: int,
                base: float, lr: float, depth: int):
    packed = p_ref[...]
    xe = g * f
    tn = t * n
    Xv = packed[:xe]                                   # [G*F]
    featv = packed[xe:xe + tn].astype(jnp.int32)       # [T*N]
    thrv = packed[xe + tn:xe + 2 * tn]                 # [T*N]
    leftv = packed[xe + 2 * tn:xe + 3 * tn].astype(jnp.int32)
    rightv = packed[xe + 3 * tn:xe + 4 * tn].astype(jnp.int32)

    # Flat-gather descent: 1-D `jnp.take` survives the text round-trip
    # where multi-dimensional take_along_axis gathers do not.
    rowbase = (jax.lax.iota(jnp.int32, t) * n)[:, None]  # [T, 1]
    gcol = jax.lax.iota(jnp.int32, g)[None, :] * f       # [1, G]

    idx = jnp.zeros((t, g), dtype=jnp.int32)
    for _ in range(depth):
        flat = rowbase + idx                             # [T, G]
        fid = jnp.take(featv, flat)
        th = jnp.take(thrv, flat)
        xv = jnp.take(Xv, gcol + jnp.maximum(fid, 0))
        nxt = jnp.where(xv <= th, jnp.take(leftv, flat), jnp.take(rightv, flat))
        idx = jnp.where(fid < 0, idx, nxt).astype(jnp.int32)
    leaves = jnp.take(thrv, rowbase + idx)               # [T, G]
    out_ref[...] = (base + lr * jnp.sum(leaves, axis=0)).astype(jnp.float32)


def pack_inputs(X, feat, thr, left, right) -> jnp.ndarray:
    """Build the kernel's single packed operand."""
    return jnp.concatenate(
        [
            jnp.asarray(X, jnp.float32).reshape(-1),
            jnp.asarray(feat, jnp.float32).reshape(-1),
            jnp.asarray(thr, jnp.float32).reshape(-1),
            jnp.asarray(left, jnp.float32).reshape(-1),
            jnp.asarray(right, jnp.float32).reshape(-1),
        ]
    )


def gbt_eval(X, feat, thr, left, right, base: float, lr: float,
             depth: int = 12) -> jnp.ndarray:
    """Evaluate the ensemble for every row of X ([G, F] -> [G])."""
    X = jnp.asarray(X, jnp.float32)
    g, f = X.shape
    t, n = np.shape(feat)
    packed = pack_inputs(X, feat, thr, left, right)
    kernel = functools.partial(
        _gbt_kernel, g=g, f=f, t=t, n=n, base=float(base), lr=float(lr), depth=depth
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.float32),
        interpret=True,
    )(packed)
