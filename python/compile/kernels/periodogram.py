"""L1 Pallas kernel: amplitude periodogram as a Fourier-basis matmul.

Hardware adaptation (DESIGN.md §2): instead of a branchy butterfly FFT
(GPU-style), the spectrum is computed as ``amp = |x · [cos | sin]|`` — a
dense (N × Kb) contraction per grid step, which is the MXU-shaped
formulation on TPU. BlockSpec tiles the frequency axis so each grid step
holds one N×Kb basis panel in VMEM (N=1024, Kb=128 ⇒ 512 KiB f32 — well
under the ~16 MiB VMEM budget, leaving room for double buffering).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _periodogram_kernel(x_ref, out_ref, *, n: int, kb: int):
    """One frequency tile: amplitudes of bins [k0, k0+kb)."""
    i = pl.program_id(0)
    x = x_ref[...]  # [n] — the (already detrended) signal
    # Bin indices for this tile; bin 0 of the output is spectral bin 1 (DC
    # is excluded by construction).
    ks = i * kb + jax.lax.iota(jnp.float32, kb) + 1.0
    t = jax.lax.iota(jnp.float32, n)
    ang = (2.0 * jnp.pi / n) * t[:, None] * ks[None, :]  # [n, kb]
    re = x @ jnp.cos(ang)  # [kb] — MXU-shaped contraction
    im = -(x @ jnp.sin(ang))
    out_ref[...] = jnp.sqrt(re * re + im * im)


def periodogram(x: jnp.ndarray, kb: int = 128) -> jnp.ndarray:
    """Amplitude spectrum: bins 1..N/2 inclusive (N/2 values, DC excluded).

    Input must have power-of-two length N >= 2*kb. The Rust side uses bins
    0..N/2-2 of this array (its native periodogram stops before Nyquist).
    """
    n = x.shape[0]
    assert n & (n - 1) == 0, "length must be a power of two"
    half = n // 2
    assert half % kb == 0, "n/2 must be divisible by the block size"
    xc = (x - jnp.mean(x)).astype(jnp.float32)
    kernel = functools.partial(_periodogram_kernel, n=n, kb=kb)
    return pl.pallas_call(
        kernel,
        grid=(half // kb,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=pl.BlockSpec((kb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((half,), jnp.float32),
        interpret=True,
    )(xc)
