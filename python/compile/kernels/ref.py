"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

``test_kernels.py`` asserts kernel == ref across shapes/dtypes
(hypothesis-driven), and ``aot.py``'s self-check runs both once more at
artifact-build time.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def periodogram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Amplitude spectrum of a real signal, bins 1..N/2 (DC excluded).

    Matches the Rust native ``signal::fft::periodogram`` on a length-N
    power-of-two input: mean-detrend, full DFT, amplitudes of bins
    1..N/2 inclusive (i.e. N/2 values).
    """
    n = x.shape[0]
    xc = x - jnp.mean(x)
    k = jnp.arange(1, n // 2 + 1)
    t = jnp.arange(n)
    ang = 2.0 * jnp.pi * jnp.outer(t, k) / n
    re = xc @ jnp.cos(ang)
    im = -(xc @ jnp.sin(ang))
    return jnp.sqrt(re * re + im * im)


def gbt_eval_ref(X, feat, thr, left, right, base, lr, depth: int = 24):
    """Reference tree-ensemble evaluation.

    X: [G, F] float; feat/thr/left/right: [T, N] dense trees
    (feat < 0 => leaf with value thr; leaves/padding self-loop).
    Returns [G] predictions = base + lr * sum_t leaf_value_t.
    """
    X = jnp.asarray(X)
    feat = jnp.asarray(feat)
    thr = jnp.asarray(thr)
    left = jnp.asarray(left)
    right = jnp.asarray(right)
    G = X.shape[0]
    T = feat.shape[0]
    idx = jnp.zeros((T, G), dtype=jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=1)  # [T, G]
        th = jnp.take_along_axis(thr, idx, axis=1)
        xv = X[jnp.arange(G)[None, :], jnp.maximum(f, 0)]  # [T, G]
        go_left = xv <= th
        nxt = jnp.where(
            go_left,
            jnp.take_along_axis(left, idx, axis=1),
            jnp.take_along_axis(right, idx, axis=1),
        )
        idx = jnp.where(f < 0, idx, nxt).astype(jnp.int32)
    leaves = jnp.take_along_axis(thr, idx, axis=1)  # [T, G]
    return base + lr * jnp.sum(leaves, axis=0)


def gbt_eval_numpy(X, model) -> np.ndarray:
    """Numpy-side oracle straight from a ``gbt.GbtModel`` (no dense form)."""
    return model.predict(np.asarray(X, dtype=np.float64))
