"""L2: the jax compute graph that composes the L1 Pallas kernels.

Two lowered modules (see aot.py):

  * ``periodogram_1024``: f32[1024] trace -> f32[512] amplitude spectrum
    (the spectral front-end of period detection, Algorithm 1 line 1).
  * ``predictor_sm`` / ``predictor_mem``: f32[16] counter features ->
    (f32[G] energy ratios, f32[G] time ratios) for every clock gear —
    the four models of Equation (1)/(2), two per module. Tree tensors are
    closed over as constants so they bake into the HLO.

Python never runs at serving time: the Rust runtime executes the lowered
artifacts via PJRT.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernels.gbt_eval import gbt_eval
from .kernels.periodogram import periodogram


def periodogram_1024(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Spectral front-end; tuple-wrapped for the AOT text bridge."""
    return (periodogram(x, kb=128),)


def make_predictor(eng_model, time_model, gear_norms: np.ndarray):
    """Build ``features[16] -> (eng_ratio[G], time_ratio[G])``.

    ``eng_model``/``time_model`` are trained ``gbt.GbtModel``s whose dense
    tensors are closed over (=> HLO constants). ``gear_norms`` is the
    normalized-gear input column for every gear in the sweep.
    """
    ge = [jnp.asarray(a) for a in eng_model.to_dense()]
    gt = [jnp.asarray(a) for a in time_model.to_dense()]
    gears = jnp.asarray(gear_norms, jnp.float32)[:, None]  # [G, 1]
    g = gears.shape[0]

    def predict(features: jnp.ndarray):
        X = jnp.concatenate(
            [gears, jnp.broadcast_to(features[None, :].astype(jnp.float32), (g, features.shape[0]))],
            axis=1,
        )  # [G, 17]
        eng = gbt_eval(X, *ge, base=eng_model.base, lr=eng_model.lr)
        time = gbt_eval(X, *gt, base=time_model.base, lr=time_model.lr)
        return eng, time

    return predict
