"""Bit-exact Python twin of ``rust/src/util/rng.rs`` (PCG64 XSL-RR 128/64).

Every stochastic quantity in the synthetic-workload model flows through
this generator so the Rust simulator and the Python training-data
generator materialize *identical* applications. The cross-language pinning
test is ``rust/tests/crosscheck.rs`` against ``artifacts/crosscheck.json``
(written by ``aot.py``).

Draw-order is part of the contract; see simdata.AppParams.
"""

from __future__ import annotations

import math

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MULT = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FBC_CFD


def splitmix64(x: int) -> int:
    """SplitMix64 — mirrors ``rng.rs::splitmix64``."""
    x = (x + 0x9E3779B97F4A7C15) & MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit — mirrors ``rng.rs::fnv1a64``."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & MASK64
    return h


class Pcg64:
    """PCG64 XSL-RR 128/64 with the same seeding scheme as the Rust twin."""

    __slots__ = ("state", "inc")

    def __init__(self, seed: int, stream: int):
        init_state = (splitmix64(seed) << 64) | splitmix64(seed ^ 0x9E3779B97F4A7C15)
        init_inc = ((splitmix64(stream) << 64) | (stream & MASK64)) | 1
        self.state = 0
        self.inc = init_inc & MASK128
        self._step()
        self.state = (self.state + init_state) & MASK128
        self._step()

    def _step(self) -> None:
        self.state = (self.state * PCG_MULT + self.inc) & MASK128

    def next_u64(self) -> int:
        self._step()
        xored = ((self.state >> 64) ^ self.state) & MASK64
        rot = (self.state >> 122) & 63
        return ((xored >> rot) | (xored << ((-rot) & 63))) & MASK64

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / 9007199254740992.0)

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()

    def below(self, n: int) -> int:
        return (self.next_u64() * n) >> 64

    def gauss(self) -> float:
        """Box-Muller drawing exactly two uniforms (no cached spare)."""
        u1 = max(self.next_f64(), 1e-300)
        u2 = self.next_f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def normal(self, mean: float, std: float) -> float:
        return mean + std * self.gauss()


def app_rng(global_seed: int, suite_salt: int, app_name: str) -> Pcg64:
    """Mirrors ``rng.rs::app_rng``."""
    h = fnv1a64(app_name.encode("utf-8"))
    seed = (global_seed ^ ((h * 0x9E3779B97F4A7C15) & MASK64)) & MASK64
    stream = (suite_salt + h) & MASK64
    return Pcg64(seed, stream)
