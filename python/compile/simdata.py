"""Python twin of the Rust simulator's analytic ground-truth model.

Parses ``data/groundtruth.json`` (same single source of truth as
``rust/src/sim/spec.rs``), materializes synthetic applications with the
exact RNG draw order of ``rust/src/sim/app.rs``, and evaluates the
analytic DVFS model (time / power / energy per clock configuration).

Used at build time only:
  * to generate the four GBT training sets (§4.3 of the paper), and
  * to emit ``artifacts/crosscheck.json``, which pins this implementation
    to the Rust one.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field

from . import prng

NUM_FEATURES = 16


def repo_root() -> str:
    return os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def groundtruth_path() -> str:
    env = os.environ.get("GPOEO_GROUNDTRUTH")
    if env:
        return env
    return os.path.join(repo_root(), "data", "groundtruth.json")


class Spec:
    """Typed view of groundtruth.json (mirror of spec.rs)."""

    def __init__(self, raw: dict):
        self.raw = raw
        self.global_seed = raw["global_seed"]
        g = raw["gears"]
        self.sm_gear_min = g["sm_gear_min"]
        self.sm_gear_max = g["sm_gear_max"]
        self.sm_mhz_base = g["sm_mhz_base"]
        self.sm_mhz_step = g["sm_mhz_step"]
        self.mem_mhz = g["mem_mhz"]
        self.reference_sm_gear = g["reference_sm_gear"]
        self.reference_mem_gear = g["reference_mem_gear"]
        self.default_sm_gear = g["default_sm_gear"]
        self.default_mem_gear = g["default_mem_gear"]
        self.power = raw["power"]
        self.time_model = raw["time_model"]
        self.noise = raw["noise"]
        self.coeff_maps = raw["coeff_maps"]
        self.archetypes = raw["archetypes"]
        self.suites = raw["suites"]
        self.feature_names = raw["feature_names"]

    @classmethod
    def load(cls, path: str | None = None) -> "Spec":
        with open(path or groundtruth_path()) as f:
            return cls(json.load(f))

    # --- gear helpers -----------------------------------------------------
    def sm_mhz(self, gear: int) -> float:
        return self.sm_mhz_base + self.sm_mhz_step * gear

    def num_sm_gears(self) -> int:
        return self.sm_gear_max - self.sm_gear_min + 1

    def sm_gears(self):
        return range(self.sm_gear_min, self.sm_gear_max + 1)

    def voltage(self, f_mhz: float) -> float:
        p = self.power
        frac = max(0.0, (f_mhz - p["f_vknee_mhz"]) / (p["f_max_mhz"] - p["f_vknee_mhz"]))
        return p["v_min"] + (p["v_max"] - p["v_min"]) * frac ** 1.4

    def coeff(self, name: str, features: list[float]) -> float:
        cm = self.coeff_maps[name]
        v = cm["bias"] + sum(f * w for f, w in zip(features, cm["weights"]))
        return min(max(v, cm["lo"]), cm["hi"])


@dataclass
class OpPoint:
    t_iter_s: float
    power_w: float
    energy_j: float
    util_sm: float
    util_mem: float


@dataclass
class AppParams:
    """Mirror of ``rust/src/sim/app.rs::AppParams`` (trace fields omitted —
    Python never generates traces, only the analytic model)."""

    name: str
    suite: str
    archetype: str
    features: list[float]
    t_base: float
    wc: float
    wm: float
    wo: float
    gamma: float
    s_m: float
    k_sm: float
    k_mem: float
    a_sm: float
    a_mem: float
    aperiodic: bool
    trace_seed: int = 0
    _default_cache: tuple | None = field(default=None, repr=False)

    @classmethod
    def materialize(cls, spec: Spec, suite: str, entry: dict) -> "AppParams":
        """Draw-for-draw mirror of AppParams::materialize (rust)."""
        name = entry["name"]
        arch = spec.archetypes[entry["archetype"]]
        salt = spec.suites[suite]["seed_salt"]
        rng = prng.app_rng(spec.global_seed, salt, name)

        features = []
        for i in range(NUM_FEATURES):
            v = arch["features_mean"][i] + arch["features_std"] * rng.gauss()
            features.append(min(max(v, 0.01), 1.0))
        if arch["period_s"][1] > 0.0:
            t_base = rng.uniform(arch["period_s"][0], arch["period_s"][1])
        else:
            t_base = rng.uniform(0.4, 1.2)
        h = spec.noise["hidden_coeff_std"]
        h_wc = math.exp(rng.normal(0.0, h))
        h_wm = math.exp(rng.normal(0.0, h))
        h_ksm = math.exp(rng.normal(0.0, h))
        h_kmem = math.exp(rng.normal(0.0, h))
        h_gamma = rng.normal(0.0, h / 2.0)

        # Phase-fraction jitter draws (trace-only in Rust, but they consume
        # stream positions, so they must happen here too).
        for _ in arch["phases"]:
            rng.normal(0.0, 0.08)
        rng.uniform(0.8, 1.25)  # micro_period jitter draw
        trace_seed = rng.next_u64()

        wc_raw = spec.coeff("w_compute", features) * h_wc
        wm_raw = spec.coeff("w_memory", features) * h_wm
        wo_raw = spec.coeff("w_other", features)
        s = wc_raw + wm_raw + wo_raw
        gm = spec.coeff_maps["gamma_sm"]
        gamma = min(max(spec.coeff("gamma_sm", features) + h_gamma, gm["lo"]), gm["hi"])

        return cls(
            name=name,
            suite=suite,
            archetype=entry["archetype"],
            features=features,
            t_base=t_base,
            wc=wc_raw / s,
            wm=wm_raw / s,
            wo=wo_raw / s,
            gamma=gamma,
            s_m=spec.coeff("mem_sens", features),
            k_sm=spec.coeff("k_sm_power", features) * h_ksm,
            k_mem=spec.coeff("k_mem_power", features) * h_kmem,
            a_sm=spec.coeff("sm_activity", features),
            a_mem=spec.coeff("mem_activity", features),
            aperiodic=entry.get("aperiodic", arch.get("aperiodic", False)),
            trace_seed=trace_seed,
        )

    # --- analytic model (mirror of app.rs) --------------------------------
    def op_point(self, spec: Spec, sm_gear: int, mem_gear: int) -> OpPoint:
        fs = spec.sm_mhz(sm_gear)
        fm = spec.mem_mhz[mem_gear]
        f_ref_s = spec.sm_mhz(spec.reference_sm_gear)
        f_ref_m = spec.mem_mhz[spec.reference_mem_gear]
        r_s = (f_ref_s / fs) ** self.gamma
        r_m = (f_ref_m / fm) ** spec.time_model["mem_exponent"]
        rme = (1.0 - self.s_m) + self.s_m * r_m
        r = self.wo + self.wc * r_s + self.wm * rme
        t_iter = self.t_base * r

        util_sm = self.a_sm * (self.wc * r_s + 0.5 * self.wo) / (r * (self.wc + 0.5 * self.wo))
        util_sm = min(max(util_sm, 0.02), 1.0)
        util_mem = self.a_mem * (self.wm * rme + 0.4 * self.wo) / (r * (self.wm + 0.4 * self.wo))
        util_mem = min(max(util_mem, 0.02), 1.0)

        p = spec.power
        v = spec.voltage(fs)
        p_sm = p["c_sm_w_per_ghz_v2"] * self.k_sm * util_sm * v * v * (fs / 1000.0)
        p_mem = (
            (p["c_mem_static_w_per_ghz"] + p["c_mem_w_per_ghz"] * self.k_mem * util_mem)
            * p["mem_v2_factor"][mem_gear]
            * (fm / 1000.0)
        )
        power = p["p_idle_w"] + p_sm + p_mem
        return OpPoint(t_iter, power, power * t_iter, util_sm, util_mem)

    def default_sm_gear(self, spec: Spec) -> int:
        mem = spec.default_mem_gear
        for g in range(spec.default_sm_gear, spec.sm_gear_min - 1, -1):
            if self.op_point(spec, g, mem).power_w <= spec.power["tdp_w"]:
                return g
        return spec.sm_gear_min

    def default_op(self, spec: Spec) -> tuple[int, int, OpPoint]:
        if self._default_cache is None:
            sm = self.default_sm_gear(spec)
            mem = spec.default_mem_gear
            self._default_cache = (sm, mem, self.op_point(spec, sm, mem))
        return self._default_cache

    def ratios_vs_default(self, spec: Spec, sm_gear: int, mem_gear: int):
        _, _, dflt = self.default_op(spec)
        pt = self.op_point(spec, sm_gear, mem_gear)
        return pt.energy_j / dflt.energy_j, pt.t_iter_s / dflt.t_iter_s


def materialize_suite(spec: Spec, suite: str) -> list[AppParams]:
    return [AppParams.materialize(spec, suite, e) for e in spec.suites[suite]["apps"]]


def optimal_sm_gear(app: AppParams, spec: Spec, max_time_ratio: float = 1.05) -> int:
    """Best SM gear under the paper's objective with memory at default —
    used to collect the memory-model training data (§4.3.2)."""
    best_g, best_e = spec.default_sm_gear, float("inf")
    for g in spec.sm_gears():
        e, t = app.ratios_vs_default(spec, g, spec.default_mem_gear)
        score = e if t <= max_time_ratio else 10.0 + (t - max_time_ratio)
        if score < best_e:
            best_e, best_g = score, g
    return best_g


def gear_norm_sm(spec: Spec, gear: int) -> float:
    """Normalized SM-gear model input (shared with meta.json / Rust)."""
    return spec.sm_mhz(gear) / spec.power["f_max_mhz"]


def gear_norm_mem(spec: Spec, gear: int) -> float:
    return spec.mem_mhz[gear] / max(spec.mem_mhz)


def training_data(spec: Spec, noise_replicas: int = 3, seed: int = 777):
    """Build the paper's four training sets from the training suite.

    Returns dict with keys sm_eng, sm_time, mem_eng, mem_time; each is
    (X, y) with X rows = [gear_norm, f0..f15].

    Per §4.3.2 the paper measures each point ten times and averages, so
    targets are clean; inputs get `noise_replicas` jittered copies of the
    feature vector (mimicking one-period online counter measurement) so
    the models are robust to what they will see online.
    """
    import numpy as np

    apps = materialize_suite(spec, "pytorch_train")
    meas_std = spec.noise["counter_meas_std"]
    rng = prng.Pcg64(seed, 42)

    def feature_variants(app):
        yield app.features
        for _ in range(noise_replicas):
            yield [
                min(max(f * math.exp(rng.normal(0.0, meas_std)), 0.005), 1.05)
                for f in app.features
            ]

    sm_X, sm_eng, sm_time = [], [], []
    mem_X, mem_eng, mem_time = [], [], []
    for app in apps:
        sm_rows = []
        for g in spec.sm_gears():
            e, t = app.ratios_vs_default(spec, g, spec.default_mem_gear)
            sm_rows.append((gear_norm_sm(spec, g), e, t))
        g_opt = optimal_sm_gear(app, spec)
        mem_rows = []
        for m in range(len(spec.mem_mhz)):
            e, t = app.ratios_vs_default(spec, g_opt, m)
            mem_rows.append((gear_norm_mem(spec, m), e, t))
        for feats in feature_variants(app):
            for gn, e, t in sm_rows:
                sm_X.append([gn] + list(feats))
                sm_eng.append(e)
                sm_time.append(t)
            for gn, e, t in mem_rows:
                mem_X.append([gn] + list(feats))
                mem_eng.append(e)
                mem_time.append(t)

    sm_X = np.asarray(sm_X, dtype=np.float64)
    mem_X = np.asarray(mem_X, dtype=np.float64)
    return {
        "sm_eng": (sm_X, np.asarray(sm_eng)),
        "sm_time": (sm_X, np.asarray(sm_time)),
        "mem_eng": (mem_X, np.asarray(mem_eng)),
        "mem_time": (mem_X, np.asarray(mem_time)),
    }


def crosscheck_payload(spec: Spec) -> dict:
    """Reference values for rust/tests/crosscheck.rs."""
    picks = [
        ("aibench", "AI_I2T"),
        ("aibench", "AI_IGEN"),
        ("gnns", "TSP_GatedGCN"),
        ("gnns", "CLB_MLP"),
        ("gnns", "CSL_GCN"),
        ("classical", "TSVM"),
        ("pytorch_train", "PTB_resnet50"),
        ("pytorch_train", "PTB_mlp_tabular"),
    ]
    out = []
    for suite, name in picks:
        entry = next(e for e in spec.suites[suite]["apps"] if e["name"] == name)
        app = AppParams.materialize(spec, suite, entry)
        probes = []
        for sm, mem in [
            (spec.default_sm_gear, spec.default_mem_gear),
            (spec.reference_sm_gear, spec.reference_mem_gear),
            (60, 2),
            (spec.sm_gear_min, 0),
        ]:
            op = app.op_point(spec, sm, mem)
            e, t = app.ratios_vs_default(spec, sm, mem)
            probes.append(
                {
                    "sm_gear": sm,
                    "mem_gear": mem,
                    "t_iter_s": op.t_iter_s,
                    "power_w": op.power_w,
                    "energy_ratio": e,
                    "time_ratio": t,
                }
            )
        out.append(
            {
                "suite": suite,
                "name": name,
                "features": app.features,
                "t_base": app.t_base,
                "wc": app.wc,
                "wm": app.wm,
                "wo": app.wo,
                "gamma": app.gamma,
                "s_m": app.s_m,
                "k_sm": app.k_sm,
                "k_mem": app.k_mem,
                "trace_seed": str(app.trace_seed),
                "default_sm_gear": app.default_sm_gear(spec),
                "probes": probes,
            }
        )
    return {"apps": out}
