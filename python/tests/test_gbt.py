"""GBT trainer unit tests: fit quality, serialization, grid search."""

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest

from compile import gbt


def test_fits_linear_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (2000, 4))
    y = 3 * X[:, 0] - 2 * X[:, 1]
    m = gbt.train(X, y, n_trees=80, max_depth=4, lr=0.2)
    mae = np.abs(m.predict(X) - y).mean()
    assert mae < 0.03, mae


def test_generalizes_smooth_function():
    rng = np.random.default_rng(1)
    X = rng.uniform(0, 1, (4000, 3))
    y = np.sin(5 * X[:, 0]) + X[:, 1] * X[:, 2]
    m = gbt.train(X, y, n_trees=100, max_depth=5, lr=0.15)
    Xt = rng.uniform(0.05, 0.95, (500, 3))
    yt = np.sin(5 * Xt[:, 0]) + Xt[:, 1] * Xt[:, 2]
    mae = np.abs(m.predict(Xt) - yt).mean()
    assert mae < 0.05, mae


def test_serialization_roundtrip():
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 1, (500, 5))
    y = X[:, 0] + X[:, 4]
    m = gbt.train(X, y, n_trees=20, max_depth=3)
    m2 = gbt.GbtModel.from_json(m.to_json())
    np.testing.assert_allclose(m.predict(X), m2.predict(X))


def test_dense_form_self_loops():
    rng = np.random.default_rng(3)
    X = rng.uniform(0, 1, (300, 3))
    m = gbt.train(X, X[:, 0], n_trees=8, max_depth=4)
    feat, thr, left, right = m.to_dense()
    T, N = feat.shape
    for t in range(T):
        for j in range(N):
            if feat[t, j] < 0:
                assert left[t, j] == j and right[t, j] == j
            else:
                assert 0 <= left[t, j] < N and 0 <= right[t, j] < N


def test_min_child_respected():
    rng = np.random.default_rng(4)
    X = rng.uniform(0, 1, (100, 2))
    m = gbt.train(X, X[:, 0], n_trees=4, max_depth=8, min_child=30)
    # With min_child=30 over 100 rows, trees can have at most ~3 leaves.
    for t in m.trees:
        leaves = sum(1 for f in t.feat if f < 0)
        assert leaves <= 4


def test_grid_search_returns_best():
    rng = np.random.default_rng(5)
    X = rng.uniform(0, 1, (800, 3))
    y = 2 * X[:, 0]
    grid = [dict(n_trees=2, max_depth=1, lr=0.05), dict(n_trees=60, max_depth=4, lr=0.2)]
    params, err = gbt.grid_search(X, y, grid)
    assert params["n_trees"] == 60
    assert err < 0.05
