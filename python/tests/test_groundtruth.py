"""Validation of ``data/groundtruth.json`` against the Rust test suite.

The Rust simulator's unit/property tests encode the physics contract of
the ground-truth spec (monotonicity, TDP capping, interior energy optima,
trace energy conservation...). This module ports those assertions to
Python — through the bit-exact ``prng``/``simdata`` twins — so the
generated spec can be validated without a Rust toolchain, and so spec
regressions are caught on the Python side too.

Each test names the Rust test it mirrors.
"""

from __future__ import annotations

import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import prng, simdata  # noqa: E402

NUM_FEATURES = simdata.NUM_FEATURES


def spec() -> simdata.Spec:
    return simdata.Spec.load()


def materialize_all(sp: simdata.Spec):
    out = []
    for suite in sp.suites:
        for app in simdata.materialize_suite(sp, suite):
            out.append(app)
    return out


# ---------------------------------------------------------------------------
# Full materialization twin (simdata omits trace fields; the trace tests
# below need the jittered phase fractions and micro parameters, drawn in
# the exact rust order).
# ---------------------------------------------------------------------------

class FullApp:
    def __init__(self, sp: simdata.Spec, suite: str, entry: dict):
        arch = sp.archetypes[entry["archetype"]]
        rng = prng.app_rng(sp.global_seed, sp.suites[suite]["seed_salt"], entry["name"])

        feats = []
        for i in range(NUM_FEATURES):
            v = arch["features_mean"][i] + arch["features_std"] * rng.gauss()
            feats.append(min(max(v, 0.01), 1.0))
        if arch["period_s"][1] > 0.0:
            t_base = rng.uniform(arch["period_s"][0], arch["period_s"][1])
        else:
            t_base = rng.uniform(0.4, 1.2)
        h = sp.noise["hidden_coeff_std"]
        h_wc = math.exp(rng.normal(0.0, h))
        h_wm = math.exp(rng.normal(0.0, h))
        h_ksm = math.exp(rng.normal(0.0, h))
        h_kmem = math.exp(rng.normal(0.0, h))
        h_gamma = rng.normal(0.0, h / 2.0)

        phases = [dict(p) for p in arch["phases"]]
        for p in phases:
            p["frac"] *= math.exp(rng.normal(0.0, 0.08))
        fsum = sum(p["frac"] for p in phases)
        for p in phases:
            p["frac"] /= fsum
        self.micro_period_s = arch["micro_period_s"] * rng.uniform(0.8, 1.25)
        self.trace_seed = rng.next_u64()

        wc_raw = sp.coeff("w_compute", feats) * h_wc
        wm_raw = sp.coeff("w_memory", feats) * h_wm
        wo_raw = sp.coeff("w_other", feats)
        s = wc_raw + wm_raw + wo_raw
        gm = sp.coeff_maps["gamma_sm"]
        self.name = entry["name"]
        self.features = feats
        self.t_base = t_base
        self.wc, self.wm, self.wo = wc_raw / s, wm_raw / s, wo_raw / s
        self.gamma = min(max(sp.coeff("gamma_sm", feats) + h_gamma, gm["lo"]), gm["hi"])
        self.s_m = sp.coeff("mem_sens", feats)
        self.k_sm = sp.coeff("k_sm_power", feats) * h_ksm
        self.k_mem = sp.coeff("k_mem_power", feats) * h_kmem
        self.a_sm = sp.coeff("sm_activity", feats)
        self.a_mem = sp.coeff("mem_activity", feats)
        self.phases = phases
        self.trace_noise = arch["trace_noise"]
        self.micro_amp = arch["micro_amp"]
        self.micro_jitter = arch["micro_jitter"]
        self.abnormal_every = entry.get("abnormal_every", arch["abnormal_every"])
        self.abnormal_scale = entry.get("abnormal_scale", arch["abnormal_scale"])
        self.aperiodic = entry.get("aperiodic", arch.get("aperiodic", False))

        self._sim = simdata.AppParams.materialize(sp, suite, entry)

    def time_factor(self, sp, sm, mem):
        fs = sp.sm_mhz(sm)
        fm = sp.mem_mhz[mem]
        r_s = (sp.sm_mhz(sp.reference_sm_gear) / fs) ** self.gamma
        r_m = (sp.mem_mhz[sp.reference_mem_gear] / fm) ** sp.time_model["mem_exponent"]
        rme = (1.0 - self.s_m) + self.s_m * r_m
        return self.wo + self.wc * r_s + self.wm * rme

    def op_point(self, sp, sm, mem):
        return self._sim.op_point(sp, sm, mem)


def full_app(sp: simdata.Spec, suite: str, name: str) -> FullApp:
    entry = next(e for e in sp.suites[suite]["apps"] if e["name"] == name)
    return FullApp(sp, suite, entry)


def find_full(sp: simdata.Spec, name: str) -> FullApp:
    for suite in sp.suites:
        for e in sp.suites[suite]["apps"]:
            if e["name"] == name:
                return FullApp(sp, suite, e)
    raise KeyError(name)


# ---------------------------------------------------------------------------
# TraceState twin (rust/src/sim/trace.rs), used by the conservation tests.
# ---------------------------------------------------------------------------

class TraceState:
    def __init__(self, app: FullApp):
        rng = prng.Pcg64(app.trace_seed, 0x7ACE)
        self.rng = rng
        if app.aperiodic:
            self.seg_phase = rng.below(len(app.phases))
            self.seg_remaining = -app.t_base * math.log(1.0 - rng.next_f64())
        else:
            self.seg_phase = 0
            self.seg_remaining = 0.0
        self.progress = 0.0
        self.iterations = 0
        self.micro_phase = 0.0
        self.power_ema = 0.0
        self.ema_init = False
        self.iter_mult = self._draw_iter_mult(app)

    def _draw_iter_mult(self, app):
        jitter = math.exp(self.rng.normal(0.0, 0.02))
        abnormal = app.abnormal_every > 0 and (self.iterations + 1) % app.abnormal_every == 0
        return jitter * app.abnormal_scale if abnormal else jitter

    def _phase_durations(self, app, sp, sm, mem):
        f_ref_s = sp.sm_mhz(sp.reference_sm_gear)
        f_ref_m = sp.mem_mhz[sp.reference_mem_gear]
        r_s = (f_ref_s / sp.sm_mhz(sm)) ** app.gamma
        r_m = (f_ref_m / sp.mem_mhz[mem]) ** sp.time_model["mem_exponent"]
        rme = (1.0 - app.s_m) + app.s_m * r_m
        durs = []
        for p in app.phases:
            rest = max(1.0 - p["cw"] - p["mw"], 0.0)
            durs.append(p["frac"] * (p["cw"] * r_s + p["mw"] * rme + rest))
        s = sum(durs)
        return [d / s for d in durs]

    def advance(self, app, sp, sm, mem, dt, speed=1.0):
        if app.micro_period_s > 0.0:
            g = self.rng.gauss()
            rate = 2.0 * math.pi / app.micro_period_s * max(1.0 + app.micro_jitter * g, 0.05)
            self.micro_phase += rate * dt

        if app.aperiodic:
            remaining = dt * speed / app.time_factor(sp, sm, mem)
            while remaining > 0.0:
                if self.seg_remaining <= remaining:
                    remaining -= self.seg_remaining
                    self.seg_phase = self.rng.below(len(app.phases))
                    self.seg_remaining = -app.t_base * math.log(1.0 - self.rng.next_f64())
                    self.iterations += 1
                else:
                    self.seg_remaining -= remaining
                    remaining = 0.0
            return

        t_iter = app.t_base * app.time_factor(sp, sm, mem)
        remaining = dt * speed
        while remaining > 0.0:
            cur_dur = t_iter * self.iter_mult
            left = (1.0 - self.progress) * cur_dur
            if left <= remaining:
                remaining -= left
                self.progress = 0.0
                self.iterations += 1
                self.iter_mult = self._draw_iter_mult(app)
            else:
                self.progress += remaining / cur_dur
                remaining = 0.0

    def sample(self, app, sp, sm, mem, dt_since_last):
        op = app.op_point(sp, sm, mem)
        p_dyn = op.power_w - sp.power["p_idle_w"]

        if app.aperiodic:
            phase_idx = self.seg_phase
            weight_norm = sum(p["pw"] for p in app.phases) / len(app.phases)
        else:
            durs = self._phase_durations(app, sp, sm, mem)
            acc, phase_idx = 0.0, len(durs) - 1
            for i, d in enumerate(durs):
                acc += d
                if self.progress < acc:
                    phase_idx = i
                    break
            weight_norm = sum(d * p["pw"] for d, p in zip(durs, app.phases))
        ph = app.phases[phase_idx]
        p_phase = p_dyn * ph["pw"] / max(weight_norm, 1e-9)

        micro = app.micro_amp * p_dyn * math.sin(self.micro_phase) if app.micro_amp > 0.0 else 0.0
        noise = self.rng.normal(0.0, app.trace_noise)
        p_raw = sp.power["p_idle_w"] + (p_phase + micro) * max(1.0 + noise, 0.0)

        if not self.ema_init:
            self.power_ema = p_raw
            self.ema_init = True
        else:
            alpha = 1.0 - math.exp(-dt_since_last / sp.power["thermal_tau_s"])
            self.power_ema += alpha * (p_raw - self.power_ema)
        return self.power_ema


# ---------------------------------------------------------------------------
# Structural tests (spec.rs).
# ---------------------------------------------------------------------------

def test_structure():
    sp = spec()
    assert sp.num_sm_gears() == 99
    assert len(sp.mem_mhz) == 5
    assert sp.sm_mhz(16) == 450.0
    assert sp.sm_mhz(114) == 1920.0
    assert sp.sm_mhz(106) == 1800.0
    assert sp.mem_mhz[3] == 9251.0
    assert len(sp.feature_names) == NUM_FEATURES
    assert "cnn" in sp.archetypes
    assert len(sp.suites["aibench"]["apps"]) == 14
    assert len(sp.suites["classical"]["apps"]) == 2
    assert len(sp.suites["gnns"]["apps"]) == 55
    assert len(sp.suites["pytorch_train"]["apps"]) >= 40
    # voltage curve (spec.rs::voltage_curve_monotone_with_knee)
    assert sp.voltage(400.0) == sp.power["v_min"]
    assert sp.voltage(960.0) == sp.power["v_min"]
    assert abs(sp.voltage(1920.0) - sp.power["v_max"]) < 1e-12
    prev = 0.0
    for mhz in range(450, 1921, 15):
        v = sp.voltage(float(mhz))
        assert v >= prev
        prev = v
    # aperiodic flags (spec.rs::aperiodic_flags)
    ap = [
        a["name"]
        for a in sp.suites["gnns"]["apps"]
        if a.get("aperiodic", sp.archetypes[a["archetype"]].get("aperiodic", False))
    ]
    assert len(ap) >= 10
    assert all(n.startswith("CSL") or n.startswith("TU") for n in ap)
    # crosscheck picks must exist
    for suite, name in [
        ("aibench", "AI_I2T"), ("aibench", "AI_IGEN"), ("gnns", "TSP_GatedGCN"),
        ("gnns", "CLB_MLP"), ("gnns", "CSL_GCN"), ("classical", "TSVM"),
        ("pytorch_train", "PTB_resnet50"), ("pytorch_train", "PTB_mlp_tabular"),
    ]:
        assert any(e["name"] == name for e in sp.suites[suite]["apps"]), name


# ---------------------------------------------------------------------------
# Analytic-model tests (app.rs + properties.rs).
# ---------------------------------------------------------------------------

def test_weights_normalized_and_positive():
    sp = spec()
    for a in materialize_all(sp):
        assert abs(a.wc + a.wm + a.wo - 1.0) < 1e-9, a.name
        assert a.wc > 0.0 and a.wm > 0.0 and a.wo > 0.0, a.name
        assert a.t_base > 0.0, a.name
        assert 0.55 <= a.gamma <= 1.0, a.name


def test_power_and_time_monotone_every_app():
    # app.rs::time_monotone_in_sm_clock + properties.rs::prop_apps_have_sane_physics,
    # checked exhaustively (every app, every mem gear, every adjacent SM pair).
    sp = spec()
    for a in materialize_all(sp):
        for mem in range(5):
            prev = None
            for g in sp.sm_gears():
                op = a.op_point(sp, g, mem)
                assert op.energy_j > 0.0 and op.power_w > 0.0
                assert 0.0 <= op.util_sm <= 1.0 and 0.0 <= op.util_mem <= 1.0
                if prev is not None:
                    assert op.t_iter_s <= prev.t_iter_s + 1e-12, (a.name, mem, g)
                    assert op.power_w >= prev.power_w - 1e-9, (
                        f"{a.name} mem {mem} gear {g}: {op.power_w} < {prev.power_w}"
                    )
                prev = op


def test_power_dynamic_range():
    # app.rs::power_monotone_in_sm_clock_at_fixed_mem (AI_I2T 30→114 > 1.3×)
    sp = spec()
    a = simdata.AppParams.materialize(
        sp, "aibench", next(e for e in sp.suites["aibench"]["apps"] if e["name"] == "AI_I2T")
    )
    lo = a.op_point(sp, 30, 3).power_w
    hi = a.op_point(sp, 114, 3).power_w
    assert hi > lo * 1.3, (lo, hi)


def test_interior_energy_minimum_exists():
    # app.rs::energy_is_convexish_with_interior_min_for_some_app
    sp = spec()
    found = False
    for a in simdata.materialize_suite(sp, "aibench"):
        es = [a.op_point(sp, g, 4).energy_j for g in sp.sm_gears()]
        i = es.index(min(es))
        if 0 < i < len(es) - 1:
            found = True
    assert found


def test_default_gear_is_power_capped():
    # app.rs::default_gear_is_power_capped + some apps actually throttled
    sp = spec()
    throttled = 0
    for a in simdata.materialize_suite(sp, "aibench"):
        sm, mem, op = a.default_op(sp)
        assert op.power_w <= sp.power["tdp_w"] + 1e-9, (a.name, op.power_w)
        if sm < sp.default_sm_gear:
            throttled += 1
            above = a.op_point(sp, sm + 1, mem)
            assert above.power_w > sp.power["tdp_w"], a.name
    # The paper's hot/cool split: both kinds must exist.
    assert 1 <= throttled <= 13, f"{throttled} of 14 TDP-throttled"


def test_runner_fixed_work_directions():
    # runner.rs::fixed_work_is_comparable_across_clocks (SBM_GIN 60 vs 114)
    sp = spec()
    a = simdata.AppParams.materialize(
        sp, "gnns", next(e for e in sp.suites["gnns"]["apps"] if e["name"] == "SBM_GIN")
    )
    sm_d, mem_d, _ = a.default_op(sp)
    lo, hi = a.op_point(sp, 60, mem_d), a.op_point(sp, 114, mem_d)
    assert lo.t_iter_s > hi.t_iter_s
    assert lo.energy_j < hi.energy_j, "downclock must save energy for SBM_GIN"
    # runner.rs::aperiodic_fixed_work_scales_with_clock (TSVM 40 vs 114)
    t = find_full(sp, "TSVM")
    assert t.aperiodic
    assert t.time_factor(sp, 40, 4) > 1.1 * t.time_factor(sp, 114, 4)


def test_oracle_headroom():
    # Paper headline: mean oracle saving under the 5% cap should sit in the
    # upper teens over the 71 evaluation apps (GPOEO itself reaches ~16%).
    sp = spec()
    savings = []
    classical_caps = {}
    for suite in ["aibench", "classical", "gnns"]:
        for a in simdata.materialize_suite(sp, suite):
            best = 1.0
            for mem in range(5):
                for g in sp.sm_gears():
                    e, t = a.ratios_vs_default(sp, g, mem)
                    if t <= 1.05 and e < best:
                        best = e
            savings.append(1.0 - best)
            if suite == "classical":
                classical_caps[a.name] = best
    mean = sum(savings) / len(savings)
    assert len(savings) == 71
    assert 0.12 <= mean <= 0.24, f"mean oracle saving {mean:.3f} out of band"
    # ODPP-on-aperiodic test (controller_integration.rs) wants the
    # classical apps to have clearly less headroom than the fleet average.
    for name, e in classical_caps.items():
        assert e >= 0.80, f"{name}: capped optimum {e:.3f} leaves too much headroom"


def test_measured_feature_noise():
    # gpu.rs::counters_noisy_copy_of_truth (meas rng, 15% tolerance) and
    # app.rs::measured_features_are_noisy_but_close (Pcg64(9,9), 20%).
    sp = spec()
    std = sp.noise["counter_meas_std"]
    a = find_full(sp, "AI_OBJ")
    rng = prng.Pcg64(a.trace_seed ^ 0x5EED0BAD, 0xF00D)
    for t in a.features:
        m = min(max(t * math.exp(rng.normal(0.0, std)), 0.005), 1.05)
        assert abs(m / t - 1.0) < 0.15
    b = find_full(sp, "AI_TS")
    rng = prng.Pcg64(9, 9)
    for t in b.features:
        m = min(max(t * math.exp(rng.normal(0.0, std)), 0.005), 1.05)
        assert abs(m / t - 1.0) < 0.2


def test_trace_energy_conservation_named():
    # trace.rs::trace_mean_power_matches_analytic (AI_OBJ @ 114,4, 5%)
    sp = spec()
    a = find_full(sp, "AI_OBJ")
    st = TraceState(a)
    op = a.op_point(sp, 114, 4)
    acc, n, dt = 0.0, 8000, 0.02
    for _ in range(n):
        st.advance(a, sp, 114, 4, dt)
        acc += st.sample(a, sp, 114, 4, dt)
    rel = abs(acc / n - op.power_w) / op.power_w
    assert rel < 0.05, f"trace mean off by {rel:.3f}"


def test_trace_energy_conservation_random():
    # properties.rs::prop_trace_energy_conservation — the exact 12 rng cases.
    sp = spec()
    suites = ["aibench", "gnns", "pytorch_train"]
    for i in range(12):
        rng = prng.Pcg64(0xBB ^ ((i * 0x9E3779B97F4A7C15) & prng.MASK64), i)
        suite = suites[rng.below(3)]
        apps = sp.suites[suite]["apps"]
        entry = apps[rng.below(len(apps))]
        a = FullApp(sp, suite, entry)
        if a.aperiodic:
            continue
        sm = 40 + rng.below(70)
        mem = 2 + rng.below(3)
        op = a.op_point(sp, sm, mem)
        st = TraceState(a)
        acc, n, dt = 0.0, 6000, 0.02
        for _ in range(n):
            st.advance(a, sp, sm, mem, dt)
            acc += st.sample(a, sp, sm, mem, dt)
        rel = abs(acc / n - op.power_w) / op.power_w
        assert rel < 0.06, f"case {i}: {entry['name']} off by {rel:.3f}"


def test_iteration_rate():
    # trace.rs::iterations_advance_at_expected_rate (AI_I2T @ 114,4)
    sp = spec()
    a = find_full(sp, "AI_I2T")
    st = TraceState(a)
    t_iter = a.t_base * a.time_factor(sp, 114, 4)
    t, total = 0.0, 40.0 * t_iter
    while t < total:
        st.advance(a, sp, 114, 4, 0.01)
        t += 0.01
    assert abs(st.iterations - 40.0) <= 3.0, st.iterations


def test_sane_physics_exact_rust_cases():
    # properties.rs::prop_apps_have_sane_physics — the exact 120 rng cases.
    sp = spec()
    all_apps = []
    for sname in sp.suites:
        for a in sp.suites[sname]["apps"]:
            all_apps.append((sname, a["name"]))
    cache = {}
    for i in range(120):
        rng = prng.Pcg64(0xBEEF ^ ((i * 0x9E3779B97F4A7C15) & prng.MASK64), i)
        suite, name = all_apps[rng.below(len(all_apps))]
        if (suite, name) not in cache:
            entry = next(e for e in sp.suites[suite]["apps"] if e["name"] == name)
            cache[(suite, name)] = simdata.AppParams.materialize(sp, suite, entry)
        app = cache[(suite, name)]
        mem = rng.below(5)
        g1 = sp.sm_gear_min + rng.below(98)
        g2 = min(g1 + 1 + rng.below(8), sp.sm_gear_max)
        p1, p2 = app.op_point(sp, g1, mem), app.op_point(sp, g2, mem)
        assert p2.t_iter_s <= p1.t_iter_s + 1e-12, (i, name)
        assert p2.power_w >= p1.power_w - 1e-9, (i, name)


if __name__ == "__main__":
    fns = [v for k, v in sorted(globals().items()) if k.startswith("test_")]
    for fn in fns:
        fn()
        print(f"ok {fn.__name__}")
    print(f"{len(fns)} groundtruth checks passed")
