"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps)."""

import sys
import os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gbt
from compile.kernels.gbt_eval import gbt_eval
from compile.kernels.periodogram import periodogram
from compile.kernels.ref import gbt_eval_ref, periodogram_ref


# ---------------------------------------------------------------- periodogram

@pytest.mark.parametrize("n,kb", [(256, 32), (512, 64), (1024, 128), (2048, 128)])
def test_periodogram_matches_ref_sizes(n, kb):
    rng = np.random.default_rng(n)
    x = np.sin(np.arange(n) * 0.21) + 0.3 * rng.normal(size=n) + 2.0
    a = np.asarray(periodogram(jnp.asarray(x, jnp.float32), kb=kb))
    b = np.asarray(periodogram_ref(jnp.asarray(x, jnp.float32)))
    assert a.shape == (n // 2,)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3 * float(b.max()))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    logn=st.integers(8, 11),
    freq=st.floats(0.01, 2.5),
    offset=st.floats(-10.0, 10.0),
)
def test_periodogram_hypothesis(seed, logn, freq, offset):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    x = np.sin(np.arange(n) * freq) + offset + 0.1 * rng.normal(size=n)
    a = np.asarray(periodogram(jnp.asarray(x, jnp.float32), kb=min(128, n // 2)))
    b = np.asarray(periodogram_ref(jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3 * float(b.max() + 1e-6))


def test_periodogram_peak_location():
    n = 1024
    k_true = 37
    x = np.cos(2 * np.pi * k_true * np.arange(n) / n)
    a = np.asarray(periodogram(jnp.asarray(x, jnp.float32)))
    # output bin i corresponds to spectral bin i+1
    assert int(np.argmax(a)) == k_true - 1
    assert a.max() == pytest.approx(n / 2, rel=1e-3)


def test_periodogram_dc_invariance():
    n = 512
    x = np.sin(np.arange(n) * 0.3)
    a0 = np.asarray(periodogram(jnp.asarray(x, jnp.float32), kb=64))
    a1 = np.asarray(periodogram(jnp.asarray(x + 123.0, jnp.float32), kb=64))
    np.testing.assert_allclose(a0, a1, atol=0.3)


# ------------------------------------------------------------------- gbt_eval

def _toy_model(seed=0, n_trees=30, depth=4):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, (800, 7))
    y = 2 * X[:, 0] - X[:, 3] ** 2 + np.sin(4 * X[:, 5])
    return gbt.train(X, y, n_trees=n_trees, max_depth=depth)


def test_gbt_kernel_matches_ref_and_model():
    m = _toy_model()
    rng = np.random.default_rng(1)
    Xq = rng.uniform(0, 1, (99, 7)).astype(np.float32)
    dense = m.to_dense()
    a = np.asarray(gbt_eval(Xq, *dense, base=m.base, lr=m.lr))
    b = np.asarray(gbt_eval_ref(Xq, *dense, m.base, m.lr))
    c = m.predict(Xq.astype(np.float64))
    np.testing.assert_allclose(a, b, atol=1e-5)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_trees=st.integers(1, 40),
    depth=st.integers(1, 6),
    g=st.integers(1, 128),
)
def test_gbt_kernel_hypothesis(seed, n_trees, depth, g):
    m = _toy_model(seed=seed % 17, n_trees=n_trees, depth=depth)
    rng = np.random.default_rng(seed)
    Xq = rng.uniform(-0.5, 1.5, (g, 7)).astype(np.float32)  # includes OOD
    dense = m.to_dense()
    a = np.asarray(gbt_eval(Xq, *dense, base=m.base, lr=m.lr))
    c = m.predict(Xq.astype(np.float64))
    np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-4)


def test_gbt_single_leaf_tree():
    # Degenerate: constant target -> every tree is one leaf.
    X = np.tile(np.linspace(0, 1, 50)[:, None], (1, 3))
    y = np.full(50, 2.5)
    m = gbt.train(X, y, n_trees=5, max_depth=3)
    pred = np.asarray(gbt_eval(X[:4].astype(np.float32), *m.to_dense(), base=m.base, lr=m.lr))
    np.testing.assert_allclose(pred, 2.5, atol=1e-5)
