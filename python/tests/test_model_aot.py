"""L2 model shape tests + AOT artifact smoke checks."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import gbt, simdata
from compile.model import make_predictor, periodogram_1024

ARTIFACTS = os.path.join(simdata.repo_root(), "artifacts")


def test_periodogram_module_shapes():
    out = periodogram_1024(jnp.zeros(1024, jnp.float32))
    assert out[0].shape == (512,)


def test_predictor_shapes_and_determinism():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (400, 17))
    m_e = gbt.train(X, X[:, 0] + 0.5, n_trees=10, max_depth=3)
    m_t = gbt.train(X, 1.5 - X[:, 0], n_trees=10, max_depth=3)
    norms = np.linspace(0.2, 1.0, 99)
    pred = make_predictor(m_e, m_t, norms)
    f = jnp.asarray(rng.uniform(0, 1, 16), jnp.float32)
    e1, t1 = pred(f)
    e2, t2 = pred(f)
    assert e1.shape == (99,) and t1.shape == (99,)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_predictor_lowers_to_stablehlo():
    rng = np.random.default_rng(1)
    X = rng.uniform(0, 1, (300, 17))
    m = gbt.train(X, X[:, 0], n_trees=5, max_depth=3)
    pred = make_predictor(m, m, np.linspace(0, 1, 5))
    lowered = jax.jit(pred).lower(jax.ShapeDtypeStruct((16,), jnp.float32))
    ir = str(lowered.compiler_ir("stablehlo"))
    assert "func.func public @main" in ir


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "predictor_sm.hlo.txt")),
    reason="run `make artifacts` first",
)
def test_artifacts_exist_and_are_hlo_text():
    for name in ("periodogram_1024", "predictor_sm", "predictor_mem"):
        path = os.path.join(ARTIFACTS, f"{name}.hlo.txt")
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name} is not HLO text"
        assert "ENTRY" in open(path).read()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "meta.json")),
    reason="run `make artifacts` first",
)
def test_meta_quality_gates():
    import json

    with open(os.path.join(ARTIFACTS, "meta.json")) as f:
        meta = json.load(f)
    # The paper reports ~2-3% mean prediction error; gate at 5%.
    assert meta["checks"]["sm_holdout_mape_eng"] < 0.05
    assert meta["checks"]["sm_holdout_mape_time"] < 0.05
    assert meta["checks"]["periodogram_rel_err"] < 1e-3
    assert len(meta["sm_gears"]) == 99
