"""Ground-truth twin tests: RNG vectors, app materialization invariants,
training-data shapes, and (when artifacts exist) crosscheck consistency."""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest

from compile import prng, simdata


def test_fnv_vectors():
    assert prng.fnv1a64(b"") == 0xCBF29CE484222325
    assert prng.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    assert prng.fnv1a64(b"foobar") == 0x85944171F73967E8


def test_pcg_deterministic_and_uniform():
    a = prng.Pcg64(42, 1)
    b = prng.Pcg64(42, 1)
    va = [a.next_u64() for _ in range(8)]
    vb = [b.next_u64() for _ in range(8)]
    assert va == vb
    xs = [prng.Pcg64(7, 7).next_f64()]
    r = prng.Pcg64(7, 7)
    xs = [r.next_f64() for _ in range(5000)]
    assert abs(np.mean(xs) - 0.5) < 0.02
    assert all(0.0 <= x < 1.0 for x in xs)


def test_gauss_moments():
    r = prng.Pcg64(11, 3)
    xs = [r.gauss() for _ in range(5000)]
    assert abs(np.mean(xs)) < 0.05
    assert abs(np.std(xs) - 1.0) < 0.05


def test_suite_sizes():
    spec = simdata.Spec.load()
    assert len(spec.suites["aibench"]["apps"]) == 14
    assert len(spec.suites["gnns"]["apps"]) == 55
    assert len(spec.suites["classical"]["apps"]) == 2


def test_app_invariants():
    spec = simdata.Spec.load()
    for suite in ("aibench", "gnns", "classical", "pytorch_train"):
        for app in simdata.materialize_suite(spec, suite):
            assert abs(app.wc + app.wm + app.wo - 1.0) < 1e-9
            assert app.t_base > 0
            (sm, mem, op) = app.default_op(spec)
            assert op.power_w <= spec.power["tdp_w"] + 1e-9
            e, t = app.ratios_vs_default(spec, sm, mem)
            assert e == pytest.approx(1.0) and t == pytest.approx(1.0)


def test_reference_point_identity():
    spec = simdata.Spec.load()
    app = simdata.materialize_suite(spec, "aibench")[0]
    op = app.op_point(spec, spec.reference_sm_gear, spec.reference_mem_gear)
    assert op.t_iter_s == pytest.approx(app.t_base)


def test_training_data_shapes():
    spec = simdata.Spec.load()
    data = simdata.training_data(spec, noise_replicas=1)
    n_apps = len(spec.suites["pytorch_train"]["apps"])
    Xs, ys = data["sm_eng"]
    assert Xs.shape == (n_apps * 99 * 2, 17)
    Xm, ym = data["mem_eng"]
    assert Xm.shape == (n_apps * 5 * 2, 17)
    # Ratios are positive and centered near 1.
    assert ys.min() > 0.2 and ys.max() < 3.0


def test_crosscheck_payload_schema():
    spec = simdata.Spec.load()
    payload = simdata.crosscheck_payload(spec)
    assert len(payload["apps"]) >= 6
    for app in payload["apps"]:
        assert len(app["features"]) == 16
        assert len(app["probes"]) == 4


ARTIFACTS = os.path.join(simdata.repo_root(), "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "crosscheck.json")),
    reason="run `make artifacts` first",
)
def test_crosscheck_file_matches_live_model():
    spec = simdata.Spec.load()
    with open(os.path.join(ARTIFACTS, "crosscheck.json")) as f:
        stored = json.load(f)
    live = simdata.crosscheck_payload(spec)
    for a, b in zip(stored["apps"], live["apps"]):
        assert a["name"] == b["name"]
        np.testing.assert_allclose(a["features"], b["features"], rtol=1e-12)
        assert a["trace_seed"] == b["trace_seed"]
        for pa, pb in zip(a["probes"], b["probes"]):
            assert pa["power_w"] == pytest.approx(pb["power_w"], rel=1e-12)
