//! Benchmark harness (`cargo bench`). The offline crate set has no
//! criterion, so this is a hand-rolled timing harness: per target, warm
//! up, run for a fixed budget, report ns/op plus per-paper-experiment
//! end-to-end timings. These are the L3 perf numbers tracked in
//! EXPERIMENTS.md §Perf.

use gpoeo::coordinator::{run_sim, DefaultPolicy, Gpoeo, GpoeoCfg};
use gpoeo::model::{NativeModels, Predictor};
use gpoeo::signal::{
    calc_period, composite_feature, online_detect, sequence_similarity_error, PeriodCfg,
    SimilarityCfg, StreamCfg, StreamingDetector,
};
use gpoeo::sim::{find_app, SimGpu, Spec};
use std::sync::Arc;
use std::time::Instant;

fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = start.elapsed().as_nanos() as f64 / iters as f64;
    let (val, unit) = if per >= 1e9 {
        (per / 1e9, "s ")
    } else if per >= 1e6 {
        (per / 1e6, "ms")
    } else if per >= 1e3 {
        (per / 1e3, "µs")
    } else {
        (per, "ns")
    };
    println!("{name:<44} {val:>9.2} {unit}/op   ({iters} iters)");
}

fn make_trace(spec: &Arc<Spec>, name: &str, dur_s: f64, ts: f64) -> Vec<f64> {
    let app = find_app(spec, name).unwrap();
    let mut gpu = SimGpu::new(spec.clone(), app);
    let n = (dur_s / ts) as usize;
    let (mut p, mut us, mut um) = (vec![], vec![], vec![]);
    for _ in 0..n {
        gpu.advance(ts);
        let s = gpu.sample(ts);
        p.push(s.power_w);
        us.push(s.util_sm);
        um.push(s.util_mem);
    }
    gpoeo::signal::composite_feature(&p, &us, &um)
}

fn main() {
    let spec = Arc::new(Spec::load_default().unwrap());
    println!("== gpoeo bench harness ==");

    // --- L3 hot paths ---------------------------------------------------
    let ts = 0.025;
    let trace = make_trace(&spec, "AI_I2T", 14.0, ts);
    bench("signal: periodogram (560 samples)", 600, || {
        let _ = gpoeo::signal::periodogram(&trace, ts);
    });
    bench("signal: similarity err (1 candidate)", 600, || {
        let _ = sequence_similarity_error(1.05, &trace, ts, &SimilarityCfg::default());
    });
    bench("signal: calc_period (Alg 1)", 1500, || {
        let _ = calc_period(&trace, ts, &PeriodCfg::default());
    });
    bench("signal: online_detect (Alg 3)", 2500, || {
        let _ = online_detect(&trace, ts, &PeriodCfg::default());
    });

    // Streaming vs batch over one full online session at a 2 Hz poll
    // cadence — the per-session cost the daemon pays per fleet worker.
    let app_s = find_app(&spec, "AI_I2T").unwrap();
    let mut gpu_s = SimGpu::new(spec.clone(), app_s);
    let n_s = (14.0 / ts) as usize;
    let (mut cp, mut cs, mut cm) = (Vec::new(), Vec::new(), Vec::new());
    for _ in 0..n_s {
        gpu_s.advance(ts);
        let s = gpu_s.sample(ts);
        cp.push(s.power_w);
        cs.push(s.util_sm);
        cm.push(s.util_mem);
    }
    let stride = (0.5 / ts).round() as usize;
    bench("signal: batch session (14 s, 2 Hz polls)", 3000, || {
        let (mut p, mut us, mut um) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..n_s {
            p.push(cp[i]);
            us.push(cs[i]);
            um.push(cm[i]);
            if (i + 1) % stride == 0 {
                let feat = composite_feature(&p, &us, &um);
                let _ = online_detect(&feat, ts, &PeriodCfg::default());
            }
        }
    });
    bench("signal: streaming session (14 s, 2 Hz polls)", 3000, || {
        let mut det = StreamingDetector::new(
            ts,
            PeriodCfg::default(),
            StreamCfg {
                retain_horizon_mult: Some(2.0),
                ..StreamCfg::default()
            },
        );
        for i in 0..n_s {
            det.push(cp[i], cs[i], cm[i]);
            if (i + 1) % stride == 0 {
                let _ = det.poll();
            }
        }
    });

    let app = find_app(&spec, "AI_I2T").unwrap();
    bench("sim: op_point eval", 300, || {
        let _ = std::hint::black_box(app.op_point(&spec, 80, 3));
    });
    let mut gpu = SimGpu::new(spec.clone(), app.clone());
    bench("sim: advance+sample tick", 400, || {
        gpu.advance(ts);
        let _ = std::hint::black_box(gpu.sample(ts));
    });

    // --- model inference: native vs AOT/PJRT ----------------------------
    if let Ok(native) = NativeModels::load_default() {
        let native = Predictor::Native(native);
        bench("predict_sm: native arena (99 gears x 2 models)", 1000, || {
            let _ = native.predict_sm(&spec, &app.features).unwrap();
        });
        if let Some(rt) = gpoeo::runtime::Runtime::try_default() {
            let feats: Vec<f32> = app.features.iter().map(|&v| v as f32).collect();
            bench("predict_sm: HLO/PJRT (99 gears x 2 models)", 1000, || {
                let _ = rt.predict_sm(&feats).unwrap();
            });
            let sig: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.13).sin()).collect();
            bench("periodogram: HLO/PJRT (1024 -> 512)", 1000, || {
                let _ = rt.periodogram_1024(&sig).unwrap();
            });
        }
    } else {
        println!("(artifacts missing: model benches skipped — run `make artifacts`)");
    }

    // --- end-to-end paper-experiment timings -----------------------------
    if let Ok(p) = Predictor::load_best() {
        let predictor = Arc::new(p);
        for name in ["AI_I2T", "CLB_MLP", "TSVM"] {
            let app = find_app(&spec, name).unwrap();
            let t0 = Instant::now();
            let base = run_sim(&spec, &app, &mut DefaultPolicy { ts }, 150);
            let mut g = Gpoeo::new(GpoeoCfg::default(), predictor.clone());
            let run = run_sim(&spec, &app, &mut g, 150);
            let s = gpoeo::coordinator::savings(&base, &run).unwrap();
            println!(
                "e2e: optimize {name:<12} 150 iters: {:>6.2}s wall ({:>7.1}s virtual, saving {:+.1}%)",
                t0.elapsed().as_secs_f64(),
                base.time_s + run.time_s,
                s.energy_saving * 100.0
            );
        }
    }
    println!("== done ==");
}
