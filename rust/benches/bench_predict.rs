//! Prediction-path benchmarks (`cargo bench --bench bench_predict`):
//! arena vs legacy native GBT inference, single-shot and fleet-shaped.
//! Runs on the trained artifacts when present, else on the
//! deterministic synthetic bundle (same tree shape), so the relative
//! numbers are always available. Same hand-rolled harness as
//! bench_main (the offline crate set has no criterion).

use gpoeo::model::{NativeModels, Predictor};
use gpoeo::sim::{make_suite, Spec};
use gpoeo::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Instant;

fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) {
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = start.elapsed().as_nanos() as f64 / iters as f64;
    let (val, unit) = if per >= 1e9 {
        (per / 1e9, "s ")
    } else if per >= 1e6 {
        (per / 1e6, "ms")
    } else if per >= 1e3 {
        (per / 1e3, "µs")
    } else {
        (per, "ns")
    };
    println!("{name:<52} {val:>9.2} {unit}/op   ({iters} iters)");
}

fn main() {
    let spec = Arc::new(Spec::load_default().unwrap());
    let (models, backend) = NativeModels::load_default_or_synthetic().unwrap();
    let predictor = Predictor::Native(models.clone());
    println!("== gpoeo predict bench ({backend}) ==");

    // One app's measured features — the single-shot shape every
    // iteration-shift pays (§4.3.3: predict all gears, then search).
    let apps = make_suite(&spec, "aibench").unwrap();
    let app = &apps[0];
    let mut rng = Pcg64::new(app.trace_seed ^ 0x00fe_a7, 0x5eed);
    let feats = app.measured_features(&spec, &mut rng);

    bench("predict_sm: arena (99 gears x 2 models)", 1200, || {
        std::hint::black_box(predictor.predict_sm(&spec, &feats).unwrap());
    });
    bench("predict_sm: legacy walk (99 gears x 2 models)", 1200, || {
        std::hint::black_box(models.legacy_predict_sm(&spec, &feats));
    });
    bench("predict_mem: arena (5 gears x 2 models)", 600, || {
        std::hint::black_box(predictor.predict_mem(&spec, &feats).unwrap());
    });
    bench("predict_mem: legacy walk (5 gears x 2 models)", 600, || {
        std::hint::black_box(models.legacy_predict_mem(&spec, &feats));
    });

    // Fleet-shaped: one full prediction step (SM + mem) for all 71
    // evaluation apps back to back — the oracle/sweep/fleet pattern
    // where per-prediction cost multiplies by apps × policies.
    let all = gpoeo::experiments::helpers::evaluation_apps(&spec).unwrap();
    let featsets: Vec<Vec<f64>> = all
        .iter()
        .map(|a| {
            let mut rng = Pcg64::new(a.trace_seed ^ 0x00fe_a7, 0x5eed);
            a.measured_features(&spec, &mut rng)
        })
        .collect();
    bench("fleet: 71 apps x (sm+mem), arena", 3000, || {
        for f in &featsets {
            std::hint::black_box(predictor.predict_sm(&spec, f).unwrap());
            std::hint::black_box(predictor.predict_mem(&spec, f).unwrap());
        }
    });
    bench("fleet: 71 apps x (sm+mem), legacy walk", 3000, || {
        for f in &featsets {
            std::hint::black_box(models.legacy_predict_sm(&spec, f));
            std::hint::black_box(models.legacy_predict_mem(&spec, f));
        }
    });
    println!("== done ==");
}
