use gpoeo::signal::*;
use std::f64::consts::PI;
fn signal(period_s: f64, ts: f64, dur_s: f64) -> Vec<f64> {
    let n = (dur_s / ts) as usize;
    (0..n).map(|i| {
        let t = i as f64 * ts;
        let ph = (t / period_s).fract();
        let base = if ph < 0.10 { 0.4 } else if ph < 0.50 { 0.95 } else if ph < 0.85 { 1.05 } else { 0.6 };
        let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let noise = ((h >> 11) as f64 / (1u64 << 53) as f64) - 0.5;
        base + 0.04 * noise
    }).collect()
}
fn main() {
    let ts = 0.025;
    let smp = signal(3.0, ts, 4.5);
    match online_detect(&smp, ts, &PeriodCfg::default()) {
        Some(d) => println!("est {:.4} err {:.4} next {:?}", d.estimate.t_iter, d.estimate.err, d.next_sampling_s),
        None => println!("none"),
    }
}
