// Scratch diagnostics: dump spectrum candidates + similarity errors.
use gpoeo::sim::{find_app, SimGpu, Spec};
use gpoeo::signal::*;
use std::sync::Arc;

fn main() {
    let name = std::env::args().nth(1).unwrap_or("AI_I2T".into());
    let spec = Arc::new(Spec::load_default().unwrap());
    let app = find_app(&spec, &name).unwrap();
    let mut gpu = SimGpu::new(spec.clone(), app);
    if let Some(g) = std::env::args().nth(2).and_then(|s| s.parse::<usize>().ok()) {
        gpu.set_sm_gear(g);
    }
    let truth = gpu.true_period();
    let ts = 0.025;
    let n = ((12.0 * truth).max(8.0) / ts) as usize;
    let (mut p, mut us, mut um) = (vec![], vec![], vec![]);
    for _ in 0..n {
        gpu.advance(ts);
        let s = gpu.sample(ts);
        p.push(s.power_w); us.push(s.util_sm); um.push(s.util_mem);
    }
    let feat = composite_feature(&p, &us, &um);
    println!("app {name} truth {truth:.4} window {:.1}s", n as f64 * ts);
    let (freqs, ampls) = periodogram(&feat, ts);
    let cands = gpoeo::signal::peaks::candidate_periods_prominence(&freqs, &ampls, 0.65, 8, (n as f64 - 1.0) * ts / 2.0);
    for c in &cands {
        println!("  cand T={:.4} ampl={:.1}", c.period_s, c.amplitude);
    }
    match online_detect(&feat, ts, &PeriodCfg::default()) {
        Some(d) => println!("  online: est {:.4} err {:.4} next {:?}", d.estimate.t_iter, d.estimate.err, d.next_sampling_s),
        None => println!("  online: none"),
    }
    let cfg = SimilarityCfg::default();
    for mult in [0.25, 0.5, 1.0, 2.0, 3.0] {
        let t = truth * mult;
        let e = sequence_similarity_error(t, &feat, ts, &cfg);
        println!("  err({:.4} = {mult}x truth) = {:.4}", t, e);
    }
}
