// Diagnostics: run probe HLOs through the PJRT loader and print outputs.
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for name in std::env::args().skip(1) {
        let path = format!("/tmp/{name}.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let x: Vec<f32> = (0..16).map(|i| i as f32 / 4.0).collect();
        let lit = xla::Literal::vec1(&x);
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        match result.to_tuple() {
            Ok(parts) => {
                for (i, p) in parts.iter().enumerate() {
                    let v = p.to_vec::<f32>().unwrap_or_default();
                    println!("{name}[{i}]: {:?}", &v[..4.min(v.len())]);
                }
            }
            Err(e) => println!("{name}: tuple error {e}"),
        }
    }
    Ok(())
}
