// Probe a 2-output predictor module.
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/p8_predictor.hlo.txt")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x: Vec<f32> = (0..16).map(|i| i as f32 / 4.0).collect();
    let result = exe.execute::<xla::Literal>(&[xla::Literal::vec1(&x)])?[0][0].to_literal_sync()?;
    let (a, b) = result.to_tuple2()?;
    println!("e[:4] = {:?}", &a.to_vec::<f32>()?[..4]);
    println!("t[:4] = {:?}", &b.to_vec::<f32>()?[..4]);
    Ok(())
}
