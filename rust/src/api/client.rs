//! `GpoeoClient` — the Rust client library for the control-plane API.
//!
//! This is the *only* supported way to talk to the daemon: the CLI
//! (`gpoeo ctl`), the protocol tests and the CI smoke all go through it,
//! so protocol strings exist in `api/` and nowhere else. The typed
//! methods ([`begin`](GpoeoClient::begin), [`status`](GpoeoClient::status),
//! [`end`](GpoeoClient::end), ...) map `Response::Error` onto
//! `anyhow::Error`, so callers never match on error strings.
//!
//! [`LegacyClient`] speaks the pre-v1 whitespace-token line protocol
//! (`POLICY`/`BEGIN`/`STATUS`/`END`) against the same daemon — the
//! compat mode the parity tests and CI use to prove both protocols
//! produce identical results.

use super::protocol::{
    read_frame, result_parity_key, Event, Frame, Request, Response, ServerMsg, SessionReport,
    MAX_REPLY_BYTES, PROTOCOL_VERSION,
};
use super::{AppInfo, PolicyInfo};
use crate::policy::PolicySpec;
use std::io::{BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

/// A v1 control-plane connection (handshake done, ready for requests).
pub struct GpoeoClient {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl GpoeoClient {
    /// Connect and perform the `hello` version handshake.
    pub fn connect(socket: &Path) -> anyhow::Result<GpoeoClient> {
        let mut c = GpoeoClient::connect_raw(socket)?;
        match c.request(&Request::Hello {
            version: PROTOCOL_VERSION,
        })? {
            Response::Hello { protocol, .. } if protocol == PROTOCOL_VERSION => Ok(c),
            Response::Hello { protocol, server } => anyhow::bail!(
                "server '{server}' speaks protocol v{protocol}, this client v{PROTOCOL_VERSION}"
            ),
            Response::Error { message, .. } => anyhow::bail!("handshake rejected: {message}"),
            other => anyhow::bail!("unexpected handshake reply '{}'", other.kind()),
        }
    }

    /// Connect *without* the handshake. Only protocol tests need this —
    /// every typed request except `hello` will be refused by the server
    /// until a `hello` goes through.
    pub fn connect_raw(socket: &Path) -> anyhow::Result<GpoeoClient> {
        let stream = UnixStream::connect(socket)
            .map_err(|e| anyhow::anyhow!("connecting to {}: {e}", socket.display()))?;
        let writer = stream.try_clone()?;
        Ok(GpoeoClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    fn send(&mut self, req: &Request) -> anyhow::Result<()> {
        let mut line = req.to_json().to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<ServerMsg> {
        match read_frame(&mut self.reader, MAX_REPLY_BYTES)? {
            Frame::Eof => anyhow::bail!("server closed the connection"),
            Frame::Oversized => anyhow::bail!("oversized server reply (> {MAX_REPLY_BYTES} bytes)"),
            Frame::Line(l) => {
                ServerMsg::parse_line(&l).map_err(|e| anyhow::anyhow!("bad server message: {e}"))
            }
        }
    }

    /// One request → one [`Response`]. Events arriving out of a
    /// subscription context are skipped. `Response::Error` is returned
    /// as a value here — the typed wrappers below turn it into `Err`.
    pub fn request(&mut self, req: &Request) -> anyhow::Result<Response> {
        self.send(req)?;
        loop {
            match self.recv()? {
                ServerMsg::Response(r) => return Ok(r),
                ServerMsg::Event(_) => continue,
            }
        }
    }

    /// Send one raw wire line and return the server's answer. This is
    /// the escape hatch the framing fuzz tests use to deliver malformed
    /// input; production code always goes through [`request`](Self::request).
    pub fn raw_line(&mut self, line: &str) -> anyhow::Result<ServerMsg> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.recv()
    }

    /// Start a session; returns its id. `iters: None` runs the app's
    /// default workload size; `policy: None` runs the connection's
    /// current default policy.
    pub fn begin(
        &mut self,
        app: &str,
        iters: Option<u64>,
        name: Option<&str>,
        policy: Option<PolicySpec>,
    ) -> anyhow::Result<String> {
        match self.request(&Request::Begin {
            app: app.to_string(),
            iters,
            name: name.map(|s| s.to_string()),
            policy,
        })? {
            Response::Begun { session } => Ok(session),
            other => Err(unexpected("begin", other)),
        }
    }

    /// Drive a slice of the session and return its telemetry.
    pub fn status(&mut self, session: &str) -> anyhow::Result<SessionReport> {
        match self.request(&Request::Status {
            session: session.to_string(),
        })? {
            Response::Status(r) => Ok(r),
            other => Err(unexpected("status", other)),
        }
    }

    /// Drive the session to its target and return the final result.
    pub fn end(&mut self, session: &str) -> anyhow::Result<SessionReport> {
        match self.request(&Request::End {
            session: session.to_string(),
        })? {
            Response::Result(r) => Ok(r),
            other => Err(unexpected("end", other)),
        }
    }

    pub fn abort(&mut self, session: &str) -> anyhow::Result<()> {
        match self.request(&Request::Abort {
            session: session.to_string(),
        })? {
            Response::Ok { .. } => Ok(()),
            other => Err(unexpected("abort", other)),
        }
    }

    /// Set this connection's default policy for subsequent `begin`s.
    pub fn set_policy(&mut self, policy: PolicySpec) -> anyhow::Result<()> {
        match self.request(&Request::SetPolicy { policy })? {
            Response::Ok { .. } => Ok(()),
            other => Err(unexpected("set_policy", other)),
        }
    }

    pub fn list_apps(&mut self) -> anyhow::Result<Vec<AppInfo>> {
        match self.request(&Request::ListApps)? {
            Response::Apps(a) => Ok(a),
            other => Err(unexpected("list_apps", other)),
        }
    }

    pub fn list_policies(&mut self) -> anyhow::Result<Vec<PolicyInfo>> {
        match self.request(&Request::ListPolicies)? {
            Response::Policies(p) => Ok(p),
            other => Err(unexpected("list_policies", other)),
        }
    }

    /// Stream status telemetry while the server drives the session:
    /// `on_event` fires per event; returns the final status snapshot
    /// (the session still needs [`end`](Self::end) to be released).
    pub fn subscribe(
        &mut self,
        session: &str,
        every_ticks: u64,
        max_events: u64,
        mut on_event: impl FnMut(&SessionReport),
    ) -> anyhow::Result<SessionReport> {
        self.send(&Request::Subscribe {
            session: session.to_string(),
            every_ticks,
            max_events,
        })?;
        loop {
            match self.recv()? {
                ServerMsg::Event(Event::Status(r)) => on_event(&r),
                ServerMsg::Response(Response::Status(r)) => return Ok(r),
                ServerMsg::Response(Response::Error { message, .. }) => {
                    anyhow::bail!("{message}")
                }
                ServerMsg::Response(other) => return Err(unexpected("subscribe", other)),
            }
        }
    }

    /// Fetch the daemon's metrics registry in Prometheus text
    /// exposition format.
    pub fn metrics(&mut self) -> anyhow::Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { text } => Ok(text),
            other => Err(unexpected("metrics", other)),
        }
    }

    /// Ask the daemon to stop serving and remove its socket file.
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::Ok { .. } => Ok(()),
            other => Err(unexpected("shutdown", other)),
        }
    }
}

/// A server-side refusal (`Response::Error`) with its machine-readable
/// category preserved: callers that must react to a specific refusal —
/// `ctl` backing off on `"rate_limited"` — downcast to this instead of
/// matching on message strings. `Display` is the bare message, so the
/// errors existing callers see are unchanged.
#[derive(Debug)]
pub struct ApiError {
    /// The wire `error_kind` (e.g. `"rate_limited"`); empty for plain
    /// errors.
    pub kind: String,
    pub message: String,
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ApiError {}

fn unexpected(what: &str, r: Response) -> anyhow::Error {
    match r {
        Response::Error { message, kind } => anyhow::Error::new(ApiError { kind, message }),
        other => anyhow::anyhow!("unexpected reply '{}' to {what}", other.kind()),
    }
}

/// Compat-mode client for the legacy whitespace-token protocol. One
/// session per connection, `POLICY` takes a bare name — exactly the
/// surface old clients had. Kept (and exercised in CI) so the
/// legacy-compat guarantee stays a tested contract, not folklore.
pub struct LegacyClient {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl LegacyClient {
    pub fn connect(socket: &Path) -> anyhow::Result<LegacyClient> {
        let stream = UnixStream::connect(socket)
            .map_err(|e| anyhow::anyhow!("connecting to {}: {e}", socket.display()))?;
        let writer = stream.try_clone()?;
        Ok(LegacyClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// One command line out, one answer line back. `ERR ...` answers
    /// become `Err`.
    fn roundtrip(&mut self, cmd: &str) -> anyhow::Result<String> {
        self.writer.write_all(cmd.as_bytes())?;
        self.writer.write_all(b"\n")?;
        match read_frame(&mut self.reader, MAX_REPLY_BYTES)? {
            Frame::Line(l) => match l.strip_prefix("ERR ") {
                Some(reason) => anyhow::bail!("{reason}"),
                None => Ok(l),
            },
            _ => anyhow::bail!("server closed the legacy connection"),
        }
    }

    /// `POLICY <name>` — selects the policy for the next `BEGIN`. The
    /// legacy protocol cannot carry configuration; that is what v1's
    /// `set_policy`/inline `begin` policy is for.
    pub fn set_policy(&mut self, name: &str) -> anyhow::Result<()> {
        self.roundtrip(&format!("POLICY {name}"))?;
        Ok(())
    }

    /// `BEGIN <app> [iters]` — `iters: None` runs the app's default
    /// workload size (same default as v1 and `gpoeo run`).
    pub fn begin(&mut self, app: &str, iters: Option<u64>) -> anyhow::Result<()> {
        let cmd = match iters {
            Some(n) => format!("BEGIN {app} {n}"),
            None => format!("BEGIN {app}"),
        };
        self.roundtrip(&cmd)?;
        Ok(())
    }

    /// `STATUS` — parse `STATUS <iter> <time_s> <energy_j> <sm> <mem>`.
    pub fn status(&mut self) -> anyhow::Result<SessionReport> {
        let line = self.roundtrip("STATUS")?;
        parse_report(&line, "STATUS", false)
    }

    /// `END` — parse `RESULT <energy_j> <time_s> <iters> <sm> <mem>`.
    pub fn end(&mut self) -> anyhow::Result<SessionReport> {
        let line = self.roundtrip("END")?;
        let mut t = line.split_whitespace();
        if t.next() != Some("RESULT") {
            anyhow::bail!("expected a RESULT line, got '{line}'");
        }
        let mut num = || -> anyhow::Result<f64> {
            t.next()
                .and_then(|s| s.parse::<f64>().ok())
                .ok_or_else(|| anyhow::anyhow!("malformed RESULT line '{line}'"))
        };
        let (energy_j, time_s, iters, sm, mem) = (num()?, num()?, num()?, num()?, num()?);
        Ok(SessionReport {
            session: String::new(),
            iterations: iters as u64,
            target_iters: 0,
            time_s,
            energy_j,
            sm_gear: sm as usize,
            mem_gear: mem as usize,
            done: true,
        })
    }

    pub fn quit(mut self) {
        let _ = self.writer.write_all(b"QUIT\n");
    }
}

fn parse_report(line: &str, tag: &str, done: bool) -> anyhow::Result<SessionReport> {
    let mut t = line.split_whitespace();
    if t.next() != Some(tag) {
        anyhow::bail!("expected a {tag} line, got '{line}'");
    }
    let mut num = || -> anyhow::Result<f64> {
        t.next()
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed {tag} line '{line}'"))
    };
    let (iters, time_s, energy_j, sm, mem) = (num()?, num()?, num()?, num()?, num()?);
    Ok(SessionReport {
        session: String::new(),
        iterations: iters as u64,
        target_iters: 0,
        time_s,
        energy_j,
        sm_gear: sm as usize,
        mem_gear: mem as usize,
        done,
    })
}

/// Run one complete (app, policy, iters) session over v1 and return the
/// result report — the v1 half of the parity check.
pub fn run_v1_session(
    socket: &Path,
    app: &str,
    policy: PolicySpec,
    iters: Option<u64>,
) -> anyhow::Result<SessionReport> {
    let mut c = GpoeoClient::connect(socket)?;
    let id = c.begin(app, iters, None, Some(policy))?;
    c.end(&id)
}

/// Run one complete (app, policy, iters) session over the legacy
/// protocol — the compat half of the parity check. The policy crosses as
/// a bare name, so only default-config policies are expressible.
pub fn run_legacy_session(
    socket: &Path,
    app: &str,
    policy_name: &str,
    iters: Option<u64>,
) -> anyhow::Result<SessionReport> {
    let mut c = LegacyClient::connect(socket)?;
    c.set_policy(policy_name)?;
    c.begin(app, iters)?;
    let r = c.end()?;
    c.quit();
    Ok(r)
}

/// Parity check: run the same (app, policy-name, iters) through both
/// protocols and compare at legacy `RESULT` precision. Returns the two
/// keys; `Err` when they differ.
pub fn check_parity(
    socket: &Path,
    app: &str,
    policy_name: &str,
    iters: Option<u64>,
) -> anyhow::Result<(String, String)> {
    let v1 = run_v1_session(socket, app, PolicySpec::registered(policy_name), iters)?;
    let legacy = run_legacy_session(socket, app, policy_name, iters)?;
    let (kv, kl) = (result_parity_key(&v1), result_parity_key(&legacy));
    if kv != kl {
        anyhow::bail!(
            "protocol parity violated for ({app}, {policy_name}): v1 [{kv}] != legacy [{kl}]"
        );
    }
    Ok((kv, kl))
}
