//! `gpoeo ctl` — command-line driver for the control-plane API.
//!
//! Every verb is a thin wrapper over [`GpoeoClient`]; no protocol
//! strings appear here. Sessions live in the daemon's global table, so
//! `ctl begin` from one invocation and `ctl status`/`ctl end` from later
//! ones address the same session by id.
//!
//! ```text
//! gpoeo ctl apps|policies           introspection listings
//! gpoeo ctl begin --app A [--iters N] [--name S] [--policy P ...]
//! gpoeo ctl status|end|abort --session ID
//! gpoeo ctl watch --session ID [--every-ticks N] [--max-events N]
//! gpoeo ctl watch --replay FILE     replay a session journal offline
//! gpoeo ctl run --app A [...]       begin + watch + end in one call
//! gpoeo ctl parity --app A [...]    v1-vs-legacy RESULT parity check
//! gpoeo ctl metrics                 Prometheus text exposition scrape
//! gpoeo ctl shutdown                stop the daemon, remove the socket
//! ```
//!
//! All verbs take `--socket PATH` (default `/tmp/gpoeo.sock`).

use super::client::{check_parity, ApiError, GpoeoClient};
use super::protocol::SessionReport;
use crate::policy::{PolicyConfig, PolicySpec};
use crate::telemetry::{read_journal, TelemetryEvent};
use crate::util::cli::Args;
use crate::util::table::{s, Cell, Table};
use std::path::{Path, PathBuf};

pub fn cli_ctl(args: &Args) -> anyhow::Result<()> {
    let socket = PathBuf::from(args.opt_or("socket", "/tmp/gpoeo.sock"));
    let verb = args.positional.first().map(|v| v.as_str()).unwrap_or("");
    let r = match verb {
        "apps" => cmd_apps(&socket, args),
        "policies" => cmd_policies(&socket, args),
        "begin" => cmd_begin(&socket, args),
        "status" => cmd_status(&socket, args),
        "end" => cmd_end(&socket, args),
        "abort" => cmd_abort(&socket, args),
        "watch" => cmd_watch(&socket, args),
        "run" => cmd_run(&socket, args),
        "parity" => cmd_parity(&socket, args),
        "metrics" => cmd_metrics(&socket),
        "shutdown" => cmd_shutdown(&socket),
        "" => anyhow::bail!(
            "ctl requires a verb: apps policies begin status end abort watch run parity metrics \
             shutdown"
        ),
        other => anyhow::bail!("unknown ctl verb '{other}'; see `gpoeo --help`"),
    };
    // Typed refusals get actionable advice; the daemon answered, so
    // this is client pacing, not a broken control plane.
    match r {
        Err(e) if is_rate_limited(&e) => {
            Err(e.context("the daemon rate-limited this connection; slow down and retry"))
        }
        r => r,
    }
}

/// Does this error chain bottom out in a `rate_limited` refusal from
/// the daemon (ADR-009)? The typed kind survives the client's error
/// mapping precisely so this check never matches message strings.
fn is_rate_limited(e: &anyhow::Error) -> bool {
    e.chain()
        .filter_map(|c| c.downcast_ref::<ApiError>())
        .any(|a| a.kind == "rate_limited")
}

/// Options `ctl` itself consumes (transport/addressing/objective) —
/// everything else is a policy knob and goes on the wire. Without this
/// filter, `--socket`/`--app`/... would leak into the policy config's
/// `opts` (harmless to today's builders, but client-local noise in the
/// protocol).
const CTL_OPTS: &[&str] = &[
    "socket",
    "app",
    "iters",
    "name",
    "session",
    "every-ticks",
    "max-events",
    "replay",
    "policy",
    "format",
    "objective",
    "slowdown-cap",
];

/// The `--policy NAME` + forwarded policy options of this invocation,
/// when a policy was named (absent: the daemon's per-connection
/// default).
fn policy_from_args(args: &Args) -> anyhow::Result<Option<PolicySpec>> {
    match args.opt("policy") {
        None => Ok(None),
        Some(name) => {
            let mut cfg = PolicyConfig::from_args(args)?;
            cfg.opts.retain(|k, _| !CTL_OPTS.contains(&k.as_str()));
            Ok(Some(PolicySpec::new(name, cfg)))
        }
    }
}

/// `--iters N`: absent means the app's default workload size; an
/// explicit 0 is rejected here, exactly like both wire protocols do —
/// never silently substituted.
fn iters_from_args(args: &Args) -> anyhow::Result<Option<u64>> {
    match args.opt("iters") {
        None => Ok(None),
        Some(_) => match args.opt_u64("iters", 0)? {
            0 => anyhow::bail!("--iters must be a positive integer"),
            n => Ok(Some(n)),
        },
    }
}

fn req_session(args: &Args) -> anyhow::Result<String> {
    args.opt("session")
        .map(|v| v.to_string())
        .ok_or_else(|| anyhow::anyhow!("this verb requires --session ID (from `ctl begin`)"))
}

fn print_report(prefix: &str, r: &SessionReport) {
    println!(
        "{prefix} iter {}/{}  time {:.3} s  energy {:.1} J  sm gear {}  mem gear {}{}",
        r.iterations,
        r.target_iters,
        r.time_s,
        r.energy_j,
        r.sm_gear,
        r.mem_gear,
        if r.done { "  [done]" } else { "" }
    );
}

fn cmd_apps(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let apps = GpoeoClient::connect(socket)?.list_apps()?;
    let mut t = Table::new(
        "Applications served by the daemon (ctl begin --app NAME)",
        &["app", "suite", "archetype", "aperiodic", "default iters"],
    );
    for a in &apps {
        t.rowf(&[
            s(&a.name),
            s(&a.suite),
            s(&a.archetype),
            s(if a.aperiodic { "yes" } else { "" }),
            Cell::U(a.default_iters as usize),
        ]);
    }
    crate::cli::print_table(&t, args);
    Ok(())
}

fn cmd_policies(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let ps = GpoeoClient::connect(socket)?.list_policies()?;
    let mut t = Table::new(
        "Policies served by the daemon (ctl begin --policy NAME)",
        &["name", "description", "default config"],
    );
    for p in &ps {
        t.rowf(&[s(&p.name), s(&p.description), s(&p.default_config)]);
    }
    crate::cli::print_table(&t, args);
    Ok(())
}

fn cmd_begin(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let app = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("begin requires --app NAME (see `ctl apps`)"))?;
    let iters = iters_from_args(args)?;
    let mut c = GpoeoClient::connect(socket)?;
    let id = c.begin(app, iters, args.opt("name"), policy_from_args(args)?)?;
    // The session survives this connection: it lives in the daemon's
    // session table until `ctl end`/`ctl abort`.
    println!("{id}");
    Ok(())
}

fn cmd_status(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let id = req_session(args)?;
    let r = GpoeoClient::connect(socket)?.status(&id)?;
    print_report(&format!("session {id}:"), &r);
    Ok(())
}

fn cmd_end(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let id = req_session(args)?;
    let r = GpoeoClient::connect(socket)?.end(&id)?;
    print_report(&format!("session {id} result:"), &r);
    Ok(())
}

fn cmd_abort(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let id = req_session(args)?;
    GpoeoClient::connect(socket)?.abort(&id)?;
    println!("session {id} aborted");
    Ok(())
}

fn cmd_watch(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.opt("replay") {
        return cmd_replay(Path::new(path));
    }
    let id = req_session(args)?;
    let every = args.opt_u64("every-ticks", 200)?;
    let max = args.opt_u64("max-events", 0)?;
    let fin = GpoeoClient::connect(socket)?.subscribe(&id, every, max, |r| {
        print_report(&format!("[{id}]"), r);
    });
    // Always say *why* the stream stopped: scripts and humans both need
    // to distinguish a clean finish from a daemon that went away.
    match fin {
        Ok(fin) => {
            print_report(&format!("session {id} now:"), &fin);
            if fin.done {
                println!("stream ended: session completed");
            } else {
                println!("stream ended: event budget reached");
            }
            Ok(())
        }
        Err(e) if format!("{e:#}").contains("server closed the connection") => {
            println!("stream ended: connection lost");
            Err(e)
        }
        Err(e) => {
            println!("stream ended: aborted: {e:#}");
            Err(e)
        }
    }
}

/// Offline journal replay: render a session journal (DESIGN.md §11)
/// without a daemon. Strict — [`read_journal`] rejects the first
/// malformed or schema-violating line with its line number, which makes
/// this verb double as CI's journal validator.
fn cmd_replay(path: &Path) -> anyhow::Result<()> {
    let events = read_journal(path)?;
    for ev in &events {
        print_event(ev);
    }
    println!("replayed {} events from {}", events.len(), path.display());
    Ok(())
}

fn print_event(ev: &TelemetryEvent) {
    match ev {
        TelemetryEvent::Begin {
            session,
            app,
            policy,
            target_iters,
        } => println!("[{session}] begin  app {app}  policy {policy}  target {target_iters} iters"),
        TelemetryEvent::Tick {
            session,
            iterations,
            time_s,
            energy_j,
            sm_gear,
            mem_gear,
            done,
        } => println!(
            "[{session}] tick   iter {iterations}  time {time_s:.3} s  energy {energy_j:.1} J  \
             sm gear {sm_gear}  mem gear {mem_gear}{}",
            if *done { "  [done]" } else { "" }
        ),
        TelemetryEvent::Detect {
            session,
            period_s,
            aperiodic,
            round,
        } => println!(
            "[{session}] detect round {round}: {}",
            if *aperiodic {
                "aperiodic".to_string()
            } else {
                format!("period {period_s:.4} s")
            }
        ),
        TelemetryEvent::GearSwitch {
            session,
            policy,
            sm_gear,
            mem_gear,
            time_s,
        } => println!(
            "[{session}] gear   sm {sm_gear}  mem {mem_gear}  by {policy}  at {time_s:.3} s"
        ),
        TelemetryEvent::End {
            session,
            iterations,
            time_s,
            energy_j,
            done,
        } => println!(
            "[{session}] end    iter {iterations}  time {time_s:.3} s  energy {energy_j:.1} J{}",
            if *done { "  [done]" } else { "  [aborted]" }
        ),
    }
}

/// Scrape the daemon's metrics registry as Prometheus text exposition
/// (DESIGN.md §11). Rendering happens off the reactor thread.
fn cmd_metrics(socket: &std::path::Path) -> anyhow::Result<()> {
    print!("{}", GpoeoClient::connect(socket)?.metrics()?);
    Ok(())
}

/// begin + watch + end over one connection — the one-shot session
/// driver (and the CI round-trip smoke).
fn cmd_run(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let app = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("run requires --app NAME (see `ctl apps`)"))?;
    let iters = iters_from_args(args)?;
    let every = args.opt_u64("every-ticks", 2000)?;
    let mut c = GpoeoClient::connect(socket)?;
    let id = c.begin(app, iters, args.opt("name"), policy_from_args(args)?)?;
    c.subscribe(&id, every, 0, |r| print_report(&format!("[{id}]"), r))?;
    let r = c.end(&id)?;
    print_report(&format!("session {id} result:"), &r);
    Ok(())
}

/// Drive the same (app, policy, iters) through protocol v1 and the
/// legacy line protocol and require bit-identical RESULT numbers (at
/// legacy print precision). Exits non-zero on divergence — the CI gate
/// for the legacy-compat guarantee.
fn cmd_parity(socket: &std::path::Path, args: &Args) -> anyhow::Result<()> {
    let app = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("parity requires --app NAME"))?;
    let policy = args.opt_or("policy", "gpoeo");
    let iters = iters_from_args(args)?;
    let (key, _) = check_parity(socket, app, policy, iters)?;
    println!("parity OK for ({app}, {policy}): RESULT {key} via both protocols");
    Ok(())
}

fn cmd_shutdown(socket: &std::path::Path) -> anyhow::Result<()> {
    GpoeoClient::connect(socket)?.shutdown()?;
    println!("daemon shutting down ({})", socket.display());
    Ok(())
}
