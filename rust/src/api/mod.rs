//! The control-plane API layer (DESIGN.md §9).
//!
//! Protocol v1 is the daemon's outward face: typed [`Request`] /
//! [`Response`] / [`Event`] enums with line-delimited JSON framing and a
//! `hello` version handshake, served alongside the legacy
//! whitespace-token protocol behind a first-byte auto-detect (`{` → v1).
//! Three pieces live here, and *all* protocol strings with them:
//!
//! - [`protocol`] — the message types, their wire codec, the framing
//!   reader and the [`PROTOCOL_VERSION`] constant (defined once, here).
//! - [`client`] — [`GpoeoClient`], the client library every consumer
//!   (CLI `ctl`, tests, CI smoke) uses, plus [`LegacyClient`] compat
//!   mode and the v1-vs-legacy parity check.
//! - [`ctl`] — the `gpoeo ctl` subcommands built on [`GpoeoClient`].
//!
//! The daemon side of the protocol lives in
//! [`crate::coordinator::daemon`], which imports these types.

pub mod client;
pub mod ctl;
pub mod protocol;

pub use client::{
    check_parity, run_legacy_session, run_v1_session, ApiError, GpoeoClient, LegacyClient,
};
pub use ctl::cli_ctl;
pub use protocol::{
    negotiate_hello, read_frame, result_parity_key, validate_session_name, AppInfo, Event, Frame,
    PolicyInfo, Request, Response, ServerMsg, SessionReport, MAX_LINE_BYTES, MAX_REPLY_BYTES,
    PROTOCOL_VERSION,
};
