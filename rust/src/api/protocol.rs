//! Control-plane protocol v1: the typed request/response/event enums and
//! their line-delimited JSON wire codec (DESIGN.md §9).
//!
//! Every message is one JSON object on one `\n`-terminated line. The
//! first byte of a v1 connection is therefore always `{` — which is how
//! the daemon tells v1 apart from the legacy whitespace-token protocol.
//! Requests carry a `"kind"` discriminator; server messages are either a
//! [`Response`] (exactly one per request) or an [`Event`]
//! (`"kind": "event"`, emitted only inside a `subscribe` stream).
//!
//! Decoding is strict: unknown request kinds, unknown fields and
//! ill-typed values all produce an error *message* (which the daemon
//! answers as [`Response::Error`]) — never a panic, never a dropped
//! connection. This module is the single place protocol strings live;
//! everything else (daemon, [`GpoeoClient`](crate::api::GpoeoClient),
//! `gpoeo ctl`, tests) goes through these types.

use crate::policy::PolicySpec;
use crate::util::json::Json;
use std::io::BufRead;

/// The protocol version this build speaks — the one `hello` negotiates
/// and the only place the constant is defined.
pub const PROTOCOL_VERSION: u64 = 1;

/// Negotiate a client hello against this server's protocol version.
/// Returns the reply to send either way — `Ok` on acceptance, `Err`
/// with the typed rejection — so transports (the reactor) never
/// compare version numbers themselves (§9).
pub fn negotiate_hello(version: u64, server: String) -> Result<Response, Response> {
    if version == 0 || version > PROTOCOL_VERSION {
        Err(Response::error(format!(
            "unsupported protocol version {version} (this server speaks v{PROTOCOL_VERSION})"
        )))
    } else {
        Ok(Response::Hello {
            protocol: PROTOCOL_VERSION,
            server,
        })
    }
}

/// Hard cap on one request line. Longer lines are drained and answered
/// with a typed error instead of buffering without bound.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Server replies may carry whole listings (71 apps); give clients a
/// roomier cap than the request direction.
pub const MAX_REPLY_BYTES: usize = 1024 * 1024;

/// A client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Version handshake; must be the first request on a connection.
    Hello { version: u64 },
    /// Start a session. `iters: None` means the app's default workload
    /// size ([`default_iters`](crate::coordinator::default_iters) — the
    /// same default `gpoeo run` uses). `name` proposes a session id
    /// (server-generated when absent); `policy` overrides the
    /// connection's default policy for this session only.
    Begin {
        app: String,
        iters: Option<u64>,
        name: Option<String>,
        policy: Option<PolicySpec>,
    },
    /// Drive a slice of the session and report telemetry.
    Status { session: String },
    /// Drive the session to its iteration target and return the result.
    End { session: String },
    /// Abandon the session without driving it to completion.
    Abort { session: String },
    /// Set the connection's default policy for subsequent `begin`s.
    SetPolicy { policy: PolicySpec },
    ListApps,
    ListPolicies,
    /// Stream `Event::Status` telemetry while driving the session:
    /// one event per `every_ticks` controller ticks, until the session
    /// reaches its target (or `max_events` events, when non-zero), then
    /// a final `Response::Status` snapshot ends the stream.
    Subscribe {
        session: String,
        every_ticks: u64,
        max_events: u64,
    },
    /// Fetch the daemon's metrics registry rendered in Prometheus text
    /// exposition format (DESIGN.md §11).
    Metrics,
    /// Stop the daemon: the listener exits and removes its socket file.
    Shutdown,
}

/// Telemetry snapshot of one session, used by `status`, `end` results
/// and subscription events.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReport {
    pub session: String,
    pub iterations: u64,
    /// The session's iteration target (0 when unknown — e.g. reports
    /// parsed from the legacy protocol, which does not carry it).
    pub target_iters: u64,
    pub time_s: f64,
    pub energy_j: f64,
    pub sm_gear: usize,
    pub mem_gear: usize,
    pub done: bool,
}

/// One row of `list_apps`.
#[derive(Debug, Clone, PartialEq)]
pub struct AppInfo {
    pub name: String,
    pub suite: String,
    pub archetype: String,
    pub aperiodic: bool,
    /// The iteration count a `begin` without `iters` would run.
    pub default_iters: u64,
}

/// One row of `list_policies` (straight from the
/// [`PolicyRegistry`](crate::policy::PolicyRegistry) metadata).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyInfo {
    pub name: String,
    pub description: String,
    pub default_config: String,
}

/// A server → client answer (exactly one per request).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Hello { protocol: u64, server: String },
    Ok { detail: String },
    Begun { session: String },
    Status(SessionReport),
    Result(SessionReport),
    Apps(Vec<AppInfo>),
    Policies(Vec<PolicyInfo>),
    /// Prometheus text exposition of the daemon's metrics registry.
    Metrics { text: String },
    Error {
        message: String,
        /// Machine-readable error category (e.g. `"rate_limited"`),
        /// empty for plain errors. On the wire as `error_kind` (the
        /// `kind` field is the message discriminator), omitted when
        /// empty so pre-existing payloads are byte-identical.
        kind: String,
    },
}

/// A server → client push, emitted only inside a `subscribe` stream.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Status(SessionReport),
}

/// Any server → client line: a response or an event.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMsg {
    Response(Response),
    Event(Event),
}

impl Request {
    /// Parse one wire line. The error string is what the daemon sends
    /// back as `Response::Error` — keep it actionable.
    pub fn parse_line(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| format!("bad request json: {e}"))?;
        Request::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "request must be a json object".to_string())?;
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| "request needs a string 'kind' field".to_string())?;
        let allow = |keys: &[&str]| -> Result<(), String> {
            for k in obj.keys() {
                if k != "kind" && !keys.contains(&k.as_str()) {
                    return Err(format!("unknown field '{k}' for request kind '{kind}'"));
                }
            }
            Ok(())
        };
        match kind {
            "hello" => {
                allow(&["v"])?;
                let version = j
                    .get("v")
                    .as_u64()
                    .ok_or_else(|| "hello needs an integer 'v' version field".to_string())?;
                Ok(Request::Hello { version })
            }
            "begin" => {
                allow(&["app", "iters", "name", "policy"])?;
                let app = j
                    .get("app")
                    .as_str()
                    .ok_or_else(|| "begin needs a string 'app' field".to_string())?
                    .to_string();
                let iters = match j.get("iters") {
                    Json::Null => None,
                    v => Some(
                        v.as_u64()
                            .filter(|&n| n > 0)
                            .ok_or_else(|| "'iters' must be a positive integer".to_string())?,
                    ),
                };
                let name = match j.get("name") {
                    Json::Null => None,
                    v => {
                        let s = v
                            .as_str()
                            .ok_or_else(|| "'name' must be a string".to_string())?;
                        validate_session_name(s)?;
                        Some(s.to_string())
                    }
                };
                let policy = match j.get("policy") {
                    Json::Null => None,
                    p => Some(PolicySpec::from_json(p).map_err(|e| format!("{e:#}"))?),
                };
                Ok(Request::Begin {
                    app,
                    iters,
                    name,
                    policy,
                })
            }
            "status" | "end" | "abort" => {
                allow(&["session"])?;
                let session = req_session(j)?;
                Ok(match kind {
                    "status" => Request::Status { session },
                    "end" => Request::End { session },
                    _ => Request::Abort { session },
                })
            }
            "set_policy" => {
                allow(&["policy"])?;
                match j.get("policy") {
                    Json::Null => Err("set_policy needs a 'policy' field".to_string()),
                    p => Ok(Request::SetPolicy {
                        policy: PolicySpec::from_json(p).map_err(|e| format!("{e:#}"))?,
                    }),
                }
            }
            "list_apps" => {
                allow(&[])?;
                Ok(Request::ListApps)
            }
            "list_policies" => {
                allow(&[])?;
                Ok(Request::ListPolicies)
            }
            "subscribe" => {
                allow(&["session", "every_ticks", "max_events"])?;
                let session = req_session(j)?;
                let every_ticks = match j.get("every_ticks") {
                    Json::Null => 200,
                    v => v
                        .as_u64()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| "'every_ticks' must be a positive integer".to_string())?,
                };
                let max_events = match j.get("max_events") {
                    Json::Null => 0,
                    v => v
                        .as_u64()
                        .ok_or_else(|| "'max_events' must be a non-negative integer".to_string())?,
                };
                Ok(Request::Subscribe {
                    session,
                    every_ticks,
                    max_events,
                })
            }
            "metrics" => {
                allow(&[])?;
                Ok(Request::Metrics)
            }
            "shutdown" => {
                allow(&[])?;
                Ok(Request::Shutdown)
            }
            other => Err(format!(
                "unknown request kind '{other}' (hello begin status end abort set_policy \
                 list_apps list_policies subscribe metrics shutdown)"
            )),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Request::Hello { version } => Json::obj(vec![
                ("kind", Json::Str("hello".into())),
                ("v", Json::Num(*version as f64)),
            ]),
            Request::Begin {
                app,
                iters,
                name,
                policy,
            } => {
                let mut f = vec![
                    ("kind", Json::Str("begin".into())),
                    ("app", Json::Str(app.clone())),
                ];
                if let Some(n) = iters {
                    f.push(("iters", Json::Num(*n as f64)));
                }
                if let Some(n) = name {
                    f.push(("name", Json::Str(n.clone())));
                }
                if let Some(p) = policy {
                    f.push(("policy", p.to_json()));
                }
                Json::obj(f)
            }
            Request::Status { session } => kind_session("status", session),
            Request::End { session } => kind_session("end", session),
            Request::Abort { session } => kind_session("abort", session),
            Request::SetPolicy { policy } => Json::obj(vec![
                ("kind", Json::Str("set_policy".into())),
                ("policy", policy.to_json()),
            ]),
            Request::ListApps => Json::obj(vec![("kind", Json::Str("list_apps".into()))]),
            Request::ListPolicies => Json::obj(vec![("kind", Json::Str("list_policies".into()))]),
            Request::Subscribe {
                session,
                every_ticks,
                max_events,
            } => Json::obj(vec![
                ("kind", Json::Str("subscribe".into())),
                ("session", Json::Str(session.clone())),
                ("every_ticks", Json::Num(*every_ticks as f64)),
                ("max_events", Json::Num(*max_events as f64)),
            ]),
            Request::Metrics => Json::obj(vec![("kind", Json::Str("metrics".into()))]),
            Request::Shutdown => Json::obj(vec![("kind", Json::Str("shutdown".into()))]),
        }
    }
}

fn kind_session(kind: &str, session: &str) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(kind.to_string())),
        ("session", Json::Str(session.to_string())),
    ])
}

fn req_session(j: &Json) -> Result<String, String> {
    j.get("session")
        .as_str()
        .ok_or_else(|| "missing string 'session' field".to_string())
        .map(|s| s.to_string())
}

/// Session names share an id space with server-generated `s<N>` ids;
/// keep them short, printable and shell-friendly.
pub fn validate_session_name(s: &str) -> Result<(), String> {
    let ok = !s.is_empty()
        && s.len() <= 64
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        Err(format!(
            "invalid session name '{s}' (1-64 chars from [A-Za-z0-9._-])"
        ))
    }
}

impl Response {
    /// Short discriminator, for "unexpected reply" diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            Response::Hello { .. } => "hello",
            Response::Ok { .. } => "ok",
            Response::Begun { .. } => "begun",
            Response::Status(_) => "status",
            Response::Result(_) => "result",
            Response::Apps(_) => "apps",
            Response::Policies(_) => "policies",
            Response::Metrics { .. } => "metrics",
            Response::Error { .. } => "error",
        }
    }

    pub fn error(message: impl Into<String>) -> Response {
        Response::Error {
            message: message.into(),
            kind: String::new(),
        }
    }

    /// A typed over-limit answer (ninelives ADR-009): the client can
    /// match on `error_kind == "rate_limited"` and back off instead of
    /// string-matching the message.
    pub fn rate_limited(message: impl Into<String>) -> Response {
        Response::Error {
            message: message.into(),
            kind: "rate_limited".to_string(),
        }
    }

    /// The answer for any request arriving before a successful hello.
    /// Lives here (not in the reactor) so version numbers and wire
    /// hints never leave the protocol layer (§9).
    pub fn handshake_required() -> Response {
        Response::error(format!(
            "handshake required: send {{\"kind\":\"hello\",\"v\":{PROTOCOL_VERSION}}} first"
        ))
    }

    pub fn to_json(&self) -> Json {
        match self {
            Response::Hello { protocol, server } => Json::obj(vec![
                ("kind", Json::Str("hello".into())),
                ("protocol", Json::Num(*protocol as f64)),
                ("server", Json::Str(server.clone())),
            ]),
            Response::Ok { detail } => Json::obj(vec![
                ("kind", Json::Str("ok".into())),
                ("detail", Json::Str(detail.clone())),
            ]),
            Response::Begun { session } => Json::obj(vec![
                ("kind", Json::Str("begun".into())),
                ("session", Json::Str(session.clone())),
            ]),
            Response::Status(r) => report_json("status", r),
            Response::Result(r) => report_json("result", r),
            Response::Apps(apps) => Json::obj(vec![
                ("kind", Json::Str("apps".into())),
                (
                    "apps",
                    Json::Arr(
                        apps.iter()
                            .map(|a| {
                                Json::obj(vec![
                                    ("name", Json::Str(a.name.clone())),
                                    ("suite", Json::Str(a.suite.clone())),
                                    ("archetype", Json::Str(a.archetype.clone())),
                                    ("aperiodic", Json::Bool(a.aperiodic)),
                                    ("default_iters", Json::Num(a.default_iters as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Policies(ps) => Json::obj(vec![
                ("kind", Json::Str("policies".into())),
                (
                    "policies",
                    Json::Arr(
                        ps.iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("name", Json::Str(p.name.clone())),
                                    ("description", Json::Str(p.description.clone())),
                                    ("default_config", Json::Str(p.default_config.clone())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Metrics { text } => Json::obj(vec![
                ("kind", Json::Str("metrics".into())),
                ("text", Json::Str(text.clone())),
            ]),
            Response::Error { message, kind } => {
                let mut fields = vec![
                    ("kind", Json::Str("error".into())),
                    ("message", Json::Str(message.clone())),
                ];
                if !kind.is_empty() {
                    fields.push(("error_kind", Json::Str(kind.clone())));
                }
                Json::obj(fields)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Response, String> {
        let kind = j
            .get("kind")
            .as_str()
            .ok_or_else(|| "server message needs a string 'kind' field".to_string())?;
        let bad = |what: &str| format!("malformed '{kind}' reply: {what}");
        match kind {
            "hello" => Ok(Response::Hello {
                protocol: j
                    .get("protocol")
                    .as_u64()
                    .ok_or_else(|| bad("missing 'protocol'"))?,
                server: j
                    .get("server")
                    .as_str()
                    .ok_or_else(|| bad("missing 'server'"))?
                    .to_string(),
            }),
            "ok" => Ok(Response::Ok {
                detail: j.get("detail").as_str().unwrap_or("").to_string(),
            }),
            "begun" => Ok(Response::Begun {
                session: j
                    .get("session")
                    .as_str()
                    .ok_or_else(|| bad("missing 'session'"))?
                    .to_string(),
            }),
            "status" => Ok(Response::Status(report_from_json(j)?)),
            "result" => Ok(Response::Result(report_from_json(j)?)),
            "apps" => {
                let arr = j.get("apps").as_arr().ok_or_else(|| bad("missing 'apps'"))?;
                let apps = arr
                    .iter()
                    .map(|a| -> Result<AppInfo, String> {
                        Ok(AppInfo {
                            name: req_str(a, "name")?,
                            suite: req_str(a, "suite")?,
                            archetype: req_str(a, "archetype")?,
                            aperiodic: a
                                .get("aperiodic")
                                .as_bool()
                                .ok_or_else(|| "missing 'aperiodic'".to_string())?,
                            default_iters: a
                                .get("default_iters")
                                .as_u64()
                                .ok_or_else(|| "missing 'default_iters'".to_string())?,
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()
                    .map_err(|e| bad(&e))?;
                Ok(Response::Apps(apps))
            }
            "policies" => {
                let arr = j
                    .get("policies")
                    .as_arr()
                    .ok_or_else(|| bad("missing 'policies'"))?;
                let ps = arr
                    .iter()
                    .map(|p| -> Result<PolicyInfo, String> {
                        Ok(PolicyInfo {
                            name: req_str(p, "name")?,
                            description: req_str(p, "description")?,
                            default_config: req_str(p, "default_config")?,
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()
                    .map_err(|e| bad(&e))?;
                Ok(Response::Policies(ps))
            }
            "metrics" => Ok(Response::Metrics {
                text: j
                    .get("text")
                    .as_str()
                    .ok_or_else(|| bad("missing 'text'"))?
                    .to_string(),
            }),
            "error" => Ok(Response::Error {
                message: j
                    .get("message")
                    .as_str()
                    .ok_or_else(|| bad("missing 'message'"))?
                    .to_string(),
                kind: j.get("error_kind").as_str().unwrap_or("").to_string(),
            }),
            other => Err(format!("unknown server reply kind '{other}'")),
        }
    }
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| format!("missing '{key}'"))
}

fn report_json(kind: &str, r: &SessionReport) -> Json {
    Json::obj(vec![
        ("kind", Json::Str(kind.to_string())),
        ("session", Json::Str(r.session.clone())),
        ("iterations", Json::Num(r.iterations as f64)),
        ("target_iters", Json::Num(r.target_iters as f64)),
        ("time_s", Json::Num(r.time_s)),
        ("energy_j", Json::Num(r.energy_j)),
        ("sm_gear", Json::Num(r.sm_gear as f64)),
        ("mem_gear", Json::Num(r.mem_gear as f64)),
        ("done", Json::Bool(r.done)),
    ])
}

fn report_from_json(j: &Json) -> Result<SessionReport, String> {
    let num = |key: &str| -> Result<u64, String> {
        j.get(key)
            .as_u64()
            .ok_or_else(|| format!("malformed report: missing '{key}'"))
    };
    let f = |key: &str| -> Result<f64, String> {
        j.get(key)
            .as_f64()
            .ok_or_else(|| format!("malformed report: missing '{key}'"))
    };
    Ok(SessionReport {
        session: j.get("session").as_str().unwrap_or("").to_string(),
        iterations: num("iterations")?,
        target_iters: num("target_iters")?,
        time_s: f("time_s")?,
        energy_j: f("energy_j")?,
        sm_gear: num("sm_gear")? as usize,
        mem_gear: num("mem_gear")? as usize,
        done: j
            .get("done")
            .as_bool()
            .ok_or_else(|| "malformed report: missing 'done'".to_string())?,
    })
}

impl Event {
    pub fn to_json(&self) -> Json {
        match self {
            Event::Status(r) => {
                let mut j = report_json("event", r);
                if let Json::Obj(o) = &mut j {
                    o.insert("event".to_string(), Json::Str("status".into()));
                }
                j
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Event, String> {
        match j.get("event").as_str() {
            Some("status") => Ok(Event::Status(report_from_json(j)?)),
            Some(other) => Err(format!("unknown event '{other}'")),
            None => Err("event message needs a string 'event' field".to_string()),
        }
    }
}

impl ServerMsg {
    pub fn parse_line(line: &str) -> Result<ServerMsg, String> {
        let j = Json::parse(line).map_err(|e| format!("bad server json: {e}"))?;
        if j.get("kind").as_str() == Some("event") {
            Event::from_json(&j).map(ServerMsg::Event)
        } else {
            Response::from_json(&j).map(ServerMsg::Response)
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ServerMsg::Response(r) => r.to_json(),
            ServerMsg::Event(e) => e.to_json(),
        }
    }

    /// Serialize as one wire line (newline included).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string();
        s.push('\n');
        s
    }
}

/// The legacy `RESULT`/comparison key: the five numbers of the legacy
/// `RESULT` line at exactly its print precision. Two reports with equal
/// keys produced the same result as far as the legacy protocol can
/// express — the parity contract between v1 and legacy sessions.
pub fn result_parity_key(r: &SessionReport) -> String {
    format!(
        "{:.1} {:.3} {} {} {}",
        r.energy_j, r.time_s, r.iterations, r.sm_gear, r.mem_gear
    )
}

/// One framed line read: the payload, or the reasons there isn't one.
#[derive(Debug, PartialEq)]
pub enum Frame {
    Line(String),
    /// The line exceeded the byte cap; it has been drained through the
    /// trailing newline so the connection can keep going.
    Oversized,
    Eof,
}

/// Read one `\n`-terminated line of at most `max` bytes (newline
/// excluded). Never allocates beyond `max`; an over-long line is drained
/// to its newline and reported as [`Frame::Oversized`] so the caller can
/// answer a typed error and continue the connection.
pub fn read_frame(reader: &mut impl BufRead, max: usize) -> std::io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            return Ok(if buf.is_empty() {
                Frame::Eof
            } else {
                Frame::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if buf.len() + i > max {
                    reader.consume(i + 1);
                    return Ok(Frame::Oversized);
                }
                buf.extend_from_slice(&chunk[..i]);
                reader.consume(i + 1);
                return Ok(Frame::Line(String::from_utf8_lossy(&buf).into_owned()));
            }
            None => {
                let len = chunk.len();
                if buf.len() + len > max {
                    reader.consume(len);
                    drain_to_newline(reader)?;
                    return Ok(Frame::Oversized);
                }
                buf.extend_from_slice(chunk);
                reader.consume(len);
            }
        }
    }
}

fn drain_to_newline(reader: &mut impl BufRead) -> std::io::Result<()> {
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            return Ok(());
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                reader.consume(i + 1);
                return Ok(());
            }
            None => {
                let len = chunk.len();
                reader.consume(len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyConfig;
    use crate::search::Objective;

    fn sample_report() -> SessionReport {
        SessionReport {
            session: "s7".into(),
            iterations: 123,
            target_iters: 300,
            time_s: 45.675,
            energy_j: 10987.25,
            sm_gear: 92,
            mem_gear: 4,
            done: false,
        }
    }

    fn all_requests() -> Vec<Request> {
        let mut cfg = PolicyConfig::new(Objective::Ed2p);
        cfg.opts.insert("switch-cost".into(), "0.5".into());
        vec![
            Request::Hello {
                version: PROTOCOL_VERSION,
            },
            Request::Begin {
                app: "AI_TS".into(),
                iters: Some(40),
                name: Some("train-1".into()),
                policy: Some(PolicySpec::new("bandit", cfg)),
            },
            Request::Begin {
                app: "AI_FE".into(),
                iters: None,
                name: None,
                policy: None,
            },
            Request::Status {
                session: "s1".into(),
            },
            Request::End {
                session: "s1".into(),
            },
            Request::Abort {
                session: "train-1".into(),
            },
            Request::SetPolicy {
                policy: PolicySpec::registered("powercap"),
            },
            Request::ListApps,
            Request::ListPolicies,
            Request::Subscribe {
                session: "s1".into(),
                every_ticks: 100,
                max_events: 5,
            },
            Request::Metrics,
            Request::Shutdown,
        ]
    }

    #[test]
    fn requests_roundtrip_through_the_wire() {
        for req in all_requests() {
            let line = req.to_json().to_string();
            assert!(line.starts_with('{'), "v1 frames must start with '{{'");
            let back = Request::parse_line(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, req, "{line}");
        }
    }

    #[test]
    fn server_messages_roundtrip_through_the_wire() {
        let msgs = vec![
            ServerMsg::Response(Response::Hello {
                protocol: PROTOCOL_VERSION,
                server: "gpoeo 0.2.0".into(),
            }),
            ServerMsg::Response(Response::Ok {
                detail: "policy bandit".into(),
            }),
            ServerMsg::Response(Response::Begun {
                session: "s1".into(),
            }),
            ServerMsg::Response(Response::Status(sample_report())),
            ServerMsg::Response(Response::Result(SessionReport {
                done: true,
                ..sample_report()
            })),
            ServerMsg::Response(Response::Apps(vec![AppInfo {
                name: "AI_TS".into(),
                suite: "aibench".into(),
                archetype: "transformer".into(),
                aperiodic: false,
                default_iters: 300,
            }])),
            ServerMsg::Response(Response::Policies(vec![PolicyInfo {
                name: "bandit".into(),
                description: "switching-aware".into(),
                default_config: "switch-cost=0".into(),
            }])),
            ServerMsg::Response(Response::Metrics {
                text: "# HELP gpoeo_sessions_begun_total Sessions registered.\n\
                       # TYPE gpoeo_sessions_begun_total counter\n\
                       gpoeo_sessions_begun_total 3\n"
                    .into(),
            }),
            ServerMsg::Response(Response::error("no such session")),
            ServerMsg::Response(Response::rate_limited("rate limit exceeded (2 req/s)")),
            ServerMsg::Event(Event::Status(sample_report())),
        ];
        for msg in msgs {
            let line = msg.to_line();
            let back = ServerMsg::parse_line(line.trim_end()).unwrap();
            assert_eq!(back, msg, "{line}");
        }
    }

    #[test]
    fn error_kind_is_on_the_wire_only_when_set() {
        // Plain errors must serialize byte-identically to the pre-kind
        // wire format (old clients parse them untouched); typed errors
        // carry `error_kind` and survive the roundtrip.
        let plain = ServerMsg::Response(Response::error("boom")).to_line();
        assert!(!plain.contains("error_kind"), "{plain}");
        let typed = ServerMsg::Response(Response::rate_limited("slow down")).to_line();
        assert!(typed.contains("\"error_kind\""), "{typed}");
        match ServerMsg::parse_line(typed.trim_end()).unwrap() {
            ServerMsg::Response(Response::Error { kind, .. }) => {
                assert_eq!(kind, "rate_limited");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn float_telemetry_roundtrips_bit_exactly() {
        let r = SessionReport {
            time_s: 1.0 / 3.0,
            energy_j: 98765.432109876,
            ..sample_report()
        };
        let line = ServerMsg::Response(Response::Status(r.clone())).to_line();
        match ServerMsg::parse_line(line.trim_end()).unwrap() {
            ServerMsg::Response(Response::Status(back)) => {
                assert_eq!(back.time_s.to_bits(), r.time_s.to_bits());
                assert_eq!(back.energy_j.to_bits(), r.energy_j.to_bits());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_requests_answer_typed_errors_not_panics() {
        let cases: Vec<(String, &str)> = vec![
            // Truncated json (every prefix of a valid request, below).
            ("[1, 2]".into(), "must be a json object"),
            ("null".into(), "must be a json object"),
            ("{}".into(), "kind"),
            (
                Json::obj(vec![("kind", Json::Str("warp".into()))]).to_string(),
                "unknown request kind 'warp'",
            ),
            (
                Json::obj(vec![
                    ("kind", Json::Str("status".into())),
                    ("session", Json::Str("s1".into())),
                    ("color", Json::Str("red".into())),
                ])
                .to_string(),
                "unknown field 'color'",
            ),
            (
                Json::obj(vec![("kind", Json::Str("status".into()))]).to_string(),
                "session",
            ),
            (
                Json::obj(vec![("kind", Json::Str("hello".into()))]).to_string(),
                "'v'",
            ),
            (
                Json::obj(vec![
                    ("kind", Json::Str("begin".into())),
                    ("app", Json::Str("AI_TS".into())),
                    ("iters", Json::Num(0.0)),
                ])
                .to_string(),
                "'iters'",
            ),
            (
                Json::obj(vec![
                    ("kind", Json::Str("begin".into())),
                    ("app", Json::Str("AI_TS".into())),
                    ("iters", Json::Num(2.5)),
                ])
                .to_string(),
                "'iters'",
            ),
            (
                Json::obj(vec![
                    ("kind", Json::Str("begin".into())),
                    ("app", Json::Str("AI_TS".into())),
                    ("name", Json::Str("bad name!".into())),
                ])
                .to_string(),
                "invalid session name",
            ),
            (
                Json::obj(vec![
                    ("kind", Json::Str("subscribe".into())),
                    ("session", Json::Str("s1".into())),
                    ("every_ticks", Json::Num(0.0)),
                ])
                .to_string(),
                "every_ticks",
            ),
        ];
        for (line, want) in cases {
            let err = Request::parse_line(&line).unwrap_err();
            assert!(err.contains(want), "{line} -> {err}");
        }
    }

    #[test]
    fn every_truncation_of_a_valid_request_is_a_clean_error() {
        for req in all_requests() {
            let line = req.to_json().to_string();
            for cut in 0..line.len() {
                if !line.is_char_boundary(cut) {
                    continue;
                }
                // Must never panic; a prefix that still parses (e.g. cut
                // at the very end) is fine, anything else is Err.
                let _ = Request::parse_line(&line[..cut]);
            }
        }
    }

    #[test]
    fn session_name_validation() {
        for good in ["s1", "train-1", "a.b_c", "X"] {
            assert!(validate_session_name(good).is_ok(), "{good}");
        }
        let long = "x".repeat(65);
        for bad in ["", "has space", "semi;colon", "new\nline", long.as_str()] {
            assert!(validate_session_name(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn read_frame_caps_and_recovers() {
        use std::io::Cursor;
        let mut data = Vec::new();
        data.extend_from_slice(b"short line\n");
        data.extend_from_slice(&vec![b'x'; 200]);
        data.push(b'\n');
        data.extend_from_slice(b"after\n");
        data.extend_from_slice(b"no newline at eof");
        let mut r = std::io::BufReader::with_capacity(16, Cursor::new(data));
        assert_eq!(read_frame(&mut r, 100).unwrap(), Frame::Line("short line".into()));
        assert_eq!(read_frame(&mut r, 100).unwrap(), Frame::Oversized);
        assert_eq!(read_frame(&mut r, 100).unwrap(), Frame::Line("after".into()));
        assert_eq!(
            read_frame(&mut r, 100).unwrap(),
            Frame::Line("no newline at eof".into())
        );
        assert_eq!(read_frame(&mut r, 100).unwrap(), Frame::Eof);
    }

    #[test]
    fn read_frame_exact_boundary() {
        use std::io::Cursor;
        let line = "a".repeat(100);
        let mut data = line.clone().into_bytes();
        data.push(b'\n');
        let mut r = std::io::BufReader::with_capacity(8, Cursor::new(data.clone()));
        assert_eq!(read_frame(&mut r, 100).unwrap(), Frame::Line(line));
        let mut r = std::io::BufReader::with_capacity(8, Cursor::new(data));
        assert_eq!(read_frame(&mut r, 99).unwrap(), Frame::Oversized);
        assert_eq!(read_frame(&mut r, 99).unwrap(), Frame::Eof);
    }

    #[test]
    fn parity_key_matches_legacy_result_precision() {
        let r = SessionReport {
            energy_j: 10987.25,
            time_s: 45.675,
            iterations: 123,
            sm_gear: 92,
            mem_gear: 4,
            ..sample_report()
        };
        assert_eq!(result_parity_key(&r), "10987.2 45.675 123 92 4");
    }
}
