//! Fleet power-budget arbiter (DESIGN.md §14).
//!
//! A [`BudgetArbiter`] owns one global power budget (watts) for every
//! enrolled session and periodically re-allocates per-session power
//! caps. Sessions in throughput-insensitive phases — classified
//! aperiodic by the streaming detector, or whose smoothed iteration
//! rate has collapsed relative to their own peak — *donate* headroom;
//! latency-critical (periodic, training-rate) sessions receive it
//! through a water-filling loop bounded by per-session `[min, max]`
//! cap floors. A hysteresis band suppresses cap thrashing, and when no
//! session has any telemetry signal at all (detached telemetry plane)
//! the arbiter degrades to a fairness fallback: an equal split of the
//! budget.
//!
//! The arbiter is pure bookkeeping: it never touches a device and never
//! blocks. The reactor drives [`BudgetArbiter::tick`] from its poll
//! loop and applies the returned caps via `SessionHandle` dispatch so
//! worker-owned (non-`Send`) devices stay worker-side — see
//! DESIGN.md §14 and §8.
//!
//! Invariant (checked by `rust/tests/arbiter.rs` against journal
//! replay): the sum of caps in any emitted [`Reallocation`] never
//! exceeds the budget in force at that epoch — the budget invariant
//! outranks hysteresis.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::telemetry::Ewma;
use std::collections::BTreeMap;

/// Arbiter knobs, settable over the v1 wire via
/// `set_policy {name: "arbiter", config: {...}}` (DESIGN.md §10).
#[derive(Debug, Clone, PartialEq)]
pub struct ArbiterCfg {
    /// Global fleet power budget in watts.
    pub budget_w: f64,
    /// Re-allocation period in seconds (reactor wall clock).
    pub period_s: f64,
    /// Per-session cap floor: water-filling never starves a session
    /// below this (unless the budget itself cannot cover the floors).
    pub min_cap_w: f64,
    /// Per-session cap ceiling: water-filling saturates here.
    pub max_cap_w: f64,
    /// Hysteresis band: a proposed cap within this distance of the
    /// session's applied cap keeps the applied cap (no thrash).
    pub hysteresis_w: f64,
    /// EWMA smoothing factor for the per-session iteration rate.
    pub rate_alpha: f64,
    /// A session donates when its smoothed rate drops below
    /// `donor_ratio` × its own peak smoothed rate.
    pub donor_ratio: f64,
}

impl Default for ArbiterCfg {
    fn default() -> ArbiterCfg {
        ArbiterCfg {
            budget_w: 1000.0,
            period_s: 1.0,
            min_cap_w: 80.0,
            max_cap_w: 350.0,
            hysteresis_w: 10.0,
            rate_alpha: 0.3,
            donor_ratio: 0.5,
        }
    }
}

/// Per-session telemetry digest. Rates come from the PR 7 windowed
/// primitives ([`Ewma`]) over journal `Tick` events — never raw tick
/// counters — and the periodic/aperiodic verdict from the PR 3
/// streaming detector's `Detect` event.
#[derive(Debug)]
struct SessionState {
    rate: Ewma,
    peak_rate: f64,
    /// Streaming-verdict classification, once one arrived.
    aperiodic: Option<bool>,
    /// Last observed (iterations, time_s) pair, for rate deltas.
    last_obs: Option<(u64, f64)>,
    has_rate: bool,
    /// Cap currently applied to the session (None before first epoch).
    applied_cap_w: Option<f64>,
}

impl SessionState {
    fn new(alpha: f64) -> SessionState {
        SessionState {
            rate: Ewma::new(alpha),
            peak_rate: 0.0,
            aperiodic: None,
            last_obs: None,
            has_rate: false,
            applied_cap_w: None,
        }
    }

    /// Any telemetry signal at all? When no enrolled session has one,
    /// the arbiter uses the fairness fallback.
    fn has_signal(&self) -> bool {
        self.aperiodic.is_some() || self.has_rate
    }

    /// Throughput-insensitive right now: classified aperiodic, or the
    /// smoothed rate collapsed relative to this session's own peak.
    fn donor(&self, ratio: f64) -> bool {
        self.aperiodic == Some(true)
            || (self.has_rate && self.peak_rate > 0.0 && self.rate.value() < ratio * self.peak_rate)
    }
}

/// One emitted re-allocation epoch: a *full snapshot* of every enrolled
/// session's cap, so each epoch in the journal is self-contained and
/// the budget invariant can be checked per-epoch without carry-forward.
#[derive(Debug, Clone, PartialEq)]
pub struct Reallocation {
    /// Monotone epoch counter; increments only when caps are emitted.
    pub epoch: u64,
    /// Budget in force for this epoch.
    pub budget_w: f64,
    /// `(session, cap_w)` for every enrolled session, ascending id.
    pub caps: Vec<(u64, f64)>,
    /// How many of those caps differ from the previously applied ones.
    pub changed: usize,
}

/// The fleet-level budget owner. See the module docs for the model.
pub struct BudgetArbiter {
    cfg: ArbiterCfg,
    sessions: BTreeMap<u64, SessionState>,
    last_tick_s: Option<f64>,
    epoch: u64,
}

impl BudgetArbiter {
    pub fn new(cfg: ArbiterCfg) -> BudgetArbiter {
        BudgetArbiter {
            cfg,
            sessions: BTreeMap::new(),
            last_tick_s: None,
            epoch: 0,
        }
    }

    pub fn cfg(&self) -> &ArbiterCfg {
        &self.cfg
    }

    /// Replace the configuration (e.g. a budget shrink over the wire)
    /// and re-arm the period gate so the next [`Self::tick`] fires
    /// immediately — a shrunk budget must not wait out a stale period.
    pub fn set_cfg(&mut self, cfg: ArbiterCfg) {
        self.cfg = cfg;
        self.last_tick_s = None;
    }

    /// Enroll a session under the budget (idempotent).
    pub fn enroll(&mut self, id: u64) {
        self.sessions
            .entry(id)
            .or_insert_with(|| SessionState::new(self.cfg.rate_alpha));
    }

    /// Remove a session; its headroom returns to the pool next tick.
    pub fn unenroll(&mut self, id: u64) {
        self.sessions.remove(&id);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Feed one journal `Tick` observation: cumulative iteration count
    /// at device time `time_s`. The arbiter differentiates to a rate
    /// and smooths it — raw ticks are never compared across sessions.
    pub fn observe_tick(&mut self, id: u64, iterations: u64, time_s: f64) {
        if let Some(s) = self.sessions.get_mut(&id) {
            if let Some((i0, t0)) = s.last_obs {
                let dt = time_s - t0;
                if dt > 1e-9 && iterations >= i0 {
                    let smoothed = s.rate.observe((iterations - i0) as f64 / dt);
                    if smoothed > s.peak_rate {
                        s.peak_rate = smoothed;
                    }
                    s.has_rate = true;
                }
            }
            s.last_obs = Some((iterations, time_s));
        }
    }

    /// Feed a streaming-detector verdict (journal `Detect` event).
    pub fn observe_detect(&mut self, id: u64, aperiodic: bool) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.aperiodic = Some(aperiodic);
        }
    }

    /// Pure allocation: the cap each enrolled session *should* have
    /// under the current budget and telemetry digest. Deterministic in
    /// the observation history (BTreeMap order, no randomness).
    ///
    /// Σ caps ≤ budget always holds on the result.
    pub fn allocate(&self) -> BTreeMap<u64, f64> {
        let mut caps = BTreeMap::new();
        let n = self.sessions.len();
        if n == 0 {
            return caps;
        }
        let nf = n as f64;
        let b = self.cfg.budget_w;

        if !self.sessions.values().any(SessionState::has_signal) {
            // Fairness fallback: telemetry detached (or no signal yet)
            // — equal split, ceiling-clamped. If the equal share is
            // below the floor the budget cannot cover the floors, so
            // degrade to the plain equal split rather than overshoot.
            let mut share = (b / nf).min(self.cfg.max_cap_w);
            if share < self.cfg.min_cap_w {
                share = b / nf;
            }
            for id in self.sessions.keys() {
                caps.insert(*id, share);
            }
            return caps;
        }

        // Water-filling: everyone starts at the floor (or the equal
        // split when the budget cannot cover the floors), then the
        // spare pours into critical sessions first, donors last.
        let base = (b / nf).min(self.cfg.min_cap_w);
        let mut spare = (b - base * nf).max(0.0);
        let mut donors = Vec::new();
        let mut critical = Vec::new();
        for (id, s) in &self.sessions {
            caps.insert(*id, base);
            if s.donor(self.cfg.donor_ratio) {
                donors.push(*id);
            } else {
                critical.push(*id);
            }
        }
        water_fill(&mut caps, &critical, self.cfg.max_cap_w, &mut spare);
        water_fill(&mut caps, &donors, self.cfg.max_cap_w, &mut spare);
        caps
    }

    /// Period-gated re-allocation. Returns `Some` only when at least
    /// one cap actually changes; the caller applies every cap in the
    /// snapshot. Hysteresis keeps applied caps inside the band — but
    /// the budget invariant outranks it: if the kept caps would exceed
    /// the (possibly shrunk) budget, the raw proposal is applied.
    pub fn tick(&mut self, now_s: f64) -> Option<Reallocation> {
        let due = match self.last_tick_s {
            None => true,
            Some(t) => now_s - t >= self.cfg.period_s,
        };
        if !due || self.sessions.is_empty() {
            if due {
                self.last_tick_s = Some(now_s);
            }
            return None;
        }
        self.last_tick_s = Some(now_s);

        let proposal = self.allocate();
        let mut kept: Vec<(u64, f64)> = Vec::with_capacity(proposal.len());
        let mut kept_sum = 0.0;
        let mut changed = 0usize;
        for (id, prop) in &proposal {
            let applied = self.sessions.get(id).and_then(|s| s.applied_cap_w);
            let cap = match applied {
                Some(c) if (prop - c).abs() <= self.cfg.hysteresis_w => c,
                _ => {
                    changed += 1;
                    *prop
                }
            };
            kept_sum += cap;
            kept.push((*id, cap));
        }
        let caps = if kept_sum > self.cfg.budget_w + 1e-9 {
            changed = proposal
                .iter()
                .filter(|(id, p)| {
                    self.sessions
                        .get(id)
                        .and_then(|s| s.applied_cap_w)
                        .map_or(true, |c| (*p - c).abs() > 1e-12)
                })
                .count();
            proposal.into_iter().collect::<Vec<(u64, f64)>>()
        } else {
            kept
        };
        if changed == 0 {
            return None;
        }
        self.epoch += 1;
        for (id, cap) in &caps {
            if let Some(s) = self.sessions.get_mut(id) {
                s.applied_cap_w = Some(*cap);
            }
        }
        Some(Reallocation {
            epoch: self.epoch,
            budget_w: self.cfg.budget_w,
            caps,
            changed,
        })
    }
}

/// Pour `spare` watts into `ids` by iterative equal shares, saturating
/// each at `max_cap_w`. Terminates: every round either consumes the
/// spare (nobody saturated) or strictly shrinks the open set.
fn water_fill(caps: &mut BTreeMap<u64, f64>, ids: &[u64], max_cap_w: f64, spare: &mut f64) {
    let mut open: Vec<u64> = ids
        .iter()
        .copied()
        .filter(|id| caps.get(id).copied().unwrap_or(max_cap_w) < max_cap_w)
        .collect();
    while *spare > 1e-9 && !open.is_empty() {
        let share = *spare / open.len() as f64;
        let mut still_open = Vec::with_capacity(open.len());
        let mut saturated = false;
        for id in &open {
            let cur = caps.get(id).copied().unwrap_or(0.0);
            let room = max_cap_w - cur;
            let add = share.min(room);
            caps.insert(*id, cur + add);
            *spare -= add;
            if add < room - 1e-12 {
                still_open.push(*id);
            } else {
                saturated = true;
            }
        }
        open = still_open;
        if !saturated {
            break;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn cap_sum(caps: &BTreeMap<u64, f64>) -> f64 {
        caps.values().sum()
    }

    fn cfg(budget_w: f64) -> ArbiterCfg {
        ArbiterCfg {
            budget_w,
            period_s: 1.0,
            min_cap_w: 80.0,
            max_cap_w: 350.0,
            hysteresis_w: 10.0,
            ..ArbiterCfg::default()
        }
    }

    /// Drive a training-like session: steady high rate.
    fn feed_training(a: &mut BudgetArbiter, id: u64, n: usize) {
        for k in 0..n {
            a.observe_tick(id, (k as u64) * 10, k as f64 * 0.5);
        }
    }

    /// Drive an idle-phase session: the rate collapses after a start.
    fn feed_idle(a: &mut BudgetArbiter, id: u64, n: usize) {
        for k in 0..n {
            let iters = if k < 3 { (k as u64) * 10 } else { 30 + k as u64 };
            a.observe_tick(id, iters, k as f64 * 0.5);
        }
    }

    #[test]
    fn fairness_fallback_splits_budget_equally() {
        let mut a = BudgetArbiter::new(cfg(400.0));
        for id in 1..=4 {
            a.enroll(id);
        }
        let caps = a.allocate();
        assert_eq!(caps.len(), 4);
        for cap in caps.values() {
            assert!((cap - 100.0).abs() < 1e-12);
        }
        // Budget below the floors: degrade to the equal split rather
        // than overshoot the budget.
        let mut tight = BudgetArbiter::new(cfg(100.0));
        for id in 1..=4 {
            tight.enroll(id);
        }
        let caps = tight.allocate();
        for cap in caps.values() {
            assert!((cap - 25.0).abs() < 1e-12);
        }
        assert!(cap_sum(&caps) <= 100.0 + 1e-9);
    }

    #[test]
    fn allocations_never_exceed_budget() {
        for budget in [90.0, 200.0, 333.0, 600.0, 1500.0, 5000.0] {
            let mut a = BudgetArbiter::new(cfg(budget));
            for id in 1..=5 {
                a.enroll(id);
            }
            feed_training(&mut a, 1, 8);
            feed_training(&mut a, 2, 8);
            feed_idle(&mut a, 3, 8);
            a.observe_detect(4, true);
            a.observe_detect(5, false);
            let caps = a.allocate();
            assert!(
                cap_sum(&caps) <= budget + 1e-9,
                "sum {} over budget {budget}",
                cap_sum(&caps)
            );
        }
    }

    #[test]
    fn donors_yield_headroom_to_critical_sessions() {
        let mut a = BudgetArbiter::new(cfg(400.0));
        a.enroll(1);
        a.enroll(2);
        feed_training(&mut a, 1, 8); // critical: steady training rate
        feed_idle(&mut a, 2, 8); // donor: rate collapsed vs. its peak
        let caps = a.allocate();
        let c1 = caps[&1];
        let c2 = caps[&2];
        assert!(c1 > c2, "critical {c1} should out-rank donor {c2}");
        // Donor holds the floor; critical takes the spare up to max.
        assert!((c2 - 80.0).abs() < 1e-9, "donor at floor, got {c2}");
        assert!((c1 - 320.0).abs() < 1e-9, "critical takes spare, got {c1}");

        // An aperiodic verdict alone also marks a donor.
        let mut b = BudgetArbiter::new(cfg(400.0));
        b.enroll(1);
        b.enroll(2);
        b.observe_detect(1, false);
        b.observe_detect(2, true);
        let caps = b.allocate();
        assert!(caps[&1] > caps[&2]);
    }

    #[test]
    fn water_filling_saturates_at_max_cap() {
        let mut a = BudgetArbiter::new(cfg(10_000.0));
        for id in 1..=3 {
            a.enroll(id);
            a.observe_detect(id, false);
        }
        let caps = a.allocate();
        for cap in caps.values() {
            assert!((cap - 350.0).abs() < 1e-9, "saturate at max, got {cap}");
        }
    }

    #[test]
    fn hysteresis_keeps_caps_but_budget_shrink_overrides() {
        let mut a = BudgetArbiter::new(cfg(400.0));
        a.enroll(1);
        a.enroll(2);
        a.observe_detect(1, false);
        a.observe_detect(2, true);
        let first = a.tick(0.0).expect("first tick allocates");
        assert_eq!(first.epoch, 1);
        assert_eq!(first.caps.len(), 2);

        // Same state one period later: proposal identical, all caps
        // inside the band — no re-allocation, no epoch bump.
        assert!(a.tick(1.0).is_none(), "no thrash under hysteresis");

        // Shrink the budget: the kept caps would overshoot, so the
        // budget invariant forces the raw proposal through.
        let mut shrunk = cfg(200.0);
        shrunk.hysteresis_w = 1e9; // hysteresis alone would keep everything
        a.set_cfg(shrunk);
        let re = a.tick(1.5).expect("shrink re-allocates immediately");
        assert_eq!(re.epoch, 2);
        let sum: f64 = re.caps.iter().map(|(_, c)| c).sum();
        assert!(sum <= 200.0 + 1e-9, "kept caps must not outlive the budget");
    }

    #[test]
    fn allocation_is_deterministic_in_the_observation_history() {
        let build = || {
            let mut a = BudgetArbiter::new(cfg(555.0));
            for id in [9, 3, 7, 1] {
                a.enroll(id);
            }
            feed_training(&mut a, 3, 6);
            feed_idle(&mut a, 7, 6);
            a.observe_detect(9, true);
            a
        };
        let mut a = build();
        let mut b = build();
        assert_eq!(a.allocate(), b.allocate());
        assert_eq!(a.tick(0.0), b.tick(0.0));
        assert_eq!(a.tick(2.0), b.tick(2.0));
    }

    #[test]
    fn period_gates_and_unenroll_returns_headroom() {
        let mut a = BudgetArbiter::new(cfg(400.0));
        a.enroll(1);
        a.enroll(2);
        a.observe_detect(1, false);
        a.observe_detect(2, true);
        assert!(a.tick(0.0).is_some());
        assert!(a.tick(0.5).is_none(), "inside the period");
        // Donor leaves: its headroom flows back to the critical session.
        a.unenroll(2);
        let re = a.tick(1.0).expect("membership change re-allocates");
        assert_eq!(re.caps.len(), 1);
        let (_, cap) = re.caps[0];
        assert!((cap - 350.0).abs() < 1e-9, "sole session takes up to max");
    }
}
