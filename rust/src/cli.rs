//! CLI dispatch for the `gpoeo` binary.

use crate::coordinator::oracle::{oracle_full, oracle_ordered};
use crate::device::sim_device;
use crate::policy::PolicyRegistry;
use crate::search::Objective;
use crate::sim::{find_app, Spec};
use crate::signal::{calc_period_fft_argmax, online_detect, composite_feature, PeriodCfg};
use crate::util::cli::Args;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

pub fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(),
        Some("policies") => cmd_policies(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("detect") => cmd_detect(args),
        Some("run") => crate::coordinator::cli_run(args),
        Some("sweep") => crate::coordinator::cli_sweep(args),
        Some("experiment") => crate::experiments::cli_experiment(args),
        Some("daemon") => crate::coordinator::cli_daemon(args),
        Some("ctl") => crate::api::cli_ctl(args),
        Some("lint") => crate::lint::cli_lint(args),
        Some(other) => anyhow::bail!("unknown subcommand '{other}'; see --help"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "gpoeo — online GPU energy optimization (GPOEO, TPDS 2022 reproduction)

USAGE: gpoeo <SUBCOMMAND> [OPTIONS]

SUBCOMMANDS:
  list                         list benchmark suites and applications
  policies                     list registered policies (descriptions +
                               default configs) — valid --policy values
  calibrate [--suite S]        ground-truth coefficients + oracle savings
  detect --app A [--sm-gear G] period detection on a simulated trace
  run --app A [--policy P]     online optimization of one app under any
                               registered policy (--objective O)
  sweep [--parallel N]         all-app sweep on a worker fleet; records
                               per-app savings + wall clock in
                               BENCH_sweep.json
                               (--suite S | --apps A,B  --policy P
                                --iters N --quick --bench PATH)
  experiment <id>              regenerate a paper table/figure
                               (fig1 fig2 fig3 fig5 fig6 fig7 fig8 fig9
                                fig10 fig11 fig12 fig13 table3 fig14
                                fig15 headline policies detect-bench
                                predict-bench api-bench sim-bench
                                arbiter-bench | all);
                                detect-bench appends streaming-vs-batch
                                detection cost to BENCH_detection.json
                                (--poll-s F --min-speedup X fails below
                                X×); predict-bench appends
                                arena-vs-legacy all-gears prediction
                                cost to BENCH_predict.json (--reps N
                                --min-speedup X, fails on any
                                arena↔legacy divergence); api-bench
                                appends control-plane conns/s, session
                                churn/s and p50/p99 request latency to
                                BENCH_api.json (--sessions N --quick
                                --min-churn X --max-p99-ms F as the CI
                                floor; --max-overhead-pct P fails when
                                the attached telemetry plane costs >P%
                                p99 at the top tier); sim-bench appends
                                stepped-vs-fast-forward simulation cost
                                and divergence to BENCH_sim.json
                                (--reps N --min-speedup X fails below
                                X×; any divergence >1e-9 fails);
                                arbiter-bench runs N concurrent sessions
                                under a shrinking fleet power budget,
                                coordinated (set_policy arbiter with
                                budget_w/period_s/min_cap_w/max_cap_w/
                                hysteresis_w knobs) vs uncoordinated
                                powercap, and appends total energy,
                                slowdown p50/p99, journaled cap
                                violations and reallocation epochs to
                                BENCH_arbiter.json (--sessions N
                                --quick; fails on any epoch over
                                budget, <3 epochs, or coordinated
                                energy not below uncoordinated)
  daemon [--socket PATH]       Begin/End API server (micro-intrusive
                               mode; --workers N fleet threads, AIMD
                               auto-scaled up to --max-workers N;
                               --rate-limit RPS --rate-burst N
                               per-connection token bucket;
                               --journal-dir DIR writes one replayable
                               JSONL journal per session). Single-
                               threaded poll(2) reactor speaking
                               control-plane protocol v1 (line-delimited
                               JSON + hello handshake, named concurrent
                               sessions, set_policy with inline config,
                               list_apps/list_policies, subscribe
                               streaming, shutdown) and the legacy line
                               protocol behind a first-byte auto-detect
  ctl <verb> [--socket PATH]   control-plane client (GpoeoClient):
                                 apps | policies      introspection
                                 begin --app A [--iters N] [--name S]
                                       [--policy P ...]  -> session id
                                 status|end|abort --session ID
                                 watch --session ID [--every-ticks N]
                                       [--max-events N]  streamed events
                                       (ends with a reason line)
                                 watch --replay FILE  replay + validate
                                                      a session journal
                                 run --app A [...]    begin+watch+end
                                 parity --app A [...] v1-vs-legacy
                                                      RESULT parity gate
                                 metrics              Prometheus text
                                                      exposition scrape
                                 shutdown             stop the daemon
  lint [--format text|json]    machine-check the DESIGN.md §12 contracts
                               over this repo's own sources: §0 layer
                               DAG + forbidden symbols (LB-*), panic-
                               free hot paths (PF-*), non-blocking
                               zones + lock discipline (NB-*), and
                               simulator determinism (DT-*). Contracts
                               live in rust/lint.toml; inline
                               `gpoeo-lint: allow(RULE) reason` waives
                               exactly one finding and is reported.
                               (--rule ID single rule/family,
                                --manifest PATH, --out PATH writes the
                                report; exits non-zero on findings)

COMMON OPTIONS:
  --artifacts DIR              AOT artifact directory (default: artifacts)
  --format text|markdown|csv   table output format (default: text)"
    );
}

fn cmd_list() -> anyhow::Result<()> {
    let spec = Spec::load_default()?;
    for (name, suite) in &spec.suites {
        println!("suite {name} ({} apps, seed_salt {})", suite.apps.len(), suite.seed_salt);
        for app in &suite.apps {
            let arch = &spec.archetypes[&app.archetype];
            let aperiodic = app.aperiodic.unwrap_or(arch.aperiodic);
            println!(
                "  {:<16} archetype={:<15}{}",
                app.name,
                app.archetype,
                if aperiodic { " [aperiodic]" } else { "" }
            );
        }
    }
    Ok(())
}

/// `gpoeo policies` — the registry, so discoverable names replace
/// tribal knowledge about what `--policy` accepts.
fn cmd_policies(args: &Args) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Registered policies (gpoeo run/sweep --policy NAME; daemon: POLICY NAME)",
        &["name", "description", "default config"],
    );
    for b in PolicyRegistry::global().iter() {
        t.rowf(&[s(b.name()), s(b.describe()), s(b.default_config())]);
    }
    print_table(&t, args);
    Ok(())
}

/// Render a table in the requested format.
pub fn print_table(t: &Table, args: &Args) {
    match args.opt_or("format", "text") {
        "markdown" => print!("{}", t.to_markdown()),
        "csv" => print!("{}", t.to_csv()),
        _ => print!("{}", t.to_text()),
    }
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let spec = Spec::load_default()?;
    let obj = Objective::paper_default();
    let suites: Vec<String> = match args.opt("suite") {
        Some(sname) => vec![sname.to_string()],
        None => spec.suites.keys().cloned().collect(),
    };

    let mut t = Table::new(
        "Ground-truth calibration (oracle under min-energy s.t. slowdown ≤5%)",
        &[
            "app", "arch", "wc", "wm", "s_m", "gamma", "dfltSM", "P@dflt", "orcSM", "orcMem",
            "save", "slow", "ed2p", "ordSM", "ordMem",
        ],
    );
    let mut savings = Vec::new();
    for sname in &suites {
        let suite = spec
            .suites
            .get(sname)
            .ok_or_else(|| anyhow::anyhow!("unknown suite '{sname}'"))?;
        for e in &suite.apps {
            let app = find_app(&spec, &e.name)?;
            let full = oracle_full(&app, &spec, obj);
            let ord = oracle_ordered(&app, &spec, obj);
            let (dflt_sm, _, dflt) = app.default_op(&spec);
            savings.push(full.energy_saving);
            t.rowf(&[
                s(&app.name),
                s(&app.archetype),
                Cell::F(app.wc, 2),
                Cell::F(app.wm, 2),
                Cell::F(app.s_m, 2),
                Cell::F(app.gamma, 2),
                Cell::U(dflt_sm),
                Cell::F(dflt.power_w, 0),
                Cell::U(full.sm_gear),
                Cell::F(spec.gears.mem_mhz_of(full.mem_gear), 0),
                Cell::Pct(full.energy_saving),
                Cell::Pct(full.slowdown),
                Cell::Pct(full.ed2p_saving),
                Cell::U(ord.sm_gear),
                Cell::F(spec.gears.mem_mhz_of(ord.mem_gear), 0),
            ]);
        }
    }
    print_table(&t, args);
    println!(
        "\nmean oracle saving {:.1}%  min {:.1}%  max {:.1}%  (n={})",
        crate::util::stats::mean(&savings) * 100.0,
        savings.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
        savings.iter().cloned().fold(f64::NEG_INFINITY, f64::max) * 100.0,
        savings.len()
    );
    Ok(())
}

fn cmd_detect(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let name = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("detect requires --app NAME"))?;
    let app = find_app(&spec, name)?;
    let sm = args.opt_usize("sm-gear", spec.gears.default_sm_gear)?;
    let mem = args.opt_usize("mem-gear", spec.gears.default_mem_gear)?;
    let ts = args.opt_f64("ts", 0.025)?;
    let dur = args.opt_f64("duration", 0.0)?;

    let mut gpu = sim_device(&spec, &app);
    gpu.set_sm_gear(sm);
    gpu.set_mem_gear(mem);
    let truth = gpu.true_period();
    let duration = if dur > 0.0 { dur } else { (12.0 * truth).max(8.0) };

    let n = (duration / ts) as usize;
    let mut power = Vec::with_capacity(n);
    let mut usm = Vec::with_capacity(n);
    let mut umem = Vec::with_capacity(n);
    for _ in 0..n {
        gpu.advance(ts);
        let smp = gpu.sample(ts);
        power.push(smp.power_w);
        usm.push(smp.util_sm);
        umem.push(smp.util_mem);
    }
    let feat = composite_feature(&power, &usm, &umem);

    println!("app {} (sm gear {sm}, mem gear {mem})", gpu.app.name);
    println!("  true period    : {truth:.4} s  (aperiodic: {})", gpu.app.aperiodic);
    match online_detect(&feat, ts, &PeriodCfg::default()) {
        Some(d) => {
            let err = (d.estimate.t_iter - truth).abs() / truth;
            println!(
                "  GPOEO detected : {:.4} s  err {:.2}%  self-err {:.3}  stable: {}",
                d.estimate.t_iter,
                err * 100.0,
                d.estimate.err,
                d.next_sampling_s.is_none()
            );
        }
        None => println!("  GPOEO detected : (none)"),
    }
    match calc_period_fft_argmax(&feat, ts) {
        Some(d) => {
            let err = (d.t_iter - truth).abs() / truth;
            println!("  ODPP  detected : {:.4} s  err {:.2}%", d.t_iter, err * 100.0);
        }
        None => println!("  ODPP  detected : (none)"),
    }
    Ok(())
}
