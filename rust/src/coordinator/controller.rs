//! The GPOEO online controller — the paper's system contribution (Fig. 4).
//!
//! Lifecycle per workload:
//!
//! 1. **Sampling** (③): sample power/util at `ts`, build the composite
//!    `Feature_dect` channel, and run the online robust period detection
//!    (Algorithms 1–3) until the iteration period stabilizes. Apps whose
//!    traces never stabilize (or stabilize with a poor similarity score)
//!    take the aperiodic path (§4.3.5) with a fixed measurement window.
//! 2. **Measure** (④): one counter session of exactly one (dilated)
//!    period — the micro-intrusive feature measurement of Algorithm 4 —
//!    yielding the Table-2 feature vector plus the (power, IPS) baseline.
//! 3. **Predict** (⑤⑥): the four GBT models (AOT-compiled HLO via PJRT,
//!    or the native twin) score every SM/memory gear; the objective picks
//!    the predicted optimum.
//! 4. **Search** (⑦): golden-section local search around the prediction —
//!    memory clock first (a wrong memory clock is catastrophic), then SM
//!    clock. Each probe measures (power, IPS) for one period at the
//!    candidate gear; ratios against the baseline feed the objective.
//! 5. **Monitor** (⑧): watch the energy characteristic (windowed mean
//!    power); on fluctuation beyond the threshold, reset to default
//!    clocks and restart from step 1.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::device::Device;
use crate::model::Predictor;
use crate::search::{local_search, Objective, SearchResult};
use crate::signal::{PeriodCfg, StreamCfg, StreamingDetector};
use crate::telemetry::{Gauge, Hist, Telemetry, TelemetryEvent};
use crate::util::stats::mean;
use std::sync::Arc;

/// Controller configuration (paper defaults).
#[derive(Clone)]
pub struct GpoeoCfg {
    /// NVML sampling interval (seconds).
    pub ts: f64,
    pub objective: Objective,
    pub period: PeriodCfg,
    /// Initial `SmpDur_init` sampling window before the first detection.
    pub initial_window_s: f64,
    /// Give up on periodicity beyond this window (aperiodic path).
    pub max_window_s: f64,
    /// Detection rounds before falling back to the aperiodic path.
    pub max_detect_rounds: usize,
    /// Similarity self-error above which the app is treated as aperiodic.
    pub aperiodic_err: f64,
    /// Fixed measurement interval for aperiodic apps (§4.3.5).
    pub aperiodic_window_s: f64,
    /// Clock-settle time before a probe measurement.
    pub settle_s: f64,
    /// Monitor: relative power fluctuation that triggers re-optimization.
    pub fluct_threshold: f64,
    /// Monitor window, in multiples of the detected period.
    pub monitor_window_mult: f64,
    /// When false, the controller measures and searches but never sets
    /// clocks — the overhead-accounting mode of Fig. 15.
    pub actuate: bool,
    /// Ablations: skip the memory- or SM-clock stage.
    pub optimize_mem: bool,
    pub optimize_sm: bool,
    /// Ablation: apply the predicted gears directly (no local search).
    pub skip_search: bool,
    /// Ablation: ignore the model (local search starts from the default
    /// gears — what a counter-free controller would have to do).
    pub ignore_prediction: bool,
}

impl Default for GpoeoCfg {
    fn default() -> Self {
        GpoeoCfg {
            ts: 0.025,
            objective: Objective::paper_default(),
            period: PeriodCfg::default(),
            initial_window_s: 6.0,
            max_window_s: 45.0,
            max_detect_rounds: 6,
            aperiodic_err: 0.35,
            aperiodic_window_s: 2.5,
            settle_s: 0.15,
            fluct_threshold: 0.12,
            monitor_window_mult: 3.0,
            actuate: true,
            optimize_mem: true,
            optimize_sm: true,
            skip_search: false,
            ignore_prediction: false,
        }
    }
}

/// Optimization trace for Table 3 / diagnostics.
#[derive(Debug, Clone, Default)]
pub struct GpoeoStats {
    pub detect_rounds: usize,
    pub detected_period_s: f64,
    pub detection_self_err: f64,
    pub treated_aperiodic: bool,
    pub predicted_sm_gear: usize,
    pub searched_sm_gear: usize,
    pub search_steps_sm: usize,
    pub predicted_mem_gear: usize,
    pub searched_mem_gear: usize,
    pub search_steps_mem: usize,
    pub reoptimizations: usize,
    /// Ground-truth period at detection time (for error scoring).
    pub true_period_s: f64,
}

enum Phase {
    Sampling { until_s: f64 },
    Monitor { window_end_s: f64, p_ref: f64 },
}

/// The online controller. Implements [`crate::coordinator::Policy`].
pub struct Gpoeo {
    pub cfg: GpoeoCfg,
    pub stats: GpoeoStats,
    predictor: Arc<Predictor>,
    phase: Phase,
    /// Streaming Feature_dect engine: the controller pushes every
    /// sampling tick and asks for an Algorithm-3 verdict at its own
    /// schedule deadlines (grow-only retention — `retain_horizon_mult:
    /// None` — so verdicts are bit-compatible with the historic
    /// re-slice-the-Vecs implementation).
    det: StreamingDetector,
    window_start_s: f64,
    // Monitor accumulator.
    mon_acc: Vec<f64>,
    period_s: f64,
    aperiodic: bool,
    /// Telemetry plane + fleet session id (DESIGN.md §11). Pure
    /// observation: never consulted by any control decision, so runs
    /// with and without it are bit-identical.
    tel: Option<(Arc<Telemetry>, u64)>,
    /// Once-per-session guards for the overhead-mode clamp warning in
    /// [`nearest_gear_index`]. Session-scoped on purpose: a session
    /// that clamps on every optimization round logs a single line, and
    /// `restart_sampling` (a new detection round within the same
    /// session) must not rearm them.
    clamp_warned_mem: bool,
    clamp_warned_sm: bool,
}

impl Gpoeo {
    pub fn new(cfg: GpoeoCfg, predictor: Arc<Predictor>) -> Gpoeo {
        let until = cfg.initial_window_s;
        let stream = StreamCfg {
            initial_window_s: cfg.initial_window_s,
            none_ext_s: cfg.initial_window_s / 2.0,
            // The retention cap must cover the controller's own schedule
            // (give-up window + the longest single extension) or push()
            // would silently trim mid-detection for non-default configs.
            max_retain_s: (cfg.max_window_s + 15.0).max(60.0),
            ..StreamCfg::default()
        };
        let det = StreamingDetector::new(cfg.ts, cfg.period.clone(), stream);
        Gpoeo {
            cfg,
            stats: GpoeoStats::default(),
            predictor,
            phase: Phase::Sampling { until_s: until },
            det,
            window_start_s: 0.0,
            mon_acc: Vec::new(),
            period_s: 0.0,
            aperiodic: false,
            tel: None,
            clamp_warned_mem: false,
            clamp_warned_sm: false,
        }
    }
}

/// Spectrum front-end: the PJRT-compiled Pallas periodogram when the
/// HLO backend is loaded, else the native FFT. The trace window is
/// linearly resampled to the kernel's fixed 1024-point input.
fn spectrum_for(predictor: &Predictor, smp: &[f64], ts: f64) -> (Vec<f64>, Vec<f64>) {
    if let Predictor::Hlo(rt) = predictor {
        if smp.len() >= 64 {
            let n = 1024usize;
            let dur = (smp.len() - 1) as f64 * ts;
            let ts2 = dur / (n - 1) as f64;
            let mut resampled = Vec::with_capacity(n);
            for i in 0..n {
                let x = i as f64 * ts2 / ts;
                let j = (x.floor() as usize).min(smp.len() - 2);
                let frac = x - j as f64;
                // gpoeo-lint: allow(PF-INDEX) j <= smp.len()-2 by the min() above (smp.len() >= 64 here)
                resampled.push((smp[j] * (1.0 - frac) + smp[j + 1] * frac) as f32);
            }
            if let Ok(ampls) = rt.periodogram_1024(&resampled) {
                // Bin k of the output is spectral bin k+1; drop the
                // Nyquist bin to match the native periodogram exactly.
                let freqs: Vec<f64> = (1..n / 2).map(|k| k as f64 / (n as f64 * ts2)).collect();
                // gpoeo-lint: allow(PF-INDEX) periodogram_1024 returns n/2 = 512 amplitudes; the slice takes 511
                let ampls: Vec<f64> = ampls[..n / 2 - 1].iter().map(|&a| a as f64).collect();
                return (freqs, ampls);
            }
        }
    }
    crate::signal::periodogram(smp, ts)
}

/// Index of gear `g` in a predicted gear table, clamped to the nearest
/// table entry when the predictor or the search hands back a gear the
/// (possibly pruned) table does not contain. A production fleet worker
/// must degrade here, not panic mid-session; the clamp is logged once
/// per search stage.
fn nearest_gear_index(gears: &[usize], g: usize, warned: &mut bool, which: &str) -> usize {
    // gpoeo-lint: allow(PF-ASSERT) load-time contract: Predictor::predict always yields a non-empty gear table; an empty one here is a build bug worth dying on, even mid-session
    assert!(!gears.is_empty(), "empty predicted gear table");
    if let Some(i) = gears.iter().position(|&x| x == g) {
        return i;
    }
    let mut best = 0usize;
    for (i, &x) in gears.iter().enumerate() {
        // gpoeo-lint: allow(PF-INDEX) best is always a previously-visited enumerate index
        if x.abs_diff(g) < gears[best].abs_diff(g) {
            best = i;
        }
    }
    if !*warned {
        eprintln!(
            "gpoeo: {which} gear {g} outside the predicted table; using nearest gear {}",
            // gpoeo-lint: allow(PF-INDEX) best indexes the non-empty table scanned above
            gears[best]
        );
        *warned = true;
    }
    best
}

impl Gpoeo {
    // ------------------------------------------------------------------
    // Synchronous measurement helpers (drive the gpu forward directly).
    // ------------------------------------------------------------------

    /// Measure (avg power, IPS) over `window_s` at the current clocks,
    /// with a counter session active.
    fn probe_measure(&mut self, gpu: &mut dyn Device, window_s: f64) -> (f64, f64) {
        // Settle after a clock change.
        gpu.advance(self.cfg.settle_s);
        gpu.start_counter_session();
        let e0 = gpu.energy_j();
        let t0 = gpu.time_s();
        let quarter = (window_s / 4.0).max(self.cfg.ts);
        let mut ips_acc = 0.0;
        for _ in 0..4 {
            gpu.advance(quarter);
            ips_acc += gpu.ips();
        }
        let e1 = gpu.energy_j();
        let t1 = gpu.time_s();
        gpu.stop_counter_session();
        let p = (e1 - e0) / (t1 - t0);
        (p, ips_acc / 4.0)
    }

    /// Average power over `window_s` without a counter session (used by
    /// the monitor to establish the post-optimization reference).
    fn plain_power(&mut self, gpu: &mut dyn Device, window_s: f64) -> f64 {
        let n = (window_s / self.cfg.ts).ceil() as usize;
        let mut acc = 0.0;
        for _ in 0..n {
            gpu.advance(self.cfg.ts);
            acc += gpu.sample(self.cfg.ts).power_w as f64;
        }
        acc / n as f64
    }

    /// Steps 2–4 of the lifecycle, run synchronously once the period is
    /// known: feature measurement, prediction, memory search, SM search.
    fn measure_and_optimize(&mut self, gpu: &mut dyn Device) -> anyhow::Result<f64> {
        let spec = gpu.spec().clone();
        let tax = spec.profiling_tax.counter_time_mult;
        let feat_window = self.period_s * tax;

        // --- Algorithm 4 tail: one (dilated) period of counter profiling.
        gpu.advance(self.cfg.settle_s);
        gpu.start_counter_session();
        gpu.advance(feat_window);
        let features = gpu.read_counters()?;
        gpu.stop_counter_session();

        // --- Baseline (power, IPS) at the entry clocks: a longer window
        // than search probes, because every downstream ratio divides by it
        // (a 1% optimistic baseline biases every decision by 1%).
        let (p_base, ips_base) = self.probe_measure(gpu, (2.0 * self.period_s).max(1.0));

        // --- Predict the optimal gears (⑤⑥).
        let predict_t0 = match &self.tel {
            Some((tel, _)) if tel.enabled() => Some(std::time::Instant::now()),
            _ => None,
        };
        let pred_sm = self.predictor.predict_sm(&spec, &features)?;
        let pred_mem = self.predictor.predict_mem(&spec, &features)?;
        if let (Some(t0), Some((tel, _))) = (predict_t0, &self.tel) {
            tel.metrics()
                .observe(Hist::PredictSeconds, t0.elapsed().as_secs_f64());
        }
        let (g_sm_pred, g_mem_pred) = if self.cfg.ignore_prediction {
            (gpu.sm_gear(), gpu.mem_gear())
        } else {
            (
                pred_sm.best(self.cfg.objective)?,
                pred_mem.best(self.cfg.objective)?,
            )
        };
        self.stats.predicted_sm_gear = g_sm_pred;
        self.stats.predicted_mem_gear = g_mem_pred;

        let probe_window = self.period_s.clamp(0.4, 4.0);
        let entry_sm = gpu.sm_gear();
        let entry_mem = gpu.mem_gear();

        // Probe evaluation: energy/time ratios vs the measured baseline.
        // time ratio = IPS_base / IPS_probe (fixed work per iteration);
        // energy ratio = (P/IPS) / (P_base/IPS_base).
        macro_rules! probe_score {
            ($self:ident, $gpu:ident, $w:expr) => {{
                let (p, ips) = $self.probe_measure($gpu, $w);
                let t_ratio = ips_base / ips.max(1e-9);
                let e_ratio = (p / ips.max(1e-9)) / (p_base / ips_base);
                $self.cfg.objective.score(e_ratio, t_ratio)
            }};
        }

        // --- Memory-clock local search first (⑦, §4.3.4).
        let mem_best = if self.cfg.optimize_mem && self.cfg.skip_search {
            if self.cfg.actuate {
                gpu.set_mem_gear(g_mem_pred);
            }
            SearchResult {
                best_gear: g_mem_pred,
                steps: 0,
                probes: vec![],
            }
        } else if self.cfg.optimize_mem {
            // Seed from (and store back to) the session-scoped guard:
            // the closure can't borrow the field while it captures
            // `self`, so the round works on a copy.
            let mut warned = self.clamp_warned_mem;
            let mut eval = |g: usize| -> f64 {
                if self.cfg.actuate {
                    gpu.set_mem_gear(g);
                    probe_score!(self, gpu, probe_window)
                } else {
                    // Overhead mode: pay the measurement, use the model.
                    let _ = self.probe_measure(gpu, probe_window);
                    let i = nearest_gear_index(&pred_mem.gears, g, &mut warned, "mem");
                    self.cfg
                        .objective
                        // gpoeo-lint: allow(PF-INDEX) i is a position inside pred_mem.gears; the ratio vectors share its length by Predictor construction
                        .score(pred_mem.energy_ratio[i], pred_mem.time_ratio[i])
                }
            };
            let r = local_search(g_mem_pred, 0, spec.gears.num_mem_gears() - 1, &mut eval);
            self.clamp_warned_mem = warned;
            if self.cfg.actuate {
                gpu.set_mem_gear(r.best_gear);
            }
            r
        } else {
            SearchResult {
                best_gear: entry_mem,
                steps: 0,
                probes: vec![],
            }
        };
        self.stats.searched_mem_gear = mem_best.best_gear;
        self.stats.search_steps_mem = mem_best.steps;

        // --- SM-clock local search on top of the chosen memory clock.
        let sm_best = if self.cfg.optimize_sm && self.cfg.skip_search {
            if self.cfg.actuate {
                gpu.set_sm_gear(g_sm_pred);
            }
            SearchResult {
                best_gear: g_sm_pred,
                steps: 0,
                probes: vec![],
            }
        } else if self.cfg.optimize_sm {
            let mut warned = self.clamp_warned_sm;
            let mut eval = |g: usize| -> f64 {
                if self.cfg.actuate {
                    gpu.set_sm_gear(g);
                    probe_score!(self, gpu, probe_window)
                } else {
                    let _ = self.probe_measure(gpu, probe_window);
                    let i = nearest_gear_index(&pred_sm.gears, g, &mut warned, "sm");
                    self.cfg
                        .objective
                        // gpoeo-lint: allow(PF-INDEX) i is a position inside pred_sm.gears; the ratio vectors share its length by Predictor construction
                        .score(pred_sm.energy_ratio[i], pred_sm.time_ratio[i])
                }
            };
            let r = local_search(
                g_sm_pred,
                spec.gears.sm_gear_min,
                spec.gears.sm_gear_max,
                &mut eval,
            );
            self.clamp_warned_sm = warned;
            if self.cfg.actuate {
                gpu.set_sm_gear(r.best_gear);
            }
            r
        } else {
            SearchResult {
                best_gear: entry_sm,
                steps: 0,
                probes: vec![],
            }
        };
        self.stats.searched_sm_gear = sm_best.best_gear;
        self.stats.search_steps_sm = sm_best.steps;

        // --- Cap confirmation: the search selects the lowest gear that
        // *measured* feasible, a one-sided (winner's-curse) estimator
        // that systematically overshoots the slowdown cap. Re-verify the
        // chosen gear with a longer window; climb back up until feasible.
        if self.cfg.actuate && self.cfg.optimize_sm {
            if let Objective::EnergyCapped { max_time_ratio } = self.cfg.objective {
                let mut g = self.stats.searched_sm_gear;
                for _ in 0..4 {
                    gpu.set_sm_gear(g);
                    let (_, ips) = self.probe_measure(gpu, (2.0 * probe_window).min(6.0));
                    self.stats.search_steps_sm += 1;
                    let t_ratio = ips_base / ips.max(1e-9);
                    if t_ratio <= max_time_ratio || g >= entry_sm {
                        break;
                    }
                    // Climb proportionally to the measured overshoot so a
                    // deep miss (noisy aperiodic probes) recovers in a few
                    // steps instead of crawling +2 at a time.
                    let overshoot = (t_ratio - max_time_ratio) / max_time_ratio;
                    let step = ((overshoot * 60.0).ceil() as usize).clamp(2, 12);
                    g = (g + step).min(entry_sm);
                }
                gpu.set_sm_gear(g);
                self.stats.searched_sm_gear = g;
            }
        }

        // --- Telemetry: one gear-switch record per optimization pass,
        // reporting the clocks the pass settled on (the entry clocks in
        // non-actuating overhead mode — still a pass worth recording).
        if let Some((tel, session)) = &self.tel {
            tel.metrics().gear_switch("gpoeo");
            tel.metrics().set_gauge(Gauge::SmGear, gpu.sm_gear() as f64);
            tel.metrics().set_gauge(Gauge::MemGear, gpu.mem_gear() as f64);
            tel.emit(TelemetryEvent::GearSwitch {
                session: *session,
                policy: "gpoeo".into(),
                sm_gear: gpu.sm_gear(),
                mem_gear: gpu.mem_gear(),
                time_s: gpu.time_s(),
            });
        }

        // --- Establish the monitor reference at the final configuration.
        let p_ref = self.plain_power(gpu, (self.period_s).clamp(0.5, 4.0));
        Ok(p_ref)
    }

    fn restart_sampling(&mut self, gpu: &mut dyn Device) {
        if let Some((tel, _)) = &self.tel {
            // Verdict gauge back to 0 ("none") while re-detecting.
            tel.metrics().set_gauge(Gauge::DetectorVerdict, 0.0);
        }
        self.det.reset();
        self.window_start_s = gpu.time_s();
        self.stats.detect_rounds = 0;
        self.aperiodic = false;
        self.phase = Phase::Sampling {
            until_s: gpu.time_s() + self.cfg.initial_window_s,
        };
    }

    fn enter_monitor(&mut self, gpu: &mut dyn Device, p_ref: f64) {
        // Aperiodic traces are random segment walks: short windows jump
        // around the mean by construction, so monitor over a much longer
        // horizon to avoid spurious re-optimizations.
        let mult = if self.aperiodic {
            4.0 * self.cfg.monitor_window_mult
        } else {
            self.cfg.monitor_window_mult
        };
        let w = self.period_s.max(0.5) * mult;
        self.mon_acc.clear();
        self.phase = Phase::Monitor {
            window_end_s: gpu.time_s() + w,
            p_ref,
        };
    }

    fn finish_detection(&mut self, gpu: &mut dyn Device) {
        self.stats.true_period_s = gpu.true_period();
        if let Some((tel, session)) = &self.tel {
            let verdict = if self.aperiodic { 2.0 } else { 1.0 };
            tel.metrics().set_gauge(Gauge::DetectorVerdict, verdict);
            tel.emit(TelemetryEvent::Detect {
                session: *session,
                period_s: self.period_s,
                aperiodic: self.aperiodic,
                round: self.det.rounds() as u64,
            });
        }
        match self.measure_and_optimize(gpu) {
            Ok(p_ref) => self.enter_monitor(gpu, p_ref),
            Err(e) => {
                eprintln!("gpoeo: optimization failed ({e}); staying at default");
                gpu.set_default_clocks();
                self.enter_monitor(gpu, f64::NAN);
            }
        }
    }
}

impl crate::coordinator::Policy for Gpoeo {
    fn name(&self) -> &'static str {
        "gpoeo"
    }

    fn gpoeo_stats(&self) -> Option<GpoeoStats> {
        Some(self.stats.clone())
    }

    fn attach_telemetry(&mut self, tel: Arc<Telemetry>, session: u64) {
        self.det.attach_metrics(tel.metrics().clone());
        self.tel = Some((tel, session));
    }

    fn tick(&mut self, gpu: &mut dyn Device) {
        let ts = self.cfg.ts;
        match self.phase {
            Phase::Sampling { until_s } => {
                gpu.advance(ts);
                let s = gpu.sample(ts);
                self.det.push(s.power_w, s.util_sm, s.util_mem);
                if gpu.time_s() < until_s {
                    return;
                }
                let window_s = gpu.time_s() - self.window_start_s;
                let pred = self.predictor.clone();
                let mut spectrum = move |smp: &[f64], t: f64| spectrum_for(&pred, smp, t);
                let det = self.det.evaluate_with(&mut spectrum).detection;
                match det {
                    Some(d) if d.next_sampling_s.is_none()
                        && d.estimate.err <= self.cfg.aperiodic_err =>
                    {
                        self.period_s = d.estimate.t_iter;
                        self.stats.detected_period_s = d.estimate.t_iter;
                        self.stats.detection_self_err = d.estimate.err;
                        self.stats.treated_aperiodic = false;
                        self.finish_detection(gpu);
                    }
                    other => {
                        self.stats.detect_rounds += 1;
                        let give_up = self.stats.detect_rounds >= self.cfg.max_detect_rounds
                            || window_s >= self.cfg.max_window_s
                            || matches!(&other, Some(d) if d.next_sampling_s.is_none());
                        if give_up {
                            // Aperiodic path (§4.3.5): fixed interval.
                            self.aperiodic = true;
                            self.period_s = self.cfg.aperiodic_window_s;
                            self.stats.treated_aperiodic = true;
                            if let Some(d) = other {
                                self.stats.detected_period_s = d.estimate.t_iter;
                                self.stats.detection_self_err = d.estimate.err;
                            }
                            self.finish_detection(gpu);
                        } else {
                            let ext = other
                                .and_then(|d| d.next_sampling_s)
                                .unwrap_or(self.cfg.initial_window_s / 2.0)
                                .clamp(0.5, 12.0);
                            self.phase = Phase::Sampling {
                                until_s: gpu.time_s() + ext,
                            };
                        }
                    }
                }
            }
            Phase::Monitor { window_end_s, p_ref } => {
                gpu.advance(ts);
                self.mon_acc.push(gpu.sample(ts).power_w);
                if gpu.time_s() < window_end_s {
                    return;
                }
                let p_now = mean(&self.mon_acc);
                self.mon_acc.clear();
                let fluct = if p_ref.is_finite() {
                    (p_now - p_ref).abs() / p_ref
                } else {
                    1.0
                };
                let threshold = if self.aperiodic {
                    2.0 * self.cfg.fluct_threshold
                } else {
                    self.cfg.fluct_threshold
                };
                if fluct > threshold {
                    // Energy characteristic shifted: workload changed.
                    self.stats.reoptimizations += 1;
                    if self.cfg.actuate {
                        gpu.set_default_clocks();
                    }
                    self.restart_sampling(gpu);
                } else {
                    let mult = if self.aperiodic {
                        4.0 * self.cfg.monitor_window_mult
                    } else {
                        self.cfg.monitor_window_mult
                    };
                    let w = self.period_s.max(0.5) * mult;
                    self.phase = Phase::Monitor {
                        window_end_s: gpu.time_s() + w,
                        p_ref,
                    };
                }
            }
        }
    }
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_gear_index_clamps_out_of_table_gears() {
        let gears = vec![40usize, 60, 80, 100];
        let mut warned = false;
        // Exact hits never warn.
        assert_eq!(nearest_gear_index(&gears, 80, &mut warned, "sm"), 2);
        assert!(!warned);
        // Above the table: clamp to the top entry (and warn once).
        assert_eq!(nearest_gear_index(&gears, 114, &mut warned, "sm"), 3);
        assert!(warned);
        // Below the table: clamp to the bottom entry.
        let mut warned = false;
        assert_eq!(nearest_gear_index(&gears, 10, &mut warned, "sm"), 0);
        // Between entries: nearest wins; exact ties keep the first.
        assert_eq!(nearest_gear_index(&gears, 73, &mut warned, "sm"), 2);
        assert_eq!(nearest_gear_index(&gears, 70, &mut warned, "sm"), 1);
    }

    #[test]
    fn clamp_warning_is_once_per_session_and_survives_restart() {
        use crate::model::NativeModels;
        use crate::sim::{find_app, SimGpu, Spec};

        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_I2T").unwrap();
        let mut gpu = SimGpu::new(spec, app);
        let p = Arc::new(Predictor::Native(NativeModels::synthetic(7)));
        let mut g = Gpoeo::new(GpoeoCfg::default(), p);
        assert!(!g.clamp_warned_mem && !g.clamp_warned_sm);

        // Round 1 clamps: the round-local copy comes back set and the
        // round stores it on the session (the copy-in/copy-out pattern
        // in measure_and_optimize).
        let mut warned = g.clamp_warned_mem;
        nearest_gear_index(&[40, 60, 80], 200, &mut warned, "mem");
        g.clamp_warned_mem = warned;
        assert!(g.clamp_warned_mem);

        // Round 2 seeds from the session flag: it enters already-set,
        // so nearest_gear_index stays silent for the session's rest.
        let mut warned = g.clamp_warned_mem;
        assert!(warned, "second round must inherit the warned state");
        nearest_gear_index(&[40, 60, 80], 200, &mut warned, "mem");
        assert!(warned);

        // A workload swap re-detects (restart_sampling) but must NOT
        // rearm the warning: it is per-session, not per-detection-round.
        g.restart_sampling(&mut gpu);
        assert!(g.clamp_warned_mem, "restart_sampling must not rearm");
        assert!(!g.clamp_warned_sm, "sm flag is tracked independently");
    }
}
