//! The daemon: control-plane API v1 + the legacy Begin/End line protocol
//! (§2.2.2/§4.2 of the paper; DESIGN.md §6, §9 and §10).
//!
//! The paper's deployment model is a two-call micro-intrusive API
//! (`Begin` at the start of the training region, `End` at the end) with
//! a separate optimizer process owning the GPU clocks. This daemon is
//! that optimizer process over a Unix socket, serving two protocols on
//! one listener with a per-connection auto-detect on the first byte:
//!
//! - `{` → **protocol v1** (line-delimited JSON, `hello` handshake):
//!   typed requests from [`crate::api`], multiple concurrent *named*
//!   sessions (daemon-global table — `begin` returns a session id,
//!   `status`/`end`/`abort`/`subscribe` take one, any connection can
//!   address any session), per-`begin` policy selection with inline
//!   config resolved through [`PolicyRegistry`], introspection
//!   (`list_apps`/`list_policies`), streamed `subscribe` telemetry, and
//!   a `shutdown` request that exits the event loop and removes the
//!   socket file.
//! - anything else → the **legacy protocol**, unchanged: one session per
//!   connection, `POLICY <name>` / `BEGIN <app> [iters]` / `STATUS` /
//!   `END` / `QUIT`, answers `OK`/`STATUS`/`RESULT`/`ERR` lines.
//!
//! Since the reactor rework, v1 connections are served by a
//! single-threaded non-blocking `poll(2)` event loop
//! ([`crate::coordinator::reactor`]) — no thread per connection, fleet
//! commands dispatched through [`crate::coordinator::Reply`] callbacks.
//! Legacy connections keep the old per-thread blocking path (the compat
//! rule: that protocol's tests and clients are untouched). The session
//! table is sharded by session-id hash so operations on different
//! sessions never contend on one lock.
//!
//! Both protocols resolve `BEGIN` without an iteration count to
//! [`default_iters`] — the same default `gpoeo run` uses — and both are
//! served by one shared [`Fleet`], so a v1 and a legacy session with the
//! same (app, policy, iters) produce bit-identical results (the parity
//! contract, tested in `tests/api_daemon.rs` and gated in CI).
//!
//! Every failure path answers a typed `Response::Error` (v1) or an
//! `ERR <reason>` line (legacy) — a client never hangs on a silent
//! close, and a malformed line never kills the connection loop. A failed
//! `accept()` is logged (rate-limited) and skipped, never fatal to the
//! daemon.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::api::{AppInfo, SessionReport};
use crate::coordinator::reactor::Reactor;
use crate::coordinator::{default_iters, AimdCfg, Fleet, SessionHandle, SessionStatus};
use crate::policy::{PolicyRegistry, PolicySpec};
use crate::sim::{find_app, make_app, AppParams, Spec};
use crate::telemetry::{Telemetry, TelemetryCfg};
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Controller ticks driven per `STATUS`/`status` poll.
pub(crate) const STATUS_TICKS: u64 = 200;

/// Control-plane tuning. [`DaemonCfg::fixed`] reproduces the historical
/// behavior exactly: a fixed-size worker pool and no rate limiting.
#[derive(Debug, Clone)]
pub struct DaemonCfg {
    /// AIMD worker-pool ceiling (ninelives P3.04). Equal to the initial
    /// worker count → the pool never scales.
    pub max_workers: usize,
    /// Per-connection request budget, requests/second (ninelives
    /// ADR-009). `0.0` disables rate limiting.
    pub rate_limit_rps: f64,
    /// Token-bucket burst capacity (clamped to ≥ 1 when limiting is on).
    pub rate_burst: f64,
    /// Write one JSONL journal per session under this directory
    /// (DESIGN.md §11; replay with `gpoeo ctl watch --replay`).
    pub journal_dir: Option<PathBuf>,
    /// Attach the telemetry plane (metrics + events). Off = the
    /// [`Telemetry::disabled`] plane: `metrics` still answers, but with
    /// an all-zero registry, and no consumer thread runs.
    pub telemetry: bool,
}

impl DaemonCfg {
    pub fn fixed(workers: usize) -> DaemonCfg {
        DaemonCfg {
            max_workers: workers,
            rate_limit_rps: 0.0,
            rate_burst: 0.0,
            journal_dir: None,
            telemetry: true,
        }
    }
}

pub struct Daemon {
    fleet: Arc<Fleet>,
    shared: Arc<Shared>,
    cfg: DaemonCfg,
}

/// Daemon-global state shared by every connection: the sharded
/// named-session table and the shutdown latch.
pub(crate) struct Shared {
    pub(crate) sessions: SessionTable,
    pub(crate) shutdown: AtomicBool,
}

/// One v1 session. The handle moves out (`None`) exactly once, when an
/// `end`/`abort` claims it — concurrent claims lose cleanly instead of
/// double-ending.
pub(crate) struct SessionEntry {
    pub(crate) handle: Mutex<Option<SessionHandle>>,
}

/// The daemon-global session table, sharded by FNV-1a hash of the
/// session id: `begin`/`status`/`end`/`subscribe` on different sessions
/// lock different shards and never contend on one mutex. Generated ids
/// and client names share one id space (a reservation in any shard
/// claims the id everywhere, because lookups hash the same way).
pub(crate) struct SessionTable {
    shards: Vec<Mutex<HashMap<String, Arc<SessionEntry>>>>,
    next_id: AtomicU64,
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl SessionTable {
    /// `shards` is rounded up to a power of two so the hash maps onto a
    /// shard with a mask instead of a modulo.
    pub(crate) fn new(shards: usize) -> SessionTable {
        let n = shards.max(1).next_power_of_two();
        SessionTable {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            next_id: AtomicU64::new(1),
        }
    }

    fn shard(&self, id: &str) -> &Mutex<HashMap<String, Arc<SessionEntry>>> {
        let mask = self.shards.len() as u64 - 1;
        &self.shards[(fnv1a(id) & mask) as usize]
    }

    /// Reserve an id with an empty entry (the handle arrives via
    /// [`SessionTable::fulfill`] as soon as the fleet begin is
    /// dispatched — worker command queues are FIFO, so requests
    /// pipelined behind the begin land after it). A client-proposed
    /// name must be free; a generated `s<N>` skips any ids a client
    /// happened to claim (names share the id space).
    pub(crate) fn reserve(&self, name: Option<String>) -> anyhow::Result<String> {
        let entry = || {
            Arc::new(SessionEntry {
                handle: Mutex::new(None),
            })
        };
        match name {
            Some(n) => {
                // Shard (and entry) locks recover from poisoning
                // throughout this table: every guard is statement-local
                // and the maps stay structurally valid mid-panic, so
                // inheriting the value beats cascading the panic into
                // every later control-plane request.
                let mut map = self.shard(&n).lock().unwrap_or_else(|e| e.into_inner());
                if map.contains_key(&n) {
                    anyhow::bail!("session '{n}' already exists");
                }
                map.insert(n.clone(), entry());
                Ok(n)
            }
            None => loop {
                let candidate = format!("s{}", self.next_id.fetch_add(1, Ordering::SeqCst));
                let mut map = self
                    .shard(&candidate)
                    .lock()
                    .unwrap_or_else(|e| e.into_inner());
                if !map.contains_key(&candidate) {
                    map.insert(candidate.clone(), entry());
                    return Ok(candidate);
                }
            },
        }
    }

    /// Install the live handle into a reserved entry, returning that
    /// entry — `None` if the reservation is gone. A reservation cannot
    /// be *claimed* meanwhile (end/abort on an empty entry answer "no
    /// longer active" without removing it), so `None` only happens if
    /// the id was never reserved; callers surface that as an error
    /// instead of panicking.
    #[must_use]
    pub(crate) fn fulfill(&self, id: &str, h: SessionHandle) -> Option<Arc<SessionEntry>> {
        let entry = self.get(id)?;
        *entry.handle.lock().unwrap_or_else(|e| e.into_inner()) = Some(h);
        Some(entry)
    }

    pub(crate) fn get(&self, id: &str) -> Option<Arc<SessionEntry>> {
        self.shard(id)
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(id)
            .cloned()
    }

    pub(crate) fn remove(&self, id: &str) -> Option<Arc<SessionEntry>> {
        self.shard(id)
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(id)
    }

    /// Remove `id` only while it still maps to `entry`. Deferred
    /// cleanups (a failed begin, a finished end) use this so they can
    /// never evict a successor session that reused the name after the
    /// original entry was already gone.
    pub(crate) fn remove_if(&self, id: &str, entry: &Arc<SessionEntry>) {
        let mut map = self.shard(id).lock().unwrap_or_else(|e| e.into_inner());
        if map.get(id).is_some_and(|e| Arc::ptr_eq(e, entry)) {
            map.remove(id);
        }
    }

    /// Total live sessions (reserved + fulfilled), across all shards.
    pub(crate) fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).len())
            .sum()
    }
}

impl Daemon {
    /// Build a daemon backed by a fixed fleet of `workers` threads.
    pub fn new(spec: Arc<Spec>, workers: usize) -> Daemon {
        Daemon::with_cfg(spec, workers, DaemonCfg::fixed(workers))
    }

    /// Build a daemon with explicit control-plane tuning: an AIMD
    /// worker-pool band (`workers..=cfg.max_workers`) and optional
    /// per-connection rate limiting.
    pub fn with_cfg(spec: Arc<Spec>, workers: usize, cfg: DaemonCfg) -> Daemon {
        let tel = if cfg.telemetry {
            Arc::new(Telemetry::new(TelemetryCfg {
                queue_capacity: 0,
                journal_dir: cfg.journal_dir.clone(),
            }))
        } else {
            Arc::new(Telemetry::disabled())
        };
        let scaling =
            (cfg.max_workers > workers).then(|| AimdCfg::bounded(workers, cfg.max_workers));
        let fleet = Fleet::with_telemetry(spec, workers, scaling, tel);
        Daemon {
            fleet: Arc::new(fleet),
            shared: Arc::new(Shared {
                sessions: SessionTable::new(16),
                shutdown: AtomicBool::new(false),
            }),
            cfg,
        }
    }

    /// Current fleet pool size (moves over time under AIMD scaling).
    pub fn num_workers(&self) -> usize {
        self.fleet.num_workers()
    }

    /// Serve on a Unix socket until a v1 `shutdown` request arrives. v1
    /// connections run on the non-blocking reactor; legacy connections
    /// get the old thread-per-connection path; the heavy lifting happens
    /// on the fleet workers either way. The socket file is removed on
    /// graceful exit, so restarts never depend on stale-socket cleanup.
    pub fn serve(&self, socket_path: &Path) -> anyhow::Result<()> {
        let _ = std::fs::remove_file(socket_path);
        let listener = UnixListener::bind(socket_path)?;
        eprintln!(
            "gpoeo daemon listening on {} ({} fleet workers, reactor, protocol v1 + legacy)",
            socket_path.display(),
            self.fleet.num_workers()
        );
        let r = Reactor::new(self.fleet.clone(), self.shared.clone(), self.cfg.clone())?
            .serve(listener);
        // Give the consumer thread a beat to land trailing journal
        // lines before the process (or test) moves on.
        self.fleet.telemetry().flush(Duration::from_millis(250));
        let _ = std::fs::remove_file(socket_path);
        r
    }
}

// ---------------------------------------------------------------------
// Accept-failure rate limiting.
// ---------------------------------------------------------------------

/// Log throttle + retry backoff for failed `accept()`s. A persistent
/// failure (EMFILE until fds free up) used to spam one log line per
/// failed accept in a tight loop; the gate logs once per window with a
/// suppressed-count summary and tells the reactor to stop re-polling the
/// listener for a short backoff.
pub(crate) struct AcceptGate {
    /// Minimum spacing between log lines.
    window: Duration,
    /// How long to stop accepting after a failure.
    backoff: Duration,
    last_log: Option<Instant>,
    suppressed: u64,
    resume_at: Option<Instant>,
}

impl AcceptGate {
    pub(crate) fn new() -> AcceptGate {
        AcceptGate::with_timing(Duration::from_secs(1), Duration::from_millis(50))
    }

    pub(crate) fn with_timing(window: Duration, backoff: Duration) -> AcceptGate {
        AcceptGate {
            window,
            backoff,
            last_log: None,
            suppressed: 0,
            resume_at: None,
        }
    }

    /// Record a failed accept at `now`. `Some(suppressed)` means "log
    /// now" and carries how many failures were swallowed since the last
    /// logged one; `None` means stay quiet.
    pub(crate) fn on_failure(&mut self, now: Instant) -> Option<u64> {
        self.resume_at = Some(now + self.backoff);
        match self.last_log {
            Some(t) if now.duration_since(t) < self.window => {
                self.suppressed += 1;
                None
            }
            _ => {
                self.last_log = Some(now);
                Some(std::mem::take(&mut self.suppressed))
            }
        }
    }

    /// Should the accept loop hold off (skip polling the listener)?
    pub(crate) fn in_backoff(&self, now: Instant) -> bool {
        self.resume_at.is_some_and(|t| now < t)
    }
}

/// The accept-loop body: a successful accept yields the stream; a failed
/// one is logged through the gate and skipped (`None`), so a *persistent*
/// failure degrades to one log line per gate window (with a suppressed
/// count) and a bounded retry cadence instead of a 100%-CPU log-spam
/// spin. Extracted so the never-kill-the-daemon contract is unit-testable
/// without a listener.
pub(crate) fn accept_stream(
    r: std::io::Result<UnixStream>,
    gate: &mut AcceptGate,
    now: Instant,
) -> Option<UnixStream> {
    match r {
        Ok(s) => Some(s),
        Err(e) => {
            match gate.on_failure(now) {
                Some(0) => eprintln!("daemon accept error: {e} (continuing to serve)"),
                Some(n) => eprintln!(
                    "daemon accept error: {e} (continuing to serve; {n} similar suppressed)"
                ),
                None => {}
            }
            None
        }
    }
}

// ---------------------------------------------------------------------
// Shared v1 helpers (used by the reactor).
// ---------------------------------------------------------------------

/// The optional iteration count of a `begin`: explicit wins, absent
/// means the app's default workload size — the *same* default `gpoeo
/// run` uses, so daemon and CLI never disagree on what "run this app"
/// means. (The legacy daemon hardcoded 300 here.)
pub(crate) fn resolve_iters(requested: Option<u64>, app: &AppParams) -> u64 {
    requested.unwrap_or_else(|| default_iters(app))
}

pub(crate) fn report(id: &str, st: SessionStatus) -> SessionReport {
    SessionReport {
        session: id.to_string(),
        iterations: st.iterations,
        target_iters: st.target_iters,
        time_s: st.time_s,
        energy_j: st.energy_j,
        sm_gear: st.sm_gear,
        mem_gear: st.mem_gear,
        done: st.done,
    }
}

/// Everything a `begin` resolves *before* any fleet traffic: the app,
/// the iteration target, and a reserved table slot. Failing here (bad
/// app, unknown policy, taken name) costs no worker round-trip.
pub(crate) struct PreparedBegin {
    pub(crate) id: String,
    pub(crate) app: AppParams,
    pub(crate) n_iters: u64,
}

pub(crate) fn prepare_begin(
    fleet: &Arc<Fleet>,
    shared: &Shared,
    app_name: &str,
    iters: Option<u64>,
    name: Option<String>,
    policy: &PolicySpec,
) -> anyhow::Result<PreparedBegin> {
    let app = find_app(fleet.spec(), app_name)?;
    let n_iters = resolve_iters(iters, &app);
    // Fail on unknown policy names here, with the registry's canonical
    // error, before any fleet traffic.
    PolicyRegistry::global().get(&policy.name)?;
    let id = shared.sessions.reserve(name)?;
    Ok(PreparedBegin { id, app, n_iters })
}

fn lookup(shared: &Shared, id: &str) -> anyhow::Result<Arc<SessionEntry>> {
    shared
        .sessions
        .get(id)
        .ok_or_else(|| anyhow::anyhow!("no such session '{id}'"))
}

/// Run `f` on the live handle of session `id` (held under the entry
/// lock — concurrent polls of one session serialize; different sessions
/// don't).
pub(crate) fn with_session<T>(
    shared: &Shared,
    id: &str,
    f: impl FnOnce(&SessionHandle) -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    let entry = lookup(shared, id)?;
    let guard = entry.handle.lock().unwrap_or_else(|e| e.into_inner());
    match guard.as_ref() {
        Some(h) => f(h),
        None => anyhow::bail!("session '{id}' is no longer active"),
    }
}

/// Move the handle out of session `id` (for `end`/`abort`). Exactly one
/// claimer wins; the table entry itself is removed by the caller once
/// the terminal operation finishes — via [`SessionTable::remove_if`]
/// with the returned entry, so a deferred cleanup cannot evict a
/// successor session that reused the name.
pub(crate) fn claim_session(
    shared: &Shared,
    id: &str,
) -> anyhow::Result<(Arc<SessionEntry>, SessionHandle)> {
    let entry = lookup(shared, id)?;
    let h = entry
        .handle
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .take()
        .ok_or_else(|| anyhow::anyhow!("session '{id}' is no longer active"))?;
    Ok((entry, h))
}

/// `list_apps`: every app the daemon can `begin`, with the workload
/// size a default `begin` would run.
pub(crate) fn list_apps(spec: &Arc<Spec>) -> anyhow::Result<Vec<AppInfo>> {
    let mut out = Vec::new();
    for (sname, suite) in &spec.suites {
        for e in &suite.apps {
            let app = make_app(spec, sname, &e.name)?;
            out.push(AppInfo {
                name: app.name.clone(),
                suite: sname.clone(),
                archetype: app.archetype.clone(),
                aperiodic: app.aperiodic,
                default_iters: default_iters(&app),
            });
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Legacy protocol (unchanged surface; see module docs).
// ---------------------------------------------------------------------

/// The optional iteration-count token of `BEGIN <app> [iters]`: absent
/// means the app default (resolved later via [`resolve_iters`]), present
/// must parse as a positive `u64`. Non-numeric, zero, negative and
/// overflowing counts all answer `ERR bad iteration count ...`.
fn parse_iters(tok: Option<&str>) -> Result<Option<u64>, String> {
    match tok {
        None => Ok(None),
        Some(t) => match t.parse::<u64>() {
            Ok(0) => Err(format!("bad iteration count '{t}' (must be positive)")),
            Ok(n) => Ok(Some(n)),
            Err(_) => Err(format!(
                "bad iteration count '{t}' (expected a positive integer)"
            )),
        },
    }
}

/// The blocking legacy-protocol loop. Generic over reader/writer so the
/// reactor can hand a sniffed connection over with its first bytes
/// re-attached (a `Chain` of the buffered prefix and the raw stream).
pub(crate) fn handle_legacy<R: BufRead, W: Write>(
    reader: R,
    mut writer: W,
    fleet: &Arc<Fleet>,
) -> anyhow::Result<()> {
    // The connection's active session, if any. Dropped (aborted) if the
    // client disconnects without END.
    let mut session: Option<SessionHandle> = None;
    // The policy the next BEGIN will run (selected via POLICY).
    let mut policy = PolicySpec::registered("gpoeo");

    for line in reader.lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("POLICY") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    match parts.next() {
                        None => writeln!(
                            writer,
                            "ERR POLICY requires a name (see `gpoeo policies`)"
                        )?,
                        // Reject trailing tokens instead of silently
                        // ignoring them — a client sending `POLICY bandit
                        // bandit-algo=exp3` must not quietly run defaults
                        // (configured policies are a v1 affair: the
                        // `begin` request carries an inline config).
                        Some(_) if line.split_whitespace().count() > 2 => writeln!(
                            writer,
                            "ERR POLICY takes a single name (configs need protocol v1 / gpoeo ctl)"
                        )?,
                        Some(name) => match PolicyRegistry::global().get(name) {
                            Ok(_) => {
                                policy = PolicySpec::registered(name);
                                writeln!(writer, "OK policy {name}")?;
                            }
                            Err(e) => writeln!(writer, "ERR {e}")?,
                        },
                    }
                }
            }
            Some("BEGIN") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    let name = parts.next().unwrap_or("");
                    match parse_iters(parts.next()) {
                        Err(msg) => writeln!(writer, "ERR {msg}")?,
                        Ok(iters) => {
                            let started = find_app(fleet.spec(), name).and_then(|app| {
                                let n = resolve_iters(iters, &app);
                                fleet.begin(app, policy.clone(), n)
                            });
                            match started {
                                Ok(h) => {
                                    session = Some(h);
                                    writeln!(writer, "OK session started")?;
                                }
                                Err(e) => writeln!(writer, "ERR {e}")?,
                            }
                        }
                    }
                }
            }
            Some("STATUS") => {
                let status = match session.as_ref() {
                    // Drive a slice of virtual time per STATUS poll.
                    Some(h) => h.step(STATUS_TICKS),
                    None => Err(anyhow::anyhow!("no session")),
                };
                match status {
                    Ok(st) => writeln!(
                        writer,
                        "STATUS {} {:.3} {:.1} {} {}",
                        st.iterations, st.time_s, st.energy_j, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                }
            }
            Some("END") => match session.take() {
                // end() blocks this connection until the run finishes,
                // but the fleet worker drives it in slices, so other
                // connections' sessions keep being served meanwhile.
                Some(h) => match h.end() {
                    Ok(st) => writeln!(
                        writer,
                        "RESULT {:.1} {:.3} {} {} {}",
                        st.energy_j, st.time_s, st.iterations, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                },
                None => writeln!(writer, "ERR no session")?,
            },
            Some("QUIT") | None => break,
            Some(other) => writeln!(writer, "ERR unknown command '{other}'")?,
        }
        writer.flush()?;
    }
    Ok(())
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Predictor;
    use std::io::{BufRead, BufReader};

    /// Start a daemon on a fresh socket; returns the socket path.
    fn spawn_daemon(tag: &str, workers: usize) -> std::path::PathBuf {
        let spec = Arc::new(Spec::load_default().unwrap());
        let daemon = Daemon::new(spec, workers);
        let dir = std::env::temp_dir().join(format!("gpoeo-test-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("d.sock");
        let sock2 = sock.clone();
        std::thread::spawn(move || {
            let _ = daemon.serve(&sock2);
        });
        for _ in 0..100 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        sock
    }

    struct Client {
        w: UnixStream,
        r: BufReader<UnixStream>,
    }

    impl Client {
        fn connect(sock: &Path) -> Client {
            let stream = UnixStream::connect(sock).unwrap();
            let w = stream.try_clone().unwrap();
            Client {
                w,
                r: BufReader::new(stream),
            }
        }

        fn roundtrip(&mut self, cmd: &str) -> String {
            writeln!(self.w, "{cmd}").unwrap();
            let mut line = String::new();
            self.r.read_line(&mut line).unwrap();
            line
        }
    }

    #[test]
    fn begin_status_end_roundtrip() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("roundtrip", 2);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 40");
        assert!(line.starts_with("OK"), "{line}");

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("STATUS"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let parts: Vec<&str> = line.split_whitespace().collect();
        let iters: u64 = parts[3].parse().unwrap();
        assert!(iters >= 40);

        let line = c.roundtrip("BOGUS");
        assert!(line.starts_with("ERR"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn protocol_error_paths_always_answer() {
        // None of these paths needs model artifacts: the daemon must
        // answer ERR (never close silently) regardless.
        let sock = spawn_daemon("errors", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("BEGIN NOT_AN_APP 10");
        assert!(line.starts_with("ERR"), "{line}");
        // Unknown app or missing predictor — either way a reason arrives.
        assert!(line.trim().len() > "ERR".len(), "reason required: {line}");

        let line = c.roundtrip("BEGIN");
        assert!(line.starts_with("ERR"), "{line}");

        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn parse_iters_contract() {
        // Absent token → None: the daemon resolves it per app, exactly
        // like `gpoeo run` (see resolve_iters_matches_cli_default).
        assert_eq!(parse_iters(None), Ok(None));
        assert_eq!(parse_iters(Some("42")), Ok(Some(42)));
        for bad in ["abc", "0", "-5", "12.5", "1e6", "18446744073709551616", ""] {
            let r = parse_iters(Some(bad));
            assert!(
                matches!(&r, Err(msg) if msg.starts_with("bad iteration count")),
                "{bad:?} -> {r:?}"
            );
        }
    }

    #[test]
    fn resolve_iters_matches_cli_default() {
        // `BEGIN <app>` without a count must run the same workload size
        // as `gpoeo run --app <app>` — default_iters, not a hardcoded
        // 300 (they disagreed for every app whose t_base makes
        // default_iters exceed the floor).
        let spec = Arc::new(Spec::load_default().unwrap());
        let mut checked_above_floor = false;
        for suite in spec.suites.keys() {
            for app in crate::sim::make_suite(&spec, suite).unwrap() {
                assert_eq!(resolve_iters(None, &app), default_iters(&app), "{}", app.name);
                assert_eq!(resolve_iters(Some(40), &app), 40);
                checked_above_floor |= default_iters(&app) > 300;
            }
        }
        assert!(
            checked_above_floor,
            "suite must contain an app where the old hardcoded 300 was wrong"
        );
    }

    #[test]
    fn accept_failure_is_skipped_not_fatal() {
        // The accept-loop body: an Err must be swallowed (logged through
        // the gate) and answered with None — never propagated to kill
        // serve().
        let mut gate = AcceptGate::new();
        let now = Instant::now();
        let err = std::io::Error::other("simulated EMFILE");
        assert!(accept_stream(Err(err), &mut gate, now).is_none());
        let (a, _b) = UnixStream::pair().unwrap();
        assert!(accept_stream(Ok(a), &mut gate, now).is_some());
    }

    #[test]
    fn accept_gate_logs_once_per_window_with_a_suppressed_count() {
        // A persistent EMFILE used to log one line per failed accept in
        // a tight loop. The gate: first failure logs immediately, the
        // storm inside the window stays silent, and the next window's
        // line carries the suppressed count.
        let window = Duration::from_secs(1);
        let backoff = Duration::from_millis(50);
        let mut gate = AcceptGate::with_timing(window, backoff);
        let t0 = Instant::now();

        assert_eq!(gate.on_failure(t0), Some(0), "first failure logs");
        // 100 more failures inside the window: all suppressed.
        for i in 1..=100u64 {
            let t = t0 + Duration::from_millis(i);
            assert_eq!(gate.on_failure(t), None, "failure {i} must be quiet");
        }
        // Past the window: one line, carrying the 100 suppressed.
        let t = t0 + window + Duration::from_millis(1);
        assert_eq!(gate.on_failure(t), Some(100));
        // The counter reset with that summary.
        let t = t0 + window + Duration::from_millis(2);
        assert_eq!(gate.on_failure(t), None);

        // Backoff: active right after a failure, expired after the pause.
        assert!(gate.in_backoff(t));
        assert!(!gate.in_backoff(t + backoff));

        // The whole storm still answers None (skip), never an abort —
        // and a healthy accept goes straight through mid-storm.
        let err = std::io::Error::other("simulated EMFILE");
        assert!(accept_stream(Err(err), &mut gate, t).is_none());
        let (a, _b) = UnixStream::pair().unwrap();
        assert!(accept_stream(Ok(a), &mut gate, t).is_some());
    }

    #[test]
    fn session_table_shards_ids_and_reserves_uniquely() {
        let t = SessionTable::new(16);
        // Named reservation: once, then refused while live.
        assert_eq!(t.reserve(Some("train-a".into())).unwrap(), "train-a");
        let err = t.reserve(Some("train-a".into())).unwrap_err().to_string();
        assert!(err.contains("already exists"), "{err}");

        // Generated ids skip squatted names (shared id space), stay
        // unique, and land in whatever shard their hash picks.
        assert_eq!(t.reserve(Some("s1".into())).unwrap(), "s1");
        assert_eq!(t.reserve(Some("s2".into())).unwrap(), "s2");
        let mut seen = std::collections::HashSet::new();
        seen.extend(["train-a".to_string(), "s1".into(), "s2".into()]);
        for _ in 0..200 {
            let id = t.reserve(None).unwrap();
            assert!(seen.insert(id.clone()), "duplicate id {id}");
        }
        assert_eq!(t.len(), 203);

        // Remove frees the name for re-reservation.
        assert!(t.remove("train-a").is_some());
        assert!(t.get("train-a").is_none());
        assert!(t.reserve(Some("train-a".into())).is_ok());
        assert!(t.remove("nope").is_none());
    }

    #[test]
    fn session_table_shard_count_rounds_to_power_of_two() {
        // The mask-based shard pick requires a power-of-two count; odd
        // requests round up rather than biasing the distribution.
        for n in [1, 3, 16, 17] {
            let t = SessionTable::new(n);
            assert!(t.shards.len().is_power_of_two(), "{n}");
            assert!(t.shards.len() >= n.max(1), "{n}");
            // Every id maps to a valid shard (the mask can't overflow).
            for i in 0..64 {
                let id = format!("s{i}");
                let _ = t.shard(&id);
            }
        }
    }

    #[test]
    fn begin_rejects_bad_iteration_counts() {
        // None of these needs model artifacts: the count is validated
        // before the app lookup or any fleet work.
        let sock = spawn_daemon("iters", 1);
        let mut c = Client::connect(&sock);
        for cmd in [
            "BEGIN AI_TS abc",
            "BEGIN AI_TS 0",
            "BEGIN AI_TS -5",
            "BEGIN AI_TS 12.5",
            "BEGIN AI_TS 18446744073709551616",
        ] {
            let line = c.roundtrip(cmd);
            assert!(line.starts_with("ERR bad iteration count"), "{cmd}: {line}");
        }
        // The connection stays healthy: a clean BEGIN still works
        // (artifact-free policy, so this runs everywhere).
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        let line = c.roundtrip("BEGIN AI_TS 20");
        assert!(line.starts_with("OK"), "{line}");
        assert!(c.roundtrip("END").starts_with("RESULT"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_selection_before_begin() {
        // `bandit` needs no model artifacts, so the full POLICY→BEGIN→END
        // cycle runs everywhere (including CI without `make artifacts`).
        let sock = spawn_daemon("policy", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("POLICY NOT_A_POLICY");
        assert!(line.starts_with("ERR unknown policy"), "{line}");

        let line = c.roundtrip("POLICY");
        assert!(line.starts_with("ERR POLICY requires a name"), "{line}");

        let line = c.roundtrip("POLICY bandit bandit-algo=exp3");
        assert!(line.starts_with("ERR POLICY takes a single name"), "{line}");

        let line = c.roundtrip("POLICY bandit");
        assert!(line.starts_with("OK policy bandit"), "{line}");

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");

        // Mid-session re-selection is rejected; the session is untouched.
        let line = c.roundtrip("POLICY odpp");
        assert!(line.starts_with("ERR session already active"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let iters: u64 = line.split_whitespace().nth(3).unwrap().parse().unwrap();
        assert!(iters >= 30);
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_survives_across_sessions_per_connection() {
        // The POLICY selection applies to every subsequent BEGIN on the
        // same connection until changed (odpp is artifact-free too).
        let sock = spawn_daemon("policy2", 1);
        let mut c = Client::connect(&sock);
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        for _ in 0..2 {
            let line = c.roundtrip("BEGIN AI_FE 20");
            assert!(line.starts_with("OK"), "{line}");
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
        }
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn double_begin_is_rejected() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("double", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");
        let line = c.roundtrip("BEGIN AI_FE 30");
        assert!(line.starts_with("ERR session already active"), "{line}");
        // The original session is untouched and still ENDs normally.
        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn concurrent_connections_share_the_fleet() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("concurrent", 2);
        let mut clients: Vec<Client> = (0..3).map(|_| Client::connect(&sock)).collect();
        for (c, app) in clients.iter_mut().zip(["AI_TS", "AI_FE", "AI_OBJ"]) {
            let line = c.roundtrip(&format!("BEGIN {app} 30"));
            assert!(line.starts_with("OK"), "{app}: {line}");
        }
        for c in &mut clients {
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
            writeln!(c.w, "QUIT").unwrap();
        }
    }
}
