//! The daemon: control-plane API v1 + the legacy Begin/End line protocol
//! (§2.2.2/§4.2 of the paper; DESIGN.md §6 and §9).
//!
//! The paper's deployment model is a two-call micro-intrusive API
//! (`Begin` at the start of the training region, `End` at the end) with
//! a separate optimizer process owning the GPU clocks. This daemon is
//! that optimizer process over a Unix socket, serving two protocols on
//! one listener with a per-connection auto-detect on the first byte:
//!
//! - `{` → **protocol v1** (line-delimited JSON, `hello` handshake):
//!   typed requests from [`crate::api`], multiple concurrent *named*
//!   sessions (daemon-global table — `begin` returns a session id,
//!   `status`/`end`/`abort`/`subscribe` take one, any connection can
//!   address any session), per-`begin` policy selection with inline
//!   config resolved through [`PolicyRegistry`], introspection
//!   (`list_apps`/`list_policies`), streamed `subscribe` telemetry, and
//!   a `shutdown` request that exits the accept loop and removes the
//!   socket file.
//! - anything else → the **legacy protocol**, unchanged: one session per
//!   connection, `POLICY <name>` / `BEGIN <app> [iters]` / `STATUS` /
//!   `END` / `QUIT`, answers `OK`/`STATUS`/`RESULT`/`ERR` lines.
//!
//! Both protocols resolve `BEGIN` without an iteration count to
//! [`default_iters`] — the same default `gpoeo run` uses — and both are
//! served by one shared [`Fleet`], so a v1 and a legacy session with the
//! same (app, policy, iters) produce bit-identical results (the parity
//! contract, tested in `tests/api_daemon.rs` and gated in CI).
//!
//! Every failure path answers a typed `Response::Error` (v1) or an
//! `ERR <reason>` line (legacy) — a client never hangs on a silent
//! close, and a malformed line never kills the connection loop. A failed
//! `accept()` is logged and skipped, never fatal to the daemon.

use crate::api::{
    read_frame, AppInfo, Event, Frame, PolicyInfo, Request, Response, ServerMsg, SessionReport,
    MAX_LINE_BYTES, PROTOCOL_VERSION,
};
use crate::coordinator::{default_iters, Fleet, SessionHandle, SessionStatus};
use crate::policy::{PolicyRegistry, PolicySpec};
use crate::sim::{find_app, make_app, AppParams, Spec};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Controller ticks driven per `STATUS`/`status` poll.
const STATUS_TICKS: u64 = 200;

pub struct Daemon {
    fleet: Arc<Fleet>,
    shared: Arc<Shared>,
}

/// Daemon-global state shared by every connection: the named-session
/// table and the shutdown latch.
struct Shared {
    sessions: Mutex<HashMap<String, Arc<SessionEntry>>>,
    next_id: AtomicU64,
    shutdown: AtomicBool,
}

/// One v1 session. The handle moves out (`None`) exactly once, when an
/// `end`/`abort` claims it — concurrent claims lose cleanly instead of
/// double-ending.
struct SessionEntry {
    handle: Mutex<Option<SessionHandle>>,
}

impl Daemon {
    /// Build a daemon backed by a fleet of `workers` threads.
    pub fn new(spec: Arc<Spec>, workers: usize) -> Daemon {
        Daemon {
            fleet: Arc::new(Fleet::new(spec, workers)),
            shared: Arc::new(Shared {
                sessions: Mutex::new(HashMap::new()),
                next_id: AtomicU64::new(1),
                shutdown: AtomicBool::new(false),
            }),
        }
    }

    /// Serve on a Unix socket (one lightweight thread per connection;
    /// the heavy lifting happens on the fleet workers) until a v1
    /// `shutdown` request arrives. The socket file is removed on
    /// graceful exit, so restarts never depend on stale-socket cleanup.
    pub fn serve(&self, socket_path: &Path) -> anyhow::Result<()> {
        let _ = std::fs::remove_file(socket_path);
        let listener = UnixListener::bind(socket_path)?;
        eprintln!(
            "gpoeo daemon listening on {} ({} fleet workers, protocol v{PROTOCOL_VERSION} + legacy)",
            socket_path.display(),
            self.fleet.num_workers()
        );
        for stream in listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // A transient accept failure (EMFILE, ECONNABORTED, ...)
            // must not take the whole daemon down with it.
            let Some(stream) = accept_stream(stream) else {
                continue;
            };
            let fleet = self.fleet.clone();
            let shared = self.shared.clone();
            let path = socket_path.to_path_buf();
            std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, fleet, shared, path) {
                    eprintln!("daemon connection error: {e}");
                }
            });
        }
        let _ = std::fs::remove_file(socket_path);
        Ok(())
    }
}

/// The accept-loop body: a successful accept yields the stream; a failed
/// one is logged and skipped (`None`) after a short sleep, so a
/// *persistent* failure (EMFILE until fds free up) degrades to a bounded
/// retry cadence instead of a 100%-CPU log-spam spin. Extracted so the
/// never-kill-the-daemon contract is unit-testable without a listener.
fn accept_stream(r: std::io::Result<UnixStream>) -> Option<UnixStream> {
    match r {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("daemon accept error: {e} (continuing to serve)");
            std::thread::sleep(std::time::Duration::from_millis(50));
            None
        }
    }
}

/// The optional iteration count of a `begin`: explicit wins, absent
/// means the app's default workload size — the *same* default `gpoeo
/// run` uses, so daemon and CLI never disagree on what "run this app"
/// means. (The legacy daemon hardcoded 300 here.)
fn resolve_iters(requested: Option<u64>, app: &AppParams) -> u64 {
    requested.unwrap_or_else(|| default_iters(app))
}

/// Sniff the first byte to pick the protocol: v1 frames are JSON objects
/// so they always start with `{`; no legacy command does.
fn handle_connection(
    stream: UnixStream,
    fleet: Arc<Fleet>,
    shared: Arc<Shared>,
    socket_path: PathBuf,
) -> anyhow::Result<()> {
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let first = reader.fill_buf()?.first().copied();
    match first {
        None => Ok(()), // connected and left without a byte
        Some(b'{') => handle_v1(reader, writer, &fleet, &shared, &socket_path),
        Some(_) => handle_legacy(reader, writer, &fleet),
    }
}

// ---------------------------------------------------------------------
// Protocol v1.
// ---------------------------------------------------------------------

fn send_msg(writer: &mut UnixStream, msg: &ServerMsg) -> std::io::Result<()> {
    writer.write_all(msg.to_line().as_bytes())?;
    writer.flush()
}

fn send_response(writer: &mut UnixStream, r: Response) -> std::io::Result<()> {
    send_msg(writer, &ServerMsg::Response(r))
}

fn report(id: &str, st: SessionStatus) -> SessionReport {
    SessionReport {
        session: id.to_string(),
        iterations: st.iterations,
        target_iters: st.target_iters,
        time_s: st.time_s,
        energy_j: st.energy_j,
        sm_gear: st.sm_gear,
        mem_gear: st.mem_gear,
        done: st.done,
    }
}

fn handle_v1(
    mut reader: BufReader<UnixStream>,
    mut writer: UnixStream,
    fleet: &Arc<Fleet>,
    shared: &Arc<Shared>,
    socket_path: &Path,
) -> anyhow::Result<()> {
    // The connection's default policy for `begin`s without an inline one.
    let mut default_policy = PolicySpec::registered("gpoeo");
    let mut hello_done = false;

    loop {
        let line = match read_frame(&mut reader, MAX_LINE_BYTES)? {
            Frame::Eof => break,
            Frame::Oversized => {
                send_response(
                    &mut writer,
                    Response::error(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                )?;
                continue;
            }
            Frame::Line(l) => l,
        };
        if line.trim().is_empty() {
            continue;
        }
        let req = match Request::parse_line(&line) {
            Ok(r) => r,
            Err(msg) => {
                send_response(&mut writer, Response::error(msg))?;
                continue;
            }
        };
        if !hello_done && !matches!(req, Request::Hello { .. }) {
            send_response(
                &mut writer,
                Response::error(format!(
                    "handshake required: send {{\"kind\":\"hello\",\"v\":{PROTOCOL_VERSION}}} first"
                )),
            )?;
            continue;
        }
        match req {
            Request::Hello { version } => {
                if version == 0 || version > PROTOCOL_VERSION {
                    send_response(
                        &mut writer,
                        Response::error(format!(
                            "unsupported protocol version {version} (this server speaks v{PROTOCOL_VERSION})"
                        )),
                    )?;
                } else {
                    hello_done = true;
                    send_response(
                        &mut writer,
                        Response::Hello {
                            protocol: PROTOCOL_VERSION,
                            server: format!("gpoeo {}", env!("CARGO_PKG_VERSION")),
                        },
                    )?;
                }
            }
            Request::Begin {
                app,
                iters,
                name,
                policy,
            } => {
                let spec = policy.unwrap_or_else(|| default_policy.clone());
                let r = begin_session(fleet, shared, &app, iters, name, spec);
                send_response(
                    &mut writer,
                    match r {
                        Ok(session) => Response::Begun { session },
                        Err(e) => Response::error(format!("{e:#}")),
                    },
                )?;
            }
            Request::Status { session } => {
                let r = with_session(shared, &session, |h| h.step(STATUS_TICKS));
                send_response(
                    &mut writer,
                    match r {
                        Ok(st) => Response::Status(report(&session, st)),
                        Err(e) => Response::error(format!("{e:#}")),
                    },
                )?;
            }
            Request::End { session } => {
                // Claim the handle, then run to completion *outside* any
                // lock: end() blocks until the target is reached, and
                // other sessions (and other connections) must keep
                // being served meanwhile.
                let r = claim_session(shared, &session).and_then(|h| {
                    let st = h.end();
                    shared.sessions.lock().unwrap().remove(&session);
                    st
                });
                send_response(
                    &mut writer,
                    match r {
                        Ok(st) => Response::Result(report(&session, st)),
                        Err(e) => Response::error(format!("{e:#}")),
                    },
                )?;
            }
            Request::Abort { session } => {
                let r = claim_session(shared, &session).map(|h| {
                    h.abort();
                    shared.sessions.lock().unwrap().remove(&session);
                });
                send_response(
                    &mut writer,
                    match r {
                        Ok(()) => Response::Ok {
                            detail: format!("session {session} aborted"),
                        },
                        Err(e) => Response::error(format!("{e:#}")),
                    },
                )?;
            }
            Request::SetPolicy { policy } => {
                match PolicyRegistry::global().get(&policy.name) {
                    Ok(_) => {
                        let detail = format!("policy {}", policy.name);
                        default_policy = policy;
                        send_response(&mut writer, Response::Ok { detail })?;
                    }
                    Err(e) => send_response(&mut writer, Response::error(format!("{e:#}")))?,
                };
            }
            Request::ListApps => {
                let r = list_apps(fleet.spec());
                send_response(
                    &mut writer,
                    match r {
                        Ok(apps) => Response::Apps(apps),
                        Err(e) => Response::error(format!("{e:#}")),
                    },
                )?;
            }
            Request::ListPolicies => {
                let ps = PolicyRegistry::global()
                    .iter()
                    .map(|b| PolicyInfo {
                        name: b.name().to_string(),
                        description: b.describe().to_string(),
                        default_config: b.default_config(),
                    })
                    .collect();
                send_response(&mut writer, Response::Policies(ps))?;
            }
            Request::Subscribe {
                session,
                every_ticks,
                max_events,
            } => subscribe(shared, &mut writer, &session, every_ticks, max_events)?,
            Request::Shutdown => {
                send_response(
                    &mut writer,
                    Response::Ok {
                        detail: "daemon shutting down".to_string(),
                    },
                )?;
                shared.shutdown.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the latch; the
                // connect itself is inert (dropped before any byte).
                let _ = UnixStream::connect(socket_path);
                break;
            }
        }
    }
    Ok(())
}

/// Start a session and register it in the daemon-global table under its
/// (client-proposed or generated) id.
fn begin_session(
    fleet: &Arc<Fleet>,
    shared: &Arc<Shared>,
    app_name: &str,
    iters: Option<u64>,
    name: Option<String>,
    policy: PolicySpec,
) -> anyhow::Result<String> {
    let app = find_app(fleet.spec(), app_name)?;
    let n_iters = resolve_iters(iters, &app);
    // Fail on unknown policy names here, with the registry's canonical
    // error, before any fleet traffic.
    PolicyRegistry::global().get(&policy.name)?;
    // Reserve an id first (an empty entry), then begin outside the map
    // lock: a Begin can trigger a worker's first predictor load, and the
    // table must stay responsive to other connections meanwhile. A
    // client-proposed name must be free; a generated `s<N>` skips any
    // ids a client happened to claim (names share the id space).
    let id = {
        let mut map = shared.sessions.lock().unwrap();
        let id = match name {
            Some(n) => {
                if map.contains_key(&n) {
                    anyhow::bail!("session '{n}' already exists");
                }
                n
            }
            None => loop {
                let candidate = format!("s{}", shared.next_id.fetch_add(1, Ordering::SeqCst));
                if !map.contains_key(&candidate) {
                    break candidate;
                }
            },
        };
        map.insert(
            id.clone(),
            Arc::new(SessionEntry {
                handle: Mutex::new(None),
            }),
        );
        id
    };
    match fleet.begin(app, policy, n_iters) {
        Ok(h) => {
            let map = shared.sessions.lock().unwrap();
            // The reservation cannot have been claimed: end/abort on an
            // empty entry answer "no longer active" without removing it.
            *map[&id].handle.lock().unwrap() = Some(h);
            Ok(id)
        }
        Err(e) => {
            shared.sessions.lock().unwrap().remove(&id);
            Err(e)
        }
    }
}

fn lookup(shared: &Shared, id: &str) -> anyhow::Result<Arc<SessionEntry>> {
    shared
        .sessions
        .lock()
        .unwrap()
        .get(id)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("no such session '{id}'"))
}

/// Run `f` on the live handle of session `id` (held under the entry
/// lock — concurrent polls of one session serialize; different sessions
/// don't).
fn with_session<T>(
    shared: &Shared,
    id: &str,
    f: impl FnOnce(&SessionHandle) -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    let entry = lookup(shared, id)?;
    let guard = entry.handle.lock().unwrap();
    match guard.as_ref() {
        Some(h) => f(h),
        None => anyhow::bail!("session '{id}' is no longer active"),
    }
}

/// Move the handle out of session `id` (for `end`/`abort`). Exactly one
/// claimer wins; the table entry itself is removed by the caller once
/// the terminal operation finishes.
fn claim_session(shared: &Shared, id: &str) -> anyhow::Result<SessionHandle> {
    let entry = lookup(shared, id)?;
    let mut guard = entry.handle.lock().unwrap();
    guard
        .take()
        .ok_or_else(|| anyhow::anyhow!("session '{id}' is no longer active"))
}

/// Drive the session and stream `Event::Status` telemetry: one event per
/// `every_ticks` ticks until the session reaches its target (or
/// `max_events` events, when non-zero), then a final `Response::Status`
/// snapshot ends the stream. The session stays registered — `end` still
/// owns the result.
fn subscribe(
    shared: &Arc<Shared>,
    writer: &mut UnixStream,
    id: &str,
    every_ticks: u64,
    max_events: u64,
) -> std::io::Result<()> {
    let mut sent = 0u64;
    let last = loop {
        // Re-acquire per slice so ends/aborts/other subscribers of the
        // same session interleave instead of starving.
        let st = match with_session(shared, id, |h| h.step(every_ticks)) {
            Ok(st) => st,
            Err(e) => return send_response(writer, Response::error(format!("{e:#}"))),
        };
        send_msg(writer, &ServerMsg::Event(Event::Status(report(id, st))))?;
        sent += 1;
        if st.done || (max_events > 0 && sent >= max_events) {
            break st;
        }
    };
    send_response(writer, Response::Status(report(id, last)))
}

/// `list_apps`: every app the daemon can `begin`, with the workload
/// size a default `begin` would run.
fn list_apps(spec: &Arc<Spec>) -> anyhow::Result<Vec<AppInfo>> {
    let mut out = Vec::new();
    for (sname, suite) in &spec.suites {
        for e in &suite.apps {
            let app = make_app(spec, sname, &e.name)?;
            out.push(AppInfo {
                name: app.name.clone(),
                suite: sname.clone(),
                archetype: app.archetype.clone(),
                aperiodic: app.aperiodic,
                default_iters: default_iters(&app),
            });
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Legacy protocol (unchanged surface; see module docs).
// ---------------------------------------------------------------------

/// The optional iteration-count token of `BEGIN <app> [iters]`: absent
/// means the app default (resolved later via [`resolve_iters`]), present
/// must parse as a positive `u64`. Non-numeric, zero, negative and
/// overflowing counts all answer `ERR bad iteration count ...`.
fn parse_iters(tok: Option<&str>) -> Result<Option<u64>, String> {
    match tok {
        None => Ok(None),
        Some(t) => match t.parse::<u64>() {
            Ok(0) => Err(format!("bad iteration count '{t}' (must be positive)")),
            Ok(n) => Ok(Some(n)),
            Err(_) => Err(format!(
                "bad iteration count '{t}' (expected a positive integer)"
            )),
        },
    }
}

fn handle_legacy(
    reader: BufReader<UnixStream>,
    mut writer: UnixStream,
    fleet: &Arc<Fleet>,
) -> anyhow::Result<()> {
    // The connection's active session, if any. Dropped (aborted) if the
    // client disconnects without END.
    let mut session: Option<SessionHandle> = None;
    // The policy the next BEGIN will run (selected via POLICY).
    let mut policy = PolicySpec::registered("gpoeo");

    for line in reader.lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("POLICY") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    match parts.next() {
                        None => writeln!(
                            writer,
                            "ERR POLICY requires a name (see `gpoeo policies`)"
                        )?,
                        // Reject trailing tokens instead of silently
                        // ignoring them — a client sending `POLICY bandit
                        // bandit-algo=exp3` must not quietly run defaults
                        // (configured policies are a v1 affair: the
                        // `begin` request carries an inline config).
                        Some(_) if line.split_whitespace().count() > 2 => writeln!(
                            writer,
                            "ERR POLICY takes a single name (configs need protocol v1 / gpoeo ctl)"
                        )?,
                        Some(name) => match PolicyRegistry::global().get(name) {
                            Ok(_) => {
                                policy = PolicySpec::registered(name);
                                writeln!(writer, "OK policy {name}")?;
                            }
                            Err(e) => writeln!(writer, "ERR {e}")?,
                        },
                    }
                }
            }
            Some("BEGIN") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    let name = parts.next().unwrap_or("");
                    match parse_iters(parts.next()) {
                        Err(msg) => writeln!(writer, "ERR {msg}")?,
                        Ok(iters) => {
                            let started = find_app(fleet.spec(), name).and_then(|app| {
                                let n = resolve_iters(iters, &app);
                                fleet.begin(app, policy.clone(), n)
                            });
                            match started {
                                Ok(h) => {
                                    session = Some(h);
                                    writeln!(writer, "OK session started")?;
                                }
                                Err(e) => writeln!(writer, "ERR {e}")?,
                            }
                        }
                    }
                }
            }
            Some("STATUS") => {
                let status = match session.as_ref() {
                    // Drive a slice of virtual time per STATUS poll.
                    Some(h) => h.step(STATUS_TICKS),
                    None => Err(anyhow::anyhow!("no session")),
                };
                match status {
                    Ok(st) => writeln!(
                        writer,
                        "STATUS {} {:.3} {:.1} {} {}",
                        st.iterations, st.time_s, st.energy_j, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                }
            }
            Some("END") => match session.take() {
                // end() blocks this connection until the run finishes,
                // but the fleet worker drives it in slices, so other
                // connections' sessions keep being served meanwhile.
                Some(h) => match h.end() {
                    Ok(st) => writeln!(
                        writer,
                        "RESULT {:.1} {:.3} {} {} {}",
                        st.energy_j, st.time_s, st.iterations, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                },
                None => writeln!(writer, "ERR no session")?,
            },
            Some("QUIT") | None => break,
            Some(other) => writeln!(writer, "ERR unknown command '{other}'")?,
        }
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Predictor;
    use std::io::BufRead;

    /// Start a daemon on a fresh socket; returns the socket path.
    fn spawn_daemon(tag: &str, workers: usize) -> std::path::PathBuf {
        let spec = Arc::new(Spec::load_default().unwrap());
        let daemon = Daemon::new(spec, workers);
        let dir = std::env::temp_dir().join(format!("gpoeo-test-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("d.sock");
        let sock2 = sock.clone();
        std::thread::spawn(move || {
            let _ = daemon.serve(&sock2);
        });
        for _ in 0..100 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        sock
    }

    struct Client {
        w: UnixStream,
        r: BufReader<UnixStream>,
    }

    impl Client {
        fn connect(sock: &Path) -> Client {
            let stream = UnixStream::connect(sock).unwrap();
            let w = stream.try_clone().unwrap();
            Client {
                w,
                r: BufReader::new(stream),
            }
        }

        fn roundtrip(&mut self, cmd: &str) -> String {
            writeln!(self.w, "{cmd}").unwrap();
            let mut line = String::new();
            self.r.read_line(&mut line).unwrap();
            line
        }
    }

    #[test]
    fn begin_status_end_roundtrip() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("roundtrip", 2);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 40");
        assert!(line.starts_with("OK"), "{line}");

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("STATUS"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let parts: Vec<&str> = line.split_whitespace().collect();
        let iters: u64 = parts[3].parse().unwrap();
        assert!(iters >= 40);

        let line = c.roundtrip("BOGUS");
        assert!(line.starts_with("ERR"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn protocol_error_paths_always_answer() {
        // None of these paths needs model artifacts: the daemon must
        // answer ERR (never close silently) regardless.
        let sock = spawn_daemon("errors", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("BEGIN NOT_AN_APP 10");
        assert!(line.starts_with("ERR"), "{line}");
        // Unknown app or missing predictor — either way a reason arrives.
        assert!(line.trim().len() > "ERR".len(), "reason required: {line}");

        let line = c.roundtrip("BEGIN");
        assert!(line.starts_with("ERR"), "{line}");

        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn parse_iters_contract() {
        // Absent token → None: the daemon resolves it per app, exactly
        // like `gpoeo run` (see resolve_iters_matches_cli_default).
        assert_eq!(parse_iters(None), Ok(None));
        assert_eq!(parse_iters(Some("42")), Ok(Some(42)));
        for bad in ["abc", "0", "-5", "12.5", "1e6", "18446744073709551616", ""] {
            let r = parse_iters(Some(bad));
            assert!(
                matches!(&r, Err(msg) if msg.starts_with("bad iteration count")),
                "{bad:?} -> {r:?}"
            );
        }
    }

    #[test]
    fn resolve_iters_matches_cli_default() {
        // `BEGIN <app>` without a count must run the same workload size
        // as `gpoeo run --app <app>` — default_iters, not a hardcoded
        // 300 (they disagreed for every app whose t_base makes
        // default_iters exceed the floor).
        let spec = Arc::new(Spec::load_default().unwrap());
        let mut checked_above_floor = false;
        for suite in spec.suites.keys() {
            for app in crate::sim::make_suite(&spec, suite).unwrap() {
                assert_eq!(resolve_iters(None, &app), default_iters(&app), "{}", app.name);
                assert_eq!(resolve_iters(Some(40), &app), 40);
                checked_above_floor |= default_iters(&app) > 300;
            }
        }
        assert!(
            checked_above_floor,
            "suite must contain an app where the old hardcoded 300 was wrong"
        );
    }

    #[test]
    fn accept_failure_is_skipped_not_fatal() {
        // The accept-loop body: an Err must be swallowed (logged) and
        // answered with None — never propagated to kill serve().
        let err = std::io::Error::other("simulated EMFILE");
        assert!(accept_stream(Err(err)).is_none());
        let (a, _b) = UnixStream::pair().unwrap();
        assert!(accept_stream(Ok(a)).is_some());
    }

    #[test]
    fn begin_rejects_bad_iteration_counts() {
        // None of these needs model artifacts: the count is validated
        // before the app lookup or any fleet work.
        let sock = spawn_daemon("iters", 1);
        let mut c = Client::connect(&sock);
        for cmd in [
            "BEGIN AI_TS abc",
            "BEGIN AI_TS 0",
            "BEGIN AI_TS -5",
            "BEGIN AI_TS 12.5",
            "BEGIN AI_TS 18446744073709551616",
        ] {
            let line = c.roundtrip(cmd);
            assert!(line.starts_with("ERR bad iteration count"), "{cmd}: {line}");
        }
        // The connection stays healthy: a clean BEGIN still works
        // (artifact-free policy, so this runs everywhere).
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        let line = c.roundtrip("BEGIN AI_TS 20");
        assert!(line.starts_with("OK"), "{line}");
        assert!(c.roundtrip("END").starts_with("RESULT"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_selection_before_begin() {
        // `bandit` needs no model artifacts, so the full POLICY→BEGIN→END
        // cycle runs everywhere (including CI without `make artifacts`).
        let sock = spawn_daemon("policy", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("POLICY NOT_A_POLICY");
        assert!(line.starts_with("ERR unknown policy"), "{line}");

        let line = c.roundtrip("POLICY");
        assert!(line.starts_with("ERR POLICY requires a name"), "{line}");

        let line = c.roundtrip("POLICY bandit bandit-algo=exp3");
        assert!(line.starts_with("ERR POLICY takes a single name"), "{line}");

        let line = c.roundtrip("POLICY bandit");
        assert!(line.starts_with("OK policy bandit"), "{line}");

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");

        // Mid-session re-selection is rejected; the session is untouched.
        let line = c.roundtrip("POLICY odpp");
        assert!(line.starts_with("ERR session already active"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let iters: u64 = line.split_whitespace().nth(3).unwrap().parse().unwrap();
        assert!(iters >= 30);
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_survives_across_sessions_per_connection() {
        // The POLICY selection applies to every subsequent BEGIN on the
        // same connection until changed (odpp is artifact-free too).
        let sock = spawn_daemon("policy2", 1);
        let mut c = Client::connect(&sock);
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        for _ in 0..2 {
            let line = c.roundtrip("BEGIN AI_FE 20");
            assert!(line.starts_with("OK"), "{line}");
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
        }
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn double_begin_is_rejected() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("double", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");
        let line = c.roundtrip("BEGIN AI_FE 30");
        assert!(line.starts_with("ERR session already active"), "{line}");
        // The original session is untouched and still ENDs normally.
        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn concurrent_connections_share_the_fleet() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("concurrent", 2);
        let mut clients: Vec<Client> = (0..3).map(|_| Client::connect(&sock)).collect();
        for (c, app) in clients.iter_mut().zip(["AI_TS", "AI_FE", "AI_OBJ"]) {
            let line = c.roundtrip(&format!("BEGIN {app} 30"));
            assert!(line.starts_with("OK"), "{app}: {line}");
        }
        for c in &mut clients {
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
            writeln!(c.w, "QUIT").unwrap();
        }
    }
}
