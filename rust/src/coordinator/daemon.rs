//! Begin/End daemon — the micro-intrusive API of §2.2.2/§4.2.
//!
//! The paper's deployment model: a training script links a two-call API
//! (`Begin` at the start of the training region, `End` at the end); a
//! separate optimizer process owns the GPU clocks. Here the daemon owns a
//! simulated device per session and drives the GPOEO controller, so an
//! external client can exercise the exact same contract over a Unix
//! socket with a line protocol:
//!
//! ```text
//! -> BEGIN <app-name> [iters]
//! <- OK session started
//! -> STATUS            (any time)
//! <- STATUS <iter> <time_s> <energy_j> <sm_gear> <mem_gear>
//! -> END
//! <- RESULT <energy_j> <time_s> <iterations> <sm_gear> <mem_gear>
//! ```
//!
//! One session at a time per connection; concurrent connections get their
//! own simulated device (one GPU each — the paper's setting).

use crate::coordinator::{Gpoeo, GpoeoCfg, Policy};
use crate::model::Predictor;
use crate::sim::{find_app, SimGpu, Spec};
// NOTE: the xla PJRT client is not Send (Rc internals), so each
// connection thread builds its own Predictor — HLO executables compile
// once per connection, then serve every session on that connection.
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::Arc;

pub struct Daemon {
    spec: Arc<Spec>,
}

struct Session {
    gpu: SimGpu,
    controller: Gpoeo,
    target_iters: u64,
}

impl Session {
    /// Advance the session by a chunk of virtual time.
    fn step(&mut self) {
        self.controller.tick(&mut self.gpu);
    }

    fn done(&self) -> bool {
        self.gpu.iterations() >= self.target_iters
    }
}

impl Daemon {
    pub fn new(spec: Arc<Spec>) -> Daemon {
        Daemon { spec }
    }

    /// Serve forever on a Unix socket (one thread per connection).
    pub fn serve(&self, socket_path: &Path) -> anyhow::Result<()> {
        let _ = std::fs::remove_file(socket_path);
        let listener = UnixListener::bind(socket_path)?;
        eprintln!("gpoeo daemon listening on {}", socket_path.display());
        for stream in listener.incoming() {
            let stream = stream?;
            let spec = self.spec.clone();
            std::thread::spawn(move || {
                let predictor = match Predictor::load_best() {
                    Ok(p) => Arc::new(p),
                    Err(e) => {
                        eprintln!("daemon: no predictor available: {e}");
                        return;
                    }
                };
                if let Err(e) = handle_connection(stream, spec, predictor) {
                    eprintln!("daemon connection error: {e}");
                }
            });
        }
        Ok(())
    }
}

fn handle_connection(
    stream: UnixStream,
    spec: Arc<Spec>,
    predictor: Arc<Predictor>,
) -> anyhow::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut session: Option<Session> = None;

    for line in reader.lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("BEGIN") => {
                let name = parts.next().unwrap_or("");
                let iters: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(300);
                match find_app(&spec, name) {
                    Ok(app) => {
                        let gpu = SimGpu::new(spec.clone(), app);
                        let controller = Gpoeo::new(GpoeoCfg::default(), predictor.clone());
                        session = Some(Session {
                            gpu,
                            controller,
                            target_iters: iters,
                        });
                        writeln!(writer, "OK session started")?;
                    }
                    Err(e) => writeln!(writer, "ERR {e}")?,
                }
            }
            Some("STATUS") => match session.as_mut() {
                Some(s) => {
                    // Drive a slice of virtual time per STATUS poll.
                    for _ in 0..200 {
                        if s.done() {
                            break;
                        }
                        s.step();
                    }
                    writeln!(
                        writer,
                        "STATUS {} {:.3} {:.1} {} {}",
                        s.gpu.iterations(),
                        s.gpu.time_s(),
                        s.gpu.true_energy_j(),
                        s.gpu.sm_gear(),
                        s.gpu.mem_gear()
                    )?;
                }
                None => writeln!(writer, "ERR no session")?,
            },
            Some("END") => match session.take() {
                Some(mut s) => {
                    while !s.done() {
                        s.step();
                    }
                    writeln!(
                        writer,
                        "RESULT {:.1} {:.3} {} {} {}",
                        s.gpu.true_energy_j(),
                        s.gpu.time_s(),
                        s.gpu.iterations(),
                        s.gpu.sm_gear(),
                        s.gpu.mem_gear()
                    )?;
                }
                None => writeln!(writer, "ERR no session")?,
            },
            Some("QUIT") | None => break,
            Some(other) => writeln!(writer, "ERR unknown command '{other}'")?,
        }
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;

    #[test]
    fn begin_status_end_roundtrip() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let daemon = Daemon::new(spec);
        let dir = std::env::temp_dir().join(format!("gpoeo-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("d.sock");
        let sock2 = sock.clone();
        std::thread::spawn(move || {
            let _ = daemon.serve(&sock2);
        });
        // Wait for the listener.
        for _ in 0..100 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        let stream = UnixStream::connect(&sock).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut line = String::new();

        writeln!(w, "BEGIN AI_TS 40").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK"), "{line}");

        line.clear();
        writeln!(w, "STATUS").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("STATUS"), "{line}");

        line.clear();
        writeln!(w, "END").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("RESULT"), "{line}");
        let parts: Vec<&str> = line.split_whitespace().collect();
        let iters: u64 = parts[3].parse().unwrap();
        assert!(iters >= 40);

        line.clear();
        writeln!(w, "BOGUS").unwrap();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"));
        writeln!(w, "QUIT").unwrap();
    }
}
