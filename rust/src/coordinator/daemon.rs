//! Begin/End daemon — the micro-intrusive API of §2.2.2/§4.2.
//!
//! The paper's deployment model: a training script links a two-call API
//! (`Begin` at the start of the training region, `End` at the end); a
//! separate optimizer process owns the GPU clocks. Here the daemon owns a
//! simulated device per session and drives the GPOEO controller, so an
//! external client can exercise the exact same contract over a Unix
//! socket with a line protocol:
//!
//! ```text
//! -> POLICY <name>     (optional, before BEGIN; default: gpoeo)
//! <- OK policy <name>
//! -> BEGIN <app-name> [iters]
//! <- OK session started
//! -> STATUS            (any time)
//! <- STATUS <iter> <time_s> <energy_j> <sm_gear> <mem_gear>
//! -> END
//! <- RESULT <energy_j> <time_s> <iterations> <sm_gear> <mem_gear>
//! ```
//!
//! One session at a time per connection. `POLICY` selects any policy
//! registered in [`crate::policy::PolicyRegistry`] for the *next*
//! session; an unregistered name answers `ERR unknown policy ...`. A
//! malformed `BEGIN` iteration count (non-numeric, zero, overflow)
//! answers `ERR bad iteration count ...` instead of silently running
//! the default.
//! Sessions from all connections are served by a shared [`Fleet`]: each
//! fleet worker owns one [`Predictor`](crate::model::Predictor) (the
//! PJRT HLO executables compile once per worker, not once per
//! connection), and concurrent clients are spread across the pool.
//! Every failure path answers with an `ERR <reason>` line — a client
//! never hangs on a silent close.

use crate::coordinator::{Fleet, SessionHandle};
use crate::policy::{PolicyRegistry, PolicySpec};
use crate::sim::{find_app, Spec};
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::Arc;

pub struct Daemon {
    fleet: Arc<Fleet>,
}

impl Daemon {
    /// Build a daemon backed by a fleet of `workers` threads.
    pub fn new(spec: Arc<Spec>, workers: usize) -> Daemon {
        Daemon {
            fleet: Arc::new(Fleet::new(spec, workers)),
        }
    }

    /// Serve forever on a Unix socket (one lightweight thread per
    /// connection; the heavy lifting happens on the fleet workers).
    pub fn serve(&self, socket_path: &Path) -> anyhow::Result<()> {
        let _ = std::fs::remove_file(socket_path);
        let listener = UnixListener::bind(socket_path)?;
        eprintln!(
            "gpoeo daemon listening on {} ({} fleet workers)",
            socket_path.display(),
            self.fleet.num_workers()
        );
        for stream in listener.incoming() {
            let stream = stream?;
            let fleet = self.fleet.clone();
            std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, fleet) {
                    eprintln!("daemon connection error: {e}");
                }
            });
        }
        Ok(())
    }
}

/// The optional iteration-count argument of `BEGIN <app> [iters]`:
/// absent means the default, anything present must parse as a positive
/// `u64`. Non-numeric, zero, negative and overflowing counts all answer
/// `ERR bad iteration count ...` — the old behavior silently ran 300
/// iterations, so a client typo'ing `BEGIN app 1e6` got a result for a
/// workload it never asked for.
fn parse_iters(tok: Option<&str>) -> Result<u64, String> {
    match tok {
        None => Ok(300),
        Some(t) => match t.parse::<u64>() {
            Ok(0) => Err(format!("bad iteration count '{t}' (must be positive)")),
            Ok(n) => Ok(n),
            Err(_) => Err(format!(
                "bad iteration count '{t}' (expected a positive integer)"
            )),
        },
    }
}

fn handle_connection(stream: UnixStream, fleet: Arc<Fleet>) -> anyhow::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    // The connection's active session, if any. Dropped (aborted) if the
    // client disconnects without END.
    let mut session: Option<SessionHandle> = None;
    // The policy the next BEGIN will run (selected via POLICY).
    let mut policy = PolicySpec::registered("gpoeo");

    for line in reader.lines() {
        let line = line?;
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("POLICY") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    match parts.next() {
                        None => writeln!(
                            writer,
                            "ERR POLICY requires a name (see `gpoeo policies`)"
                        )?,
                        // Reject trailing tokens instead of silently
                        // ignoring them — a client sending `POLICY bandit
                        // bandit-algo=exp3` must not quietly run defaults
                        // (policy options are a CLI affair: run/sweep).
                        Some(_) if line.split_whitespace().count() > 2 => writeln!(
                            writer,
                            "ERR POLICY takes a single name (options only via gpoeo run/sweep)"
                        )?,
                        Some(name) => match PolicyRegistry::global().get(name) {
                            Ok(_) => {
                                policy = PolicySpec::registered(name);
                                writeln!(writer, "OK policy {name}")?;
                            }
                            Err(e) => writeln!(writer, "ERR {e}")?,
                        },
                    }
                }
            }
            Some("BEGIN") => {
                if session.is_some() {
                    writeln!(writer, "ERR session already active (END it first)")?;
                } else {
                    let name = parts.next().unwrap_or("");
                    match parse_iters(parts.next()) {
                        Err(msg) => writeln!(writer, "ERR {msg}")?,
                        Ok(iters) => {
                            let started = find_app(fleet.spec(), name)
                                .and_then(|app| fleet.begin(app, policy.clone(), iters));
                            match started {
                                Ok(h) => {
                                    session = Some(h);
                                    writeln!(writer, "OK session started")?;
                                }
                                Err(e) => writeln!(writer, "ERR {e}")?,
                            }
                        }
                    }
                }
            }
            Some("STATUS") => {
                let status = match session.as_ref() {
                    // Drive a slice of virtual time per STATUS poll.
                    Some(h) => h.step(200),
                    None => Err(anyhow::anyhow!("no session")),
                };
                match status {
                    Ok(st) => writeln!(
                        writer,
                        "STATUS {} {:.3} {:.1} {} {}",
                        st.iterations, st.time_s, st.energy_j, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                }
            }
            Some("END") => match session.take() {
                // end() blocks this connection until the run finishes,
                // but the fleet worker drives it in slices, so other
                // connections' sessions keep being served meanwhile.
                Some(h) => match h.end() {
                    Ok(st) => writeln!(
                        writer,
                        "RESULT {:.1} {:.3} {} {} {}",
                        st.energy_j, st.time_s, st.iterations, st.sm_gear, st.mem_gear
                    )?,
                    Err(e) => writeln!(writer, "ERR {e}")?,
                },
                None => writeln!(writer, "ERR no session")?,
            },
            Some("QUIT") | None => break,
            Some(other) => writeln!(writer, "ERR unknown command '{other}'")?,
        }
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Predictor;
    use std::io::BufRead;

    /// Start a daemon on a fresh socket; returns the socket path.
    fn spawn_daemon(tag: &str, workers: usize) -> std::path::PathBuf {
        let spec = Arc::new(Spec::load_default().unwrap());
        let daemon = Daemon::new(spec, workers);
        let dir = std::env::temp_dir().join(format!("gpoeo-test-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("d.sock");
        let sock2 = sock.clone();
        std::thread::spawn(move || {
            let _ = daemon.serve(&sock2);
        });
        for _ in 0..100 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        sock
    }

    struct Client {
        w: UnixStream,
        r: BufReader<UnixStream>,
    }

    impl Client {
        fn connect(sock: &Path) -> Client {
            let stream = UnixStream::connect(sock).unwrap();
            let w = stream.try_clone().unwrap();
            Client {
                w,
                r: BufReader::new(stream),
            }
        }

        fn roundtrip(&mut self, cmd: &str) -> String {
            writeln!(self.w, "{cmd}").unwrap();
            let mut line = String::new();
            self.r.read_line(&mut line).unwrap();
            line
        }
    }

    #[test]
    fn begin_status_end_roundtrip() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("roundtrip", 2);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 40");
        assert!(line.starts_with("OK"), "{line}");

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("STATUS"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let parts: Vec<&str> = line.split_whitespace().collect();
        let iters: u64 = parts[3].parse().unwrap();
        assert!(iters >= 40);

        let line = c.roundtrip("BOGUS");
        assert!(line.starts_with("ERR"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn protocol_error_paths_always_answer() {
        // None of these paths needs model artifacts: the daemon must
        // answer ERR (never close silently) regardless.
        let sock = spawn_daemon("errors", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("STATUS");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("ERR no session"), "{line}");

        let line = c.roundtrip("BEGIN NOT_AN_APP 10");
        assert!(line.starts_with("ERR"), "{line}");
        // Unknown app or missing predictor — either way a reason arrives.
        assert!(line.trim().len() > "ERR".len(), "reason required: {line}");

        let line = c.roundtrip("BEGIN");
        assert!(line.starts_with("ERR"), "{line}");

        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn parse_iters_contract() {
        assert_eq!(parse_iters(None), Ok(300));
        assert_eq!(parse_iters(Some("42")), Ok(42));
        for bad in ["abc", "0", "-5", "12.5", "1e6", "18446744073709551616", ""] {
            let r = parse_iters(Some(bad));
            assert!(
                matches!(&r, Err(msg) if msg.starts_with("bad iteration count")),
                "{bad:?} -> {r:?}"
            );
        }
    }

    #[test]
    fn begin_rejects_bad_iteration_counts() {
        // None of these needs model artifacts: the count is validated
        // before the app lookup or any fleet work.
        let sock = spawn_daemon("iters", 1);
        let mut c = Client::connect(&sock);
        for cmd in [
            "BEGIN AI_TS abc",
            "BEGIN AI_TS 0",
            "BEGIN AI_TS -5",
            "BEGIN AI_TS 12.5",
            "BEGIN AI_TS 18446744073709551616",
        ] {
            let line = c.roundtrip(cmd);
            assert!(line.starts_with("ERR bad iteration count"), "{cmd}: {line}");
        }
        // The connection stays healthy: a clean BEGIN still works
        // (artifact-free policy, so this runs everywhere).
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        let line = c.roundtrip("BEGIN AI_TS 20");
        assert!(line.starts_with("OK"), "{line}");
        assert!(c.roundtrip("END").starts_with("RESULT"));
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_selection_before_begin() {
        // `bandit` needs no model artifacts, so the full POLICY→BEGIN→END
        // cycle runs everywhere (including CI without `make artifacts`).
        let sock = spawn_daemon("policy", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("POLICY NOT_A_POLICY");
        assert!(line.starts_with("ERR unknown policy"), "{line}");

        let line = c.roundtrip("POLICY");
        assert!(line.starts_with("ERR POLICY requires a name"), "{line}");

        let line = c.roundtrip("POLICY bandit bandit-algo=exp3");
        assert!(line.starts_with("ERR POLICY takes a single name"), "{line}");

        let line = c.roundtrip("POLICY bandit");
        assert!(line.starts_with("OK policy bandit"), "{line}");

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");

        // Mid-session re-selection is rejected; the session is untouched.
        let line = c.roundtrip("POLICY odpp");
        assert!(line.starts_with("ERR session already active"), "{line}");

        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        let iters: u64 = line.split_whitespace().nth(3).unwrap().parse().unwrap();
        assert!(iters >= 30);
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn policy_survives_across_sessions_per_connection() {
        // The POLICY selection applies to every subsequent BEGIN on the
        // same connection until changed (odpp is artifact-free too).
        let sock = spawn_daemon("policy2", 1);
        let mut c = Client::connect(&sock);
        assert!(c.roundtrip("POLICY powercap").starts_with("OK"));
        for _ in 0..2 {
            let line = c.roundtrip("BEGIN AI_FE 20");
            assert!(line.starts_with("OK"), "{line}");
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
        }
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn double_begin_is_rejected() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("double", 1);
        let mut c = Client::connect(&sock);

        let line = c.roundtrip("BEGIN AI_TS 30");
        assert!(line.starts_with("OK"), "{line}");
        let line = c.roundtrip("BEGIN AI_FE 30");
        assert!(line.starts_with("ERR session already active"), "{line}");
        // The original session is untouched and still ENDs normally.
        let line = c.roundtrip("END");
        assert!(line.starts_with("RESULT"), "{line}");
        writeln!(c.w, "QUIT").unwrap();
    }

    #[test]
    fn concurrent_connections_share_the_fleet() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing");
            return;
        }
        let sock = spawn_daemon("concurrent", 2);
        let mut clients: Vec<Client> = (0..3).map(|_| Client::connect(&sock)).collect();
        for (c, app) in clients.iter_mut().zip(["AI_TS", "AI_FE", "AI_OBJ"]) {
            let line = c.roundtrip(&format!("BEGIN {app} 30"));
            assert!(line.starts_with("OK"), "{app}: {line}");
        }
        for c in &mut clients {
            let line = c.roundtrip("END");
            assert!(line.starts_with("RESULT"), "{line}");
            writeln!(c.w, "QUIT").unwrap();
        }
    }
}
