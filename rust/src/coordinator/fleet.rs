//! Parallel fleet engine: many (app × policy) sessions across a worker
//! pool (DESIGN.md §6).
//!
//! The paper evaluates GPOEO one training job at a time; a production
//! optimizer service faces a *fleet* — 71-app sweeps, many concurrent
//! Begin/End clients. Two constraints shape the design:
//!
//! - The PJRT client inside [`Predictor::Hlo`] is not `Send` (`Rc`
//!   internals), so a predictor can never migrate between threads.
//!   Each worker thread therefore builds **one** predictor, on first
//!   use, and serves every job and session routed to it — the HLO
//!   executables compile at most once per worker, not once per
//!   connection (the old daemon recompiled them for every client).
//! - Simulated devices are deterministic given (spec, app): a session's
//!   outcome is independent of which worker runs it or what else runs
//!   concurrently, so a parallel sweep is bit-identical to a serial one
//!   and results can be returned in deterministic (submission) order.
//!
//! Two modes of use:
//! - [`Fleet::run_jobs`] — batch: run a vector of [`SweepJob`]s to
//!   completion, results in submission order (`gpoeo sweep --parallel`).
//! - [`Fleet::begin`] / [`SessionHandle`] — interactive: long-lived
//!   sessions pinned to a worker, driven incrementally (the daemon's
//!   Begin/Status/End protocol).

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::coordinator::{run_budget_s, run_sim, savings, GpoeoStats, Policy, RunResult, Savings};
use crate::device::{boxed_sim_device, Device};
use crate::model::Predictor;
use crate::policy::{PolicyCtx, PolicyRegistry, PolicySpec};
use crate::sim::{AppParams, Spec};
use crate::telemetry::{Counter, Hist, Telemetry, TelemetryEvent};
use std::cell::OnceCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// One unit of sweep work: run `policy` on `app` for `n_iters` work
/// units, scored against a fresh NVIDIA-default baseline. The policy is
/// a registry [`PolicySpec`] — it crosses to the worker as (name,
/// config) and is built there, next to the worker's predictor.
#[derive(Clone)]
pub struct SweepJob {
    pub app: AppParams,
    pub policy: PolicySpec,
    pub n_iters: u64,
}

/// Outcome of one [`SweepJob`].
pub struct JobOutcome {
    pub base: RunResult,
    pub run: RunResult,
    pub savings: Savings,
    pub stats: Option<GpoeoStats>,
}

/// Everything a default-policy baseline run depends on (DESIGN.md §13).
/// Two jobs with equal keys have bit-identical baselines: the simulator
/// is deterministic in (spec, app, ts, n_iters), the app is pinned by
/// (suite, name, trace_seed), the spec by its groundtruth digest, and
/// the default policy's only knob is its tick `ts`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BaselineKey {
    pub suite: String,
    pub app: String,
    pub trace_seed: u64,
    pub n_iters: u64,
    /// `ts.to_bits()` — the tick is part of the trajectory (it sets the
    /// RNG draw count), so baselines at different ticks never unify.
    pub ts_bits: u64,
    pub spec_digest: u64,
}

/// Sweep-wide cache of default-policy baseline runs, shared by every
/// worker of a [`Fleet`]. A sweep scores each (app × policy) job against
/// the same NVIDIA-default baseline; without the cache that baseline is
/// re-simulated once per *policy*, which is pure waste — with it, once
/// per (app, iters, tick, spec).
///
/// Races are benign: workers compute outside the lock, so two workers
/// may both miss on the same key and compute duplicate (bit-identical —
/// deterministic simulator) baselines; the first insert wins and the
/// `misses` counter records the duplicate work honestly.
pub struct BaselineCache {
    map: Mutex<HashMap<BaselineKey, Arc<RunResult>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl BaselineCache {
    pub fn new() -> BaselineCache {
        BaselineCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The cached baseline for `key`, or `compute()` stored under it.
    pub fn get_or_compute(
        &self,
        key: BaselineKey,
        compute: impl FnOnce() -> RunResult,
    ) -> Arc<RunResult> {
        if let Some(hit) = self
            .map
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(&key)
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        // Compute outside the lock: a baseline run takes real time, and
        // holding the map across it would serialize the whole pool.
        let v = Arc::new(compute());
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        Arc::clone(map.entry(key).or_insert(v))
    }

    /// (hits, misses) so far. Misses count computes, including duplicate
    /// races, so `hits + misses` equals the number of lookups.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

impl Default for BaselineCache {
    fn default() -> BaselineCache {
        BaselineCache::new()
    }
}

/// Telemetry snapshot of an interactive session.
#[derive(Debug, Clone, Copy)]
pub struct SessionStatus {
    pub iterations: u64,
    /// The session's iteration target (what `done` is measured against).
    pub target_iters: u64,
    pub time_s: f64,
    pub energy_j: f64,
    pub sm_gear: usize,
    pub mem_gear: usize,
    pub done: bool,
}

/// Session parameters shipped to a worker by [`Fleet::begin`].
struct BeginReq {
    app: AppParams,
    policy: PolicySpec,
    target_iters: u64,
}

/// One-shot completion callback for a fleet command.
///
/// The old fleet answered every command over a dedicated mpsc channel,
/// which forces the caller to block on `recv()` — a dead end for the
/// single-threaded reactor. A `Reply` is the generalization: the worker
/// invokes it with `Some(value)` when the command completes, and if the
/// worker dies (or shuts down) with the reply still pending, dropping it
/// invokes the callback with `None` so the caller can observe the loss
/// instead of hanging. Blocking callers are recovered by pointing the
/// callback at a channel ([`Reply::channel_pair`], used by
/// `Fleet::begin` and `SessionHandle::step`/`end`); the reactor points
/// it at its completion queue plus a wake-pipe byte.
pub struct Reply<T> {
    f: Option<Box<dyn FnOnce(Option<T>) + Send>>,
}

impl<T: Send + 'static> Reply<T> {
    pub fn new(f: impl FnOnce(Option<T>) + Send + 'static) -> Reply<T> {
        Reply {
            f: Some(Box::new(f)),
        }
    }

    /// Deliver the value. Consumes the reply; each reply fires exactly
    /// once (here, or with `None` on drop).
    pub fn send(mut self, v: T) {
        if let Some(f) = self.f.take() {
            f(Some(v));
        }
    }

    /// Wrap with a pre-hook that runs right before the callback fires —
    /// on success *and* on the dropped-reply path, so bookkeeping (like
    /// a load-counter decrement) happens exactly once either way.
    pub fn before(mut self, pre: impl FnOnce() + Send + 'static) -> Reply<T> {
        // Invariant expect: `f` is Some from construction until the
        // one-shot send/drop consumes self — `before` takes self by
        // value, so it cannot run after either.
        #[allow(clippy::expect_used)]
        let f = self.f.take().expect("reply already consumed");
        Reply {
            f: Some(Box::new(move |v| {
                pre();
                f(v)
            })),
        }
    }

    /// A reply wired to a channel, for blocking callers: `recv()` yields
    /// `Some(value)` on completion and `None` if the worker vanished.
    fn channel_pair() -> (Reply<T>, Receiver<Option<T>>) {
        let (tx, rx) = channel();
        (
            Reply::new(move |v| {
                let _ = tx.send(v);
            }),
            rx,
        )
    }
}

impl<T> Drop for Reply<T> {
    fn drop(&mut self) {
        if let Some(f) = self.f.take() {
            f(None);
        }
    }
}

// Large payloads are boxed so the enum stays small for the frequent
// Step/End/Drop traffic.
enum Cmd {
    Job {
        /// Index of the worker the job was sent to (echoed back so the
        /// dispatcher knows which worker freed up).
        worker: usize,
        idx: usize,
        job: Box<SweepJob>,
        reply: Sender<(usize, usize, anyhow::Result<JobOutcome>)>,
    },
    Begin {
        id: u64,
        req: Box<BeginReq>,
        reply: Reply<anyhow::Result<()>>,
    },
    Step {
        id: u64,
        max_ticks: u64,
        reply: Reply<anyhow::Result<SessionStatus>>,
    },
    End {
        id: u64,
        /// Errant-policy virtual-time cap, computed on the first slice
        /// and carried through the re-enqueued slices.
        budget_s: Option<f64>,
        reply: Reply<anyhow::Result<SessionStatus>>,
    },
    /// Fire-and-forget power-cap application from the budget arbiter
    /// (DESIGN.md §14). Applied on the worker thread that owns the
    /// (non-`Send`) device; the *applied* (range-clamped) value is what
    /// gets journaled.
    SetCap {
        id: u64,
        cap_w: f64,
        /// The fleet budget this cap was allocated under (journaled so
        /// replay can check the per-epoch budget invariant).
        budget_w: f64,
        /// Arbiter re-allocation epoch the cap belongs to.
        epoch: u64,
    },
    Drop {
        id: u64,
    },
    /// Exit the worker loop even if session handles still hold sender
    /// clones (see `Fleet::drop`).
    Shutdown,
}

struct WorkerHandle {
    tx: Option<Sender<Cmd>>,
    /// Interactive sessions currently pinned to this worker (for
    /// least-loaded placement).
    active: Arc<AtomicUsize>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    fn send(&self, cmd: Cmd) -> anyhow::Result<()> {
        match self.tx.as_ref() {
            Some(tx) => tx
                .send(cmd)
                .map_err(|_| anyhow::anyhow!("fleet worker thread is gone")),
            None => anyhow::bail!("fleet worker already shut down"),
        }
    }
}

/// AIMD worker-pool scaling knobs (ninelives P3.04): additive growth
/// under sustained backlog, multiplicative (halving) back-off once the
/// queue has stayed empty for a while.
#[derive(Debug, Clone, Copy)]
pub struct AimdCfg {
    /// Never shrink below this many workers.
    pub min_workers: usize,
    /// Never grow beyond this many workers.
    pub max_workers: usize,
    /// Queue depth above `live_workers × backlog_per_worker` counts as
    /// backlogged.
    pub backlog_per_worker: usize,
    /// Backlog sustained for this long → grow by one worker.
    pub grow_after_s: f64,
    /// Empty queue sustained for this long → halve toward `min_workers`.
    pub shrink_after_s: f64,
}

impl AimdCfg {
    /// Sensible defaults around a fixed floor/ceiling.
    pub fn bounded(min_workers: usize, max_workers: usize) -> AimdCfg {
        AimdCfg {
            min_workers: min_workers.max(1),
            max_workers: max_workers.max(min_workers.max(1)),
            backlog_per_worker: 2,
            grow_after_s: 0.05,
            shrink_after_s: 1.0,
        }
    }
}

/// What [`AimdState::observe`] wants done to the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Add one worker (additive increase).
    Grow,
    /// Retire idle workers down toward this target (multiplicative
    /// decrease; the pool may stop early if tail workers are busy).
    Shrink(usize),
}

/// Pure AIMD window tracker. Time is injected (seconds on any
/// monotonically increasing clock) so the unit tests replay exact
/// timelines instead of sleeping.
#[derive(Debug)]
pub struct AimdState {
    cfg: AimdCfg,
    busy_since: Option<f64>,
    idle_since: Option<f64>,
}

impl AimdState {
    pub fn new(cfg: AimdCfg) -> AimdState {
        AimdState {
            cfg,
            busy_since: None,
            idle_since: None,
        }
    }

    /// Feed one (queue depth, live worker count) observation at `now_s`.
    pub fn observe(&mut self, now_s: f64, depth: usize, live: usize) -> ScaleDecision {
        let backlogged = depth > live.saturating_mul(self.cfg.backlog_per_worker);
        if backlogged {
            self.idle_since = None;
            let since = *self.busy_since.get_or_insert(now_s);
            if now_s - since >= self.cfg.grow_after_s && live < self.cfg.max_workers {
                // Restart the window: each grow step must be earned by a
                // full further interval of sustained backlog.
                self.busy_since = Some(now_s);
                return ScaleDecision::Grow;
            }
        } else {
            self.busy_since = None;
            if depth == 0 {
                let since = *self.idle_since.get_or_insert(now_s);
                if now_s - since >= self.cfg.shrink_after_s && live > self.cfg.min_workers {
                    self.idle_since = Some(now_s);
                    return ScaleDecision::Shrink((live / 2).max(self.cfg.min_workers));
                }
            } else {
                // A non-empty (but not backlogged) queue is neither busy
                // nor idle: both windows reset.
                self.idle_since = None;
            }
        }
        ScaleDecision::Hold
    }
}

/// A pool of worker threads, each owning one predictor, serving sweep
/// jobs and interactive sessions. The pool is fixed-size under
/// [`Fleet::new`]; [`Fleet::with_scaling`] adds AIMD auto-scaling driven
/// by [`Fleet::autoscale`] observations.
pub struct Fleet {
    spec: Arc<Spec>,
    workers: RwLock<Vec<WorkerHandle>>,
    next_session: AtomicU64,
    next_worker: AtomicUsize,
    scaler: Option<Mutex<AimdState>>,
    started: Instant,
    /// Telemetry plane shared by every worker (DESIGN.md §11).
    /// [`Telemetry::disabled`] unless wired via [`Fleet::with_telemetry`].
    tel: Arc<Telemetry>,
    /// Sweep-wide default-policy baseline cache shared by every worker
    /// (DESIGN.md §13).
    baseline: Arc<BaselineCache>,
}

impl Fleet {
    /// Spawn `workers` threads (at least one). Each worker builds its
    /// own [`Predictor`] on first use — an ODPP- or default-only
    /// workload never pays the HLO compile, and a failed load only
    /// surfaces when a job or session actually needs prediction.
    pub fn new(spec: Arc<Spec>, workers: usize) -> Fleet {
        Fleet::build(spec, workers, None, Arc::new(Telemetry::disabled()))
    }

    /// Like [`Fleet::new`], but the pool auto-scales between
    /// `cfg.min_workers` and `cfg.max_workers` as [`Fleet::autoscale`]
    /// feeds it queue-depth observations. The initial size is clamped
    /// into the configured band.
    pub fn with_scaling(spec: Arc<Spec>, workers: usize, cfg: AimdCfg) -> Fleet {
        Fleet::with_telemetry(spec, workers, Some(cfg), Arc::new(Telemetry::disabled()))
    }

    /// The fully-wired constructor: optional AIMD scaling plus a shared
    /// telemetry plane. Workers attach the plane to every session's
    /// policy and emit begin/tick/end events for it — pure observation,
    /// so outcomes are bit-identical with [`Telemetry::disabled`].
    pub fn with_telemetry(
        spec: Arc<Spec>,
        workers: usize,
        scaling: Option<AimdCfg>,
        tel: Arc<Telemetry>,
    ) -> Fleet {
        match scaling {
            Some(mut cfg) => {
                cfg.min_workers = cfg.min_workers.max(1);
                cfg.max_workers = cfg.max_workers.max(cfg.min_workers);
                let initial = workers.clamp(cfg.min_workers, cfg.max_workers);
                Fleet::build(spec, initial, Some(cfg), tel)
            }
            None => Fleet::build(spec, workers, None, tel),
        }
    }

    fn build(spec: Arc<Spec>, workers: usize, cfg: Option<AimdCfg>, tel: Arc<Telemetry>) -> Fleet {
        let n = workers.max(1);
        let next_worker = AtomicUsize::new(0);
        let baseline = Arc::new(BaselineCache::new());
        let workers = (0..n)
            .map(|_| {
                spawn_worker(
                    &spec,
                    next_worker.fetch_add(1, Ordering::SeqCst),
                    &tel,
                    &baseline,
                )
            })
            .collect();
        Fleet {
            spec,
            workers: RwLock::new(workers),
            next_session: AtomicU64::new(1),
            next_worker,
            scaler: cfg.map(|c| Mutex::new(AimdState::new(c))),
            started: Instant::now(),
            tel,
            baseline,
        }
    }

    pub fn spec(&self) -> &Arc<Spec> {
        &self.spec
    }

    /// The sweep-wide baseline cache (hit/miss counters for reporting).
    pub fn baseline_cache(&self) -> &Arc<BaselineCache> {
        &self.baseline
    }

    /// The telemetry plane the fleet's workers emit into.
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.tel
    }

    pub fn num_workers(&self) -> usize {
        // The workers RwLock (and the scaler mutex below) recover from
        // poisoning: the Vec/scaler state stays structurally valid, and
        // serving control-plane traffic beats cascading a worker panic.
        self.workers.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Feed the scaler one queue-depth observation and apply whatever it
    /// decides. Returns the new pool size when it changed. A fleet built
    /// without scaling ([`Fleet::new`]) always holds.
    ///
    /// Shrinking retires only workers with zero pinned sessions, from
    /// the tail of the pool — a busy tail stops the shrink early rather
    /// than stalling behind a long-running session.
    pub fn autoscale(&self, depth: usize) -> Option<usize> {
        let scaler = self.scaler.as_ref()?;
        let now_s = self.started.elapsed().as_secs_f64();
        let live = self.num_workers();
        let decision = scaler
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .observe(now_s, depth, live);
        match decision {
            ScaleDecision::Hold => None,
            ScaleDecision::Grow => {
                let mut ws = self.workers.write().unwrap_or_else(|e| e.into_inner());
                ws.push(spawn_worker(
                    &self.spec,
                    self.next_worker.fetch_add(1, Ordering::SeqCst),
                    &self.tel,
                    &self.baseline,
                ));
                Some(ws.len())
            }
            ScaleDecision::Shrink(target) => {
                let mut ws = self.workers.write().unwrap_or_else(|e| e.into_inner());
                let before = ws.len();
                while ws.len() > target {
                    let idle = ws
                        .last()
                        .map(|w| w.active.load(Ordering::SeqCst) == 0)
                        .unwrap_or(false);
                    if !idle {
                        break;
                    }
                    let Some(mut w) = ws.pop() else { break };
                    if let Some(tx) = w.tx.take() {
                        let _ = tx.send(Cmd::Shutdown);
                    }
                    if let Some(j) = w.join.take() {
                        let _ = j.join();
                    }
                }
                (ws.len() != before).then(|| ws.len())
            }
        }
    }

    /// Run a batch of jobs across the pool. Blocks until every job
    /// finishes; results come back in submission order, and (for the
    /// deterministic simulator) are identical to a serial run.
    ///
    /// Dispatch is completion-driven — one outstanding job per worker,
    /// each completion pulls the next job from the shared queue — so the
    /// wall-clock tracks total-work / workers even when job costs are
    /// wildly uneven (they are: `default_iters` varies per app).
    pub fn run_jobs(&self, jobs: Vec<SweepJob>) -> Vec<anyhow::Result<JobOutcome>> {
        // The read guard is held for the whole batch: autoscale's write
        // lock can never retire a worker out from under an in-flight job.
        let workers = self.workers.read().unwrap_or_else(|e| e.into_inner());
        let n = jobs.len();
        let mut out: Vec<Option<anyhow::Result<JobOutcome>>> = (0..n).map(|_| None).collect();
        let (tx, rx) = channel();
        let mut queue: VecDeque<(usize, SweepJob)> = jobs.into_iter().enumerate().collect();
        let mut inflight = 0usize;
        let mut per_worker: Vec<usize> = vec![0; workers.len()];

        for (wi, w) in workers.iter().enumerate() {
            if feed_worker(w, wi, &mut queue, &tx, &mut out) {
                inflight += 1;
                per_worker[wi] += 1;
            }
        }
        while inflight > 0 {
            match rx.recv_timeout(std::time::Duration::from_millis(500)) {
                Ok((wi, idx, outcome)) => {
                    inflight -= 1;
                    per_worker[wi] -= 1;
                    out[idx] = Some(outcome);
                    if feed_worker(&workers[wi], wi, &mut queue, &tx, &mut out) {
                        inflight += 1;
                        per_worker[wi] += 1;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // Our own `tx` clone keeps the channel open, so a
                    // worker dying mid-job never disconnects it — detect
                    // that case explicitly instead of blocking forever.
                    let stalled = per_worker.iter().enumerate().all(|(wi, &c)| {
                        c == 0 || workers[wi].join.as_ref().map_or(true, |j| j.is_finished())
                    });
                    if stalled {
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("fleet worker died mid-job"))))
            .collect()
    }

    /// Start an interactive session on the least-loaded worker, driving
    /// any registered policy. Fails on an unknown policy name, or when
    /// the policy needs a predictor the worker cannot load
    /// (`no predictor: ...`).
    pub fn begin(
        &self,
        app: AppParams,
        policy: PolicySpec,
        target_iters: u64,
    ) -> anyhow::Result<SessionHandle> {
        let (reply, rx) = Reply::channel_pair();
        let handle = self.begin_async(app, policy, target_iters, reply)?;
        match rx.recv() {
            Ok(Some(Ok(()))) => Ok(handle),
            // Dropping `handle` here sends Cmd::Drop (a no-op remove on
            // the worker, which never registered the session) and undoes
            // the eager active-count increment.
            Ok(Some(Err(e))) => Err(e),
            _ => Err(anyhow::anyhow!("fleet worker thread is gone")),
        }
    }

    /// Non-blocking [`Fleet::begin`]: the session handle comes back
    /// immediately; `reply` fires once the worker has built the policy
    /// (or failed to). The caller must treat the handle as live only
    /// after a successful reply — on failure, dropping it cleans up.
    ///
    /// The worker's load count is incremented *eagerly*, before the
    /// Begin is even queued, so least-loaded placement and idle-worker
    /// retirement both see the session the moment it exists.
    pub fn begin_async(
        &self,
        app: AppParams,
        policy: PolicySpec,
        target_iters: u64,
        reply: Reply<anyhow::Result<()>>,
    ) -> anyhow::Result<SessionHandle> {
        let workers = self.workers.read().unwrap_or_else(|e| e.into_inner());
        let w = workers
            .iter()
            .min_by_key(|w| w.active.load(Ordering::SeqCst))
            .ok_or_else(|| anyhow::anyhow!("fleet has no workers"))?;
        let Some(tx) = w.tx.as_ref() else {
            anyhow::bail!("fleet worker already shut down");
        };
        let id = self.next_session.fetch_add(1, Ordering::SeqCst);
        w.active.fetch_add(1, Ordering::SeqCst);
        let sent = tx.send(Cmd::Begin {
            id,
            req: Box::new(BeginReq {
                app,
                policy,
                target_iters,
            }),
            reply,
        });
        if sent.is_err() {
            w.active.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("fleet worker thread is gone");
        }
        Ok(SessionHandle {
            id,
            target_iters,
            tx: tx.clone(),
            active: w.active.clone(),
            open: true,
        })
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // An explicit Shutdown (processed after any already-queued
        // commands) rather than just hanging up: outstanding
        // SessionHandles hold sender clones, so channel disconnection
        // alone would leave the worker loops — and this join — blocked
        // forever. After shutdown, surviving handles get an error from
        // their next call instead of an answer.
        let workers = self.workers.get_mut().unwrap_or_else(|e| e.into_inner());
        for w in workers.iter_mut() {
            if let Some(tx) = &w.tx {
                let _ = tx.send(Cmd::Shutdown);
            }
            w.tx.take();
        }
        for w in workers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Handle to an interactive session pinned to one fleet worker. Dropping
/// the handle without [`end`](SessionHandle::end) aborts the session.
pub struct SessionHandle {
    id: u64,
    target_iters: u64,
    tx: Sender<Cmd>,
    active: Arc<AtomicUsize>,
    open: bool,
}

impl SessionHandle {
    /// The fleet-wide session id — the `session` field of every
    /// telemetry event this session emits, and its journal file name.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The iteration target the session was begun with. Telemetry
    /// `tick` events carry progress but not the target; stream
    /// consumers (the reactor's `subscribe` path) read it here.
    pub fn target_iters(&self) -> u64 {
        self.target_iters
    }

    fn roundtrip(
        &self,
        make: impl FnOnce(Reply<anyhow::Result<SessionStatus>>) -> Cmd,
    ) -> anyhow::Result<SessionStatus> {
        let (reply, rx) = Reply::channel_pair();
        self.tx
            .send(make(reply))
            .map_err(|_| anyhow::anyhow!("fleet worker thread is gone"))?;
        match rx.recv() {
            Ok(Some(r)) => r,
            _ => Err(anyhow::anyhow!("fleet worker thread is gone")),
        }
    }

    /// Advance the session by at most `max_ticks` controller ticks
    /// (stops early once the iteration target is reached).
    pub fn step(&self, max_ticks: u64) -> anyhow::Result<SessionStatus> {
        let id = self.id;
        self.roundtrip(|reply| Cmd::Step {
            id,
            max_ticks,
            reply,
        })
    }

    /// Non-blocking [`SessionHandle::step`]: queue the step and fire
    /// `reply` when the worker answers.
    pub fn dispatch_step(&self, max_ticks: u64, reply: Reply<anyhow::Result<SessionStatus>>) {
        let _ = self.tx.send(Cmd::Step {
            id: self.id,
            max_ticks,
            reply,
        });
        // A failed send drops the reply, which fires it with None — the
        // caller observes the dead worker through its callback.
    }

    /// Fire-and-forget cap application from the fleet budget arbiter
    /// (DESIGN.md §14). No reply: the arbiter observes the applied cap
    /// through the telemetry plane (`CapChange` events), and a dead
    /// worker surfaces through the next Step/End on this handle.
    pub fn dispatch_set_cap(&self, cap_w: f64, budget_w: f64, epoch: u64) {
        let _ = self.tx.send(Cmd::SetCap {
            id: self.id,
            cap_w,
            budget_w,
            epoch,
        });
    }

    /// Abandon the session without driving it to its target (the
    /// explicit spelling of what dropping the handle does; the daemon's
    /// `abort` request uses it).
    pub fn abort(self) {
        drop(self);
    }

    /// Drive the session to its iteration target and release it.
    pub fn end(mut self) -> anyhow::Result<SessionStatus> {
        self.open = false;
        let id = self.id;
        let active = self.active.clone();
        let (reply, rx) = Reply::channel_pair();
        // Only decrement once the run has actually finished — a worker
        // mid-END must keep looking loaded to least-loaded placement.
        let reply = reply.before(move || {
            active.fetch_sub(1, Ordering::SeqCst);
        });
        let sent = self.tx.send(Cmd::End {
            id,
            budget_s: None,
            reply,
        });
        if sent.is_err() {
            return Err(anyhow::anyhow!("fleet worker thread is gone"));
        }
        match rx.recv() {
            Ok(Some(r)) => r,
            _ => Err(anyhow::anyhow!("fleet worker thread is gone")),
        }
    }

    /// Non-blocking [`SessionHandle::end`]: consumes the handle, fires
    /// `reply` with the final status once the run completes. The
    /// worker's load count is released exactly when the reply fires
    /// (success or worker death), same as the blocking path.
    pub fn dispatch_end(mut self, reply: Reply<anyhow::Result<SessionStatus>>) {
        self.open = false;
        let active = self.active.clone();
        let reply = reply.before(move || {
            active.fetch_sub(1, Ordering::SeqCst);
        });
        let _ = self.tx.send(Cmd::End {
            id: self.id,
            budget_s: None,
            reply,
        });
    }
}

impl Drop for SessionHandle {
    fn drop(&mut self) {
        if self.open {
            let _ = self.tx.send(Cmd::Drop { id: self.id });
            self.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Hand `w` the next queued job, if any. Returns true when a job went
/// out; on a dead worker the job is recorded as failed and no retry is
/// attempted (the remaining queue drains through the other workers).
fn feed_worker(
    w: &WorkerHandle,
    wi: usize,
    queue: &mut VecDeque<(usize, SweepJob)>,
    reply: &Sender<(usize, usize, anyhow::Result<JobOutcome>)>,
    out: &mut [Option<anyhow::Result<JobOutcome>>],
) -> bool {
    let Some((idx, job)) = queue.pop_front() else {
        return false;
    };
    match w.send(Cmd::Job {
        worker: wi,
        idx,
        job: Box::new(job),
        reply: reply.clone(),
    }) {
        Ok(()) => true,
        Err(e) => {
            out[idx] = Some(Err(e));
            false
        }
    }
}

/// Spawn one worker thread with its command queue. `i` is a process-wide
/// worker ordinal (monotonic across autoscale grow events) so thread
/// names stay unique for the life of the fleet.
fn spawn_worker(
    spec: &Arc<Spec>,
    i: usize,
    tel: &Arc<Telemetry>,
    baseline: &Arc<BaselineCache>,
) -> WorkerHandle {
    let (tx, rx) = channel();
    let spec = spec.clone();
    let tel = tel.clone();
    let baseline = baseline.clone();
    // The worker keeps a sender to its own queue so a long END can
    // re-enqueue itself in slices (see worker_loop).
    let self_tx = tx.clone();
    // Invariant expect: spawn fails only on OS thread exhaustion; a
    // fleet that cannot start workers has no degraded mode to offer.
    #[allow(clippy::expect_used)]
    let join = std::thread::Builder::new()
        .name(format!("fleet-worker-{i}"))
        .spawn(move || worker_loop(spec, rx, self_tx, tel, baseline))
        .expect("failed to spawn fleet worker");
    WorkerHandle {
        tx: Some(tx),
        active: Arc::new(AtomicUsize::new(0)),
        join: Some(join),
    }
}

// ---------------------------------------------------------------------
// Worker side.
// ---------------------------------------------------------------------

/// Ticks per END slice: enough to make real progress per hand-off
/// (hundreds of virtual seconds), small enough that other sessions'
/// queued commands interleave with sub-second latency.
const END_SLICE_TICKS: u64 = 20_000;

struct WorkerSession {
    dev: Box<dyn Device>,
    policy: Box<dyn Policy>,
    target_iters: u64,
}

impl WorkerSession {
    fn done(&self) -> bool {
        self.dev.iterations() >= self.target_iters
    }

    /// Advance by at most `max_ticks`; returns the ticks executed (the
    /// telemetry layer divides wall time by it for per-tick latency).
    /// Routed through [`Policy::drive`] so tick-less policies (the
    /// default baseline) fast-forward instead of looping here.
    fn step(&mut self, max_ticks: u64) -> u64 {
        self.policy
            .drive(self.dev.as_mut(), self.target_iters, f64::INFINITY, max_ticks)
    }

    /// One bounded slice of the run; `.0` is true once the session is
    /// finished (target reached, or the errant-policy budget exhausted),
    /// `.1` the ticks executed.
    fn slice(&mut self, max_ticks: u64, budget_s: f64) -> (bool, u64) {
        let n = self
            .policy
            .drive(self.dev.as_mut(), self.target_iters, budget_s, max_ticks);
        (self.done() || self.dev.time_s() >= budget_s, n)
    }

    fn status(&self) -> SessionStatus {
        SessionStatus {
            iterations: self.dev.iterations(),
            target_iters: self.target_iters,
            time_s: self.dev.time_s(),
            energy_j: self.dev.true_energy_j(),
            sm_gear: self.dev.sm_gear(),
            mem_gear: self.dev.mem_gear(),
            done: self.done(),
        }
    }
}

fn load_predictor() -> Result<Arc<Predictor>, String> {
    Predictor::load_best()
        .map(Arc::new)
        .map_err(|e| format!("{e:#}"))
}

/// The progress snapshot a drive slice emits (always *before* the
/// command's reply, so a flushed telemetry plane has forwarded every
/// event of a session by the time its final reply is on the wire).
fn tick_event(id: u64, st: &SessionStatus) -> TelemetryEvent {
    TelemetryEvent::Tick {
        session: id,
        iterations: st.iterations,
        time_s: st.time_s,
        energy_j: st.energy_j,
        sm_gear: st.sm_gear,
        mem_gear: st.mem_gear,
        done: st.done,
    }
}

fn end_event(id: u64, st: &SessionStatus) -> TelemetryEvent {
    TelemetryEvent::End {
        session: id,
        iterations: st.iterations,
        time_s: st.time_s,
        energy_j: st.energy_j,
        done: st.done,
    }
}

fn worker_loop(
    spec: Arc<Spec>,
    rx: Receiver<Cmd>,
    self_tx: Sender<Cmd>,
    tel: Arc<Telemetry>,
    baseline: Arc<BaselineCache>,
) {
    // One predictor per worker thread — compiled on first use (never,
    // for an ODPP/default-only workload), then reused by every job and
    // session this worker runs. Built here (not in the Fleet) because
    // the PJRT client must not cross threads.
    let predictor: OnceCell<Result<Arc<Predictor>, String>> = OnceCell::new();
    let mut sessions: HashMap<u64, WorkerSession> = HashMap::new();

    for cmd in rx {
        match cmd {
            Cmd::Job {
                worker,
                idx,
                job,
                reply,
            } => {
                let _ = reply.send((worker, idx, run_job(&spec, &predictor, &job, &baseline)));
            }
            Cmd::Begin { id, req, reply } => {
                // Build the policy here, on the worker thread: a policy
                // that needs the predictor gets this worker's copy; a
                // model-free one never triggers the load at all.
                let provider = || {
                    predictor
                        .get_or_init(load_predictor)
                        .clone()
                        .map_err(|e| anyhow::anyhow!("no predictor: {e}"))
                };
                let ctx = PolicyCtx {
                    spec: &spec,
                    predictor: &provider,
                };
                let r = PolicyRegistry::global()
                    .build_spec(&req.policy, &ctx)
                    .map(|mut policy| {
                        if tel.enabled() {
                            policy.attach_telemetry(tel.clone(), id);
                            tel.metrics().inc(Counter::SessionsBegun);
                            tel.emit(TelemetryEvent::Begin {
                                session: id,
                                app: req.app.name.clone(),
                                policy: req.policy.name.clone(),
                                target_iters: req.target_iters,
                            });
                        }
                        sessions.insert(
                            id,
                            WorkerSession {
                                dev: boxed_sim_device(&spec, &req.app),
                                policy,
                                target_iters: req.target_iters,
                            },
                        );
                    });
                reply.send(r);
            }
            Cmd::Step {
                id,
                max_ticks,
                reply,
            } => {
                let r = match sessions.get_mut(&id) {
                    Some(s) => {
                        let t0 = tel.enabled().then(Instant::now);
                        let n = s.step(max_ticks);
                        let st = s.status();
                        if let Some(t0) = t0 {
                            if n > 0 {
                                let per_tick = t0.elapsed().as_secs_f64() / n as f64;
                                tel.metrics().observe(Hist::TickSeconds, per_tick);
                            }
                            tel.emit(tick_event(id, &st));
                        }
                        Ok(st)
                    }
                    None => Err(anyhow::anyhow!("no such session")),
                };
                reply.send(r);
            }
            Cmd::End {
                id,
                budget_s,
                reply,
            } => {
                // Drive one slice, then re-enqueue behind whatever other
                // commands arrived meanwhile — a long END never
                // head-of-line blocks the worker's other sessions.
                let (finished, budget) = match sessions.get_mut(&id) {
                    Some(s) => {
                        let b = budget_s.unwrap_or_else(|| {
                            run_budget_s(s.dev.time_s(), s.target_iters, s.dev.nominal_iter_s())
                        });
                        let t0 = tel.enabled().then(Instant::now);
                        let (fin, n) = s.slice(END_SLICE_TICKS, b);
                        if let Some(t0) = t0 {
                            if n > 0 {
                                let per_tick = t0.elapsed().as_secs_f64() / n as f64;
                                tel.metrics().observe(Hist::TickSeconds, per_tick);
                            }
                            tel.emit(tick_event(id, &s.status()));
                        }
                        (fin.then(|| s.status()), b)
                    }
                    None => {
                        reply.send(Err(anyhow::anyhow!("no such session")));
                        continue;
                    }
                };
                match finished {
                    Some(st) => {
                        sessions.remove(&id);
                        if tel.enabled() {
                            tel.metrics().inc(Counter::SessionsEnded);
                            tel.metrics().remove_session_cap(id);
                            tel.emit(end_event(id, &st));
                        }
                        reply.send(Ok(st));
                    }
                    None => {
                        let requeued = self_tx.send(Cmd::End {
                            id,
                            budget_s: Some(budget),
                            reply,
                        });
                        if requeued.is_err() {
                            // Shutting down mid-run: release the session;
                            // the requeued Cmd (and its reply) died with
                            // the send, so the client observes the loss.
                            sessions.remove(&id);
                        }
                    }
                }
            }
            Cmd::SetCap {
                id,
                cap_w,
                budget_w,
                epoch,
            } => {
                // Unknown ids are dropped silently: the arbiter may race
                // an End, and a cap for a finished session is moot.
                if let Some(s) = sessions.get_mut(&id) {
                    let applied = s.dev.set_power_limit_w(cap_w);
                    if tel.enabled() {
                        tel.metrics().set_session_cap(id, applied);
                        tel.emit(TelemetryEvent::CapChange {
                            session: id,
                            cap_w: applied,
                            budget_w,
                            epoch,
                            time_s: s.dev.time_s(),
                        });
                    }
                }
            }
            Cmd::Drop { id } => {
                if let Some(s) = sessions.remove(&id) {
                    if tel.enabled() {
                        tel.metrics().inc(Counter::SessionsEnded);
                        tel.metrics().remove_session_cap(id);
                        tel.emit(end_event(id, &s.status()));
                    }
                }
            }
            Cmd::Shutdown => break,
        }
    }
}

fn run_job(
    spec: &Arc<Spec>,
    predictor: &OnceCell<Result<Arc<Predictor>, String>>,
    job: &SweepJob,
    baseline: &BaselineCache,
) -> anyhow::Result<JobOutcome> {
    let provider = || {
        predictor
            .get_or_init(load_predictor)
            .clone()
            .map_err(|e| anyhow::anyhow!("no predictor: {e}"))
    };
    let ctx = PolicyCtx {
        spec,
        predictor: &provider,
    };
    let reg = PolicyRegistry::global();

    // The baseline is itself a registered policy, fetched through the
    // sweep-wide cache: a sweep scores P policies against one baseline
    // per app, so only the first (app, iters, tick) job per fleet pays
    // the simulation. The `ts` knob mirrors the default builder's
    // (policy/mod.rs) — it is the only config the baseline run reads.
    let ts = job.policy.cfg.opt_f64("ts", 0.025)?;
    let mut base_policy = reg.build("default", &ctx, &job.policy.cfg)?;
    let key = BaselineKey {
        suite: job.app.suite.clone(),
        app: job.app.name.clone(),
        trace_seed: job.app.trace_seed,
        n_iters: job.n_iters,
        ts_bits: ts.to_bits(),
        spec_digest: spec.digest,
    };
    let base = baseline.get_or_compute(key, || {
        run_sim(spec, &job.app, base_policy.as_mut(), job.n_iters)
    });

    let mut policy = reg.build_spec(&job.policy, &ctx)?;
    let run = run_sim(spec, &job.app, policy.as_mut(), job.n_iters);
    let stats = policy.gpoeo_stats();

    let sv = savings(&base, &run)?;
    Ok(JobOutcome {
        base: (*base).clone(),
        run,
        savings: sv,
        stats,
    })
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::make_suite;

    fn test_jobs(spec: &Arc<Spec>, policy: PolicySpec, n: usize) -> Vec<SweepJob> {
        make_suite(spec, "aibench")
            .unwrap()
            .into_iter()
            .take(n)
            .map(|app| SweepJob {
                app,
                policy: policy.clone(),
                n_iters: 40,
            })
            .collect()
    }

    fn assert_same_outcomes(a: &[anyhow::Result<JobOutcome>], b: &[anyhow::Result<JobOutcome>]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
            // The simulator is deterministic: parallel placement must not
            // change a single bit of any result.
            assert_eq!(x.run.app, y.run.app);
            assert_eq!(x.run.iterations, y.run.iterations);
            assert_eq!(x.run.energy_j, y.run.energy_j);
            assert_eq!(x.run.time_s, y.run.time_s);
            assert_eq!(x.run.final_sm_gear, y.run.final_sm_gear);
            assert_eq!(x.run.final_mem_gear, y.run.final_mem_gear);
            assert_eq!(x.base.energy_j, y.base.energy_j);
            assert_eq!(x.savings.energy_saving, y.savings.energy_saving);
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_and_preserves_order() {
        // ODPP needs no model artifacts, so this always runs.
        let spec = Arc::new(Spec::load_default().unwrap());
        let jobs = test_jobs(&spec, PolicySpec::registered("odpp"), 6);
        let expect_order: Vec<String> = jobs.iter().map(|j| j.app.name.clone()).collect();

        let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
        let parallel = Fleet::new(spec.clone(), 3).run_jobs(jobs);

        let got_order: Vec<String> = parallel
            .iter()
            .map(|r| r.as_ref().unwrap().run.app.clone())
            .collect();
        assert_eq!(got_order, expect_order, "submission order must be kept");
        assert_same_outcomes(&serial, &parallel);
    }

    #[test]
    fn gpoeo_parallel_sweep_matches_serial() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let jobs = test_jobs(&spec, PolicySpec::registered("gpoeo"), 4);
        let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
        let parallel = Fleet::new(spec.clone(), 4).run_jobs(jobs);
        assert_same_outcomes(&serial, &parallel);
    }

    #[test]
    fn registered_policies_parallel_sweep_matches_serial() {
        // The new model-free families through the fleet: no artifacts
        // needed, so the registry dispatch path is always exercised.
        let spec = Arc::new(Spec::load_default().unwrap());
        for name in ["bandit", "powercap"] {
            let jobs = test_jobs(&spec, PolicySpec::registered(name), 4);
            let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
            let parallel = Fleet::new(spec.clone(), 2).run_jobs(jobs);
            assert_same_outcomes(&serial, &parallel);
        }
    }

    #[test]
    fn baseline_cache_hits_are_bit_identical_to_uncached() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let apps: Vec<AppParams> = make_suite(&spec, "aibench")
            .unwrap()
            .into_iter()
            .take(4)
            .collect();
        // Two model-free policies over the same 4 apps through ONE
        // fleet: the first policy's jobs compute the baselines, the
        // second policy's jobs must hit the cache.
        let mut jobs = Vec::new();
        for name in ["odpp", "bandit"] {
            for app in &apps {
                jobs.push(SweepJob {
                    app: app.clone(),
                    policy: PolicySpec::registered(name),
                    n_iters: 40,
                });
            }
        }
        let fleet = Fleet::new(spec.clone(), 1);
        let cached = fleet.run_jobs(jobs.clone());
        let (hits, misses) = fleet.baseline_cache().stats();
        assert_eq!(misses, 4, "one baseline compute per app");
        assert_eq!(hits, 4, "the second policy reuses every baseline");

        // Every job re-run through its own fresh fleet (nothing shared,
        // every baseline computed from scratch) must match bit-for-bit —
        // including the baseline fields and the derived savings.
        let uncached: Vec<anyhow::Result<JobOutcome>> = jobs
            .iter()
            .map(|j| {
                Fleet::new(spec.clone(), 1)
                    .run_jobs(vec![j.clone()])
                    .remove(0)
            })
            .collect();
        assert_same_outcomes(&cached, &uncached);
    }

    #[test]
    fn unknown_policy_fails_the_job_not_the_fleet() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let mut jobs = test_jobs(&spec, PolicySpec::registered("odpp"), 2);
        jobs[0].policy = PolicySpec::registered("warpdrive");
        let out = Fleet::new(spec, 2).run_jobs(jobs);
        let err = out[0].as_ref().unwrap_err().to_string();
        assert!(err.starts_with("unknown policy"), "{err}");
        assert!(out[1].is_ok(), "the healthy job must still complete");
    }

    #[test]
    fn interactive_sessions_spread_and_complete() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 2);
        let apps = make_suite(&spec, "aibench").unwrap();
        // Three sessions on two workers: placement must still serve all.
        let handles: Vec<SessionHandle> = apps
            .iter()
            .take(3)
            .map(|a| fleet.begin(a.clone(), PolicySpec::registered("gpoeo"), 30).unwrap())
            .collect();
        for h in &handles {
            let st = h.step(50).unwrap();
            assert!(st.time_s > 0.0);
        }
        for (h, app) in handles.into_iter().zip(&apps) {
            let fin = h.end().unwrap();
            assert!(fin.done, "{}: session must reach its target", app.name);
            assert!(fin.iterations >= 30);
            assert!(fin.energy_j > 0.0);
        }
    }

    #[test]
    fn model_free_interactive_session_runs_without_artifacts() {
        // `bandit` needs no predictor: Begin must succeed on a worker
        // that could never load one, and the unknown-name path must
        // answer with the registry error.
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 1);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        let h = fleet
            .begin(app.clone(), PolicySpec::registered("bandit"), 25)
            .unwrap();
        let st = h.step(50).unwrap();
        assert!(st.time_s > 0.0);
        assert_eq!(st.target_iters, 25, "status must carry the session target");
        let fin = h.end().unwrap();
        assert!(fin.done && fin.iterations >= 25);

        let err = fleet
            .begin(app, PolicySpec::registered("warpdrive"), 10)
            .unwrap_err()
            .to_string();
        assert!(err.starts_with("unknown policy"), "{err}");
    }

    #[test]
    fn dropping_a_session_releases_it_without_killing_the_worker() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 1);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        let h = fleet
            .begin(app.clone(), PolicySpec::registered("gpoeo"), 20)
            .unwrap();
        let h2 = fleet.begin(app, PolicySpec::registered("gpoeo"), 20).unwrap();
        drop(h);
        // The worker is still alive and still serves the other session.
        assert!(h2.step(10).is_ok());
        assert!(h2.end().unwrap().done);
    }

    fn aimd_cfg() -> AimdCfg {
        AimdCfg {
            min_workers: 1,
            max_workers: 4,
            backlog_per_worker: 2,
            grow_after_s: 1.0,
            shrink_after_s: 5.0,
        }
    }

    #[test]
    fn aimd_grows_only_after_a_sustained_backlog_window() {
        let mut s = AimdState::new(aimd_cfg());
        // Backlog threshold is live × per-worker = 2: depth 2 is "fine".
        assert_eq!(s.observe(0.0, 2, 1), ScaleDecision::Hold);
        // Backlogged, but the window hasn't elapsed yet.
        assert_eq!(s.observe(0.1, 9, 1), ScaleDecision::Hold);
        assert_eq!(s.observe(0.9, 9, 1), ScaleDecision::Hold);
        // 1.0s of sustained backlog → one additive step.
        assert_eq!(s.observe(1.1, 9, 1), ScaleDecision::Grow);
        // The window restarts: the next grow needs another full second.
        assert_eq!(s.observe(1.2, 9, 2), ScaleDecision::Hold);
        assert_eq!(s.observe(2.2, 9, 2), ScaleDecision::Grow);
        // A dip below the backlog line resets the busy window entirely.
        assert_eq!(s.observe(2.3, 1, 3), ScaleDecision::Hold);
        assert_eq!(s.observe(3.4, 9, 3), ScaleDecision::Hold);
        assert_eq!(s.observe(4.5, 9, 3), ScaleDecision::Grow);
        // At the ceiling, sustained backlog holds instead of growing.
        assert_eq!(s.observe(9.0, 99, 4), ScaleDecision::Hold);
        assert_eq!(s.observe(99.0, 99, 4), ScaleDecision::Hold);
    }

    #[test]
    fn aimd_shrinks_multiplicatively_after_sustained_idle() {
        let mut s = AimdState::new(aimd_cfg());
        assert_eq!(s.observe(0.0, 0, 4), ScaleDecision::Hold);
        assert_eq!(s.observe(4.9, 0, 4), ScaleDecision::Hold);
        // 5s empty → halve. A trickle of work (depth 1, not backlogged)
        // is neither busy nor idle: it resets the idle window.
        assert_eq!(s.observe(5.0, 0, 4), ScaleDecision::Shrink(2));
        assert_eq!(s.observe(7.0, 1, 2), ScaleDecision::Hold);
        assert_eq!(s.observe(11.9, 0, 2), ScaleDecision::Hold);
        assert_eq!(s.observe(12.1, 0, 2), ScaleDecision::Hold);
        assert_eq!(s.observe(17.2, 0, 2), ScaleDecision::Shrink(1));
        // At the floor, idleness holds.
        assert_eq!(s.observe(99.0, 0, 1), ScaleDecision::Hold);
    }

    #[test]
    fn fleet_autoscale_grows_and_retires_idle_workers() {
        let spec = Arc::new(Spec::load_default().unwrap());
        // Zero-length windows make every decision fire on the first
        // qualifying observation — no sleeping in the test.
        let cfg = AimdCfg {
            min_workers: 1,
            max_workers: 3,
            backlog_per_worker: 1,
            grow_after_s: 0.0,
            shrink_after_s: 0.0,
        };
        let fleet = Fleet::with_scaling(spec.clone(), 1, cfg);
        assert_eq!(fleet.num_workers(), 1);
        assert_eq!(fleet.autoscale(5), Some(2));
        assert_eq!(fleet.autoscale(5), Some(3));
        // At the ceiling: hold.
        assert_eq!(fleet.autoscale(5), None);
        assert_eq!(fleet.num_workers(), 3);

        // A session pins the tail-most... any worker; all are idle except
        // the one it lands on, so a shrink stops at that worker if it's
        // at the tail. End it first to make the full shrink observable.
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        let h = fleet
            .begin(app.clone(), PolicySpec::registered("powercap"), 15)
            .unwrap();
        assert!(h.end().unwrap().done);

        // Idle with an empty queue → halve, then floor.
        assert_eq!(fleet.autoscale(0), Some(1));
        assert_eq!(fleet.num_workers(), 1);
        assert_eq!(fleet.autoscale(0), None);

        // The survivor still serves sessions after the churn.
        let h = fleet
            .begin(app, PolicySpec::registered("powercap"), 15)
            .unwrap();
        let fin = h.end().unwrap();
        assert!(fin.done && fin.iterations >= 15);
    }

    #[test]
    fn fixed_fleet_never_scales() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec, 2);
        assert_eq!(fleet.autoscale(1_000), None);
        assert_eq!(fleet.autoscale(0), None);
        assert_eq!(fleet.num_workers(), 2);
    }

    #[test]
    fn shrink_spares_workers_with_pinned_sessions() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let cfg = AimdCfg {
            min_workers: 1,
            max_workers: 2,
            backlog_per_worker: 1,
            grow_after_s: 0.0,
            shrink_after_s: 0.0,
        };
        let fleet = Fleet::with_scaling(spec.clone(), 2, cfg);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        // Two sessions: least-loaded placement puts one on each worker,
        // so the tail worker is busy and the shrink must stop early.
        let h1 = fleet
            .begin(app.clone(), PolicySpec::registered("powercap"), 10)
            .unwrap();
        let h2 = fleet
            .begin(app, PolicySpec::registered("powercap"), 10)
            .unwrap();
        assert_eq!(fleet.autoscale(0), None);
        assert_eq!(fleet.num_workers(), 2);
        // Sessions still answer — nobody's worker was retired.
        assert!(h1.step(5).is_ok());
        assert!(h1.end().unwrap().done);
        assert!(h2.end().unwrap().done);
        // With both released, the same observation now shrinks.
        assert_eq!(fleet.autoscale(0), Some(1));
    }

    #[test]
    fn dispatch_calls_fire_their_replies() {
        use std::sync::mpsc::channel;
        // The reactor-facing async path: begin_async → dispatch_step →
        // dispatch_end, all through Reply callbacks, no blocking recv on
        // the session side until the assertion points.
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 1);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();

        let (btx, brx) = channel();
        let h = fleet
            .begin_async(
                app,
                PolicySpec::registered("powercap"),
                20,
                Reply::new(move |r| {
                    let _ = btx.send(r);
                }),
            )
            .unwrap();
        assert!(brx.recv().unwrap().unwrap().is_ok());

        let (stx, srx) = channel();
        h.dispatch_step(
            5,
            Reply::new(move |r| {
                let _ = stx.send(r);
            }),
        );
        let st = srx.recv().unwrap().unwrap().unwrap();
        assert!(st.time_s > 0.0);
        assert_eq!(st.target_iters, 20);

        let (etx, erx) = channel();
        h.dispatch_end(Reply::new(move |r| {
            let _ = etx.send(r);
        }));
        let fin = erx.recv().unwrap().unwrap().unwrap();
        assert!(fin.done && fin.iterations >= 20);
    }

    #[test]
    fn dropped_reply_reports_loss_not_hang() {
        use std::sync::mpsc::channel;
        // Killing the fleet with an End in flight must fire the pending
        // reply with None (loss), never strand it.
        let spec = Arc::new(Spec::load_default().unwrap());
        let (tx, rx) = channel();
        {
            let fleet = Fleet::new(spec.clone(), 1);
            let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
            let h = fleet
                .begin(app, PolicySpec::registered("powercap"), 1_000_000)
                .unwrap();
            h.dispatch_end(Reply::new(move |r| {
                let _ = tx.send(r.is_some());
            }));
            // Fleet drops here: Shutdown beats the (long) End's requeued
            // slices, so the worker exits and drops the pending reply.
        }
        // Either the run finished in time (Some → true) or the reply
        // was dropped on shutdown (None → false) — both mean the
        // callback fired; a hang here is the failure mode.
        rx.recv().expect("pending reply must fire on shutdown");
    }
}
