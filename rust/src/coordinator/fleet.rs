//! Parallel fleet engine: many (app × policy) sessions across a worker
//! pool (DESIGN.md §6).
//!
//! The paper evaluates GPOEO one training job at a time; a production
//! optimizer service faces a *fleet* — 71-app sweeps, many concurrent
//! Begin/End clients. Two constraints shape the design:
//!
//! - The PJRT client inside [`Predictor::Hlo`] is not `Send` (`Rc`
//!   internals), so a predictor can never migrate between threads.
//!   Each worker thread therefore builds **one** predictor, on first
//!   use, and serves every job and session routed to it — the HLO
//!   executables compile at most once per worker, not once per
//!   connection (the old daemon recompiled them for every client).
//! - Simulated devices are deterministic given (spec, app): a session's
//!   outcome is independent of which worker runs it or what else runs
//!   concurrently, so a parallel sweep is bit-identical to a serial one
//!   and results can be returned in deterministic (submission) order.
//!
//! Two modes of use:
//! - [`Fleet::run_jobs`] — batch: run a vector of [`SweepJob`]s to
//!   completion, results in submission order (`gpoeo sweep --parallel`).
//! - [`Fleet::begin`] / [`SessionHandle`] — interactive: long-lived
//!   sessions pinned to a worker, driven incrementally (the daemon's
//!   Begin/Status/End protocol).

use crate::coordinator::{run_budget_s, run_sim, savings, GpoeoStats, Policy, RunResult, Savings};
use crate::device::{boxed_sim_device, Device};
use crate::model::Predictor;
use crate::policy::{PolicyCtx, PolicyRegistry, PolicySpec};
use crate::sim::{AppParams, Spec};
use std::cell::OnceCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// One unit of sweep work: run `policy` on `app` for `n_iters` work
/// units, scored against a fresh NVIDIA-default baseline. The policy is
/// a registry [`PolicySpec`] — it crosses to the worker as (name,
/// config) and is built there, next to the worker's predictor.
#[derive(Clone)]
pub struct SweepJob {
    pub app: AppParams,
    pub policy: PolicySpec,
    pub n_iters: u64,
}

/// Outcome of one [`SweepJob`].
pub struct JobOutcome {
    pub base: RunResult,
    pub run: RunResult,
    pub savings: Savings,
    pub stats: Option<GpoeoStats>,
}

/// Telemetry snapshot of an interactive session.
#[derive(Debug, Clone, Copy)]
pub struct SessionStatus {
    pub iterations: u64,
    /// The session's iteration target (what `done` is measured against).
    pub target_iters: u64,
    pub time_s: f64,
    pub energy_j: f64,
    pub sm_gear: usize,
    pub mem_gear: usize,
    pub done: bool,
}

/// Session parameters shipped to a worker by [`Fleet::begin`].
struct BeginReq {
    app: AppParams,
    policy: PolicySpec,
    target_iters: u64,
}

// Large payloads are boxed so the enum stays small for the frequent
// Step/End/Drop traffic.
enum Cmd {
    Job {
        /// Index of the worker the job was sent to (echoed back so the
        /// dispatcher knows which worker freed up).
        worker: usize,
        idx: usize,
        job: Box<SweepJob>,
        reply: Sender<(usize, usize, anyhow::Result<JobOutcome>)>,
    },
    Begin {
        id: u64,
        req: Box<BeginReq>,
        reply: Sender<anyhow::Result<()>>,
    },
    Step {
        id: u64,
        max_ticks: u64,
        reply: Sender<anyhow::Result<SessionStatus>>,
    },
    End {
        id: u64,
        /// Errant-policy virtual-time cap, computed on the first slice
        /// and carried through the re-enqueued slices.
        budget_s: Option<f64>,
        reply: Sender<anyhow::Result<SessionStatus>>,
    },
    Drop {
        id: u64,
    },
    /// Exit the worker loop even if session handles still hold sender
    /// clones (see `Fleet::drop`).
    Shutdown,
}

struct WorkerHandle {
    tx: Option<Sender<Cmd>>,
    /// Interactive sessions currently pinned to this worker (for
    /// least-loaded placement).
    active: Arc<AtomicUsize>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    fn send(&self, cmd: Cmd) -> anyhow::Result<()> {
        self.tx
            .as_ref()
            .expect("fleet worker already shut down")
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("fleet worker thread is gone"))
    }
}

/// A pool of worker threads, each owning one predictor, serving sweep
/// jobs and interactive sessions.
pub struct Fleet {
    spec: Arc<Spec>,
    workers: Vec<WorkerHandle>,
    next_session: AtomicU64,
}

impl Fleet {
    /// Spawn `workers` threads (at least one). Each worker builds its
    /// own [`Predictor`] on first use — an ODPP- or default-only
    /// workload never pays the HLO compile, and a failed load only
    /// surfaces when a job or session actually needs prediction.
    pub fn new(spec: Arc<Spec>, workers: usize) -> Fleet {
        let n = workers.max(1);
        let workers = (0..n)
            .map(|i| {
                let (tx, rx) = channel();
                let spec = spec.clone();
                // The worker keeps a sender to its own queue so a long
                // END can re-enqueue itself in slices (see worker_loop).
                let self_tx = tx.clone();
                let join = std::thread::Builder::new()
                    .name(format!("fleet-worker-{i}"))
                    .spawn(move || worker_loop(spec, rx, self_tx))
                    .expect("failed to spawn fleet worker");
                WorkerHandle {
                    tx: Some(tx),
                    active: Arc::new(AtomicUsize::new(0)),
                    join: Some(join),
                }
            })
            .collect();
        Fleet {
            spec,
            workers,
            next_session: AtomicU64::new(1),
        }
    }

    pub fn spec(&self) -> &Arc<Spec> {
        &self.spec
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of jobs across the pool. Blocks until every job
    /// finishes; results come back in submission order, and (for the
    /// deterministic simulator) are identical to a serial run.
    ///
    /// Dispatch is completion-driven — one outstanding job per worker,
    /// each completion pulls the next job from the shared queue — so the
    /// wall-clock tracks total-work / workers even when job costs are
    /// wildly uneven (they are: `default_iters` varies per app).
    pub fn run_jobs(&self, jobs: Vec<SweepJob>) -> Vec<anyhow::Result<JobOutcome>> {
        let n = jobs.len();
        let mut out: Vec<Option<anyhow::Result<JobOutcome>>> = (0..n).map(|_| None).collect();
        let (tx, rx) = channel();
        let mut queue: VecDeque<(usize, SweepJob)> = jobs.into_iter().enumerate().collect();
        let mut inflight = 0usize;
        let mut per_worker: Vec<usize> = vec![0; self.workers.len()];

        for (wi, w) in self.workers.iter().enumerate() {
            if feed_worker(w, wi, &mut queue, &tx, &mut out) {
                inflight += 1;
                per_worker[wi] += 1;
            }
        }
        while inflight > 0 {
            match rx.recv_timeout(std::time::Duration::from_millis(500)) {
                Ok((wi, idx, outcome)) => {
                    inflight -= 1;
                    per_worker[wi] -= 1;
                    out[idx] = Some(outcome);
                    if feed_worker(&self.workers[wi], wi, &mut queue, &tx, &mut out) {
                        inflight += 1;
                        per_worker[wi] += 1;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // Our own `tx` clone keeps the channel open, so a
                    // worker dying mid-job never disconnects it — detect
                    // that case explicitly instead of blocking forever.
                    let stalled = per_worker.iter().enumerate().all(|(wi, &c)| {
                        c == 0
                            || self.workers[wi]
                                .join
                                .as_ref()
                                .map_or(true, |j| j.is_finished())
                    });
                    if stalled {
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(anyhow::anyhow!("fleet worker died mid-job"))))
            .collect()
    }

    /// Start an interactive session on the least-loaded worker, driving
    /// any registered policy. Fails on an unknown policy name, or when
    /// the policy needs a predictor the worker cannot load
    /// (`no predictor: ...`).
    pub fn begin(
        &self,
        app: AppParams,
        policy: PolicySpec,
        target_iters: u64,
    ) -> anyhow::Result<SessionHandle> {
        let w = self
            .workers
            .iter()
            .min_by_key(|w| w.active.load(Ordering::SeqCst))
            .expect("fleet has at least one worker");
        let id = self.next_session.fetch_add(1, Ordering::SeqCst);
        let (reply, rx) = channel();
        w.send(Cmd::Begin {
            id,
            req: Box::new(BeginReq {
                app,
                policy,
                target_iters,
            }),
            reply,
        })?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("fleet worker thread is gone"))??;
        w.active.fetch_add(1, Ordering::SeqCst);
        Ok(SessionHandle {
            id,
            tx: w.tx.as_ref().expect("worker is live").clone(),
            active: w.active.clone(),
            open: true,
        })
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // An explicit Shutdown (processed after any already-queued
        // commands) rather than just hanging up: outstanding
        // SessionHandles hold sender clones, so channel disconnection
        // alone would leave the worker loops — and this join — blocked
        // forever. After shutdown, surviving handles get an error from
        // their next call instead of an answer.
        for w in &mut self.workers {
            if let Some(tx) = &w.tx {
                let _ = tx.send(Cmd::Shutdown);
            }
            w.tx.take();
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Handle to an interactive session pinned to one fleet worker. Dropping
/// the handle without [`end`](SessionHandle::end) aborts the session.
pub struct SessionHandle {
    id: u64,
    tx: Sender<Cmd>,
    active: Arc<AtomicUsize>,
    open: bool,
}

impl SessionHandle {
    fn roundtrip(
        &self,
        make: impl FnOnce(Sender<anyhow::Result<SessionStatus>>) -> Cmd,
    ) -> anyhow::Result<SessionStatus> {
        let (reply, rx) = channel();
        self.tx
            .send(make(reply))
            .map_err(|_| anyhow::anyhow!("fleet worker thread is gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("fleet worker thread is gone"))?
    }

    /// Advance the session by at most `max_ticks` controller ticks
    /// (stops early once the iteration target is reached).
    pub fn step(&self, max_ticks: u64) -> anyhow::Result<SessionStatus> {
        let id = self.id;
        self.roundtrip(|reply| Cmd::Step {
            id,
            max_ticks,
            reply,
        })
    }

    /// Abandon the session without driving it to its target (the
    /// explicit spelling of what dropping the handle does; the daemon's
    /// `abort` request uses it).
    pub fn abort(self) {
        drop(self);
    }

    /// Drive the session to its iteration target and release it.
    pub fn end(mut self) -> anyhow::Result<SessionStatus> {
        self.open = false;
        let id = self.id;
        let r = self.roundtrip(|reply| Cmd::End {
            id,
            budget_s: None,
            reply,
        });
        // Only decrement once the run has actually finished — a worker
        // mid-END must keep looking loaded to least-loaded placement.
        self.active.fetch_sub(1, Ordering::SeqCst);
        r
    }
}

impl Drop for SessionHandle {
    fn drop(&mut self) {
        if self.open {
            let _ = self.tx.send(Cmd::Drop { id: self.id });
            self.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Hand `w` the next queued job, if any. Returns true when a job went
/// out; on a dead worker the job is recorded as failed and no retry is
/// attempted (the remaining queue drains through the other workers).
fn feed_worker(
    w: &WorkerHandle,
    wi: usize,
    queue: &mut VecDeque<(usize, SweepJob)>,
    reply: &Sender<(usize, usize, anyhow::Result<JobOutcome>)>,
    out: &mut [Option<anyhow::Result<JobOutcome>>],
) -> bool {
    let Some((idx, job)) = queue.pop_front() else {
        return false;
    };
    match w.send(Cmd::Job {
        worker: wi,
        idx,
        job: Box::new(job),
        reply: reply.clone(),
    }) {
        Ok(()) => true,
        Err(e) => {
            out[idx] = Some(Err(e));
            false
        }
    }
}

// ---------------------------------------------------------------------
// Worker side.
// ---------------------------------------------------------------------

/// Ticks per END slice: enough to make real progress per hand-off
/// (hundreds of virtual seconds), small enough that other sessions'
/// queued commands interleave with sub-second latency.
const END_SLICE_TICKS: u64 = 20_000;

struct WorkerSession {
    dev: Box<dyn Device>,
    policy: Box<dyn Policy>,
    target_iters: u64,
}

impl WorkerSession {
    fn done(&self) -> bool {
        self.dev.iterations() >= self.target_iters
    }

    fn step(&mut self, max_ticks: u64) {
        for _ in 0..max_ticks {
            if self.done() {
                break;
            }
            self.policy.tick(self.dev.as_mut());
        }
    }

    /// One bounded slice of the run; true once the session is finished
    /// (target reached, or the errant-policy budget exhausted).
    fn slice(&mut self, max_ticks: u64, budget_s: f64) -> bool {
        for _ in 0..max_ticks {
            if self.done() || self.dev.time_s() >= budget_s {
                break;
            }
            self.policy.tick(self.dev.as_mut());
        }
        self.done() || self.dev.time_s() >= budget_s
    }

    fn status(&self) -> SessionStatus {
        SessionStatus {
            iterations: self.dev.iterations(),
            target_iters: self.target_iters,
            time_s: self.dev.time_s(),
            energy_j: self.dev.true_energy_j(),
            sm_gear: self.dev.sm_gear(),
            mem_gear: self.dev.mem_gear(),
            done: self.done(),
        }
    }
}

fn load_predictor() -> Result<Arc<Predictor>, String> {
    Predictor::load_best()
        .map(Arc::new)
        .map_err(|e| format!("{e:#}"))
}

fn worker_loop(spec: Arc<Spec>, rx: Receiver<Cmd>, self_tx: Sender<Cmd>) {
    // One predictor per worker thread — compiled on first use (never,
    // for an ODPP/default-only workload), then reused by every job and
    // session this worker runs. Built here (not in the Fleet) because
    // the PJRT client must not cross threads.
    let predictor: OnceCell<Result<Arc<Predictor>, String>> = OnceCell::new();
    let mut sessions: HashMap<u64, WorkerSession> = HashMap::new();

    for cmd in rx {
        match cmd {
            Cmd::Job {
                worker,
                idx,
                job,
                reply,
            } => {
                let _ = reply.send((worker, idx, run_job(&spec, &predictor, &job)));
            }
            Cmd::Begin { id, req, reply } => {
                // Build the policy here, on the worker thread: a policy
                // that needs the predictor gets this worker's copy; a
                // model-free one never triggers the load at all.
                let provider = || {
                    predictor
                        .get_or_init(load_predictor)
                        .clone()
                        .map_err(|e| anyhow::anyhow!("no predictor: {e}"))
                };
                let ctx = PolicyCtx {
                    spec: &spec,
                    predictor: &provider,
                };
                let r = PolicyRegistry::global()
                    .build_spec(&req.policy, &ctx)
                    .map(|policy| {
                        sessions.insert(
                            id,
                            WorkerSession {
                                dev: boxed_sim_device(&spec, &req.app),
                                policy,
                                target_iters: req.target_iters,
                            },
                        );
                    });
                let _ = reply.send(r);
            }
            Cmd::Step {
                id,
                max_ticks,
                reply,
            } => {
                let r = match sessions.get_mut(&id) {
                    Some(s) => {
                        s.step(max_ticks);
                        Ok(s.status())
                    }
                    None => Err(anyhow::anyhow!("no such session")),
                };
                let _ = reply.send(r);
            }
            Cmd::End {
                id,
                budget_s,
                reply,
            } => {
                // Drive one slice, then re-enqueue behind whatever other
                // commands arrived meanwhile — a long END never
                // head-of-line blocks the worker's other sessions.
                let (finished, budget) = match sessions.get_mut(&id) {
                    Some(s) => {
                        let b = budget_s.unwrap_or_else(|| {
                            run_budget_s(s.dev.time_s(), s.target_iters, s.dev.nominal_iter_s())
                        });
                        (s.slice(END_SLICE_TICKS, b).then(|| s.status()), b)
                    }
                    None => {
                        let _ = reply.send(Err(anyhow::anyhow!("no such session")));
                        continue;
                    }
                };
                match finished {
                    Some(st) => {
                        sessions.remove(&id);
                        let _ = reply.send(Ok(st));
                    }
                    None => {
                        let requeued = self_tx.send(Cmd::End {
                            id,
                            budget_s: Some(budget),
                            reply,
                        });
                        if requeued.is_err() {
                            // Shutting down mid-run: release the session;
                            // the client's end() observes the hangup.
                            sessions.remove(&id);
                        }
                    }
                }
            }
            Cmd::Drop { id } => {
                sessions.remove(&id);
            }
            Cmd::Shutdown => break,
        }
    }
}

fn run_job(
    spec: &Arc<Spec>,
    predictor: &OnceCell<Result<Arc<Predictor>, String>>,
    job: &SweepJob,
) -> anyhow::Result<JobOutcome> {
    let provider = || {
        predictor
            .get_or_init(load_predictor)
            .clone()
            .map_err(|e| anyhow::anyhow!("no predictor: {e}"))
    };
    let ctx = PolicyCtx {
        spec,
        predictor: &provider,
    };
    let reg = PolicyRegistry::global();

    // The baseline is itself a registered policy; running it fresh (even
    // for `default` jobs) keeps this loop free of name matching, and the
    // deterministic simulator makes the re-run bit-identical anyway.
    let mut base_policy = reg.build("default", &ctx, &job.policy.cfg)?;
    let base = run_sim(spec, &job.app, base_policy.as_mut(), job.n_iters);

    let mut policy = reg.build_spec(&job.policy, &ctx)?;
    let run = run_sim(spec, &job.app, policy.as_mut(), job.n_iters);
    let stats = policy.gpoeo_stats();

    let sv = savings(&base, &run);
    Ok(JobOutcome {
        base,
        run,
        savings: sv,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::make_suite;

    fn test_jobs(spec: &Arc<Spec>, policy: PolicySpec, n: usize) -> Vec<SweepJob> {
        make_suite(spec, "aibench")
            .unwrap()
            .into_iter()
            .take(n)
            .map(|app| SweepJob {
                app,
                policy: policy.clone(),
                n_iters: 40,
            })
            .collect()
    }

    fn assert_same_outcomes(a: &[anyhow::Result<JobOutcome>], b: &[anyhow::Result<JobOutcome>]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
            // The simulator is deterministic: parallel placement must not
            // change a single bit of any result.
            assert_eq!(x.run.app, y.run.app);
            assert_eq!(x.run.iterations, y.run.iterations);
            assert_eq!(x.run.energy_j, y.run.energy_j);
            assert_eq!(x.run.time_s, y.run.time_s);
            assert_eq!(x.run.final_sm_gear, y.run.final_sm_gear);
            assert_eq!(x.run.final_mem_gear, y.run.final_mem_gear);
            assert_eq!(x.base.energy_j, y.base.energy_j);
            assert_eq!(x.savings.energy_saving, y.savings.energy_saving);
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_and_preserves_order() {
        // ODPP needs no model artifacts, so this always runs.
        let spec = Arc::new(Spec::load_default().unwrap());
        let jobs = test_jobs(&spec, PolicySpec::registered("odpp"), 6);
        let expect_order: Vec<String> = jobs.iter().map(|j| j.app.name.clone()).collect();

        let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
        let parallel = Fleet::new(spec.clone(), 3).run_jobs(jobs);

        let got_order: Vec<String> = parallel
            .iter()
            .map(|r| r.as_ref().unwrap().run.app.clone())
            .collect();
        assert_eq!(got_order, expect_order, "submission order must be kept");
        assert_same_outcomes(&serial, &parallel);
    }

    #[test]
    fn gpoeo_parallel_sweep_matches_serial() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let jobs = test_jobs(&spec, PolicySpec::registered("gpoeo"), 4);
        let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
        let parallel = Fleet::new(spec.clone(), 4).run_jobs(jobs);
        assert_same_outcomes(&serial, &parallel);
    }

    #[test]
    fn registered_policies_parallel_sweep_matches_serial() {
        // The new model-free families through the fleet: no artifacts
        // needed, so the registry dispatch path is always exercised.
        let spec = Arc::new(Spec::load_default().unwrap());
        for name in ["bandit", "powercap"] {
            let jobs = test_jobs(&spec, PolicySpec::registered(name), 4);
            let serial = Fleet::new(spec.clone(), 1).run_jobs(jobs.clone());
            let parallel = Fleet::new(spec.clone(), 2).run_jobs(jobs);
            assert_same_outcomes(&serial, &parallel);
        }
    }

    #[test]
    fn unknown_policy_fails_the_job_not_the_fleet() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let mut jobs = test_jobs(&spec, PolicySpec::registered("odpp"), 2);
        jobs[0].policy = PolicySpec::registered("warpdrive");
        let out = Fleet::new(spec, 2).run_jobs(jobs);
        let err = out[0].as_ref().unwrap_err().to_string();
        assert!(err.starts_with("unknown policy"), "{err}");
        assert!(out[1].is_ok(), "the healthy job must still complete");
    }

    #[test]
    fn interactive_sessions_spread_and_complete() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 2);
        let apps = make_suite(&spec, "aibench").unwrap();
        // Three sessions on two workers: placement must still serve all.
        let handles: Vec<SessionHandle> = apps
            .iter()
            .take(3)
            .map(|a| fleet.begin(a.clone(), PolicySpec::registered("gpoeo"), 30).unwrap())
            .collect();
        for h in &handles {
            let st = h.step(50).unwrap();
            assert!(st.time_s > 0.0);
        }
        for (h, app) in handles.into_iter().zip(&apps) {
            let fin = h.end().unwrap();
            assert!(fin.done, "{}: session must reach its target", app.name);
            assert!(fin.iterations >= 30);
            assert!(fin.energy_j > 0.0);
        }
    }

    #[test]
    fn model_free_interactive_session_runs_without_artifacts() {
        // `bandit` needs no predictor: Begin must succeed on a worker
        // that could never load one, and the unknown-name path must
        // answer with the registry error.
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 1);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        let h = fleet
            .begin(app.clone(), PolicySpec::registered("bandit"), 25)
            .unwrap();
        let st = h.step(50).unwrap();
        assert!(st.time_s > 0.0);
        assert_eq!(st.target_iters, 25, "status must carry the session target");
        let fin = h.end().unwrap();
        assert!(fin.done && fin.iterations >= 25);

        let err = fleet
            .begin(app, PolicySpec::registered("warpdrive"), 10)
            .unwrap_err()
            .to_string();
        assert!(err.starts_with("unknown policy"), "{err}");
    }

    #[test]
    fn dropping_a_session_releases_it_without_killing_the_worker() {
        if Predictor::load_best().is_err() {
            eprintln!("skipping: artifacts missing (run `make artifacts`)");
            return;
        }
        let spec = Arc::new(Spec::load_default().unwrap());
        let fleet = Fleet::new(spec.clone(), 1);
        let app = crate::sim::find_app(&spec, "AI_TS").unwrap();
        let h = fleet
            .begin(app.clone(), PolicySpec::registered("gpoeo"), 20)
            .unwrap();
        let h2 = fleet.begin(app, PolicySpec::registered("gpoeo"), 20).unwrap();
        drop(h);
        // The worker is still alive and still serves the other session.
        assert!(h2.step(10).is_ok());
        assert!(h2.end().unwrap().done);
    }
}
