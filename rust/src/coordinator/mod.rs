//! The GPOEO coordination layer: the online controller (Fig. 4 workflow),
//! adaptive measurement (Algorithm 4), the aperiodic IPS path (§4.3.5),
//! the ODPP baseline, the exhaustive oracle and the Begin/End daemon API.

pub mod controller;
pub mod daemon;
pub mod odpp;
pub mod oracle;
pub mod runner;

pub use controller::{Gpoeo, GpoeoCfg, GpoeoStats};
pub use odpp::{Odpp, OdppCfg};
pub use oracle::{oracle_full, oracle_ordered, OracleResult};
pub use runner::{default_iters, run_policy, savings, DefaultPolicy, Policy, RunResult, Savings};

use crate::model::Predictor;
use crate::search::Objective;
use crate::sim::{find_app, Spec};
use crate::util::cli::Args;
use std::sync::Arc;

/// Parse `--objective` (energy-capped:X | edp | ed2p | energy).
pub fn parse_objective(args: &Args) -> anyhow::Result<Objective> {
    Ok(match args.opt_or("objective", "capped") {
        "edp" => Objective::Edp,
        "ed2p" => Objective::Ed2p,
        "energy" => Objective::Energy,
        "capped" => Objective::EnergyCapped {
            max_time_ratio: 1.0 + args.opt_f64("slowdown-cap", 0.05)?,
        },
        other => anyhow::bail!("unknown objective '{other}'"),
    })
}

/// `gpoeo run --app NAME [--policy gpoeo|odpp|default] [--iters N]`
pub fn cli_run(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let name = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("run requires --app NAME"))?;
    let app = find_app(&spec, name)?;
    let objective = parse_objective(args)?;
    let n_iters = args.opt_u64("iters", default_iters(&app))?;

    // Baseline.
    let mut dflt = DefaultPolicy { ts: 0.025 };
    let base = run_policy(&spec, &app, &mut dflt, n_iters);

    let policy_name = args.opt_or("policy", "gpoeo");
    let (result, stats) = match policy_name {
        "default" => (base.clone(), None),
        "odpp" => {
            let mut p = Odpp::new(OdppCfg {
                objective,
                ..OdppCfg::default()
            });
            (run_policy(&spec, &app, &mut p, n_iters), None)
        }
        "gpoeo" => {
            let predictor = Arc::new(Predictor::load_best()?);
            let mut p = Gpoeo::new(
                GpoeoCfg {
                    objective,
                    ..GpoeoCfg::default()
                },
                predictor,
            );
            let r = run_policy(&spec, &app, &mut p, n_iters);
            (r, Some(p.stats.clone()))
        }
        other => anyhow::bail!("unknown policy '{other}'"),
    };

    let s = savings(&base, &result);
    println!("app {name} ({} iterations)", n_iters);
    println!(
        "  baseline : {:>10.1} J  {:>8.1} s  (sm gear {}, mem gear {})",
        base.energy_j, base.time_s, base.final_sm_gear, base.final_mem_gear
    );
    println!(
        "  {:<9}: {:>10.1} J  {:>8.1} s  (sm gear {}, mem gear {})",
        policy_name, result.energy_j, result.time_s, result.final_sm_gear, result.final_mem_gear
    );
    println!(
        "  energy saving {:+.1}%  slowdown {:+.1}%  ED²P saving {:+.1}%",
        s.energy_saving * 100.0,
        s.slowdown * 100.0,
        s.ed2p_saving * 100.0
    );
    if let Some(st) = stats {
        println!(
            "  period {:.3}s (true {:.3}s, self-err {:.3}{})  pred sm {} -> search {} ({} steps)  pred mem {} -> search {} ({} steps)",
            st.detected_period_s,
            st.true_period_s,
            st.detection_self_err,
            if st.treated_aperiodic { ", aperiodic" } else { "" },
            st.predicted_sm_gear,
            st.searched_sm_gear,
            st.search_steps_sm,
            st.predicted_mem_gear,
            st.searched_mem_gear,
            st.search_steps_mem
        );
    }
    Ok(())
}

/// `gpoeo daemon [--socket PATH]` — serve the Begin/End API.
pub fn cli_daemon(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let sock = args.opt_or("socket", "/tmp/gpoeo.sock").to_string();
    daemon::Daemon::new(spec).serve(std::path::Path::new(&sock))
}
