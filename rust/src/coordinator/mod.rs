//! The GPOEO coordination layer: the online controller (Fig. 4 workflow),
//! adaptive measurement (Algorithm 4), the aperiodic IPS path (§4.3.5),
//! the ODPP baseline, the exhaustive oracle, the parallel fleet engine
//! and the Begin/End daemon. Everything here drives devices through
//! [`crate::device::Device`] — nothing below this line names the
//! concrete simulator — and constructs policies exclusively through
//! [`crate::policy::PolicyRegistry`], so adding an optimizer never
//! touches this module. The daemon's wire surface (typed protocol v1,
//! client library, `gpoeo ctl`) lives in [`crate::api`]; this module
//! only implements the server side of it.

pub mod controller;
pub mod daemon;
pub mod fleet;
pub mod odpp;
pub mod oracle;
pub mod reactor;
pub mod runner;

pub use controller::{Gpoeo, GpoeoCfg, GpoeoStats};
pub use fleet::{
    AimdCfg, AimdState, BaselineCache, BaselineKey, Fleet, JobOutcome, Reply, ScaleDecision,
    SessionHandle, SessionStatus, SweepJob,
};
pub use odpp::{Odpp, OdppCfg};
pub use oracle::{oracle_full, oracle_ordered, OracleResult};
pub use runner::{
    default_iters, run_budget_s, run_policy, run_sim, savings, DefaultPolicy, Policy, RunResult,
    Savings, ZeroWorkError,
};
// Re-exported for continuity: the policy-selection type moved into the
// policy subsystem when construction was centralized there.
pub use crate::policy::PolicySpec;

use crate::model::Predictor;
use crate::policy::{PolicyConfig, PolicyCtx, PolicyRegistry};
use crate::search::Objective;
use crate::sim::{find_app, make_suite, AppParams, Spec};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// Parse `--objective` (capped | edp | ed2p | energy) + `--slowdown-cap`.
/// Decodes through [`Objective::from_wire`] — the same single point the
/// control-plane API uses, so CLI and wire accept the same names.
pub fn parse_objective(args: &Args) -> anyhow::Result<Objective> {
    Objective::from_wire(
        args.opt_or("objective", "capped"),
        1.0 + args.opt_f64("slowdown-cap", 0.05)?,
    )
}

/// `gpoeo run --app NAME [--policy NAME] [--iters N]` — any registered
/// policy (see `gpoeo policies`).
pub fn cli_run(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let name = args
        .opt("app")
        .ok_or_else(|| anyhow::anyhow!("run requires --app NAME"))?;
    let app = find_app(&spec, name)?;
    let cfg = PolicyConfig::from_args(args)?;
    let n_iters = args.opt_u64("iters", default_iters(&app))?;

    let reg = PolicyRegistry::global();
    let policy_name = args.opt_or("policy", "gpoeo");
    reg.get(policy_name)?; // fail fast, before the baseline run
    let load = || Predictor::load_best().map(Arc::new);
    let ctx = PolicyCtx {
        spec: &spec,
        predictor: &load,
    };

    // Baseline: the registry's `default` policy is the baseline itself.
    let mut dflt = reg.build("default", &ctx, &cfg)?;
    let base = run_sim(&spec, &app, dflt.as_mut(), n_iters);

    let mut policy = reg.build(policy_name, &ctx, &cfg)?;
    let result = run_sim(&spec, &app, policy.as_mut(), n_iters);
    let stats = policy.gpoeo_stats();

    let s = savings(&base, &result)?;
    println!("app {name} ({} iterations)", n_iters);
    println!(
        "  baseline : {:>10.1} J  {:>8.1} s  (sm gear {}, mem gear {})",
        base.energy_j, base.time_s, base.final_sm_gear, base.final_mem_gear
    );
    println!(
        "  {:<9}: {:>10.1} J  {:>8.1} s  (sm gear {}, mem gear {})",
        policy_name, result.energy_j, result.time_s, result.final_sm_gear, result.final_mem_gear
    );
    println!(
        "  energy saving {:+.1}%  slowdown {:+.1}%  ED²P saving {:+.1}%",
        s.energy_saving * 100.0,
        s.slowdown * 100.0,
        s.ed2p_saving * 100.0
    );
    if let Some(st) = stats {
        println!(
            "  period {:.3}s (true {:.3}s, self-err {:.3}{})  pred sm {} -> search {} ({} steps)  pred mem {} -> search {} ({} steps)",
            st.detected_period_s,
            st.true_period_s,
            st.detection_self_err,
            if st.treated_aperiodic { ", aperiodic" } else { "" },
            st.predicted_sm_gear,
            st.searched_sm_gear,
            st.search_steps_sm,
            st.predicted_mem_gear,
            st.searched_mem_gear,
            st.search_steps_mem
        );
    }
    Ok(())
}

/// `gpoeo sweep [--suite S|--apps A,B] [--policy P] [--parallel N]
///              [--iters N] [--quick] [--bench PATH]`
///
/// Runs the (app × policy) sweep on a [`Fleet`] of `--parallel` workers
/// and appends a machine-readable record (per-app savings + wall-clock)
/// to `BENCH_sweep.json`, so the serial-vs-parallel trajectory is kept
/// across runs.
pub fn cli_sweep(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let workers = args.opt_usize("parallel", 1)?.max(1);
    let quick = args.has_flag("quick");

    let apps: Vec<AppParams> = match args.opt("apps") {
        Some(list) => list
            .split(',')
            .map(|n| find_app(&spec, n.trim()))
            .collect::<anyhow::Result<_>>()?,
        None => {
            let suites: Vec<String> = match args.opt("suite") {
                Some(sname) => vec![sname.to_string()],
                None => spec.suites.keys().cloned().collect(),
            };
            let mut v = Vec::new();
            for sname in &suites {
                v.extend(make_suite(&spec, sname)?);
            }
            v
        }
    };

    let policy_name = args.opt_or("policy", "gpoeo").to_string();
    PolicyRegistry::global().get(&policy_name)?; // fail fast on unknown names
    let policy = PolicySpec::new(&policy_name, PolicyConfig::from_args(args)?);

    let fixed_iters = args.opt_u64("iters", 0)?;
    let jobs: Vec<SweepJob> = apps
        .iter()
        .map(|app| {
            let n_iters = if fixed_iters > 0 {
                fixed_iters
            } else if quick {
                (default_iters(app) / 3).max(60)
            } else {
                default_iters(app)
            };
            SweepJob {
                app: app.clone(),
                policy: policy.clone(),
                n_iters,
            }
        })
        .collect();
    let n_jobs = jobs.len();

    let fleet = Fleet::new(spec.clone(), workers);
    let t0 = std::time::Instant::now();
    let outcomes = fleet.run_jobs(jobs);
    let wall_s = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!("Sweep — {policy_name} vs NVIDIA default ({n_jobs} apps, {workers} workers)"),
        &["app", "energy saving", "slowdown", "ED2P saving", "iters"],
    );
    let mut rows = Vec::new();
    let (mut sv, mut sl, mut ed) = (Vec::new(), Vec::new(), Vec::new());
    let mut failures = 0usize;
    for (app, outcome) in apps.iter().zip(outcomes) {
        match outcome {
            Ok(o) => {
                t.rowf(&[
                    s(&app.name),
                    Cell::Pct(o.savings.energy_saving),
                    Cell::Pct(o.savings.slowdown),
                    Cell::Pct(o.savings.ed2p_saving),
                    Cell::U(o.run.iterations as usize),
                ]);
                sv.push(o.savings.energy_saving);
                sl.push(o.savings.slowdown);
                ed.push(o.savings.ed2p_saving);
                rows.push((app.name.clone(), o));
            }
            Err(e) => {
                failures += 1;
                eprintln!("sweep: {} failed: {e}", app.name);
            }
        }
    }
    crate::cli::print_table(&t, args);
    println!(
        "\nmean: saving {:.1}%  slowdown {:.1}%  ED2P {:.1}%  ({} apps, {} failed)",
        crate::util::stats::mean(&sv) * 100.0,
        crate::util::stats::mean(&sl) * 100.0,
        crate::util::stats::mean(&ed) * 100.0,
        rows.len(),
        failures
    );
    println!("wall clock: {wall_s:.2}s with {workers} worker(s)");

    let bench_path = args.opt_or("bench", "BENCH_sweep.json");
    write_bench(bench_path, &policy_name, workers, wall_s, &rows)?;
    println!("bench record appended to {bench_path}");
    if failures > 0 {
        anyhow::bail!("{failures}/{n_jobs} sweep jobs failed");
    }
    Ok(())
}

/// Append one sweep record to the bench file. The file keeps every run
/// (`runs`: wall-clock per worker count — the serial-vs-parallel
/// trajectory) and the latest per-app results (`per_app`). A results
/// digest ties each run to the exact per-app numbers it produced, so
/// "parallel == serial" is checkable from the file alone.
fn write_bench(
    path: &str,
    policy: &str,
    workers: usize,
    wall_s: f64,
    rows: &[(String, JobOutcome)],
) -> anyhow::Result<()> {
    let per_app: Vec<Json> = rows
        .iter()
        .map(|(name, o)| {
            Json::obj(vec![
                ("app", Json::Str(name.clone())),
                ("energy_saving", Json::Num(o.savings.energy_saving)),
                ("slowdown", Json::Num(o.savings.slowdown)),
                ("ed2p_saving", Json::Num(o.savings.ed2p_saving)),
                ("energy_j", Json::Num(o.run.energy_j)),
                ("time_s", Json::Num(o.run.time_s)),
                ("iterations", Json::Num(o.run.iterations as f64)),
                ("final_sm_gear", Json::Num(o.run.final_sm_gear as f64)),
                ("final_mem_gear", Json::Num(o.run.final_mem_gear as f64)),
            ])
        })
        .collect();

    // FNV-1a over the canonical row serialization: two runs with equal
    // digests produced bit-identical per-app results.
    let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
    for r in &per_app {
        for b in r.to_string().bytes() {
            digest ^= b as u64;
            digest = digest.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let run = Json::obj(vec![
        ("policy", Json::Str(policy.to_string())),
        ("workers", Json::Num(workers as f64)),
        ("apps", Json::Num(rows.len() as f64)),
        ("wall_clock_s", Json::Num(wall_s)),
        ("results_digest", Json::Str(format!("{digest:016x}"))),
        ("unix_time_s", Json::Num(unix_s)),
    ]);

    let mut runs = Json::bench_runs(path);
    runs.push(run);

    let doc = Json::obj(vec![
        ("runs", Json::Arr(runs)),
        ("per_app", Json::Arr(per_app)),
    ]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

/// `gpoeo daemon [--socket PATH] [--workers N] [--max-workers N]
///               [--rate-limit RPS] [--rate-burst N] [--journal-dir DIR]`
///
/// Serve the Begin/End API on a shared fleet: control-plane protocol v1
/// (on the non-blocking reactor) and the legacy line protocol behind a
/// first-byte auto-detect (drive it with `gpoeo ctl`). `--max-workers`
/// above `--workers` turns on AIMD pool scaling between the two;
/// `--rate-limit` enables per-connection token-bucket limiting;
/// `--journal-dir` writes one replayable JSONL journal per session
/// (DESIGN.md §11, `ctl watch --replay`).
pub fn cli_daemon(args: &Args) -> anyhow::Result<()> {
    let spec = Arc::new(Spec::load_default()?);
    let sock = args.opt_or("socket", "/tmp/gpoeo.sock").to_string();
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2);
    let workers = args.opt_usize("workers", default_workers)?;
    let cfg = daemon::DaemonCfg {
        max_workers: args.opt_usize("max-workers", workers)?.max(workers),
        rate_limit_rps: args.opt_f64("rate-limit", 0.0)?,
        rate_burst: args.opt_f64("rate-burst", 0.0)?,
        journal_dir: args.opt("journal-dir").map(std::path::PathBuf::from),
        telemetry: true,
    };
    daemon::Daemon::with_cfg(spec, workers, cfg).serve(std::path::Path::new(&sock))
}
