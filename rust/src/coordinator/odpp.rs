//! ODPP baseline [11] — implemented from the paper's description for the
//! head-to-head comparisons (Figs. 13/14 and the period-error studies).
//!
//! ODPP's two structural weaknesses (paper §2.2.3/§2.2.4, §6):
//! - period detection is a plain FFT arg-max over the power trace — no
//!   similarity verification, so harmonics, jittered micro-oscillations
//!   and aperiodic workloads produce wildly wrong periods;
//! - its online energy/time models are piecewise-linear in clock
//!   frequency over coarse features (power/util only, no performance
//!   counters), and the time axis is derived from the detected period —
//!   so period errors propagate straight into the decisions.
//!
//! It pays no counter-profiling tax (it never opens a CUPTI session),
//! which is its one advantage (the paper notes it meets the slowdown cap
//! on more GNN apps purely because its measurement is cheaper).

use crate::device::Device;
use crate::search::Objective;
use crate::signal::calc_period_fft_argmax;

#[derive(Clone)]
pub struct OdppCfg {
    pub ts: f64,
    pub objective: Objective,
    /// Initial sampling window for period detection.
    pub window_s: f64,
    /// Probe window per candidate gear.
    pub probe_s: f64,
    /// SM gears probed for the piecewise-linear model.
    pub sm_probes: Vec<usize>,
    /// Memory gears probed.
    pub mem_probes: Vec<usize>,
}

impl Default for OdppCfg {
    fn default() -> Self {
        OdppCfg {
            ts: 0.025,
            objective: Objective::paper_default(),
            window_s: 8.0,
            probe_s: 3.0,
            sm_probes: vec![114, 90, 66],
            mem_probes: vec![4, 3, 2],
        }
    }
}

enum Phase {
    Sampling,
    Done,
}

/// The ODPP controller.
pub struct Odpp {
    pub cfg: OdppCfg,
    phase: Phase,
    power: Vec<f64>,
    /// Detected period at the default config (NaN until measured).
    pub detected_period_s: f64,
    pub chosen_sm: usize,
    pub chosen_mem: usize,
}

impl Odpp {
    pub fn new(cfg: OdppCfg) -> Odpp {
        Odpp {
            cfg,
            phase: Phase::Sampling,
            power: Vec::new(),
            detected_period_s: f64::NAN,
            chosen_sm: 0,
            chosen_mem: 0,
        }
    }

    /// FFT-arg-max period over a freshly sampled window (ODPP's detector).
    fn detect_period(&mut self, gpu: &mut dyn Device, window_s: f64) -> f64 {
        let n = (window_s / self.cfg.ts).ceil() as usize;
        let mut power = Vec::with_capacity(n);
        for _ in 0..n {
            gpu.advance(self.cfg.ts);
            power.push(gpu.sample(self.cfg.ts).power_w);
        }
        calc_period_fft_argmax(&power, self.cfg.ts)
            .map(|e| e.t_iter)
            .unwrap_or(window_s / 4.0)
    }

    /// Probe one configuration: (avg power, detected period).
    fn probe(&mut self, gpu: &mut dyn Device) -> (f64, f64) {
        gpu.advance(0.15); // settle
        let e0 = gpu.energy_j();
        let t0 = gpu.time_s();
        let period = self.detect_period(gpu, self.cfg.probe_s);
        let e1 = gpu.energy_j();
        let t1 = gpu.time_s();
        ((e1 - e0) / (t1 - t0), period)
    }

    /// Piecewise-linear interpolation of (x, y) samples at query x.
    fn pw_linear(xs: &[f64], ys: &[f64], x: f64) -> f64 {
        if x <= xs[0] {
            return ys[0];
        }
        for w in xs.windows(2).zip(ys.windows(2)) {
            let ((x0, x1), (y0, y1)) = ((w.0[0], w.0[1]), (w.1[0], w.1[1]));
            if x <= x1 {
                let f = (x - x0) / (x1 - x0);
                return y0 + f * (y1 - y0);
            }
        }
        *ys.last().unwrap()
    }

    fn optimize(&mut self, gpu: &mut dyn Device) {
        // Baseline at default clocks.
        let (p_base, t_base) = self.probe(gpu);
        self.detected_period_s = t_base;
        // Probe windows scale with the detected period (~4-5 periods).
        // The FFT-bin quantization of the arg-max detector then rounds
        // time ratios to ~±25% — the instability that drives ODPP's
        // "less saving AND heavier slowdown" profile in the paper.
        self.cfg.probe_s = (4.0 * t_base).clamp(3.0, 12.0);

        // --- SM stage: probe descending gears, fit PW-linear E/T models.
        let probes = self.cfg.sm_probes.clone();
        let mut xs = Vec::new();
        let mut e_ratio = Vec::new();
        let mut t_ratio = Vec::new();
        for &g in &probes {
            gpu.set_sm_gear(g);
            let (p, per) = self.probe(gpu);
            let tr = per / t_base; // period-derived time ratio (fragile!)
            xs.push(g as f64);
            t_ratio.push(tr);
            e_ratio.push((p * per) / (p_base * t_base));
        }
        // Ascending x for interpolation.
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
        let xs_s: Vec<f64> = idx.iter().map(|&i| xs[i]).collect();
        let es: Vec<f64> = idx.iter().map(|&i| e_ratio[i]).collect();
        let tsr: Vec<f64> = idx.iter().map(|&i| t_ratio[i]).collect();

        let spec = gpu.spec().clone();
        // Only interpolate inside the probed range — extrapolating the
        // flat tail below the lowest probe would let a single optimistic
        // probe send the GPU to the floor gear.
        let g_lo = xs_s[0] as usize;
        let g_hi = *xs_s.last().unwrap() as usize;
        let mut best = (f64::INFINITY, spec.gears.default_sm_gear);
        for g in g_lo..=g_hi {
            let e = Self::pw_linear(&xs_s, &es, g as f64);
            let t = Self::pw_linear(&xs_s, &tsr, g as f64);
            let s = self.cfg.objective.score(e, t);
            if s < best.0 {
                best = (s, g);
            }
        }
        gpu.set_sm_gear(best.1);
        self.chosen_sm = best.1;

        // --- Memory stage: same treatment over the probed mem gears.
        let mem_probes = self.cfg.mem_probes.clone();
        let mut best_mem = (f64::INFINITY, spec.gears.default_mem_gear);
        for &m in &mem_probes {
            gpu.set_mem_gear(m);
            let (p, per) = self.probe(gpu);
            let e = (p * per) / (p_base * t_base);
            let t = per / t_base;
            let s = self.cfg.objective.score(e, t);
            if s < best_mem.0 {
                best_mem = (s, m);
            }
        }
        gpu.set_mem_gear(best_mem.1);
        self.chosen_mem = best_mem.1;
    }
}

impl crate::coordinator::Policy for Odpp {
    fn name(&self) -> &'static str {
        "odpp"
    }

    fn tick(&mut self, gpu: &mut dyn Device) {
        match self.phase {
            Phase::Sampling => {
                // Initial window, then the whole optimization runs
                // synchronously (discrete-event time).
                let n = (self.cfg.window_s / self.cfg.ts).ceil() as usize;
                for _ in 0..n {
                    gpu.advance(self.cfg.ts);
                    self.power.push(gpu.sample(self.cfg.ts).power_w);
                }
                self.optimize(gpu);
                self.phase = Phase::Done;
            }
            Phase::Done => {
                gpu.advance(self.cfg.ts);
            }
        }
    }
}
