//! ODPP baseline [11] — implemented from the paper's description for the
//! head-to-head comparisons (Figs. 13/14 and the period-error studies).
//!
//! ODPP's two structural weaknesses (paper §2.2.3/§2.2.4, §6):
//! - period detection is a plain FFT arg-max over the power trace — no
//!   similarity verification, so harmonics, jittered micro-oscillations
//!   and aperiodic workloads produce wildly wrong periods;
//! - its online energy/time models are piecewise-linear in clock
//!   frequency over coarse features (power/util only, no performance
//!   counters), and the time axis is derived from the detected period —
//!   so period errors propagate straight into the decisions.
//!
//! It pays no counter-profiling tax (it never opens a CUPTI session),
//! which is its one advantage (the paper notes it meets the slowdown cap
//! on more GNN apps purely because its measurement is cheaper).

use crate::device::Device;
use crate::search::Objective;
use crate::signal::calc_period_fft_argmax;

#[derive(Clone)]
pub struct OdppCfg {
    pub ts: f64,
    pub objective: Objective,
    /// Initial sampling window for period detection.
    pub window_s: f64,
    /// Probe window per candidate gear.
    pub probe_s: f64,
    /// SM gears probed for the piecewise-linear model.
    pub sm_probes: Vec<usize>,
    /// Memory gears probed.
    pub mem_probes: Vec<usize>,
}

impl Default for OdppCfg {
    fn default() -> Self {
        OdppCfg {
            ts: 0.025,
            objective: Objective::paper_default(),
            window_s: 8.0,
            probe_s: 3.0,
            sm_probes: vec![114, 90, 66],
            mem_probes: vec![4, 3, 2],
        }
    }
}

enum Phase {
    Sampling,
    Done,
}

/// The ODPP controller.
pub struct Odpp {
    pub cfg: OdppCfg,
    phase: Phase,
    power: Vec<f64>,
    /// Detected period at the default config (NaN until measured).
    pub detected_period_s: f64,
    pub chosen_sm: usize,
    pub chosen_mem: usize,
}

impl Odpp {
    pub fn new(cfg: OdppCfg) -> Odpp {
        Odpp {
            cfg,
            phase: Phase::Sampling,
            power: Vec::new(),
            detected_period_s: f64::NAN,
            chosen_sm: 0,
            chosen_mem: 0,
        }
    }

    /// FFT-arg-max period over a freshly sampled window (ODPP's detector).
    fn detect_period(&mut self, gpu: &mut dyn Device, window_s: f64) -> f64 {
        let n = (window_s / self.cfg.ts).ceil() as usize;
        let mut power = Vec::with_capacity(n);
        for _ in 0..n {
            gpu.advance(self.cfg.ts);
            power.push(gpu.sample(self.cfg.ts).power_w);
        }
        // A NaN reading would poison the detrended spectrum wholesale —
        // and dropping samples in place would compress the time axis and
        // bias the period low. Treat a poisoned window as "no detection"
        // and take the same fallback as an empty spectrum.
        if power.iter().any(|x| !x.is_finite()) {
            return window_s / 4.0;
        }
        calc_period_fft_argmax(&power, self.cfg.ts)
            .map(|e| e.t_iter)
            .unwrap_or(window_s / 4.0)
    }

    /// Probe one configuration: (avg power, detected period).
    fn probe(&mut self, gpu: &mut dyn Device) -> (f64, f64) {
        gpu.advance(0.15); // settle
        let e0 = gpu.energy_j();
        let t0 = gpu.time_s();
        let period = self.detect_period(gpu, self.cfg.probe_s);
        let e1 = gpu.energy_j();
        let t1 = gpu.time_s();
        ((e1 - e0) / (t1 - t0), period)
    }

    /// Piecewise-linear interpolation of (x, y) samples at query x.
    fn pw_linear(xs: &[f64], ys: &[f64], x: f64) -> f64 {
        if x <= xs[0] {
            return ys[0];
        }
        for w in xs.windows(2).zip(ys.windows(2)) {
            let ((x0, x1), (y0, y1)) = ((w.0[0], w.0[1]), (w.1[0], w.1[1]));
            if x <= x1 {
                let f = (x - x0) / (x1 - x0);
                return y0 + f * (y1 - y0);
            }
        }
        *ys.last().unwrap()
    }

    fn optimize(&mut self, gpu: &mut dyn Device) {
        // Baseline at default clocks.
        let (p_base, t_base) = self.probe(gpu);
        self.detected_period_s = t_base;
        // A non-finite or degenerate baseline (a NaN energy reading while
        // probing) leaves nothing to normalize against: stay at the
        // default clocks rather than poison every ratio downstream.
        if !p_base.is_finite() || !t_base.is_finite() || p_base <= 0.0 || t_base <= 0.0 {
            return;
        }
        // Probe windows scale with the detected period (~4-5 periods).
        // The FFT-bin quantization of the arg-max detector then rounds
        // time ratios to ~±25% — the instability that drives ODPP's
        // "less saving AND heavier slowdown" profile in the paper.
        self.cfg.probe_s = (4.0 * t_base).clamp(3.0, 12.0);

        // --- SM stage: probe descending gears, fit PW-linear E/T models.
        let probes = self.cfg.sm_probes.clone();
        let mut xs = Vec::new();
        let mut e_ratio = Vec::new();
        let mut t_ratio = Vec::new();
        for &g in &probes {
            gpu.set_sm_gear(g);
            let (p, per) = self.probe(gpu);
            // A NaN measurement drops this probe, not the worker thread
            // (regression: nan_measurements_do_not_panic_the_worker).
            if !p.is_finite() || !per.is_finite() {
                continue;
            }
            let tr = per / t_base; // period-derived time ratio (fragile!)
            xs.push(g as f64);
            t_ratio.push(tr);
            e_ratio.push((p * per) / (p_base * t_base));
        }
        let spec = gpu.spec().clone();
        if xs.len() >= 2 {
            // Ascending x for interpolation.
            let mut idx: Vec<usize> = (0..xs.len()).collect();
            idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
            let xs_s: Vec<f64> = idx.iter().map(|&i| xs[i]).collect();
            let es: Vec<f64> = idx.iter().map(|&i| e_ratio[i]).collect();
            let tsr: Vec<f64> = idx.iter().map(|&i| t_ratio[i]).collect();

            // Only interpolate inside the probed range — extrapolating the
            // flat tail below the lowest probe would let a single optimistic
            // probe send the GPU to the floor gear.
            let g_lo = xs_s[0] as usize;
            let g_hi = *xs_s.last().unwrap() as usize;
            let mut best = (f64::INFINITY, spec.gears.default_sm_gear);
            for g in g_lo..=g_hi {
                let e = Self::pw_linear(&xs_s, &es, g as f64);
                let t = Self::pw_linear(&xs_s, &tsr, g as f64);
                let s = self.cfg.objective.score(e, t);
                if s < best.0 {
                    best = (s, g);
                }
            }
            gpu.set_sm_gear(best.1);
            self.chosen_sm = best.1;
        } else {
            // Fewer than two usable probes: no model to fit.
            gpu.set_sm_gear(spec.gears.default_sm_gear);
            self.chosen_sm = spec.gears.default_sm_gear;
        }

        // --- Memory stage: same treatment over the probed mem gears.
        let mem_probes = self.cfg.mem_probes.clone();
        let mut best_mem = (f64::INFINITY, spec.gears.default_mem_gear);
        for &m in &mem_probes {
            gpu.set_mem_gear(m);
            let (p, per) = self.probe(gpu);
            if !p.is_finite() || !per.is_finite() {
                continue;
            }
            let e = (p * per) / (p_base * t_base);
            let t = per / t_base;
            let s = self.cfg.objective.score(e, t);
            if s < best_mem.0 {
                best_mem = (s, m);
            }
        }
        gpu.set_mem_gear(best_mem.1);
        self.chosen_mem = best_mem.1;
    }
}

impl crate::coordinator::Policy for Odpp {
    fn name(&self) -> &'static str {
        "odpp"
    }

    fn tick(&mut self, gpu: &mut dyn Device) {
        match self.phase {
            Phase::Sampling => {
                // Initial window, then the whole optimization runs
                // synchronously (discrete-event time).
                let n = (self.cfg.window_s / self.cfg.ts).ceil() as usize;
                for _ in 0..n {
                    gpu.advance(self.cfg.ts);
                    self.power.push(gpu.sample(self.cfg.ts).power_w);
                }
                self.optimize(gpu);
                self.phase = Phase::Done;
            }
            Phase::Done => {
                gpu.advance(self.cfg.ts);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_policy;
    use crate::device::sim_device;
    use crate::sim::{find_app, Instant, SimGpu, Spec};
    use std::sync::Arc;

    /// Device wrapper that poisons a slice of telemetry with NaN — the
    /// NVML glitch a long-lived fleet worker must survive. Clocks, time
    /// and the workload itself are untouched.
    struct NanGlitch {
        inner: SimGpu,
        from_s: f64,
        to_s: f64,
    }

    impl NanGlitch {
        fn glitching(&self) -> bool {
            (self.from_s..self.to_s).contains(&self.inner.time_s())
        }
    }

    impl Device for NanGlitch {
        fn spec(&self) -> &Arc<Spec> {
            self.inner.spec()
        }
        fn workload(&self) -> &str {
            self.inner.workload()
        }
        fn nominal_iter_s(&self) -> f64 {
            self.inner.nominal_iter_s()
        }
        fn set_sm_gear(&mut self, gear: usize) {
            self.inner.set_sm_gear(gear)
        }
        fn set_mem_gear(&mut self, gear: usize) {
            self.inner.set_mem_gear(gear)
        }
        fn set_default_clocks(&mut self) {
            self.inner.set_default_clocks()
        }
        fn sm_gear(&self) -> usize {
            self.inner.sm_gear()
        }
        fn mem_gear(&self) -> usize {
            self.inner.mem_gear()
        }
        fn set_power_limit_w(&mut self, limit_w: f64) -> f64 {
            self.inner.set_power_limit_w(limit_w)
        }
        fn power_limit_w(&self) -> f64 {
            self.inner.power_limit_w()
        }
        fn sample(&mut self, dt_since_last: f64) -> Instant {
            let mut s = self.inner.sample(dt_since_last);
            if self.glitching() {
                s.power_w = f64::NAN;
            }
            s
        }
        fn energy_j(&mut self) -> f64 {
            if self.glitching() {
                f64::NAN
            } else {
                self.inner.energy_j()
            }
        }
        fn ips(&mut self) -> f64 {
            self.inner.ips()
        }
        fn start_counter_session(&mut self) {
            self.inner.start_counter_session()
        }
        fn stop_counter_session(&mut self) {
            self.inner.stop_counter_session()
        }
        fn profiling_active(&self) -> bool {
            self.inner.profiling_active()
        }
        fn read_counters(&mut self) -> Result<Vec<f64>, crate::sim::CounterSessionError> {
            self.inner.read_counters()
        }
        fn advance(&mut self, dt: f64) {
            self.inner.advance(dt)
        }
        fn iterations(&self) -> u64 {
            self.inner.iterations()
        }
        fn time_s(&self) -> f64 {
            self.inner.time_s()
        }
        fn true_energy_j(&self) -> f64 {
            self.inner.true_energy_j()
        }
        fn true_period(&self) -> f64 {
            self.inner.true_period()
        }
    }

    /// A NaN slice anywhere in the optimization transient must degrade
    /// (skipped probes, default gears), never panic the worker thread.
    /// Two placements: one that poisons the baseline probe, one that
    /// poisons a mid-search probe (the `partial_cmp` panic of old).
    #[test]
    fn nan_measurements_do_not_panic_the_worker() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        for (from_s, to_s) in [(8.0, 30.0), (13.0, 16.0)] {
            let mut dev = NanGlitch {
                inner: sim_device(&spec, &app),
                from_s,
                to_s,
            };
            let mut o = Odpp::new(OdppCfg::default());
            let r = run_policy(&mut dev, &mut o, 60);
            assert!(r.iterations >= 60, "run must complete: {r:?}");
            assert!(dev.sm_gear() <= spec.gears.sm_gear_max);
        }
    }

    /// With clean telemetry the NaN guards must be inert: the optimizer
    /// still leaves the default configuration for something it chose.
    #[test]
    fn clean_run_still_optimizes() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let mut dev = sim_device(&spec, &app);
        let mut o = Odpp::new(OdppCfg::default());
        let r = run_policy(&mut dev, &mut o, 60);
        assert!(r.iterations >= 60);
        let probed_range: Vec<usize> = o.cfg.sm_probes.clone();
        assert!(
            o.chosen_sm >= *probed_range.iter().min().unwrap()
                && o.chosen_sm <= *probed_range.iter().max().unwrap(),
            "chosen SM gear {} outside the probed range",
            o.chosen_sm
        );
    }
}
