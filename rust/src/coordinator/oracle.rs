//! Exhaustive oracle: the best achievable configuration under an
//! objective, computed from the noise-free analytic model. This is the
//! upper bound GPOEO is scored against (Fig. 1) and the source of the
//! "Oracle SM Gear"/"Oracle Mem clock" rows of Table 3.

use crate::search::Objective;
use crate::sim::{AppParams, Spec};

/// Oracle outcome for one application.
#[derive(Debug, Clone, Copy)]
pub struct OracleResult {
    pub sm_gear: usize,
    pub mem_gear: usize,
    pub energy_ratio: f64,
    pub time_ratio: f64,
    /// 1 - energy_ratio.
    pub energy_saving: f64,
    /// time_ratio - 1.
    pub slowdown: f64,
    /// 1 - energy_ratio · time_ratio².
    pub ed2p_saving: f64,
}

fn result_at(app: &AppParams, spec: &Spec, sm: usize, mem: usize) -> OracleResult {
    let (e, t) = app.ratios_vs_default(spec, sm, mem);
    OracleResult {
        sm_gear: sm,
        mem_gear: mem,
        energy_ratio: e,
        time_ratio: t,
        energy_saving: 1.0 - e,
        slowdown: t - 1.0,
        ed2p_saving: 1.0 - e * t * t,
    }
}

/// Full-sweep oracle over every (SM gear, mem gear) pair.
pub fn oracle_full(app: &AppParams, spec: &Spec, obj: Objective) -> OracleResult {
    let mut best: Option<(f64, OracleResult)> = None;
    for mem in 0..spec.gears.num_mem_gears() {
        for sm in spec.gears.sm_gears() {
            let r = result_at(app, spec, sm, mem);
            let s = obj.score(r.energy_ratio, r.time_ratio);
            if best.as_ref().map(|(bs, _)| s < *bs).unwrap_or(true) {
                best = Some((s, r));
            }
        }
    }
    best.unwrap().1
}

/// Ordered oracle matching the paper's two-stage procedure (§3.1 assumes
/// a convex search space and optimizes SM then memory): the best SM gear
/// with memory at the default gear, then the best memory gear given that
/// SM gear. This is what Table 3's oracle rows report.
pub fn oracle_ordered(app: &AppParams, spec: &Spec, obj: Objective) -> OracleResult {
    let mem_default = spec.gears.default_mem_gear;
    let mut best_sm = spec.gears.default_sm_gear;
    let mut best_score = f64::INFINITY;
    for sm in spec.gears.sm_gears() {
        let r = result_at(app, spec, sm, mem_default);
        let s = obj.score(r.energy_ratio, r.time_ratio);
        if s < best_score {
            best_score = s;
            best_sm = sm;
        }
    }
    let mut best: Option<(f64, OracleResult)> = None;
    for mem in 0..spec.gears.num_mem_gears() {
        let r = result_at(app, spec, best_sm, mem);
        let s = obj.score(r.energy_ratio, r.time_ratio);
        if best.as_ref().map(|(bs, _)| s < *bs).unwrap_or(true) {
            best = Some((s, r));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::find_app;

    #[test]
    fn oracle_feasible_under_capped_objective() {
        let spec = Spec::load_default().unwrap();
        let obj = Objective::paper_default();
        for suite in ["aibench", "gnns"] {
            for e in &spec.suites[suite].apps {
                let app = find_app(&spec, &e.name).unwrap();
                let r = oracle_full(&app, &spec, obj);
                assert!(
                    r.time_ratio <= 1.05 + 1e-9,
                    "{}: oracle violates cap ({})",
                    e.name,
                    r.time_ratio
                );
                assert!(r.energy_ratio <= 1.0 + 1e-9, "{}: oracle must not cost energy", e.name);
            }
        }
    }

    #[test]
    fn ordered_oracle_never_beats_full() {
        let spec = Spec::load_default().unwrap();
        let obj = Objective::paper_default();
        for e in spec.suites["aibench"].apps.iter().take(6) {
            let app = find_app(&spec, &e.name).unwrap();
            let full = oracle_full(&app, &spec, obj);
            let ord = oracle_ordered(&app, &spec, obj);
            let sf = obj.score(full.energy_ratio, full.time_ratio);
            let so = obj.score(ord.energy_ratio, ord.time_ratio);
            assert!(sf <= so + 1e-9, "{}: full {sf} vs ordered {so}", e.name);
        }
    }
}
