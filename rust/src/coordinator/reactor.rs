//! The non-blocking control-plane reactor (DESIGN.md §10).
//!
//! PR 5's daemon spent one OS thread per connection, parked in blocking
//! `read_frame`/`recv()` calls. At api-bench scale (10k sessions over
//! hundreds of connections) that is 10k stacks and a thundering herd of
//! wakeups for work the fleet serializes anyway. This module replaces
//! the per-connection threads for protocol v1 with a single-threaded
//! event loop over a hand-rolled `poll(2)` shim (`vendor/pollshim` — no
//! crates.io dependencies, same offline rule as `vendor/anyhow`):
//!
//! - **Connection state machines.** Every accepted socket starts in
//!   `Sniff`; its first byte picks the protocol. `{` promotes it to a
//!   `V1` machine: an incremental [`LineFramer`] (byte-for-byte the
//!   semantics of [`read_frame`](crate::api::read_frame), including the
//!   oversized-line cap/drain behavior), a response-ordering queue of
//!   [`Slot`]s so pipelined requests answer in request order even when
//!   their fleet commands complete out of order, and an output buffer
//!   flushed as `POLLOUT` allows (a consumer that stops reading past
//!   [`MAX_OUTBUF`] is dropped, not buffered forever). Any other first
//!   byte falls back to the old blocking thread running the unchanged
//!   legacy protocol — the compat rule: legacy clients and tests see
//!   the PR 5 daemon exactly.
//! - **Completion plumbing.** Fleet commands are dispatched with
//!   [`Reply`] callbacks that push a [`Done`] onto an mpsc queue and
//!   write one byte into a socketpair wake pipe, so `poll` wakes the
//!   moment a worker finishes. The reactor never blocks on the fleet.
//! - **`status` coalescing** (ninelives ADR-010): while a tick-drive
//!   for session S is in flight, further `status S` requests attach to
//!   it and share its answer — N concurrent pollers cost one drive.
//! - **Per-connection rate limiting** (ninelives ADR-009): an optional
//!   [`TokenBucket`] charges every request line; over budget answers a
//!   typed `Response::Error { kind: "rate_limited" }` and keeps the
//!   connection alive.
//! - **AIMD autoscaling hook**: each loop iteration reports the
//!   in-flight op count to [`Fleet::autoscale`] — sustained backlog
//!   grows the worker pool additively, sustained idle halves it.
//!
//! Failed `accept()`s go through the daemon's [`AcceptGate`]: one log
//! line per window (with a suppressed count) and a short backoff during
//! which the listener is dropped from the poll set, so a persistent
//! EMFILE can neither spam the log nor spin the loop.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::api::{
    negotiate_hello, Event, PolicyInfo, Request, Response, ServerMsg, SessionReport,
    MAX_LINE_BYTES,
};
use crate::arbiter::{ArbiterCfg, BudgetArbiter};
use crate::coordinator::daemon::{
    accept_stream, claim_session, handle_legacy, list_apps, prepare_begin, report, with_session,
    AcceptGate, DaemonCfg, SessionEntry, Shared, STATUS_TICKS,
};
use crate::coordinator::fleet::{Fleet, Reply, SessionStatus};
use crate::policy::{PolicyRegistry, PolicySpec};
use crate::telemetry::{Counter, Ewma, Gauge, Hist, TelemetryEvent, WindowedRate};
use pollshim::{poll_fds, PollFd, POLLIN, POLLOUT};
use std::collections::{HashMap, VecDeque};
use std::io::{self, BufReader, Cursor, Read, Write};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Kill a connection whose peer stops reading once this much output is
/// queued — a slow consumer must not grow the buffer without bound.
const MAX_OUTBUF: usize = 4 * 1024 * 1024;

/// Poll timeout: completions arrive via the wake pipe, so this only
/// bounds how stale the AIMD/backoff clocks can get.
const POLL_TIMEOUT_MS: i32 = 100;

/// After a `shutdown` request: how long to keep flushing response bytes
/// before exiting anyway.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(1);

/// High-bit namespace for budget-arbiter telemetry taps on the shared
/// `sub_rx` channel: tap tokens are `ARB_TAG | fleet_id`, disjoint from
/// connection tokens (which count up from zero) for the life of any
/// realistic daemon.
const ARB_TAG: u64 = 1 << 63;

// ---------------------------------------------------------------------
// Incremental line framing.
// ---------------------------------------------------------------------

/// A framed line, or the reason there isn't one. The non-blocking twin
/// of [`crate::api::Frame`] (EOF is a connection-level event here).
#[derive(Debug, PartialEq)]
pub(crate) enum FrameEvent {
    Line(String),
    /// The line exceeded the byte cap; the remainder through its
    /// newline is swallowed so the connection can keep going.
    Oversized,
}

/// Incremental, non-blocking version of
/// [`read_frame`](crate::api::read_frame), fed whatever byte chunks the
/// socket yields. Byte-for-byte the same outcomes: a line is `Oversized`
/// exactly when its content (newline excluded) exceeds `max`, detection
/// happens as soon as the cap is crossed, and the rest of an oversized
/// line is drained silently. Parity is pinned by a test that runs both
/// over the same corpus at every chunking.
pub(crate) struct LineFramer {
    buf: Vec<u8>,
    /// Inside an oversized line, swallowing bytes up to its newline.
    draining: bool,
    max: usize,
}

impl LineFramer {
    pub(crate) fn new(max: usize) -> LineFramer {
        LineFramer {
            buf: Vec::new(),
            draining: false,
            max,
        }
    }

    /// Feed one chunk; completed frames are appended to `out`.
    pub(crate) fn push(&mut self, data: &[u8], out: &mut VecDeque<FrameEvent>) {
        let mut rest = data;
        while !rest.is_empty() {
            if self.draining {
                match rest.iter().position(|b| *b == b'\n') {
                    Some(i) => {
                        self.draining = false;
                        rest = &rest[i + 1..];
                    }
                    None => return,
                }
                continue;
            }
            match rest.iter().position(|b| *b == b'\n') {
                Some(i) => {
                    if self.buf.len() + i > self.max {
                        out.push_back(FrameEvent::Oversized);
                    } else {
                        self.buf.extend_from_slice(&rest[..i]);
                        out.push_back(FrameEvent::Line(
                            String::from_utf8_lossy(&self.buf).into_owned(),
                        ));
                    }
                    self.buf.clear();
                    rest = &rest[i + 1..];
                }
                None => {
                    if self.buf.len() + rest.len() > self.max {
                        self.buf.clear();
                        self.draining = true;
                        out.push_back(FrameEvent::Oversized);
                        return;
                    }
                    self.buf.extend_from_slice(rest);
                    return;
                }
            }
        }
    }

    /// EOF: a trailing line without its newline is still a line
    /// (`read_frame` parity).
    pub(crate) fn take_trailing(&mut self) -> Option<FrameEvent> {
        if self.buf.is_empty() {
            return None;
        }
        let s = String::from_utf8_lossy(&self.buf).into_owned();
        self.buf.clear();
        Some(FrameEvent::Line(s))
    }
}

// ---------------------------------------------------------------------
// Per-connection rate limiting (ninelives ADR-009).
// ---------------------------------------------------------------------

/// Classic token bucket over an injected monotonic clock (f64 seconds):
/// `rate` tokens/second refill, capacity `burst`, one token per request.
pub(crate) struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last_s: f64,
}

impl TokenBucket {
    pub(crate) fn new(rate: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket {
            rate,
            burst,
            tokens: burst,
            last_s: 0.0,
        }
    }

    /// Charge one request at `now_s`. `false` means over budget — the
    /// caller answers a typed `rate_limited` error and moves on.
    pub(crate) fn admit(&mut self, now_s: f64) -> bool {
        let dt = (now_s - self.last_s).max(0.0);
        self.last_s = now_s;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------
// Connection state machines.
// ---------------------------------------------------------------------

/// One queued answer position. Responses must leave in request order,
/// but fleet commands complete in any order — so each request takes a
/// slot, and only the contiguous `Ready` prefix is flushed.
enum Slot {
    /// Serialized wire line, ready to flush.
    Ready(String),
    /// Waiting on the op with this id. The `Instant` feeds the
    /// request-latency histogram when the slot fills (`None` with the
    /// telemetry plane detached — no clock reads for nobody).
    Pending(u64, Option<Instant>),
}

/// An active `subscribe` stream: events flow until the session is done
/// (or `max_events` is reached), then a final status snapshot.
///
/// With the telemetry plane attached, the event lines are *forwarded
/// sink output*: a tap on the session's telemetry `tick` events
/// (subscribe is just another sink consumer — DESIGN.md §11). `sent`
/// counts driven slices (termination), `events_sent` counts forwarded
/// event lines (the `max_events` cap). With the plane detached, the
/// drive replies themselves become the events, as before.
struct Sub {
    sid: String,
    every_ticks: u64,
    max_events: u64,
    sent: u64,
    events_sent: u64,
    target_iters: u64,
}

/// A `subscribe` request parked until earlier responses drain (events
/// must not jump ahead of pipelined responses).
struct SubReq {
    sid: String,
    every_ticks: u64,
    max_events: u64,
}

/// Protocol v1 connection state.
struct V1 {
    hello_done: bool,
    /// Default policy for `begin`s without an inline one (`set_policy`).
    default_policy: PolicySpec,
    bucket: Option<TokenBucket>,
    slots: VecDeque<Slot>,
    sub: Option<Sub>,
    pending_sub: Option<SubReq>,
    /// A `shutdown` was answered: flush and close, process nothing more.
    closing: bool,
}

impl V1 {
    fn new(bucket: Option<TokenBucket>) -> V1 {
        V1 {
            hello_done: false,
            default_policy: PolicySpec::registered("gpoeo"),
            bucket,
            slots: VecDeque::new(),
            sub: None,
            pending_sub: None,
            closing: false,
        }
    }
}

enum ConnState {
    /// Waiting for the first byte to pick a protocol.
    Sniff,
    V1(V1),
}

struct Conn {
    stream: UnixStream,
    framer: LineFramer,
    /// Framed but not yet processed (requests queue here while a
    /// subscribe stream owns the connection).
    events: VecDeque<FrameEvent>,
    out: Vec<u8>,
    out_pos: usize,
    state: ConnState,
    dead: bool,
    eof: bool,
}

impl Conn {
    fn new(stream: UnixStream) -> Conn {
        Conn {
            stream,
            framer: LineFramer::new(MAX_LINE_BYTES),
            events: VecDeque::new(),
            out: Vec::new(),
            out_pos: 0,
            state: ConnState::Sniff,
            dead: false,
            eof: false,
        }
    }

    /// Read interest. Paused while a subscribe stream owns the
    /// connection (the blocking daemon didn't read mid-stream either)
    /// and after a shutdown answer.
    fn wants_read(&self) -> bool {
        if self.dead || self.eof {
            return false;
        }
        match &self.state {
            ConnState::Sniff => true,
            ConnState::V1(v) => v.sub.is_none() && v.pending_sub.is_none() && !v.closing,
        }
    }

    fn wants_write(&self) -> bool {
        !self.dead && self.out_pos < self.out.len()
    }

    fn flushed(&self) -> bool {
        self.out_pos >= self.out.len()
    }
}

// ---------------------------------------------------------------------
// In-flight fleet operations.
// ---------------------------------------------------------------------

/// What a completed fleet command should turn into. `Begin`/`End`
/// carry their session-table entry so the deferred cleanup can use
/// [`SessionTable::remove_if`](crate::coordinator::daemon::SessionTable::remove_if)
/// — removal by name alone could evict a successor session that reused
/// the name in the meantime.
enum Op {
    Begin {
        conn: u64,
        id: String,
        entry: Arc<SessionEntry>,
    },
    /// One tick-drive serving every coalesced `status` poller of `sid`
    /// (each entry in `targets` fills one slot on that connection).
    Status { sid: String, targets: Vec<u64> },
    End {
        conn: u64,
        sid: String,
        entry: Arc<SessionEntry>,
    },
    /// One slice of a subscribe stream.
    SubStep { conn: u64, sid: String },
    /// Prometheus rendering in flight on its one-shot thread.
    Metrics { conn: u64 },
}

/// A completion, queued from a fleet worker thread alongside a wake
/// byte. `None` payloads mean the worker died with the reply pending.
enum Done {
    Begin(u64, Option<anyhow::Result<()>>),
    Session(u64, Option<anyhow::Result<SessionStatus>>),
    /// Rendered Prometheus exposition text.
    Metrics(u64, String),
}

const WORKER_GONE: &str = "fleet worker thread is gone";

/// Daemon-side state of the fleet power-budget arbiter (DESIGN.md §14),
/// installed by the first `set_policy` selecting the arbiter family.
/// The arbiter itself is pure bookkeeping; everything effectful — cap
/// application, journaling — happens worker-side via `Cmd::SetCap`.
struct ArbiterState {
    arb: BudgetArbiter,
    /// fleet session id → session-table name (for cap dispatch).
    enrolled: HashMap<u64, String>,
    /// fleet session id → telemetry tap id feeding `arbiter_observe`.
    taps: HashMap<u64, u64>,
}

// ---------------------------------------------------------------------
// The reactor.
// ---------------------------------------------------------------------

pub(crate) struct Reactor {
    fleet: Arc<Fleet>,
    shared: Arc<Shared>,
    cfg: DaemonCfg,
    conns: HashMap<u64, Conn>,
    /// Monotonic connection tokens — never reused, so a late completion
    /// can never address a recycled connection.
    next_tok: u64,
    ops: HashMap<u64, Op>,
    next_op: u64,
    /// Coalescing map (ADR-010): session id → in-flight `Op::Status`.
    driving: HashMap<String, u64>,
    done_tx: Sender<Done>,
    done_rx: Receiver<Done>,
    /// Write end of the wake pipe, cloned into every `Reply`.
    wake_w: Arc<UnixStream>,
    wake_r: UnixStream,
    started: Instant,
    /// Telemetry taps backing subscribe streams: conn token → tap id.
    taps: HashMap<u64, u64>,
    /// Tap forwarding channel — `(conn token, event)` pairs sent by the
    /// telemetry consumer thread, drained every loop iteration.
    sub_tx: Sender<(u64, TelemetryEvent)>,
    sub_rx: Receiver<(u64, TelemetryEvent)>,
    /// Cached `fleet.telemetry().enabled()` — hot paths branch on this
    /// instead of chasing the Arc.
    tel_enabled: bool,
    /// EWMA-smoothed in-flight op depth (ninelives P3.01): what the
    /// AIMD scaler sees instead of the raw per-iteration count.
    depth: Ewma,
    /// Request arrival rate over a trailing window (gauge only).
    req_rate: WindowedRate,
    /// Fleet power-budget arbiter, `None` until a `set_policy` selects
    /// the arbiter family (DESIGN.md §14).
    arbiter: Option<ArbiterState>,
}

impl Reactor {
    pub(crate) fn new(
        fleet: Arc<Fleet>,
        shared: Arc<Shared>,
        cfg: DaemonCfg,
    ) -> io::Result<Reactor> {
        let (done_tx, done_rx) = channel();
        let (sub_tx, sub_rx) = channel();
        let (wake_r, wake_w) = UnixStream::pair()?;
        wake_r.set_nonblocking(true)?;
        wake_w.set_nonblocking(true)?;
        let tel_enabled = fleet.telemetry().enabled();
        Ok(Reactor {
            fleet,
            shared,
            cfg,
            conns: HashMap::new(),
            next_tok: 0,
            ops: HashMap::new(),
            next_op: 0,
            driving: HashMap::new(),
            done_tx,
            done_rx,
            wake_w: Arc::new(wake_w),
            wake_r,
            started: Instant::now(),
            taps: HashMap::new(),
            sub_tx,
            sub_rx,
            tel_enabled,
            depth: Ewma::new(0.3),
            req_rate: WindowedRate::new(1.0),
            arbiter: None,
        })
    }

    /// The event loop. Runs until a v1 `shutdown` request is answered
    /// and flushed (or the grace period expires).
    pub(crate) fn serve(mut self, listener: UnixListener) -> anyhow::Result<()> {
        listener.set_nonblocking(true)?;
        let mut gate = AcceptGate::new();
        let mut shutdown_at: Option<Instant> = None;
        loop {
            // Harvest worker completions first: they fill slots and
            // produce output for this iteration's flush. Forwarded
            // subscribe events drain before completions so a stream's
            // tick never trails the drive reply that finishes it.
            self.drain_wakes();
            self.drain_sub_events();
            while let Ok(d) = self.done_rx.try_recv() {
                self.on_done(d);
            }
            // AIMD (ninelives P3.04) over the EWMA-smoothed in-flight
            // depth (P3.01): every pending op is queue depth the worker
            // pool hasn't absorbed yet, but only the sustained signal
            // may move the pool.
            let depth = self.depth.observe(self.ops.len() as f64);
            self.fleet.autoscale(depth.round() as usize);
            self.arbiter_tick();
            self.observe_gauges(depth);
            self.flush_all();
            self.reap();

            let now = Instant::now();
            if self.shared.shutdown.load(Ordering::SeqCst) {
                let deadline = *shutdown_at.get_or_insert(now + SHUTDOWN_GRACE);
                if self.conns.values().all(Conn::flushed) || now >= deadline {
                    break;
                }
            }

            // Build the poll set: wake pipe always; listener unless
            // shutting down or in accept backoff; connections by
            // read/write interest.
            let mut fds = vec![PollFd::new(self.wake_r.as_raw_fd(), POLLIN)];
            let accept_open = shutdown_at.is_none() && !gate.in_backoff(now);
            if accept_open {
                fds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
            }
            let conn_base = fds.len();
            let mut toks = Vec::with_capacity(self.conns.len());
            for (tok, c) in &self.conns {
                let mut ev = 0i16;
                if c.wants_read() {
                    ev |= POLLIN;
                }
                if c.wants_write() {
                    ev |= POLLOUT;
                }
                if ev != 0 {
                    toks.push(*tok);
                    fds.push(PollFd::new(c.stream.as_raw_fd(), ev));
                }
            }
            poll_fds(&mut fds, POLL_TIMEOUT_MS)?;

            if accept_open && fds[1].readable() {
                self.accept_burst(&listener, &mut gate);
            }
            for (i, tok) in toks.iter().enumerate() {
                if fds[conn_base + i].readable() {
                    self.read_conn(*tok);
                }
                // Write-ready connections are served by the next
                // iteration's flush_all.
            }
        }
        Ok(())
    }

    // -- completions ---------------------------------------------------

    fn drain_wakes(&mut self) {
        let mut buf = [0u8; 256];
        loop {
            match (&self.wake_r).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    /// A `Reply` that queues `wrap(result)` and pokes the wake pipe.
    fn make_reply<T: Send + 'static>(
        &self,
        wrap: impl FnOnce(Option<T>) -> Done + Send + 'static,
    ) -> Reply<T> {
        let tx = self.done_tx.clone();
        let wake = self.wake_w.clone();
        Reply::new(move |r| {
            let _ = tx.send(wrap(r));
            let _ = (&*wake).write(&[1u8]);
        })
    }

    fn next_op(&mut self) -> u64 {
        self.next_op += 1;
        self.next_op
    }

    fn on_done(&mut self, d: Done) {
        match d {
            Done::Begin(op, r) => {
                // Unknown ops are fine: a reply dropped on a failed
                // dispatch fires before its op was ever registered.
                let Some(Op::Begin { conn, id, entry }) = self.ops.remove(&op) else {
                    return;
                };
                let resp = match r {
                    // The handle is already in the table (fulfilled
                    // eagerly at dispatch): this reply only confirms
                    // the worker built the policy.
                    Some(Ok(())) => Response::Begun { session: id },
                    fail => {
                        // Reclaim the eagerly-installed handle (unless
                        // a pipelined end/abort already took it) and
                        // drop the reservation — ours only, never a
                        // successor's. The entry mutex is a leaf held
                        // for single statements, so a poisoned lock
                        // still carries a usable value — recover it
                        // rather than poison-cascade the reactor.
                        // gpoeo-lint: allow(blocking) leaf mutex, held only for single statements by spawn/end/abort — bounded wait, no I/O under it
                        drop(entry.handle.lock().unwrap_or_else(|e| e.into_inner()).take());
                        self.shared.sessions.remove_if(&id, &entry);
                        match fail {
                            Some(Err(e)) => Response::error(format!("{e:#}")),
                            _ => Response::error(WORKER_GONE.to_string()),
                        }
                    }
                };
                self.fill_slot(conn, op, ServerMsg::Response(resp).to_line());
            }
            Done::Session(op, r) => match self.ops.remove(&op) {
                Some(Op::Status { sid, targets }) => {
                    // Late joiners can no longer attach to this drive.
                    if self.driving.get(&sid) == Some(&op) {
                        self.driving.remove(&sid);
                    }
                    let resp = match r {
                        Some(Ok(st)) => Response::Status(report(&sid, st)),
                        Some(Err(e)) => Response::error(format!("{e:#}")),
                        None => Response::error(WORKER_GONE.to_string()),
                    };
                    let line = ServerMsg::Response(resp).to_line();
                    for t in targets {
                        self.fill_slot(t, op, line.clone());
                    }
                }
                Some(Op::End { conn, sid, entry }) => {
                    self.shared.sessions.remove_if(&sid, &entry);
                    let resp = match r {
                        Some(Ok(st)) => Response::Result(report(&sid, st)),
                        Some(Err(e)) => Response::error(format!("{e:#}")),
                        None => Response::error(WORKER_GONE.to_string()),
                    };
                    self.fill_slot(conn, op, ServerMsg::Response(resp).to_line());
                }
                Some(Op::SubStep { conn, sid }) => self.on_sub_step(conn, &sid, r),
                Some(Op::Begin { .. }) | Some(Op::Metrics { .. }) | None => {}
            },
            Done::Metrics(op, text) => {
                let Some(Op::Metrics { conn }) = self.ops.remove(&op) else {
                    return;
                };
                let line = ServerMsg::Response(Response::Metrics { text }).to_line();
                self.fill_slot(conn, op, line);
            }
        }
    }

    /// Per-iteration gauge refresh: plain atomic stores, skipped
    /// entirely when the plane is detached.
    fn observe_gauges(&mut self, depth: f64) {
        if !self.tel_enabled {
            return;
        }
        let rate = self.req_rate.rate(self.started.elapsed().as_secs_f64());
        let m = self.fleet.telemetry().metrics();
        m.set_gauge(Gauge::Workers, self.fleet.num_workers() as f64);
        m.set_gauge(Gauge::SessionsLive, self.shared.sessions.len() as f64);
        m.set_gauge(Gauge::AimdDepthEwma, depth);
        m.set_gauge(Gauge::RequestRateHz, rate);
    }

    // -- accept / read / write ----------------------------------------

    fn accept_burst(&mut self, listener: &UnixListener, gate: &mut AcceptGate) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => self.add_conn(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Logs through the gate; the backoff drops the
                    // listener from the poll set for a beat. The
                    // counter sees every failure, including the ones
                    // the gate's log throttle swallows.
                    self.fleet
                        .telemetry()
                        .metrics()
                        .inc(Counter::AcceptErrorsSuppressed);
                    let _ = accept_stream(Err(e), gate, Instant::now());
                    return;
                }
            }
        }
    }

    fn add_conn(&mut self, stream: UnixStream) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let tok = self.next_tok;
        self.next_tok += 1;
        self.conns.insert(tok, Conn::new(stream));
        // The client's first bytes are often already queued.
        self.read_conn(tok);
    }

    fn read_conn(&mut self, tok: u64) {
        enum Action {
            Eof,
            Feed(usize),
            Legacy(usize),
            Drop,
        }
        let mut buf = [0u8; 8192];
        loop {
            let action = {
                let Some(c) = self.conns.get_mut(&tok) else { return };
                if !c.wants_read() {
                    return;
                }
                match (&c.stream).read(&mut buf) {
                    Ok(0) => Action::Eof,
                    Ok(n) => {
                        if matches!(c.state, ConnState::Sniff) {
                            if buf[0] == b'{' {
                                let bucket = (self.cfg.rate_limit_rps > 0.0).then(|| {
                                    TokenBucket::new(self.cfg.rate_limit_rps, self.cfg.rate_burst)
                                });
                                c.state = ConnState::V1(V1::new(bucket));
                                Action::Feed(n)
                            } else {
                                Action::Legacy(n)
                            }
                        } else {
                            Action::Feed(n)
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => Action::Drop,
                }
            };
            match action {
                Action::Eof => {
                    self.on_eof(tok);
                    return;
                }
                Action::Feed(n) => {
                    if let Some(c) = self.conns.get_mut(&tok) {
                        let Conn { framer, events, .. } = c;
                        framer.push(&buf[..n], events);
                    }
                    self.pump(tok);
                }
                Action::Legacy(n) => {
                    self.legacy_handoff(tok, &buf[..n]);
                    return;
                }
                Action::Drop => {
                    self.conns.remove(&tok);
                    return;
                }
            }
        }
    }

    fn on_eof(&mut self, tok: u64) {
        let Some(c) = self.conns.get_mut(&tok) else { return };
        c.eof = true;
        if let ConnState::V1(_) = c.state {
            if let Some(ev) = c.framer.take_trailing() {
                c.events.push_back(ev);
            }
        }
        self.pump(tok);
    }

    /// Non-`{` first byte: hand the connection (with its already-read
    /// bytes re-attached) to a blocking thread running the unchanged
    /// legacy protocol.
    fn legacy_handoff(&mut self, tok: u64, first: &[u8]) {
        let Some(c) = self.conns.remove(&tok) else { return };
        let stream = c.stream;
        if stream.set_nonblocking(false).is_err() {
            return;
        }
        let Ok(writer) = stream.try_clone() else { return };
        let fleet = self.fleet.clone();
        let buffered = first.to_vec();
        std::thread::spawn(move || {
            let reader = BufReader::new(Cursor::new(buffered).chain(stream));
            let _ = handle_legacy(reader, writer, &fleet);
        });
    }

    fn flush_all(&mut self) {
        for c in self.conns.values_mut() {
            while !c.dead && !c.flushed() {
                match (&c.stream).write(&c.out[c.out_pos..]) {
                    Ok(0) => c.dead = true,
                    Ok(n) => c.out_pos += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => c.dead = true,
                }
            }
            if c.flushed() {
                c.out.clear();
                c.out_pos = 0;
            } else if c.out.len() - c.out_pos > MAX_OUTBUF {
                c.dead = true;
            }
        }
    }

    fn reap(&mut self) {
        self.conns.retain(|_, c| !Reactor::spent(c));
    }

    fn spent(c: &Conn) -> bool {
        if c.dead {
            return true;
        }
        match &c.state {
            ConnState::Sniff => c.eof,
            ConnState::V1(v) => {
                let idle = c.events.is_empty()
                    && v.slots.is_empty()
                    && v.sub.is_none()
                    && v.pending_sub.is_none();
                (c.eof && idle && c.flushed()) || (v.closing && c.flushed())
            }
        }
    }

    // -- v1 request processing ----------------------------------------

    fn v1_mut(&mut self, tok: u64) -> Option<&mut V1> {
        match self.conns.get_mut(&tok).map(|c| &mut c.state) {
            Some(ConnState::V1(v)) => Some(v),
            _ => None,
        }
    }

    /// Process framed events until the connection blocks (subscribe in
    /// progress, shutdown answered) or the backlog drains.
    fn pump(&mut self, tok: u64) {
        loop {
            let ev = {
                let Some(c) = self.conns.get_mut(&tok) else { return };
                let ConnState::V1(v) = &c.state else { return };
                if v.sub.is_some() || v.pending_sub.is_some() || v.closing {
                    break;
                }
                match c.events.pop_front() {
                    Some(e) => e,
                    None => break,
                }
            };
            self.handle_event(tok, ev);
        }
        self.maybe_start_sub(tok);
    }

    /// Queue a response for `tok`, preserving request order.
    fn answer(&mut self, tok: u64, r: Response) {
        self.answer_line(tok, ServerMsg::Response(r).to_line());
    }

    fn answer_line(&mut self, tok: u64, line: String) {
        let Some(c) = self.conns.get_mut(&tok) else { return };
        if let ConnState::V1(v) = &mut c.state {
            v.slots.push_back(Slot::Ready(line));
        }
        Self::drain_ready(c);
    }

    fn push_pending(&mut self, tok: u64, op: u64) {
        let t0 = self.tel_enabled.then(Instant::now);
        if let Some(v) = self.v1_mut(tok) {
            v.slots.push_back(Slot::Pending(op, t0));
        }
    }

    /// Resolve one `Pending(op)` slot and flush the contiguous `Ready`
    /// prefix into the output buffer. Queued-to-answered time feeds the
    /// request-latency histogram.
    fn fill_slot(&mut self, tok: u64, op: u64, line: String) {
        let mut latency = None;
        let Some(c) = self.conns.get_mut(&tok) else { return };
        if let ConnState::V1(v) = &mut c.state {
            if let Some(slot) = v
                .slots
                .iter_mut()
                .find(|s| matches!(s, Slot::Pending(o, _) if *o == op))
            {
                if let Slot::Pending(_, Some(t0)) = slot {
                    latency = Some(t0.elapsed());
                }
                *slot = Slot::Ready(line);
            }
        }
        Self::drain_ready(c);
        if let Some(d) = latency {
            self.fleet
                .telemetry()
                .metrics()
                .observe(Hist::RequestSeconds, d.as_secs_f64());
        }
        self.maybe_start_sub(tok);
    }

    fn drain_ready(c: &mut Conn) {
        let Conn { state, out, .. } = c;
        let ConnState::V1(v) = state else { return };
        while matches!(v.slots.front(), Some(Slot::Ready(_))) {
            if let Some(Slot::Ready(l)) = v.slots.pop_front() {
                out.extend_from_slice(l.as_bytes());
            }
        }
    }

    /// Bytes appended outside the slot queue — subscribe events and the
    /// stream's final response (legal only while the stream owns the
    /// connection, i.e. the slot queue is empty).
    fn append_out(&mut self, tok: u64, line: &str) {
        if let Some(c) = self.conns.get_mut(&tok) {
            c.out.extend_from_slice(line.as_bytes());
        }
    }

    fn handle_event(&mut self, tok: u64, ev: FrameEvent) {
        let line = match ev {
            FrameEvent::Oversized => {
                self.answer(
                    tok,
                    Response::error(format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                );
                return;
            }
            FrameEvent::Line(l) => l,
        };
        if line.trim().is_empty() {
            return;
        }
        // Rate limit before parsing: a flood of malformed lines is
        // still a flood.
        let (rate, burst) = (self.cfg.rate_limit_rps, self.cfg.rate_burst.max(1.0));
        let now_s = self.started.elapsed().as_secs_f64();
        if self.tel_enabled {
            self.req_rate.record(now_s);
        }
        let over = match self.v1_mut(tok) {
            Some(v) => match v.bucket.as_mut() {
                Some(b) => !b.admit(now_s),
                None => false,
            },
            None => return,
        };
        if over {
            self.fleet
                .telemetry()
                .metrics()
                .inc(Counter::RequestsRateLimited);
            self.answer(
                tok,
                Response::rate_limited(format!(
                    "rate limit exceeded ({rate} req/s, burst {burst})"
                )),
            );
            return;
        }
        let req = match Request::parse_line(&line) {
            Ok(r) => r,
            Err(msg) => {
                self.answer(tok, Response::error(msg));
                return;
            }
        };
        let hello_done = self.v1_mut(tok).is_some_and(|v| v.hello_done);
        if !hello_done && !matches!(req, Request::Hello { .. }) {
            self.answer(tok, Response::handshake_required());
            return;
        }
        match req {
            Request::Hello { version } => {
                let server = format!("gpoeo {}", env!("CARGO_PKG_VERSION"));
                match negotiate_hello(version, server) {
                    Ok(resp) => {
                        if let Some(v) = self.v1_mut(tok) {
                            v.hello_done = true;
                        }
                        self.answer(tok, resp);
                    }
                    Err(resp) => self.answer(tok, resp),
                }
            }
            Request::Begin {
                app,
                iters,
                name,
                policy,
            } => self.start_begin(tok, &app, iters, name, policy),
            Request::Status { session } => self.start_status(tok, session),
            Request::End { session } => match claim_session(&self.shared, &session) {
                Ok((entry, h)) => {
                    // Leave the arbiter before the (possibly long) final
                    // drive: the departing session's headroom goes back
                    // into the pool at the next reallocation.
                    self.arbiter_unenroll(h.id());
                    let op = self.next_op();
                    let reply = self.make_reply(move |r| Done::Session(op, r));
                    h.dispatch_end(reply);
                    self.ops.insert(
                        op,
                        Op::End {
                            conn: tok,
                            sid: session,
                            entry,
                        },
                    );
                    self.push_pending(tok, op);
                }
                Err(e) => self.answer(tok, Response::error(format!("{e:#}"))),
            },
            Request::Abort { session } => {
                let r = claim_session(&self.shared, &session).map(|(entry, h)| {
                    self.arbiter_unenroll(h.id());
                    h.abort();
                    self.shared.sessions.remove_if(&session, &entry);
                });
                let resp = match r {
                    Ok(()) => Response::Ok {
                        detail: format!("session {session} aborted"),
                    },
                    Err(e) => Response::error(format!("{e:#}")),
                };
                self.answer(tok, resp);
            }
            Request::SetPolicy { policy } => match PolicyRegistry::global().get(&policy.name) {
                Ok(_) => {
                    // Selecting the arbiter family also (re)configures
                    // the daemon-wide budget arbiter — re-issuing
                    // `set_policy` with a smaller `budget_w` is how an
                    // operator shrinks the fleet budget live.
                    match crate::policy::arbiter::arbiter_config(&policy) {
                        Some(Err(e)) => {
                            self.answer(tok, Response::error(format!("{e:#}")));
                            return;
                        }
                        Some(Ok(acfg)) => self.install_arbiter(acfg),
                        None => {}
                    }
                    let detail = format!("policy {}", policy.name);
                    if let Some(v) = self.v1_mut(tok) {
                        v.default_policy = policy;
                    }
                    self.answer(tok, Response::Ok { detail });
                }
                Err(e) => self.answer(tok, Response::error(format!("{e:#}"))),
            },
            Request::ListApps => {
                let resp = match list_apps(self.fleet.spec()) {
                    Ok(apps) => Response::Apps(apps),
                    Err(e) => Response::error(format!("{e:#}")),
                };
                self.answer(tok, resp);
            }
            Request::ListPolicies => {
                let ps = PolicyRegistry::global()
                    .iter()
                    .map(|b| PolicyInfo {
                        name: b.name().to_string(),
                        description: b.describe().to_string(),
                        default_config: b.default_config(),
                    })
                    .collect();
                self.answer(tok, Response::Policies(ps));
            }
            Request::Subscribe {
                session,
                every_ticks,
                max_events,
            } => {
                if let Some(v) = self.v1_mut(tok) {
                    v.pending_sub = Some(SubReq {
                        sid: session,
                        every_ticks,
                        max_events,
                    });
                }
                // Started by maybe_start_sub once earlier slots drain.
            }
            Request::Metrics => {
                let op = self.next_op();
                self.push_pending(tok, op);
                self.ops.insert(op, Op::Metrics { conn: tok });
                let tel = self.fleet.telemetry().clone();
                let tx = self.done_tx.clone();
                let wake = self.wake_w.clone();
                // Rendering walks every family (histogram buckets, the
                // per-policy label map): off the reactor thread.
                std::thread::spawn(move || {
                    let text = tel.metrics().render_prometheus();
                    let _ = tx.send(Done::Metrics(op, text));
                    let _ = (&*wake).write(&[1u8]);
                });
            }
            Request::Shutdown => {
                self.answer(
                    tok,
                    Response::Ok {
                        detail: "daemon shutting down".to_string(),
                    },
                );
                if let Some(v) = self.v1_mut(tok) {
                    v.closing = true;
                }
                self.shared.shutdown.store(true, Ordering::SeqCst);
            }
        }
    }

    fn start_begin(
        &mut self,
        tok: u64,
        app: &str,
        iters: Option<u64>,
        name: Option<String>,
        policy: Option<PolicySpec>,
    ) {
        let spec = match policy {
            Some(p) => p,
            None => match self.v1_mut(tok) {
                Some(v) => v.default_policy.clone(),
                None => return,
            },
        };
        let prepared = match prepare_begin(&self.fleet, &self.shared, app, iters, name, &spec) {
            Ok(p) => p,
            Err(e) => {
                self.answer(tok, Response::error(format!("{e:#}")));
                return;
            }
        };
        let op = self.next_op();
        let reply = self.make_reply(move |r| Done::Begin(op, r));
        // Decided before `spec` moves into the fleet: arbiter-family
        // sessions enroll in the budget arbiter (if one is installed).
        let enroll = self.arbiter.is_some() && crate::policy::arbiter::is_arbiter(&spec);
        match self.fleet.begin_async(prepared.app, spec, prepared.n_iters, reply) {
            Ok(handle) => {
                let fleet_id = handle.id();
                // Fulfill the table *now*, not when the worker confirms:
                // worker command queues are FIFO, so a status/end
                // pipelined right behind this begin queues after it on
                // the same worker — exactly the old blocking-path
                // ordering. If the begin then fails, the queued command
                // answers "no such session" and `on_done` reclaims the
                // entry.
                let Some(entry) = self.shared.sessions.fulfill(&prepared.id, handle) else {
                    // prepare_begin reserved this id moments ago on
                    // this same thread; a missing entry means the
                    // table was torn down — answer instead of panic.
                    self.answer(
                        tok,
                        Response::error(format!("session '{}' reservation vanished", prepared.id)),
                    );
                    return;
                };
                if enroll {
                    self.arbiter_enroll(fleet_id, &prepared.id);
                }
                self.ops.insert(
                    op,
                    Op::Begin {
                        conn: tok,
                        id: prepared.id,
                        entry,
                    },
                );
                self.push_pending(tok, op);
            }
            Err(e) => {
                self.shared.sessions.remove(&prepared.id);
                self.answer(tok, Response::error(format!("{e:#}")));
            }
        }
    }

    // -- budget arbiter (DESIGN.md §14) -------------------------------

    /// Install the arbiter, or retune a live one. `set_cfg` re-arms an
    /// immediate reallocation, so a budget change takes effect on the
    /// very next loop iteration rather than a full period later.
    fn install_arbiter(&mut self, cfg: ArbiterCfg) {
        match self.arbiter.as_mut() {
            Some(st) => st.arb.set_cfg(cfg),
            None => {
                self.arbiter = Some(ArbiterState {
                    arb: BudgetArbiter::new(cfg),
                    enrolled: HashMap::new(),
                    taps: HashMap::new(),
                });
            }
        }
        self.arbiter_tick();
    }

    /// Enroll a just-begun arbiter-family session: bookkeeping plus a
    /// telemetry tap (tagged `ARB_TAG | fleet_id`) feeding its tick and
    /// detect events to [`Reactor::arbiter_observe`]. With the plane
    /// detached there is no tap — no signal ever arrives and the
    /// arbiter stays on its fairness fallback, by design.
    fn arbiter_enroll(&mut self, fleet_id: u64, sid: &str) {
        if self.arbiter.is_none() {
            return;
        }
        let tap = self.tel_enabled.then(|| {
            let wake = self.wake_w.clone();
            self.fleet.telemetry().subscribe_session(
                fleet_id,
                ARB_TAG | fleet_id,
                self.sub_tx.clone(),
                Box::new(move || {
                    let _ = (&*wake).write(&[1u8]);
                }),
            )
        });
        if let Some(st) = self.arbiter.as_mut() {
            st.arb.enroll(fleet_id);
            st.enrolled.insert(fleet_id, sid.to_string());
            if let Some(tap) = tap {
                st.taps.insert(fleet_id, tap);
            }
        }
    }

    /// Remove a session from arbitration (end/abort/observed End).
    /// Unknown ids are a no-op, so the explicit end-path call and the
    /// telemetry-observed End may both fire.
    fn arbiter_unenroll(&mut self, fleet_id: u64) {
        let tap = match self.arbiter.as_mut() {
            Some(st) => {
                st.arb.unenroll(fleet_id);
                st.enrolled.remove(&fleet_id);
                st.taps.remove(&fleet_id)
            }
            None => return,
        };
        if let Some(tap) = tap {
            self.fleet.telemetry().unsubscribe(tap);
        }
    }

    /// Feed one tapped telemetry event to the arbiter's observers. Only
    /// iteration progress (never raw ticks — the smoothing contract in
    /// DESIGN.md §14), streaming-detector verdicts, and session End.
    fn arbiter_observe(&mut self, ev: TelemetryEvent) {
        match ev {
            TelemetryEvent::Tick {
                session,
                iterations,
                time_s,
                ..
            } => {
                if let Some(st) = self.arbiter.as_mut() {
                    st.arb.observe_tick(session, iterations, time_s);
                }
            }
            TelemetryEvent::Detect {
                session, aperiodic, ..
            } => {
                if let Some(st) = self.arbiter.as_mut() {
                    st.arb.observe_detect(session, aperiodic);
                }
            }
            TelemetryEvent::End { session, .. } => self.arbiter_unenroll(session),
            _ => {}
        }
    }

    /// One arbiter step per loop iteration. Period-gating lives inside
    /// [`BudgetArbiter::tick`], so the idle cost is one clock read and a
    /// compare. Cap dispatch is fire-and-forget through each owning
    /// worker's FIFO (`Cmd::SetCap`) — the reactor never blocks on it.
    fn arbiter_tick(&mut self) {
        let now_s = self.started.elapsed().as_secs_f64();
        let Some(st) = self.arbiter.as_mut() else { return };
        let Some(re) = st.arb.tick(now_s) else { return };
        let mut gone: Vec<u64> = Vec::new();
        for (fid, cap_w) in &re.caps {
            let Some(sid) = st.enrolled.get(fid) else {
                continue;
            };
            let sent = with_session(&self.shared, sid, |h| {
                h.dispatch_set_cap(*cap_w, re.budget_w, re.epoch);
                Ok(())
            });
            if sent.is_err() {
                // The session left the table (end/abort raced the
                // reallocation): retire it from arbitration.
                gone.push(*fid);
            }
        }
        if self.tel_enabled {
            let m = self.fleet.telemetry().metrics();
            m.set_gauge(Gauge::ArbiterBudgetW, re.budget_w);
            m.add(Counter::ArbiterReallocations, re.changed as u64);
        }
        for fid in gone {
            self.arbiter_unenroll(fid);
        }
    }

    /// `status` with coalescing (ADR-010): if a tick-drive for this
    /// session is already in flight, join it instead of driving again.
    fn start_status(&mut self, tok: u64, session: String) {
        if let Some(&op) = self.driving.get(&session) {
            if let Some(Op::Status { targets, .. }) = self.ops.get_mut(&op) {
                targets.push(tok);
                self.fleet
                    .telemetry()
                    .metrics()
                    .inc(Counter::RequestsCoalesced);
                self.push_pending(tok, op);
                return;
            }
        }
        let op = self.next_op();
        let reply = self.make_reply(move |r| Done::Session(op, r));
        let dispatched = with_session(&self.shared, &session, |h| {
            h.dispatch_step(STATUS_TICKS, reply);
            Ok(())
        });
        match dispatched {
            Ok(()) => {
                self.driving.insert(session.clone(), op);
                self.ops.insert(
                    op,
                    Op::Status {
                        sid: session,
                        targets: vec![tok],
                    },
                );
                self.push_pending(tok, op);
            }
            Err(e) => self.answer(tok, Response::error(format!("{e:#}"))),
        }
    }

    // -- subscribe streams --------------------------------------------

    fn maybe_start_sub(&mut self, tok: u64) {
        let ready = match self.v1_mut(tok) {
            Some(v) => {
                v.sub.is_none() && v.pending_sub.is_some() && v.slots.is_empty() && !v.closing
            }
            None => return,
        };
        if !ready {
            return;
        }
        let Some(req) = self.v1_mut(tok).and_then(|v| v.pending_sub.take()) else {
            return;
        };
        // Resolve the fleet-level identity first: the telemetry tap
        // keys on the numeric session id, not the table name.
        let ids = with_session(&self.shared, &req.sid, |h| Ok((h.id(), h.target_iters())));
        let (fleet_id, target_iters) = match ids {
            Ok(pair) => pair,
            // A dead session answers a single typed error, no events.
            Err(e) => {
                self.answer(tok, Response::error(format!("{e:#}")));
                return;
            }
        };
        // Register the tap *before* the first drive: the worker emits
        // the slice's tick ahead of its reply, and an unregistered tap
        // would lose it.
        if self.tel_enabled {
            let wake = self.wake_w.clone();
            let tap = self.fleet.telemetry().subscribe_session(
                fleet_id,
                tok,
                self.sub_tx.clone(),
                Box::new(move || {
                    let _ = (&*wake).write(&[1u8]);
                }),
            );
            self.taps.insert(tok, tap);
        }
        match self.dispatch_sub_step(tok, &req.sid, req.every_ticks) {
            Ok(()) => {
                if let Some(v) = self.v1_mut(tok) {
                    v.sub = Some(Sub {
                        sid: req.sid,
                        every_ticks: req.every_ticks,
                        max_events: req.max_events,
                        sent: 0,
                        events_sent: 0,
                        target_iters,
                    });
                }
            }
            Err(e) => {
                self.drop_tap(tok);
                self.answer(tok, Response::error(format!("{e:#}")));
            }
        }
    }

    fn dispatch_sub_step(&mut self, tok: u64, sid: &str, every_ticks: u64) -> anyhow::Result<()> {
        let op = self.next_op();
        let reply = self.make_reply(move |r| Done::Session(op, r));
        with_session(&self.shared, sid, |h| {
            h.dispatch_step(every_ticks, reply);
            Ok(())
        })?;
        self.ops.insert(
            op,
            Op::SubStep {
                conn: tok,
                sid: sid.to_string(),
            },
        );
        Ok(())
    }

    fn on_sub_step(&mut self, tok: u64, sid: &str, r: Option<anyhow::Result<SessionStatus>>) {
        if !self.conns.contains_key(&tok) {
            // Subscriber vanished: the stream dies, the session stays
            // registered (end still owns the result).
            self.drop_tap(tok);
            return;
        }
        let st = match r {
            Some(Ok(st)) => st,
            Some(Err(e)) => {
                let line = ServerMsg::Response(Response::error(format!("{e:#}"))).to_line();
                self.finish_sub(tok, line);
                return;
            }
            None => {
                let line = ServerMsg::Response(Response::error(WORKER_GONE.to_string())).to_line();
                self.finish_sub(tok, line);
                return;
            }
        };
        let finished = {
            let Some(v) = self.v1_mut(tok) else { return };
            let Some(sub) = v.sub.as_mut() else { return };
            sub.sent += 1;
            st.done || (sub.max_events > 0 && sub.sent >= sub.max_events)
        };
        if !self.taps.contains_key(&tok) {
            // Plane detached: the drive reply itself is the event.
            let ev = ServerMsg::Event(Event::Status(report(sid, st))).to_line();
            self.append_out(tok, &ev);
        }
        if finished {
            let fin = ServerMsg::Response(Response::Status(report(sid, st))).to_line();
            self.finish_sub(tok, fin);
            return;
        }
        let every = self.v1_mut(tok).and_then(|v| v.sub.as_ref().map(|s| s.every_ticks));
        let Some(every) = every else { return };
        if let Err(e) = self.dispatch_sub_step(tok, sid, every) {
            let line = ServerMsg::Response(Response::error(format!("{e:#}"))).to_line();
            self.finish_sub(tok, line);
        }
    }

    /// Terminal path of a subscribe stream: make sure every event the
    /// fleet emitted for it has been forwarded (bounded flush → drain),
    /// close the tap, then append the final line — the stream's last
    /// event never trails its final response.
    fn finish_sub(&mut self, tok: u64, final_line: String) {
        if self.taps.contains_key(&tok) {
            // Bounded: a stalled consumer thread costs ≤ 50 ms once per
            // stream end, never a reactor stall per event (the tick it
            // held back is simply missing — lossy-tap semantics).
            self.fleet.telemetry().flush(Duration::from_millis(50));
            self.drop_tap(tok);
            self.drain_sub_events();
        }
        self.append_out(tok, &final_line);
        self.end_sub(tok);
    }

    fn drop_tap(&mut self, tok: u64) {
        if let Some(tap) = self.taps.remove(&tok) {
            self.fleet.telemetry().unsubscribe(tap);
        }
    }

    /// Forward queued telemetry events to their subscribe streams, and
    /// arbiter-tagged taps to the budget arbiter's observers.
    fn drain_sub_events(&mut self) {
        while let Ok((tok, ev)) = self.sub_rx.try_recv() {
            if tok & ARB_TAG != 0 {
                self.arbiter_observe(ev);
            } else {
                self.route_sub_event(tok, ev);
            }
        }
    }

    fn route_sub_event(&mut self, tok: u64, ev: TelemetryEvent) {
        // Only progress ticks become wire events; begin/detect/
        // gear-switch/end stay journal- and metrics-side.
        let TelemetryEvent::Tick {
            iterations,
            time_s,
            energy_j,
            sm_gear,
            mem_gear,
            done,
            ..
        } = ev
        else {
            return;
        };
        let line = {
            let Some(v) = self.v1_mut(tok) else { return };
            let Some(sub) = v.sub.as_mut() else { return };
            if sub.max_events > 0 && sub.events_sent >= sub.max_events {
                return;
            }
            sub.events_sent += 1;
            ServerMsg::Event(Event::Status(SessionReport {
                session: sub.sid.clone(),
                iterations,
                target_iters: sub.target_iters,
                time_s,
                energy_j,
                sm_gear,
                mem_gear,
                done,
            }))
            .to_line()
        };
        self.append_out(tok, &line);
    }

    fn end_sub(&mut self, tok: u64) {
        if let Some(v) = self.v1_mut(tok) {
            v.sub = None;
        }
        // Resume whatever queued behind the stream.
        self.pump(tok);
    }
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{read_frame, Frame};

    /// Frame a byte stream through the blocking `read_frame`.
    fn via_read_frame(data: &[u8], max: usize) -> Vec<Frame> {
        let mut r = std::io::BufReader::new(Cursor::new(data.to_vec()));
        let mut out = Vec::new();
        loop {
            match read_frame(&mut r, max).unwrap() {
                Frame::Eof => return out,
                f => out.push(f),
            }
        }
    }

    /// Frame the same stream through the incremental framer, fed in
    /// `chunk`-sized pieces.
    fn via_framer(data: &[u8], chunk: usize, max: usize) -> Vec<Frame> {
        let mut framer = LineFramer::new(max);
        let mut events = VecDeque::new();
        for piece in data.chunks(chunk.max(1)) {
            framer.push(piece, &mut events);
        }
        if let Some(ev) = framer.take_trailing() {
            events.push_back(ev);
        }
        events
            .into_iter()
            .map(|e| match e {
                FrameEvent::Line(l) => Frame::Line(l),
                FrameEvent::Oversized => Frame::Oversized,
            })
            .collect()
    }

    #[test]
    fn framer_matches_read_frame_at_every_chunking() {
        let max = 8;
        let corpus: &[&[u8]] = &[
            b"ab\ncd\n",
            b"exactly8\n",
            b"123456789\n",
            b"123456789\nok\n",
            b"\n\n",
            b"tail",
            b"over-the-cap-line\nx",
            b"aaaaaaaaaaaaaaaaaaaaaaaa",
            b"first\naaaaaaaaaaaaaaaaaaaa\nlast\n",
            b"caf\xc3\xa9\nbad\xffbyte\n",
            b"",
        ];
        for data in corpus {
            let expect = via_read_frame(data, max);
            for chunk in [1, 2, 3, 5, 7, 64] {
                let got = via_framer(data, chunk, max);
                assert_eq!(got, expect, "data {data:?} chunk {chunk}");
            }
        }
    }

    #[test]
    fn framer_emits_oversized_at_detection_and_swallows_the_rest() {
        // The cap trips mid-line, before the newline ever arrives — the
        // event must not wait for the line to finish (the blocking
        // read_frame drains first, but it has the luxury of blocking).
        let mut f = LineFramer::new(4);
        let mut out = VecDeque::new();
        f.push(b"123456", &mut out);
        assert_eq!(out.pop_front(), Some(FrameEvent::Oversized));
        // Everything up to the newline is swallowed silently...
        f.push(b"789", &mut out);
        assert!(out.is_empty());
        f.push(b"\nok\n", &mut out);
        // ...and the next line comes through clean.
        assert_eq!(out.pop_front(), Some(FrameEvent::Line("ok".into())));
        assert!(out.is_empty());
        assert_eq!(f.take_trailing(), None);
    }

    #[test]
    fn token_bucket_refills_at_rate_and_caps_at_burst() {
        let mut b = TokenBucket::new(2.0, 4.0);
        // The burst is available immediately...
        for i in 0..4 {
            assert!(b.admit(0.0), "burst token {i}");
        }
        // ...then the bucket is dry.
        assert!(!b.admit(0.0));
        // 0.4s at 2 tokens/s refills 0.8 — still short of one token.
        assert!(!b.admit(0.4));
        // 0.1s more crosses 1.0.
        assert!(b.admit(0.5));
        assert!(!b.admit(0.5));
        // A long idle refills to the burst cap, not beyond.
        for i in 0..4 {
            assert!(b.admit(100.0), "refilled token {i}");
        }
        assert!(!b.admit(100.0));
    }

    #[test]
    fn token_bucket_burst_floor_is_one_request() {
        // burst 0 would deadlock every connection; it clamps to 1.
        let mut b = TokenBucket::new(5.0, 0.0);
        assert!(b.admit(0.0));
        assert!(!b.admit(0.0));
        // Time running backwards (clock hiccup) must not mint tokens.
        assert!(!b.admit(-50.0));
    }
}
