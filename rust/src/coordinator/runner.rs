//! Policy abstraction and the measurement harness that scores a policy
//! against the NVIDIA-default baseline on a fixed amount of work.
//!
//! Policies are written against [`Device`] (DESIGN.md §4): the same
//! controller code drives the simulator today and would drive an
//! NVML-backed device unchanged.

use crate::coordinator::GpoeoStats;
use crate::device::{sim_device, Device};
use crate::sim::{AppParams, Spec};
use std::sync::Arc;

/// An online clock-management policy driven by sampling ticks. The policy
/// owns the cadence: `tick` must advance the device by its sampling
/// interval.
///
/// Policies are constructed by name through
/// [`crate::policy::PolicyRegistry`] — nothing outside `policy/` matches
/// on policy-name strings.
pub trait Policy {
    fn name(&self) -> &'static str;
    fn tick(&mut self, dev: &mut dyn Device);

    /// The GPOEO optimization trace, when this policy is the GPOEO
    /// controller — the reporting hook the fleet and CLI use on boxed
    /// policies. Everything else reports `None`.
    fn gpoeo_stats(&self) -> Option<GpoeoStats> {
        None
    }

    /// Attach the telemetry plane (DESIGN.md §11). Fleet workers call
    /// this once per session, right after construction; policies that
    /// emit (gear switches, detection events, predict latencies) store
    /// the handle + session id, everything else ignores it. Telemetry
    /// is pure observation — attaching must never change a policy's
    /// decisions (the parallel==serial and parity gates run both ways).
    fn attach_telemetry(&mut self, _tel: Arc<crate::telemetry::Telemetry>, _session: u64) {}
}

/// The NVIDIA default scheduling strategy: no controller at all (the
/// device boots power-capped-boosted and stays there).
pub struct DefaultPolicy {
    pub ts: f64,
}

impl Policy for DefaultPolicy {
    fn name(&self) -> &'static str {
        // Matches the registry key, so `RunResult::policy` strings and
        // `--policy` values line up.
        "default"
    }
    fn tick(&mut self, dev: &mut dyn Device) {
        dev.advance(self.ts);
    }
}

/// Outcome of running one policy on one app for a fixed work amount.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub app: String,
    pub policy: String,
    pub energy_j: f64,
    pub time_s: f64,
    pub iterations: u64,
    pub final_sm_gear: usize,
    pub final_mem_gear: usize,
}

/// Virtual-time budget for driving `n_iters` work units starting at
/// `now_s`: generous for any sane policy, finite for errant ones. The
/// single source of truth for every drive loop (here and in the fleet).
pub fn run_budget_s(now_s: f64, n_iters: u64, nominal_iter_s: f64) -> f64 {
    now_s + 50.0 * n_iters as f64 * nominal_iter_s + 3600.0
}

/// Run `policy` on an already-attached device until `n_iters` iterations
/// (work units) finish.
pub fn run_policy(dev: &mut dyn Device, policy: &mut dyn Policy, n_iters: u64) -> RunResult {
    // Hard stop at a generous virtual-time budget (errant policies).
    let budget_s = run_budget_s(dev.time_s(), n_iters, dev.nominal_iter_s());
    while dev.iterations() < n_iters && dev.time_s() < budget_s {
        policy.tick(dev);
    }
    RunResult {
        app: dev.workload().to_string(),
        policy: policy.name().to_string(),
        energy_j: dev.true_energy_j(),
        time_s: dev.time_s(),
        iterations: dev.iterations(),
        final_sm_gear: dev.sm_gear(),
        final_mem_gear: dev.mem_gear(),
    }
}

/// Run `policy` on `app` on a fresh simulated device — the standard
/// entry point for experiments and sweeps.
pub fn run_sim(
    spec: &Arc<Spec>,
    app: &AppParams,
    policy: &mut dyn Policy,
    n_iters: u64,
) -> RunResult {
    let mut dev = sim_device(spec, app);
    run_policy(&mut dev, policy, n_iters)
}

/// Savings of `run` relative to `base` (same app, same n_iters).
#[derive(Debug, Clone, Copy)]
pub struct Savings {
    pub energy_saving: f64,
    pub slowdown: f64,
    pub ed2p_saving: f64,
}

pub fn savings(base: &RunResult, run: &RunResult) -> Savings {
    // Normalize per work unit: policies overshoot the iteration target by
    // different amounts (a probe window can span several iterations), so
    // raw totals would compare different amounts of work.
    let e = (run.energy_j / run.iterations as f64) / (base.energy_j / base.iterations as f64);
    let t = (run.time_s / run.iterations as f64) / (base.time_s / base.iterations as f64);
    Savings {
        energy_saving: 1.0 - e,
        slowdown: t - 1.0,
        ed2p_saving: 1.0 - e * t * t,
    }
}

/// Work-unit budget for one app: enough iterations that the optimization
/// transient amortizes the way a real (hours-long) training run would,
/// without making the 71-app sweeps slow.
pub fn default_iters(app: &AppParams) -> u64 {
    let by_time = (420.0 / app.t_base).ceil() as u64;
    by_time.max(300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::find_app;

    #[test]
    fn default_policy_runs_to_completion() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let mut p = DefaultPolicy { ts: 0.025 };
        let r = run_sim(&spec, &app, &mut p, 50);
        assert!(r.iterations >= 50);
        assert!(r.energy_j > 0.0 && r.time_s > 0.0);
        let (sm, mem, _) = app.default_op(&spec);
        assert_eq!(r.final_sm_gear, sm);
        assert_eq!(r.final_mem_gear, mem);
    }

    #[test]
    fn savings_math() {
        let base = RunResult {
            app: "x".into(),
            policy: "a".into(),
            energy_j: 1000.0,
            time_s: 100.0,
            iterations: 10,
            final_sm_gear: 114,
            final_mem_gear: 4,
        };
        let run = RunResult {
            energy_j: 850.0,
            time_s: 104.0,
            ..base.clone()
        }; // same iteration count => plain ratios
        let s = savings(&base, &run);
        assert!((s.energy_saving - 0.15).abs() < 1e-12);
        assert!((s.slowdown - 0.04).abs() < 1e-12);
    }

    #[test]
    fn fixed_work_is_comparable_across_clocks() {
        // Same iteration count at different clocks => different time/energy.
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "SBM_GIN").unwrap();
        struct Fixed {
            ts: f64,
            gear: usize,
        }
        impl Policy for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn tick(&mut self, dev: &mut dyn Device) {
                dev.set_sm_gear(self.gear);
                dev.advance(self.ts);
            }
        }
        let mut hi = Fixed { ts: 0.05, gear: 114 };
        let mut lo = Fixed { ts: 0.05, gear: 60 };
        let rh = run_sim(&spec, &app, &mut hi, 40);
        let rl = run_sim(&spec, &app, &mut lo, 40);
        assert!(rl.time_s > rh.time_s);
        assert!(rl.energy_j < rh.energy_j, "downclock must save energy here");
    }

    #[test]
    fn aperiodic_fixed_work_scales_with_clock() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "TSVM").unwrap();
        assert!(app.aperiodic);
        struct Fixed {
            gear: usize,
        }
        impl Policy for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn tick(&mut self, dev: &mut dyn Device) {
                dev.set_sm_gear(self.gear);
                dev.advance(0.05);
            }
        }
        let rh = run_sim(&spec, &app, &mut Fixed { gear: 114 }, 60);
        let rl = run_sim(&spec, &app, &mut Fixed { gear: 40 }, 60);
        assert!(
            rl.time_s > rh.time_s * 1.1,
            "aperiodic work must slow down when downclocked ({} vs {})",
            rl.time_s,
            rh.time_s
        );
    }
}
