//! Policy abstraction and the measurement harness that scores a policy
//! against the NVIDIA-default baseline on a fixed amount of work.
//!
//! Policies are written against [`Device`] (DESIGN.md §4): the same
//! controller code drives the simulator today and would drive an
//! NVML-backed device unchanged.

use crate::coordinator::GpoeoStats;
use crate::device::{sim_device, Device};
use crate::sim::{AppParams, Spec};
use std::sync::Arc;

/// An online clock-management policy driven by sampling ticks. The policy
/// owns the cadence: `tick` must advance the device by its sampling
/// interval.
///
/// Policies are constructed by name through
/// [`crate::policy::PolicyRegistry`] — nothing outside `policy/` matches
/// on policy-name strings.
pub trait Policy {
    fn name(&self) -> &'static str;
    fn tick(&mut self, dev: &mut dyn Device);

    /// Drive the device toward `target_iters` total iterations, stopping
    /// early when device time reaches `budget_s` or after `max_ticks`
    /// ticks. Returns the number of ticks executed. The default is the
    /// plain tick loop every driver historically ran; policies whose
    /// tick is a pure `advance` (no per-tick decisions) override it with
    /// the device's segment fast-forward — with bit-identical results
    /// (DESIGN.md §13). `run_policy` and the fleet's session drive both
    /// route through this single method.
    fn drive(
        &mut self,
        dev: &mut dyn Device,
        target_iters: u64,
        budget_s: f64,
        max_ticks: u64,
    ) -> u64 {
        let mut n = 0;
        while n < max_ticks && dev.iterations() < target_iters && dev.time_s() < budget_s {
            self.tick(dev);
            n += 1;
        }
        n
    }

    /// The GPOEO optimization trace, when this policy is the GPOEO
    /// controller — the reporting hook the fleet and CLI use on boxed
    /// policies. Everything else reports `None`.
    fn gpoeo_stats(&self) -> Option<GpoeoStats> {
        None
    }

    /// Attach the telemetry plane (DESIGN.md §11). Fleet workers call
    /// this once per session, right after construction; policies that
    /// emit (gear switches, detection events, predict latencies) store
    /// the handle + session id, everything else ignores it. Telemetry
    /// is pure observation — attaching must never change a policy's
    /// decisions (the parallel==serial and parity gates run both ways).
    fn attach_telemetry(&mut self, _tel: Arc<crate::telemetry::Telemetry>, _session: u64) {}
}

/// The NVIDIA default scheduling strategy: no controller at all (the
/// device boots power-capped-boosted and stays there).
pub struct DefaultPolicy {
    pub ts: f64,
}

impl Policy for DefaultPolicy {
    fn name(&self) -> &'static str {
        // Matches the registry key, so `RunResult::policy` strings and
        // `--policy` values line up.
        "default"
    }
    fn tick(&mut self, dev: &mut dyn Device) {
        dev.advance(self.ts);
    }

    /// The default policy makes no per-tick decisions, so driving it is
    /// pure advancing — hand the whole span to the device's segment
    /// fast-forward. The tick count is recovered from elapsed device
    /// time; the half-tick margin on the tick bound keeps accumulated
    /// floating-point error from ever executing `max_ticks + 1` ticks.
    fn drive(
        &mut self,
        dev: &mut dyn Device,
        target_iters: u64,
        budget_s: f64,
        max_ticks: u64,
    ) -> u64 {
        let t0 = dev.time_s();
        let t_slice = t0 + (max_ticks as f64 - 0.5) * self.ts;
        dev.advance_until(target_iters, budget_s.min(t_slice), self.ts);
        ((dev.time_s() - t0) / self.ts).round() as u64
    }
}

/// Outcome of running one policy on one app for a fixed work amount.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub app: String,
    pub policy: String,
    pub energy_j: f64,
    pub time_s: f64,
    pub iterations: u64,
    pub final_sm_gear: usize,
    pub final_mem_gear: usize,
}

/// Virtual-time budget for driving `n_iters` work units (re-exported
/// from `sim`, where `SimGpu::run_iterations` shares it — the single
/// source of truth for every drive loop).
pub use crate::sim::run_budget_s;

/// Run `policy` on an already-attached device until `n_iters` iterations
/// (work units) finish, with a hard stop at the shared `run_budget_s`
/// cutoff (errant policies).
pub fn run_policy(dev: &mut dyn Device, policy: &mut dyn Policy, n_iters: u64) -> RunResult {
    let budget_s = run_budget_s(dev.time_s(), n_iters, dev.nominal_iter_s());
    policy.drive(dev, n_iters, budget_s, u64::MAX);
    RunResult {
        app: dev.workload().to_string(),
        policy: policy.name().to_string(),
        energy_j: dev.true_energy_j(),
        time_s: dev.time_s(),
        iterations: dev.iterations(),
        final_sm_gear: dev.sm_gear(),
        final_mem_gear: dev.mem_gear(),
    }
}

/// Run `policy` on `app` on a fresh simulated device — the standard
/// entry point for experiments and sweeps.
pub fn run_sim(
    spec: &Arc<Spec>,
    app: &AppParams,
    policy: &mut dyn Policy,
    n_iters: u64,
) -> RunResult {
    let mut dev = sim_device(spec, app);
    run_policy(&mut dev, policy, n_iters)
}

/// Savings of `run` relative to `base` (same app, same n_iters).
#[derive(Debug, Clone, Copy)]
pub struct Savings {
    pub energy_saving: f64,
    pub slowdown: f64,
    pub ed2p_saving: f64,
}

/// A run finished with zero completed iterations (budget-exhausted
/// before any work), so per-work-unit savings are undefined. Typed so
/// callers log-and-skip instead of letting NaN poison `BENCH_*.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZeroWorkError {
    pub base_iterations: u64,
    pub run_iterations: u64,
}

impl std::fmt::Display for ZeroWorkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "savings undefined on a zero-iteration run (base {} iters, run {} iters)",
            self.base_iterations, self.run_iterations
        )
    }
}

impl std::error::Error for ZeroWorkError {}

pub fn savings(base: &RunResult, run: &RunResult) -> Result<Savings, ZeroWorkError> {
    if base.iterations == 0 || run.iterations == 0 {
        return Err(ZeroWorkError {
            base_iterations: base.iterations,
            run_iterations: run.iterations,
        });
    }
    // Normalize per work unit: policies overshoot the iteration target by
    // different amounts (a probe window can span several iterations), so
    // raw totals would compare different amounts of work.
    let e = (run.energy_j / run.iterations as f64) / (base.energy_j / base.iterations as f64);
    let t = (run.time_s / run.iterations as f64) / (base.time_s / base.iterations as f64);
    Ok(Savings {
        energy_saving: 1.0 - e,
        slowdown: t - 1.0,
        ed2p_saving: 1.0 - e * t * t,
    })
}

/// Work-unit budget for one app: enough iterations that the optimization
/// transient amortizes the way a real (hours-long) training run would,
/// without making the 71-app sweeps slow.
pub fn default_iters(app: &AppParams) -> u64 {
    let by_time = (420.0 / app.t_base).ceil() as u64;
    by_time.max(300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::find_app;

    #[test]
    fn default_policy_runs_to_completion() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let mut p = DefaultPolicy { ts: 0.025 };
        let r = run_sim(&spec, &app, &mut p, 50);
        assert!(r.iterations >= 50);
        assert!(r.energy_j > 0.0 && r.time_s > 0.0);
        let (sm, mem, _) = app.default_op(&spec);
        assert_eq!(r.final_sm_gear, sm);
        assert_eq!(r.final_mem_gear, mem);
    }

    #[test]
    fn savings_math() {
        let base = RunResult {
            app: "x".into(),
            policy: "a".into(),
            energy_j: 1000.0,
            time_s: 100.0,
            iterations: 10,
            final_sm_gear: 114,
            final_mem_gear: 4,
        };
        let run = RunResult {
            energy_j: 850.0,
            time_s: 104.0,
            ..base.clone()
        }; // same iteration count => plain ratios
        let s = savings(&base, &run).unwrap();
        assert!((s.energy_saving - 0.15).abs() < 1e-12);
        assert!((s.slowdown - 0.04).abs() < 1e-12);
    }

    #[test]
    fn savings_rejects_zero_iteration_runs() {
        let base = RunResult {
            app: "x".into(),
            policy: "a".into(),
            energy_j: 1000.0,
            time_s: 100.0,
            iterations: 10,
            final_sm_gear: 114,
            final_mem_gear: 4,
        };
        let stalled = RunResult {
            iterations: 0,
            ..base.clone()
        };
        assert_eq!(
            savings(&base, &stalled),
            Err(ZeroWorkError {
                base_iterations: 10,
                run_iterations: 0
            })
        );
        assert!(savings(&stalled, &base).is_err());
        // The error formats without NaN leaking anywhere.
        let msg = savings(&base, &stalled).unwrap_err().to_string();
        assert!(msg.contains("zero-iteration"));
    }

    /// A device whose workload never progresses — the shape of an errant
    /// run that must be stopped by the `run_budget_s` cutoff rather than
    /// hanging the sweep (a healthy `SimGpu` always progresses, so the
    /// cutoff can only be exercised through a wrapper like this).
    struct StalledDevice(crate::sim::SimGpu);

    impl Device for StalledDevice {
        fn spec(&self) -> &Arc<Spec> {
            self.0.spec()
        }
        fn workload(&self) -> &str {
            self.0.workload()
        }
        fn nominal_iter_s(&self) -> f64 {
            self.0.nominal_iter_s()
        }
        fn set_sm_gear(&mut self, gear: usize) {
            self.0.set_sm_gear(gear);
        }
        fn set_mem_gear(&mut self, gear: usize) {
            self.0.set_mem_gear(gear);
        }
        fn set_default_clocks(&mut self) {
            self.0.set_default_clocks();
        }
        fn sm_gear(&self) -> usize {
            self.0.sm_gear()
        }
        fn mem_gear(&self) -> usize {
            self.0.mem_gear()
        }
        fn set_power_limit_w(&mut self, limit_w: f64) -> f64 {
            self.0.set_power_limit_w(limit_w)
        }
        fn power_limit_w(&self) -> f64 {
            Device::power_limit_w(&self.0)
        }
        fn sample(&mut self, dt: f64) -> crate::sim::Instant {
            self.0.sample(dt)
        }
        fn energy_j(&mut self) -> f64 {
            Device::energy_j(&mut self.0)
        }
        fn ips(&mut self) -> f64 {
            self.0.ips()
        }
        fn start_counter_session(&mut self) {
            self.0.start_counter_session();
        }
        fn stop_counter_session(&mut self) {
            self.0.stop_counter_session();
        }
        fn profiling_active(&self) -> bool {
            self.0.profiling_active()
        }
        fn read_counters(&mut self) -> Result<Vec<f64>, crate::sim::CounterSessionError> {
            self.0.read_counters()
        }
        fn advance(&mut self, dt: f64) {
            self.0.advance(dt);
        }
        fn iterations(&self) -> u64 {
            0 // never makes progress
        }
        fn time_s(&self) -> f64 {
            Device::time_s(&self.0)
        }
        fn true_energy_j(&self) -> f64 {
            Device::true_energy_j(&self.0)
        }
        fn true_period(&self) -> f64 {
            self.0.true_period()
        }
    }

    #[test]
    fn errant_runs_stop_at_the_shared_budget_cutoff() {
        // With a stalled workload the iteration target is unreachable:
        // run_policy must terminate at run_budget_s, not hang, and the
        // zero-iteration result must surface as a typed savings error.
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let mut dev = StalledDevice(sim_device(&spec, &app));
        let n_iters = 5;
        let budget = run_budget_s(0.0, n_iters, dev.nominal_iter_s());
        let mut p = DefaultPolicy { ts: 1.0 };
        let r = run_policy(&mut dev, &mut p, n_iters);
        assert_eq!(r.iterations, 0);
        assert!(r.time_s >= budget && r.time_s < budget + 1.1, "stopped at the cutoff");
        assert!(savings(&r, &r).is_err());
    }

    #[test]
    fn default_policy_fast_drive_matches_tick_loop() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_FE").unwrap();
        let mut a = sim_device(&spec, &app);
        let mut b = sim_device(&spec, &app);
        let mut pa = DefaultPolicy { ts: 0.025 };
        let mut pb = DefaultPolicy { ts: 0.025 };
        let budget = run_budget_s(0.0, 40, app.t_base);

        // Override vs the documented default tick-loop semantics.
        let na = pa.drive(&mut a, 40, budget, 1000);
        let mut nb = 0u64;
        while nb < 1000 && b.iterations() < 40 && Device::time_s(&b) < budget {
            pb.tick(&mut b);
            nb += 1;
        }
        assert_eq!(na, nb);
        assert_eq!(a.true_energy_j(), b.true_energy_j());
        assert_eq!(a.iterations(), b.iterations());
        assert_eq!(a.time_s(), b.time_s());

        // A tick-bounded slice executes exactly max_ticks ticks.
        let t0 = a.time_s();
        let n = pa.drive(&mut a, u64::MAX, f64::INFINITY, 137);
        assert_eq!(n, 137);
        assert_eq!(((a.time_s() - t0) / 0.025).round() as u64, 137);
    }

    #[test]
    fn fixed_work_is_comparable_across_clocks() {
        // Same iteration count at different clocks => different time/energy.
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "SBM_GIN").unwrap();
        struct Fixed {
            ts: f64,
            gear: usize,
        }
        impl Policy for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn tick(&mut self, dev: &mut dyn Device) {
                dev.set_sm_gear(self.gear);
                dev.advance(self.ts);
            }
        }
        let mut hi = Fixed { ts: 0.05, gear: 114 };
        let mut lo = Fixed { ts: 0.05, gear: 60 };
        let rh = run_sim(&spec, &app, &mut hi, 40);
        let rl = run_sim(&spec, &app, &mut lo, 40);
        assert!(rl.time_s > rh.time_s);
        assert!(rl.energy_j < rh.energy_j, "downclock must save energy here");
    }

    #[test]
    fn aperiodic_fixed_work_scales_with_clock() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "TSVM").unwrap();
        assert!(app.aperiodic);
        struct Fixed {
            gear: usize,
        }
        impl Policy for Fixed {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn tick(&mut self, dev: &mut dyn Device) {
                dev.set_sm_gear(self.gear);
                dev.advance(0.05);
            }
        }
        let rh = run_sim(&spec, &app, &mut Fixed { gear: 114 }, 60);
        let rl = run_sim(&spec, &app, &mut Fixed { gear: 40 }, 60);
        assert!(
            rl.time_s > rh.time_s * 1.1,
            "aperiodic work must slow down when downclocked ({} vs {})",
            rl.time_s,
            rh.time_s
        );
    }
}
