//! The device abstraction layer (DESIGN.md §4).
//!
//! The paper's deployment model (§2.2.2) is a standalone optimizer
//! process that owns the GPU clocks; training scripts only call
//! Begin/End. The controller therefore never cares *what* it is driving
//! — it needs exactly the NVML/CUPTI surface: set clock gears, sample
//! power/utilization, open a performance-counter session, read the
//! accumulated energy meter. [`Device`] captures that surface so the
//! whole coordinator stack ([`crate::coordinator::Policy`],
//! [`crate::coordinator::run_policy`], the GPOEO and ODPP controllers,
//! the daemon and the fleet engine) is written against `&mut dyn Device`.
//!
//! Implementations:
//! - [`crate::sim::SimGpu`] — the calibrated discrete-event simulator
//!   (the only backend in this repo; see DESIGN.md §1 for why).
//! - A future `NvmlDevice` would map `set_sm_gear` to
//!   `nvmlDeviceSetGpuLockedClocks`, `sample` to the NVML power/util
//!   queries, the counter session to CUPTI, and `advance(dt)` to a real
//!   `sleep(dt)` — the controller owns the sampling cadence either way.

mod sim;

use crate::sim::{AppParams, CounterSessionError, Instant, SimGpu, Spec};
use std::sync::Arc;

/// The clock/telemetry surface the controller drives.
///
/// Time is device-owned: `advance(dt)` moves the device forward by `dt`
/// seconds (virtual time on the simulator, wall time on real hardware).
/// All telemetry (`sample`, `energy_j`, `ips`, `read_counters`) is what
/// the controller is allowed to see — noisy, meter-grade readings. The
/// `true_*` methods are noise-free ground truth for experiment
/// bookkeeping only; a policy must never base decisions on them.
pub trait Device {
    /// The hardware spec (gear tables, power model, noise model).
    fn spec(&self) -> &Arc<Spec>;

    /// Name of the workload currently occupying the device.
    fn workload(&self) -> &str;

    /// Expected iteration period at the reference clocks, seconds — used
    /// only to size virtual-time budgets, never for control decisions.
    fn nominal_iter_s(&self) -> f64;

    // ------------------------------------------------------- NVML-like --

    /// Set the SM clock gear (clamped to the valid range).
    fn set_sm_gear(&mut self, gear: usize);

    /// Set the memory clock gear (clamped to the valid range).
    fn set_mem_gear(&mut self, gear: usize);

    /// Reset to the NVIDIA default scheduling configuration.
    fn set_default_clocks(&mut self);

    fn sm_gear(&self) -> usize;

    fn mem_gear(&self) -> usize;

    /// Set the board power limit in watts (`f64::INFINITY` = uncapped) —
    /// mirrors `nvmlDeviceSetPowerManagementLimit`. Finite requests are
    /// clamped to the device's supported cap range and the *applied*
    /// value is returned (callers that report or journal the cap must
    /// use the return value, not the request). The device throttles
    /// its *effective* SM clock down to the highest gear at or below the
    /// requested one whose steady power fits under the limit; the
    /// requested gear (`sm_gear()`) is preserved and restored when the
    /// limit is lifted.
    fn set_power_limit_w(&mut self, limit_w: f64) -> f64;

    /// Current board power limit (`f64::INFINITY` when uncapped).
    fn power_limit_w(&self) -> f64;

    /// Instantaneous (power, SM util, mem util) with measurement noise —
    /// the sampling channel used for period detection.
    fn sample(&mut self, dt_since_last: f64) -> Instant;

    /// Accumulated energy counter (joules), with meter noise — mirrors
    /// `nvmlDeviceGetTotalEnergyConsumption`.
    fn energy_j(&mut self) -> f64;

    /// Instructions-per-second proxy (aperiodic path, §4.3.5).
    fn ips(&mut self) -> f64;

    // ------------------------------------------------------ CUPTI-like --

    /// Begin a performance-counter session. While active, the workload
    /// pays the profiling tax (slower iterations, higher power).
    fn start_counter_session(&mut self);

    fn stop_counter_session(&mut self);

    fn profiling_active(&self) -> bool;

    /// Collect the Table-2 feature vector measured over the session
    /// window. Errors without an active session.
    fn read_counters(&mut self) -> Result<Vec<f64>, CounterSessionError>;

    // ---------------------------------------------------------- clock --

    /// Move the device forward by `dt` seconds.
    fn advance(&mut self, dt: f64);

    /// Fast-forward in `tick` increments until `target_iters` total
    /// iterations complete or device time reaches `t_limit_s`, whichever
    /// comes first. Contract (DESIGN.md §13): semantically exactly
    /// `while iterations() < target && time_s() < limit { advance(tick) }`
    /// — same tick quantization, same overshoot — and implementations
    /// must produce results bit-identical to that loop. The default does
    /// literally that; the simulator overrides it with the segment
    /// fast-forward.
    fn advance_until(&mut self, target_iters: u64, t_limit_s: f64, tick: f64) {
        if !(tick > 0.0) {
            return; // zero/negative/NaN tick would never terminate
        }
        while self.iterations() < target_iters && self.time_s() < t_limit_s {
            self.advance(tick);
        }
    }

    /// Completed workload iterations since attach.
    fn iterations(&self) -> u64;

    /// Seconds since attach.
    fn time_s(&self) -> f64;

    // --------------------------------------- experiment bookkeeping --

    /// Noise-free total energy (joules). Policies must use `energy_j()`.
    fn true_energy_j(&self) -> f64;

    /// Ground-truth current iteration period (seconds), including the
    /// profiling dilation if a counter session is active.
    fn true_period(&self) -> f64;
}

/// A simulated device running `app`, booted at the NVIDIA default
/// configuration — the standard way every harness obtains a device.
pub fn sim_device(spec: &Arc<Spec>, app: &AppParams) -> SimGpu {
    SimGpu::new(spec.clone(), app.clone())
}

/// [`sim_device`], boxed as a trait object (for owners that must not
/// name the concrete simulator type, e.g. fleet sessions).
pub fn boxed_sim_device(spec: &Arc<Spec>, app: &AppParams) -> Box<dyn Device> {
    Box::new(sim_device(spec, app))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::find_app;

    #[test]
    fn sim_device_honors_the_trait_surface() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let mut dev = boxed_sim_device(&spec, &app);
        assert_eq!(dev.workload(), "AI_TS");
        assert!(dev.nominal_iter_s() > 0.0);
        assert_eq!(dev.iterations(), 0);

        // Drive it blind through the trait: clocks, time, energy, counters.
        dev.set_sm_gear(60);
        assert_eq!(dev.sm_gear(), 60);
        dev.advance(1.0);
        assert!(dev.time_s() >= 1.0);
        assert!(dev.true_energy_j() > 0.0);
        let s = dev.sample(0.025);
        assert!(s.power_w > 0.0);

        // Power-limit surface: capping throttles (and reports what was
        // actually applied after range clamping), lifting restores.
        assert_eq!(dev.power_limit_w(), f64::INFINITY);
        let applied = dev.set_power_limit_w(180.0);
        assert!(applied.is_finite() && applied > 0.0);
        assert_eq!(dev.power_limit_w(), applied);
        assert_eq!(dev.set_power_limit_w(f64::INFINITY), f64::INFINITY);
        assert_eq!(dev.power_limit_w(), f64::INFINITY);

        assert!(!dev.profiling_active());
        dev.start_counter_session();
        assert!(dev.profiling_active());
        let feats = dev.read_counters().unwrap();
        assert!(!feats.is_empty());
        dev.stop_counter_session();

        dev.set_default_clocks();
        let (sm, mem, _) = app.default_op(dev.spec());
        assert_eq!(dev.sm_gear(), sm);
        assert_eq!(dev.mem_gear(), mem);
    }

    #[test]
    fn trait_and_inherent_views_agree() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "SBM_GIN").unwrap();
        let mut a = sim_device(&spec, &app);
        let mut b = boxed_sim_device(&spec, &app);
        for _ in 0..200 {
            a.advance(0.05);
            b.advance(0.05);
        }
        assert_eq!(a.true_energy_j(), b.true_energy_j());
        assert_eq!(a.iterations(), b.iterations());
        assert_eq!(a.true_period(), b.true_period());

        // The trait's default advance_until (stepped loop) and the
        // simulator's fast-forward override must agree bit-for-bit.
        let target = a.iterations() + 25;
        a.advance_until(target, 1e9, 0.05); // SimGpu override
        while b.iterations() < target && b.time_s() < 1e9 {
            b.advance(0.05); // the documented default-loop semantics
        }
        assert_eq!(a.true_energy_j(), b.true_energy_j());
        assert_eq!(a.iterations(), b.iterations());
        assert_eq!(a.time_s(), b.time_s());
    }
}
