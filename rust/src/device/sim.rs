//! [`Device`] implementation for the simulated GPU.
//!
//! Pure delegation to [`SimGpu`]'s inherent methods — the simulator was
//! built to mirror the NVML/CUPTI surface (see `sim/gpu.rs`), so the
//! trait impl adds no behavior, only the seam that lets everything above
//! it stay backend-agnostic.

use super::Device;
use crate::sim::{CounterSessionError, Instant, SimGpu, Spec};
use std::sync::Arc;

impl Device for SimGpu {
    fn spec(&self) -> &Arc<Spec> {
        &self.spec
    }

    fn workload(&self) -> &str {
        &self.app.name
    }

    fn nominal_iter_s(&self) -> f64 {
        self.app.t_base
    }

    fn set_sm_gear(&mut self, gear: usize) {
        SimGpu::set_sm_gear(self, gear);
    }

    fn set_mem_gear(&mut self, gear: usize) {
        SimGpu::set_mem_gear(self, gear);
    }

    fn set_default_clocks(&mut self) {
        SimGpu::set_default_clocks(self);
    }

    fn sm_gear(&self) -> usize {
        SimGpu::sm_gear(self)
    }

    fn mem_gear(&self) -> usize {
        SimGpu::mem_gear(self)
    }

    fn set_power_limit_w(&mut self, limit_w: f64) -> f64 {
        SimGpu::set_power_limit_w(self, limit_w)
    }

    fn power_limit_w(&self) -> f64 {
        SimGpu::power_limit_w(self)
    }

    fn sample(&mut self, dt_since_last: f64) -> Instant {
        SimGpu::sample(self, dt_since_last)
    }

    fn energy_j(&mut self) -> f64 {
        SimGpu::energy_j(self)
    }

    fn ips(&mut self) -> f64 {
        SimGpu::ips(self)
    }

    fn start_counter_session(&mut self) {
        SimGpu::start_counter_session(self);
    }

    fn stop_counter_session(&mut self) {
        SimGpu::stop_counter_session(self);
    }

    fn profiling_active(&self) -> bool {
        SimGpu::profiling_active(self)
    }

    fn read_counters(&mut self) -> Result<Vec<f64>, CounterSessionError> {
        SimGpu::read_counters(self)
    }

    fn advance(&mut self, dt: f64) {
        SimGpu::advance(self, dt);
    }

    fn advance_until(&mut self, target_iters: u64, t_limit_s: f64, tick: f64) {
        SimGpu::advance_until(self, target_iters, t_limit_s, tick);
    }

    fn iterations(&self) -> u64 {
        SimGpu::iterations(self)
    }

    fn time_s(&self) -> f64 {
        SimGpu::time_s(self)
    }

    fn true_energy_j(&self) -> f64 {
        SimGpu::true_energy_j(self)
    }

    fn true_period(&self) -> f64 {
        SimGpu::true_period(self)
    }
}
