//! Ablation study over the controller's design choices (DESIGN.md §5):
//! what each GPOEO ingredient buys. Variants, all under the paper's
//! capped objective, on the AIBench suite:
//!
//! - **full**        the complete pipeline (predict + search, SM + mem)
//! - **no-search**   apply the predicted gears directly (§4.3.4 ablated)
//! - **no-model**    golden-section search from the default gears
//!                   (counter-based prediction ablated — §2.2.4's claim)
//! - **sm-only**     memory-clock stage disabled
//! - **mem-only**    SM-clock stage disabled

use crate::coordinator::{default_iters, run_sim, savings, DefaultPolicy, Gpoeo, GpoeoCfg};
use crate::model::Predictor;
use crate::sim::{make_suite, Spec};
use crate::util::stats::mean;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

fn variant(name: &str) -> GpoeoCfg {
    let mut cfg = GpoeoCfg::default();
    match name {
        "full" => {}
        "no-search" => cfg.skip_search = true,
        "no-model" => cfg.ignore_prediction = true,
        "sm-only" => cfg.optimize_mem = false,
        "mem-only" => cfg.optimize_sm = false,
        _ => unreachable!(),
    }
    cfg
}

pub const VARIANTS: &[&str] = &["full", "no-search", "no-model", "sm-only", "mem-only"];

pub fn run(spec: &Arc<Spec>, predictor: &Arc<Predictor>) -> (Table, Vec<(String, f64, f64, f64)>) {
    let apps = make_suite(spec, "aibench").unwrap();
    let mut t = Table::new(
        "Ablation — contribution of each GPOEO ingredient (AIBench means)",
        &["variant", "energy saving", "slowdown", "ED2P saving", "search steps"],
    );
    let mut rows = Vec::new();
    for v in VARIANTS {
        let (mut sv, mut sl, mut ed, mut steps) = (vec![], vec![], vec![], vec![]);
        for app in &apps {
            let n = default_iters(app) / 2;
            let base = run_sim(spec, app, &mut DefaultPolicy { ts: 0.025 }, n);
            let mut g = Gpoeo::new(variant(v), predictor.clone());
            let r = run_sim(spec, app, &mut g, n);
            let s = savings(&base, &r).expect("ablation run completed zero iterations");
            sv.push(s.energy_saving);
            sl.push(s.slowdown);
            ed.push(s.ed2p_saving);
            steps.push((g.stats.search_steps_sm + g.stats.search_steps_mem) as f64);
        }
        t.rowf(&[
            s(*v),
            Cell::Pct(mean(&sv)),
            Cell::Pct(mean(&sl)),
            Cell::Pct(mean(&ed)),
            Cell::F(mean(&steps), 1),
        ]);
        rows.push((v.to_string(), mean(&sv), mean(&sl), mean(&ed)));
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NativeModels;

    #[test]
    fn search_and_model_both_matter() {
        let Ok(native) = NativeModels::load_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let spec = Arc::new(Spec::load_default().unwrap());
        let predictor = Arc::new(Predictor::Native(native));
        let (_, rows) = run(&spec, &predictor);
        let get = |name: &str| rows.iter().find(|r| r.0 == name).unwrap().clone();
        let full = get("full");
        let sm_only = get("sm-only");
        let mem_only = get("mem-only");
        // The SM stage carries most of the energy; the full pipeline must
        // beat either single stage on ED2P-or-energy.
        assert!(full.1 > mem_only.1, "full beats mem-only on energy");
        assert!(full.1 >= sm_only.1 - 0.02, "mem stage must not hurt");
        assert!(sm_only.1 > mem_only.1, "SM stage dominates savings");
    }
}
