//! `gpoeo experiment api-bench` — control-plane scale benchmark for the
//! reactor daemon (DESIGN.md §10).
//!
//! Spins an in-process daemon (AIMD-scaled fleet) on a temp socket per
//! tier, then measures what the event loop actually delivers:
//!
//! - **connections/sec** — serial `connect` + `hello` handshakes;
//! - **session churn/sec** — `begin` → `status` → `end` cycles driven
//!   by concurrent [`GpoeoClient`]s across many connections;
//! - **p50/p99 request latency** — per-request wall clock over every
//!   typed request in the churn phase.
//!
//! Each tier runs **twice**: once with the telemetry plane attached
//! (the primary numbers) and once with it detached
//! ([`Telemetry::disabled`](crate::telemetry::Telemetry::disabled) —
//! the control arm). The detached p99 is recorded alongside, so the
//! bench file prices what observability costs the hot path; CI gates
//! the regression with `--max-overhead-pct`.
//!
//! Default tiers are 100, 1000 and 10000 sessions (`--quick` runs only
//! 100; `--sessions N` pins a single tier). Every tier is appended to
//! `BENCH_api.json` whether it passed or not — a failed 10k attempt is
//! a recorded data point, not a silent hole. CI gates the quick tier
//! with `--min-churn` / `--max-p99-ms` (see `cli_experiment`).

use crate::api::GpoeoClient;
use crate::coordinator::daemon::{Daemon, DaemonCfg};
use crate::coordinator::PolicySpec;
use crate::sim::Spec;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::{s, Cell, Table};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Fleet band for the bench daemon: start small, let AIMD grow.
const BENCH_WORKERS: usize = 2;
const BENCH_MAX_WORKERS: usize = 8;

/// Concurrent client connections driving the churn phase.
const CHURN_THREADS: usize = 32;

/// Serial connect+hello probes for the connections/sec figure.
const CONN_PROBES: usize = 100;

/// Workload per session: tiny on purpose — the bench measures the
/// control plane, not the simulator (`status` drives the session to
/// completion in one slice, so `end` returns immediately).
const BENCH_APP: &str = "AI_TS";
const BENCH_ITERS: u64 = 6;

/// One tier's outcome. `ok: false` tiers carry the first error instead
/// of aborting the whole bench — a failed 10k attempt is still data.
pub struct ApiBenchTier {
    pub sessions: usize,
    pub threads: usize,
    pub ok: bool,
    pub error: String,
    pub conns_per_s: f64,
    pub churn_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// p99 of the control arm (telemetry plane detached) — the baseline
    /// the `--max-overhead-pct` gate compares [`Self::p99_ms`] against.
    pub p99_detached_ms: f64,
    pub workers_start: usize,
    pub workers_end: usize,
    pub wall_s: f64,
}

impl ApiBenchTier {
    fn zeroed(sessions: usize, threads: usize) -> ApiBenchTier {
        ApiBenchTier {
            sessions,
            threads,
            ok: false,
            error: String::new(),
            conns_per_s: 0.0,
            churn_per_s: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            p99_detached_ms: 0.0,
            workers_start: 0,
            workers_end: 0,
            wall_s: 0.0,
        }
    }
}

pub struct ApiBench {
    pub table: Table,
    pub tiers: Vec<ApiBenchTier>,
}

impl ApiBench {
    pub fn print_summary(&self) {
        for t in &self.tiers {
            if t.ok {
                println!(
                    "api-bench {:>6} sessions: {:.0} conns/s  {:.0} churn/s  p50 {:.2}ms  p99 {:.2}ms (detached {:.2}ms)  workers {}->{}  ({:.2}s)",
                    t.sessions,
                    t.conns_per_s,
                    t.churn_per_s,
                    t.p50_ms,
                    t.p99_ms,
                    t.p99_detached_ms,
                    t.workers_start,
                    t.workers_end,
                    t.wall_s
                );
            } else {
                println!("api-bench {:>6} sessions: FAILED: {}", t.sessions, t.error);
            }
        }
    }
}

pub fn run(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<ApiBench> {
    let pinned = args.opt_usize("sessions", 0)?;
    let tiers: Vec<usize> = if pinned > 0 {
        vec![pinned]
    } else if quick {
        vec![100]
    } else {
        vec![100, 1000, 10000]
    };

    let dir = std::env::temp_dir().join(format!("gpoeo-apibench-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    let mut table = Table::new(
        "api-bench — reactor control-plane throughput",
        &[
            "sessions", "conns", "conn/s", "churn/s", "p50 ms", "p99 ms", "p99 det", "workers",
            "wall s", "ok",
        ],
    );
    let mut out = Vec::new();
    for sessions in tiers {
        let tier = run_tier(spec, &dir, sessions);
        table.rowf(&[
            Cell::U(tier.sessions),
            Cell::U(tier.threads),
            Cell::F(tier.conns_per_s, 0),
            Cell::F(tier.churn_per_s, 0),
            Cell::F(tier.p50_ms, 2),
            Cell::F(tier.p99_ms, 2),
            Cell::F(tier.p99_detached_ms, 2),
            s(format!("{}->{}", tier.workers_start, tier.workers_end)),
            Cell::F(tier.wall_s, 2),
            s(if tier.ok { "yes" } else { "FAIL" }),
        ]);
        out.push(tier);
    }
    Ok(ApiBench { table, tiers: out })
}

/// One tier: the attached pass (primary numbers), then the detached
/// control pass whose p99 prices the telemetry plane.
fn run_tier(spec: &Arc<Spec>, dir: &Path, sessions: usize) -> ApiBenchTier {
    let threads = sessions.min(CHURN_THREADS).max(1);
    let mut tier = ApiBenchTier::zeroed(sessions, threads);
    let r = bench_tier(spec, dir, sessions, threads, true, &mut tier).and_then(|()| {
        // Control arm: same churn against a daemon whose telemetry
        // plane is [`Telemetry::disabled`]. Only its p99 is kept.
        let mut detached = ApiBenchTier::zeroed(sessions, threads);
        bench_tier(spec, dir, sessions, threads, false, &mut detached)?;
        tier.p99_detached_ms = detached.p99_ms;
        Ok(())
    });
    match r {
        Ok(()) => tier.ok = true,
        Err(e) => tier.error = format!("{e:#}"),
    }
    tier
}

fn bench_tier(
    spec: &Arc<Spec>,
    dir: &Path,
    sessions: usize,
    threads: usize,
    telemetry: bool,
    tier: &mut ApiBenchTier,
) -> anyhow::Result<()> {
    let arm = if telemetry { "attached" } else { "detached" };
    let sock = dir.join(format!("bench-{sessions}-{arm}.sock"));
    let daemon = Arc::new(Daemon::with_cfg(
        spec.clone(),
        BENCH_WORKERS,
        DaemonCfg {
            max_workers: BENCH_MAX_WORKERS,
            rate_limit_rps: 0.0,
            rate_burst: 0.0,
            journal_dir: None,
            telemetry,
        },
    ));
    let serve = {
        let daemon = daemon.clone();
        let sock = sock.clone();
        std::thread::spawn(move || daemon.serve(&sock))
    };
    wait_for_socket(&sock)?;
    tier.workers_start = daemon.num_workers();

    // Phase 1: serial connect+hello throughput.
    let t0 = Instant::now();
    for _ in 0..CONN_PROBES {
        GpoeoClient::connect(&sock)?;
    }
    tier.conns_per_s = CONN_PROBES as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // Phase 2: concurrent session churn with per-request latencies.
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(sessions * 3));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t1 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads {
            let my_sessions = sessions / threads + usize::from(t < sessions % threads);
            let (sock, latencies, errors) = (&sock, &latencies, &errors);
            scope.spawn(move || {
                let mut local = Vec::with_capacity(my_sessions * 3);
                let r = churn(sock, my_sessions, &mut local);
                latencies.lock().expect("latency lock").extend(local);
                if let Err(e) = r {
                    errors.lock().expect("error lock").push(format!("{e:#}"));
                }
            });
        }
    });
    tier.wall_s = t1.elapsed().as_secs_f64();
    tier.workers_end = daemon.num_workers();

    let lat = latencies.into_inner().expect("latency lock");
    let completed = lat.len() / 3;
    tier.churn_per_s = completed as f64 / tier.wall_s.max(1e-9);
    tier.p50_ms = percentile(&lat, 50.0);
    tier.p99_ms = percentile(&lat, 99.0);

    // Tear the daemon down (best-effort) before reporting churn errors.
    let down = GpoeoClient::connect(&sock).and_then(|mut c| c.shutdown());
    let served = serve.join();
    if let Some(e) = errors.into_inner().expect("error lock").into_iter().next() {
        anyhow::bail!("{}/{} sessions completed; first error: {e}", completed, sessions);
    }
    down?;
    match served {
        Ok(r) => r?,
        Err(_) => anyhow::bail!("daemon serve thread panicked"),
    }
    anyhow::ensure!(
        completed == sessions,
        "only {completed}/{sessions} sessions completed"
    );
    Ok(())
}

/// One churn worker: short-lived sessions over one connection, every
/// request timed individually.
fn churn(sock: &Path, n: usize, lat_ms: &mut Vec<f64>) -> anyhow::Result<()> {
    let mut c = GpoeoClient::connect(sock)?;
    for _ in 0..n {
        let q = Instant::now();
        let sid = c.begin(
            BENCH_APP,
            Some(BENCH_ITERS),
            None,
            Some(PolicySpec::registered("powercap")),
        )?;
        lat_ms.push(q.elapsed().as_secs_f64() * 1e3);
        let q = Instant::now();
        c.status(&sid)?;
        lat_ms.push(q.elapsed().as_secs_f64() * 1e3);
        let q = Instant::now();
        c.end(&sid)?;
        lat_ms.push(q.elapsed().as_secs_f64() * 1e3);
    }
    Ok(())
}

fn wait_for_socket(sock: &PathBuf) -> anyhow::Result<()> {
    for _ in 0..200 {
        if sock.exists() {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    anyhow::bail!("daemon socket {} never appeared", sock.display())
}

/// Append every tier to the bench file (`runs` array, one record per
/// tier per invocation — the cross-run trajectory, same shape idiom as
/// `BENCH_sweep.json` / `BENCH_detect.json`).
pub fn append_bench(path: &str, r: &ApiBench, quick: bool) -> anyhow::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let mut runs = Json::bench_runs(path);
    for t in &r.tiers {
        runs.push(Json::obj(vec![
            ("sessions", Json::Num(t.sessions as f64)),
            ("threads", Json::Num(t.threads as f64)),
            ("ok", Json::Bool(t.ok)),
            ("error", Json::Str(t.error.clone())),
            ("conns_per_s", Json::Num(t.conns_per_s)),
            ("churn_per_s", Json::Num(t.churn_per_s)),
            ("p50_ms", Json::Num(t.p50_ms)),
            ("p99_ms", Json::Num(t.p99_ms)),
            ("p99_detached_ms", Json::Num(t.p99_detached_ms)),
            ("workers_start", Json::Num(t.workers_start as f64)),
            ("workers_end", Json::Num(t.workers_end as f64)),
            ("wall_clock_s", Json::Num(t.wall_s)),
            ("quick", Json::Bool(quick)),
            ("unix_time_s", Json::Num(unix_s)),
        ]));
    }
    let doc = Json::obj(vec![("runs", Json::Arr(runs))]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}
