//! `gpoeo experiment arbiter-bench` — fleet power-budget arbiter
//! benchmark (DESIGN.md §14).
//!
//! Two arms, same workload mix (periodic trainers plus an aperiodic
//! donor every third slot), same virtual-time horizon per session:
//!
//! - **coordinated** — one in-process daemon whose default policy is
//!   the `arbiter` family. All sessions enroll under a single global
//!   power budget that *shrinks twice* mid-run (re-issued over the wire
//!   via `set_policy`), forcing the water-filling allocator to squeeze
//!   donors to the floor so latency-critical sessions keep headroom.
//!   Journals are enabled: the budget invariant is checked afterwards
//!   by replaying every session's `cap_change` events and summing each
//!   epoch's full cap snapshot against the budget in force.
//! - **uncoordinated** — the same sessions under per-session `powercap`
//!   ladders: each one optimizes alone, nobody observes the fleet, no
//!   global budget exists.
//!
//! Both arms drive each session for `rounds × STATUS_TICKS` controller
//! ticks (equal virtual seconds), so total energy is comparable at
//! fixed duration and "slowdown" is the per-slot ratio of uncoordinated
//! to coordinated iterations completed. CI gates on zero cap-budget
//! violations and coordinated total energy strictly below uncoordinated
//! (see `cli_experiment`); every run is appended to `BENCH_arbiter.json`
//! either way.
//!
//! Budgets are derived from the simulated boards' own
//! `power_limit_range_w` so the floors always remain satisfiable: caps
//! the arbiter requests never clamp *upwards* at the device, which
//! would otherwise let applied power exceed a too-tight budget.

use crate::api::GpoeoClient;
use crate::coordinator::daemon::{Daemon, DaemonCfg};
use crate::coordinator::PolicySpec;
use crate::device::sim_device;
use crate::policy::PolicyConfig;
use crate::sim::{find_app, Spec};
use crate::telemetry::{read_journal, TelemetryEvent};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table::{s, Cell, Table};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Session app mix: two periodic trainers, then an aperiodic donor.
const BENCH_APPS: [&str; 3] = ["AI_TS", "AI_I2T", "TSVM"];

/// A target no session reaches inside the bench horizon — sessions are
/// duration-bounded (aborted after the last round), not work-bounded.
const ENDLESS_ITERS: u64 = 1_000_000_000;

/// Arbiter re-allocation period (wall seconds). Short on purpose: the
/// bench drives virtual time much faster than the wall clock.
const ARB_PERIOD_S: f64 = 0.05;

/// Hysteresis band for the bench arbiter (watts).
const ARB_HYST_W: f64 = 5.0;

/// One arm's raw outcome.
struct ArmOut {
    energy_j: f64,
    iters: Vec<u64>,
    reallocations: u64,
}

pub struct ArbiterBench {
    pub table: Table,
    pub sessions: usize,
    pub rounds: usize,
    pub coord_energy_j: f64,
    pub uncoord_energy_j: f64,
    /// coordinated / uncoordinated total energy (< 1 is a win).
    pub energy_ratio: f64,
    pub slowdown_p50: f64,
    pub slowdown_p99: f64,
    /// Epochs whose cap snapshot summed over the budget in force.
    pub cap_violations: u64,
    /// Distinct re-allocation epochs replayed from the journals.
    pub epochs: u64,
    /// `gpoeo_arbiter_reallocations_total` scraped from the daemon.
    pub reallocations: u64,
    pub budget_start_w: f64,
    pub budget_final_w: f64,
    pub wall_s: f64,
}

impl ArbiterBench {
    pub fn print_summary(&self) {
        println!(
            "arbiter-bench {} sessions x {} rounds: energy {:.0} J coordinated vs {:.0} J uncoordinated (ratio {:.3})  slowdown p50 {:.2} p99 {:.2}  {} epochs  {} reallocations  {} violations  budget {:.0}->{:.0} W  ({:.2}s)",
            self.sessions,
            self.rounds,
            self.coord_energy_j,
            self.uncoord_energy_j,
            self.energy_ratio,
            self.slowdown_p50,
            self.slowdown_p99,
            self.epochs,
            self.reallocations,
            self.cap_violations,
            self.budget_start_w,
            self.budget_final_w,
            self.wall_s
        );
    }
}

pub fn run(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<ArbiterBench> {
    let sessions = {
        let n = args.opt_usize("sessions", 0)?;
        if n > 0 {
            n
        } else if quick {
            8
        } else {
            12
        }
    };
    anyhow::ensure!(sessions >= 2, "arbiter-bench needs at least 2 sessions");
    let rounds = if quick { 18 } else { 30 };

    let dir = std::env::temp_dir().join(format!("gpoeo-arbiterbench-{}", std::process::id()));
    let jdir = dir.join("journal");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // Budgets from the boards' own cap ranges: the floor sits just above
    // the highest per-board minimum so requested caps never clamp up.
    let mut lo_max = 0.0f64;
    let mut hi_max = 0.0f64;
    for i in 0..sessions {
        let app = find_app(spec, BENCH_APPS[i % BENCH_APPS.len()])?;
        let (lo, hi) = sim_device(spec, &app).power_limit_range_w();
        lo_max = lo_max.max(lo);
        hi_max = hi_max.max(hi);
    }
    let min_cap = lo_max + 1.0;
    let max_cap = hi_max.max(min_cap);
    let span = (max_cap - min_cap).max(0.0);
    let nf = sessions as f64;
    let budgets = [
        nf * (min_cap + 0.40 * span),
        nf * (min_cap + 0.20 * span),
        nf * (min_cap * 1.08),
    ];

    let t0 = Instant::now();
    let coord = run_arm(spec, &dir, sessions, rounds, &budgets, min_cap, max_cap, Some(&jdir))?;
    let uncoord = run_arm(spec, &dir, sessions, rounds, &budgets, min_cap, max_cap, None)?;
    let wall_s = t0.elapsed().as_secs_f64();

    let (cap_violations, epochs) = replay_cap_epochs(&jdir)?;

    let slowdowns: Vec<f64> = coord
        .iters
        .iter()
        .zip(&uncoord.iters)
        .map(|(c, u)| *u as f64 / (*c).max(1) as f64)
        .collect();

    let energy_ratio = coord.energy_j / uncoord.energy_j.max(1e-9);
    let mut table = Table::new(
        "arbiter-bench — fleet budget arbiter vs uncoordinated powercap",
        &[
            "arm", "sessions", "energy J", "iters", "realloc", "epochs", "violations",
        ],
    );
    table.rowf(&[
        s("coordinated"),
        Cell::U(sessions),
        Cell::F(coord.energy_j, 0),
        Cell::U(coord.iters.iter().sum::<u64>() as usize),
        Cell::U(coord.reallocations as usize),
        Cell::U(epochs as usize),
        Cell::U(cap_violations as usize),
    ]);
    table.rowf(&[
        s("uncoordinated"),
        Cell::U(sessions),
        Cell::F(uncoord.energy_j, 0),
        Cell::U(uncoord.iters.iter().sum::<u64>() as usize),
        Cell::U(0),
        Cell::U(0),
        Cell::U(0),
    ]);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(ArbiterBench {
        table,
        sessions,
        rounds,
        coord_energy_j: coord.energy_j,
        uncoord_energy_j: uncoord.energy_j,
        energy_ratio,
        slowdown_p50: percentile(&slowdowns, 50.0),
        slowdown_p99: percentile(&slowdowns, 99.0),
        cap_violations,
        epochs,
        reallocations: coord.reallocations,
        budget_start_w: budgets[0],
        budget_final_w: budgets[2],
        wall_s,
    })
}

/// The arbiter policy spec carrying the daemon-level knobs on the wire.
fn arbiter_spec(budget_w: f64, min_cap_w: f64, max_cap_w: f64) -> PolicySpec {
    let mut cfg = PolicyConfig::default();
    cfg.opts.insert("budget_w".into(), format!("{budget_w}"));
    cfg.opts.insert("period_s".into(), format!("{ARB_PERIOD_S}"));
    cfg.opts.insert("min_cap_w".into(), format!("{min_cap_w}"));
    cfg.opts.insert("max_cap_w".into(), format!("{max_cap_w}"));
    cfg.opts.insert("hysteresis_w".into(), format!("{ARB_HYST_W}"));
    PolicySpec::new("arbiter", cfg)
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    spec: &Arc<Spec>,
    dir: &Path,
    sessions: usize,
    rounds: usize,
    budgets: &[f64; 3],
    min_cap: f64,
    max_cap: f64,
    jdir: Option<&PathBuf>,
) -> anyhow::Result<ArmOut> {
    let coordinated = jdir.is_some();
    let arm = if coordinated { "coord" } else { "uncoord" };
    let sock = dir.join(format!("arbiter-{arm}.sock"));
    let daemon = Arc::new(Daemon::with_cfg(
        spec.clone(),
        2,
        DaemonCfg {
            max_workers: 4,
            rate_limit_rps: 0.0,
            rate_burst: 0.0,
            journal_dir: jdir.cloned(),
            telemetry: true,
        },
    ));
    let serve = {
        let daemon = daemon.clone();
        let sock = sock.clone();
        std::thread::spawn(move || daemon.serve(&sock))
    };
    wait_for_socket(&sock)?;

    let run = || -> anyhow::Result<ArmOut> {
        let mut c = GpoeoClient::connect(&sock)?;
        // Default policy first, so every begin below inherits it (and,
        // coordinated, installs the fleet arbiter in the reactor).
        if coordinated {
            c.set_policy(arbiter_spec(budgets[0], min_cap, max_cap))?;
        } else {
            c.set_policy(PolicySpec::registered("powercap"))?;
        }
        let mut sids = Vec::with_capacity(sessions);
        for i in 0..sessions {
            let app = BENCH_APPS[i % BENCH_APPS.len()];
            sids.push(c.begin(app, Some(ENDLESS_ITERS), None, None)?);
        }

        // Equal virtual time per session and per arm: each status poll
        // drives one STATUS_TICKS slice. The global budget shrinks at
        // 1/3 and 2/3 of the horizon (coordinated arm only).
        let mut iters = vec![0u64; sessions];
        let mut energy_j = 0.0;
        for round in 0..rounds {
            if coordinated && round == rounds / 3 {
                c.set_policy(arbiter_spec(budgets[1], min_cap, max_cap))?;
            }
            if coordinated && round == 2 * rounds / 3 {
                c.set_policy(arbiter_spec(budgets[2], min_cap, max_cap))?;
            }
            for (i, sid) in sids.iter().enumerate() {
                let r = c.status(sid)?;
                if round == rounds - 1 {
                    iters[i] = r.iterations;
                    energy_j += r.energy_j;
                }
            }
        }

        let reallocations = if coordinated {
            scrape_counter(&c.metrics()?, "gpoeo_arbiter_reallocations_total")
        } else {
            0
        };
        for sid in &sids {
            c.abort(sid)?;
        }
        Ok(ArmOut {
            energy_j,
            iters,
            reallocations,
        })
    };
    let out = run();

    let down = GpoeoClient::connect(&sock).and_then(|mut c| c.shutdown());
    let served = serve.join();
    let out = out?;
    down?;
    match served {
        Ok(r) => r?,
        Err(_) => anyhow::bail!("daemon serve thread panicked"),
    }
    Ok(out)
}

/// Replay every session journal and check the budget invariant: each
/// epoch's `cap_change` events are a full snapshot of the enrolled
/// fleet, so Σ cap_w per epoch must stay within that epoch's budget.
/// Returns `(violations, epochs)`.
fn replay_cap_epochs(jdir: &Path) -> anyhow::Result<(u64, u64)> {
    let mut by_epoch: BTreeMap<u64, (f64, f64)> = BTreeMap::new();
    for entry in std::fs::read_dir(jdir)
        .map_err(|e| anyhow::anyhow!("journal dir {}: {e}", jdir.display()))?
    {
        let p = entry?.path();
        if p.extension().map_or(true, |e| e != "jsonl") {
            continue;
        }
        for ev in read_journal(&p)? {
            if let TelemetryEvent::CapChange {
                cap_w,
                budget_w,
                epoch,
                ..
            } = ev
            {
                let slot = by_epoch.entry(epoch).or_insert((0.0, budget_w));
                slot.0 += cap_w;
                slot.1 = budget_w;
            }
        }
    }
    let epochs = by_epoch.len() as u64;
    let violations = by_epoch
        .values()
        .filter(|(sum, budget)| *sum > *budget + 1e-6)
        .count() as u64;
    Ok((violations, epochs))
}

/// Pull one counter's value out of Prometheus exposition text.
fn scrape_counter(text: &str, name: &str) -> u64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| l.strip_prefix(name).and_then(|v| v.trim().parse::<f64>().ok()))
        .map(|v| v as u64)
        .unwrap_or(0)
}

fn wait_for_socket(sock: &PathBuf) -> anyhow::Result<()> {
    for _ in 0..200 {
        if sock.exists() {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    anyhow::bail!("daemon socket {} never appeared", sock.display())
}

/// Append the run to the bench file (`runs` array — the cross-run
/// trajectory, same shape idiom as `BENCH_api.json`).
pub fn append_bench(path: &str, r: &ArbiterBench, quick: bool) -> anyhow::Result<()> {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let mut runs = Json::bench_runs(path);
    runs.push(Json::obj(vec![
        ("sessions", Json::Num(r.sessions as f64)),
        ("rounds", Json::Num(r.rounds as f64)),
        ("coord_energy_j", Json::Num(r.coord_energy_j)),
        ("uncoord_energy_j", Json::Num(r.uncoord_energy_j)),
        ("energy_ratio", Json::Num(r.energy_ratio)),
        ("slowdown_p50", Json::Num(r.slowdown_p50)),
        ("slowdown_p99", Json::Num(r.slowdown_p99)),
        ("cap_violations", Json::Num(r.cap_violations as f64)),
        ("epochs", Json::Num(r.epochs as f64)),
        ("reallocations", Json::Num(r.reallocations as f64)),
        ("budget_start_w", Json::Num(r.budget_start_w)),
        ("budget_final_w", Json::Num(r.budget_final_w)),
        ("wall_clock_s", Json::Num(r.wall_s)),
        ("quick", Json::Bool(quick)),
        ("unix_time_s", Json::Num(unix_s)),
    ]));
    let doc = Json::obj(vec![("runs", Json::Arr(runs))]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}
