//! Period-detection experiments: Fig. 2 (motivating errors under clock
//! sweep), Fig. 5 (34-app study), Figs. 6/7/8 (per-app clock sweeps).

use crate::experiments::helpers::{detection_errors, detection_study_apps, frac_within, sweep_gears};
use crate::sim::{find_app, Spec};
use crate::util::stats::mean;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// Period-detection error sweep over SM gears for one app.
pub fn clock_sweep_table(spec: &Arc<Spec>, name: &str, title: &str) -> Table {
    let app = find_app(spec, name).unwrap();
    let mut t = Table::new(
        title,
        &["SM MHz", "GPOEO err", "ODPP err"],
    );
    for g in sweep_gears() {
        let (ge, oe) = detection_errors(spec, &app, g, spec.gears.default_mem_gear);
        t.rowf(&[
            Cell::F(spec.gears.sm_mhz(g), 0),
            Cell::Pct(ge),
            Cell::Pct(oe),
        ]);
    }
    t
}

/// Fig. 2 — the motivating comparison on MLC_3WLGNN and SP_GCN.
pub fn fig2(spec: &Arc<Spec>) -> Vec<Table> {
    vec![
        clock_sweep_table(spec, "MLC_3WLGNN", "Fig 2a — period detection error vs SM clock (MLC_3WLGNN)"),
        clock_sweep_table(spec, "SP_GCN", "Fig 2b — period detection error vs SM clock (SP_GCN)"),
    ]
}

/// Fig. 5 — detection errors of GPOEO vs ODPP on 34 ML applications
/// under the NVIDIA default scheduling strategy.
pub fn fig5(spec: &Arc<Spec>) -> (Table, Fig5Summary) {
    let apps = detection_study_apps(spec);
    let mut t = Table::new(
        "Fig 5 — period detection errors, GPOEO vs ODPP (34 apps, default clocks)",
        &["app", "GPOEO err", "ODPP err"],
    );
    let mut ge_all = Vec::new();
    let mut oe_all = Vec::new();
    for app in &apps {
        let (sm, mem, _) = app.default_op(spec);
        let (ge, oe) = detection_errors(spec, app, sm, mem);
        ge_all.push(ge);
        oe_all.push(oe);
        t.rowf(&[s(&app.name), Cell::Pct(ge), Cell::Pct(oe)]);
    }
    let summary = Fig5Summary {
        n: apps.len(),
        gpoeo_mean: mean(&ge_all),
        odpp_mean: mean(&oe_all),
        gpoeo_max: ge_all.iter().cloned().fold(0.0, f64::max),
        gpoeo_within_5pct: frac_within(&ge_all, 0.05),
        odpp_over_50pct: oe_all.iter().filter(|&&e| e > 0.5).count(),
        gpoeo_wins: ge_all
            .iter()
            .zip(&oe_all)
            .filter(|(g, o)| *g < *o)
            .count(),
    };
    (t, summary)
}

#[derive(Debug, Clone, Copy)]
pub struct Fig5Summary {
    pub n: usize,
    pub gpoeo_mean: f64,
    pub odpp_mean: f64,
    pub gpoeo_max: f64,
    pub gpoeo_within_5pct: f64,
    pub odpp_over_50pct: usize,
    pub gpoeo_wins: usize,
}

impl Fig5Summary {
    pub fn print(&self) {
        println!(
            "summary: n={}  GPOEO mean {:.2}% (paper 1.72%)  ODPP mean {:.2}% (paper 23.16%)",
            self.n,
            self.gpoeo_mean * 100.0,
            self.odpp_mean * 100.0
        );
        println!(
            "         GPOEO max {:.1}%, {:.0}% of apps within 5%;  ODPP >50% on {} apps;  GPOEO more accurate on {}/{}",
            self.gpoeo_max * 100.0,
            self.gpoeo_within_5pct * 100.0,
            self.odpp_over_50pct,
            self.gpoeo_wins,
            self.n
        );
    }
}

/// Figs. 6/7/8 — per-app SM-clock sensitivity sweeps.
pub fn fig6(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "CLB_GAT", "Fig 6 — period detection error vs SM clock (CLB_GAT)")
}

pub fn fig7(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "SBM_3WLGNN", "Fig 7 — period detection error vs SM clock (SBM_3WLGNN)")
}

pub fn fig8(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "TSP_GatedGCN", "Fig 8 — period detection error vs SM clock (TSP_GatedGCN)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_odpp_locks_micro_period_at_all_clocks() {
        // Paper: ODPP errs ~100% on TSP_GatedGCN under every frequency;
        // GPOEO stays accurate.
        let spec = Arc::new(Spec::load_default().unwrap());
        let t = fig8(&spec);
        let mut gpoeo_ok = 0;
        let mut odpp_bad = 0;
        for row in &t.rows {
            let ge: f64 = row[1].trim_end_matches('%').parse().unwrap();
            let oe: f64 = row[2].trim_end_matches('%').parse().unwrap();
            if ge < 10.0 {
                gpoeo_ok += 1;
            }
            if oe > 50.0 {
                odpp_bad += 1;
            }
        }
        assert!(gpoeo_ok >= 5, "GPOEO accurate on most clocks: {gpoeo_ok}/7");
        assert!(odpp_bad >= 5, "ODPP fooled on most clocks: {odpp_bad}/7");
    }
}
