//! Period-detection experiments: Fig. 2 (motivating errors under clock
//! sweep), Fig. 5 (34-app study), Figs. 6/7/8 (per-app clock sweeps),
//! and the post-paper `detect-bench` (streaming vs batch detection cost
//! over the 71 evaluation apps, appended to `BENCH_detection.json`).

use crate::device::sim_device;
use crate::experiments::helpers::{
    capture_channels, detection_errors, detection_study_apps, evaluation_apps, frac_within,
    sweep_gears,
};
use crate::signal::{
    composite_feature, online_detect, OnlineDetection, PeriodCfg, StreamCfg, StreamingDetector,
};
use crate::sim::{find_app, Spec};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::mean;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// Period-detection error sweep over SM gears for one app.
pub fn clock_sweep_table(spec: &Arc<Spec>, name: &str, title: &str) -> Table {
    let app = find_app(spec, name).unwrap();
    let mut t = Table::new(
        title,
        &["SM MHz", "GPOEO err", "ODPP err"],
    );
    for g in sweep_gears() {
        let (ge, oe) = detection_errors(spec, &app, g, spec.gears.default_mem_gear);
        t.rowf(&[
            Cell::F(spec.gears.sm_mhz(g), 0),
            Cell::Pct(ge),
            Cell::Pct(oe),
        ]);
    }
    t
}

/// Fig. 2 — the motivating comparison on MLC_3WLGNN and SP_GCN.
pub fn fig2(spec: &Arc<Spec>) -> Vec<Table> {
    vec![
        clock_sweep_table(spec, "MLC_3WLGNN", "Fig 2a — period detection error vs SM clock (MLC_3WLGNN)"),
        clock_sweep_table(spec, "SP_GCN", "Fig 2b — period detection error vs SM clock (SP_GCN)"),
    ]
}

/// Fig. 5 — detection errors of GPOEO vs ODPP on 34 ML applications
/// under the NVIDIA default scheduling strategy.
pub fn fig5(spec: &Arc<Spec>) -> (Table, Fig5Summary) {
    let apps = detection_study_apps(spec);
    let mut t = Table::new(
        "Fig 5 — period detection errors, GPOEO vs ODPP (34 apps, default clocks)",
        &["app", "GPOEO err", "ODPP err"],
    );
    let mut ge_all = Vec::new();
    let mut oe_all = Vec::new();
    for app in &apps {
        let (sm, mem, _) = app.default_op(spec);
        let (ge, oe) = detection_errors(spec, app, sm, mem);
        ge_all.push(ge);
        oe_all.push(oe);
        t.rowf(&[s(&app.name), Cell::Pct(ge), Cell::Pct(oe)]);
    }
    let summary = Fig5Summary {
        n: apps.len(),
        gpoeo_mean: mean(&ge_all),
        odpp_mean: mean(&oe_all),
        gpoeo_max: ge_all.iter().cloned().fold(0.0, f64::max),
        gpoeo_within_5pct: frac_within(&ge_all, 0.05),
        odpp_over_50pct: oe_all.iter().filter(|&&e| e > 0.5).count(),
        gpoeo_wins: ge_all
            .iter()
            .zip(&oe_all)
            .filter(|(g, o)| *g < *o)
            .count(),
    };
    (t, summary)
}

#[derive(Debug, Clone, Copy)]
pub struct Fig5Summary {
    pub n: usize,
    pub gpoeo_mean: f64,
    pub odpp_mean: f64,
    pub gpoeo_max: f64,
    pub gpoeo_within_5pct: f64,
    pub odpp_over_50pct: usize,
    pub gpoeo_wins: usize,
}

impl Fig5Summary {
    pub fn print(&self) {
        println!(
            "summary: n={}  GPOEO mean {:.2}% (paper 1.72%)  ODPP mean {:.2}% (paper 23.16%)",
            self.n,
            self.gpoeo_mean * 100.0,
            self.odpp_mean * 100.0
        );
        println!(
            "         GPOEO max {:.1}%, {:.0}% of apps within 5%;  ODPP >50% on {} apps;  GPOEO more accurate on {}/{}",
            self.gpoeo_max * 100.0,
            self.gpoeo_within_5pct * 100.0,
            self.odpp_over_50pct,
            self.gpoeo_wins,
            self.n
        );
    }
}

/// Figs. 6/7/8 — per-app SM-clock sensitivity sweeps.
pub fn fig6(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "CLB_GAT", "Fig 6 — period detection error vs SM clock (CLB_GAT)")
}

pub fn fig7(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "SBM_3WLGNN", "Fig 7 — period detection error vs SM clock (SBM_3WLGNN)")
}

pub fn fig8(spec: &Arc<Spec>) -> Table {
    clock_sweep_table(spec, "TSP_GatedGCN", "Fig 8 — period detection error vs SM clock (TSP_GatedGCN)")
}

// ---------------------------------------------------------------------
// detect-bench: the streaming-engine cost study.
// ---------------------------------------------------------------------

/// Per-app outcome of one detect-bench session pair.
pub struct DetectBenchRow {
    pub app: String,
    pub aperiodic: bool,
    pub true_period_s: f64,
    pub batch_wall_s: f64,
    pub batch_evals: usize,
    pub batch_detected_s: f64,
    pub stream_wall_s: f64,
    pub stream_evals: usize,
    pub stream_detected_s: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub retained_max: usize,
}

pub struct DetectBench {
    pub table: Table,
    pub rows: Vec<DetectBenchRow>,
    pub batch_wall_s: f64,
    pub stream_wall_s: f64,
    pub speedup: f64,
}

impl DetectBench {
    pub fn print_summary(&self) {
        println!(
            "detection wall-clock over {} apps: batch {:.3}s  streaming {:.3}s  speedup {:.1}x",
            self.rows.len(),
            self.batch_wall_s,
            self.stream_wall_s,
            self.speedup
        );
        let (h, m) = self
            .rows
            .iter()
            .fold((0u64, 0u64), |(h, m), r| (h + r.cache_hits, m + r.cache_misses));
        println!(
            "streaming evaluations {}  batch evaluations {}  sub-window cache hit rate {:.0}%",
            self.rows.iter().map(|r| r.stream_evals).sum::<usize>(),
            self.rows.iter().map(|r| r.batch_evals).sum::<usize>(),
            100.0 * h as f64 / (h + m).max(1) as f64
        );
    }
}

/// Relative detected-vs-true error; -1 when no detection or no usable
/// ground truth (aperiodic apps).
fn rel_err(detected_s: f64, truth: f64, aperiodic: bool) -> f64 {
    if aperiodic || !detected_s.is_finite() || !truth.is_finite() || truth <= 0.0 {
        -1.0
    } else {
        (detected_s - truth).abs() / truth
    }
}

/// `gpoeo experiment detect-bench [--quick] [--poll-s F] [--bench PATH]`
///
/// For every app in the three suites, replays the same online session
/// twice against the same captured trace:
///
/// - **batch**: the pre-detector consumer pattern — accumulate the
///   channels and recompute `composite_feature` + `online_detect` over
///   the *entire* window at every poll (no standing verdict to answer
///   from, so every poll pays O(window));
/// - **streaming**: push each tick into a [`StreamingDetector`]
///   (advancing start line on) and poll at the same cadence; the
///   detector re-evaluates only when Algorithm 3's requested extension
///   has arrived, over its bounded retained window.
///
/// Wall-clock, evaluation counts, cache hit rates and detected-vs-true
/// periods are tabulated and appended to `BENCH_detection.json`.
pub fn detect_bench(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<DetectBench> {
    let ts = 0.025;
    let poll_s = args.opt_f64("poll-s", 0.5)?;
    let poll_stride = ((poll_s / ts).round() as usize).max(1);
    let cfg = PeriodCfg::default();

    let apps = evaluation_apps(spec)?;

    let mut rows = Vec::new();
    for app in &apps {
        let (sm, mem, _) = app.default_op(spec);
        let mut probe = sim_device(spec, app);
        probe.set_sm_gear(sm);
        probe.set_mem_gear(mem);
        let truth = probe.true_period();
        let dur = if quick {
            (8.0 * truth).clamp(8.0, 16.0)
        } else {
            (12.0 * truth).clamp(10.0, 40.0)
        };
        let (p, us, um, truth) = capture_channels(spec, app, sm, mem, ts, dur);

        // --- Streaming pass.
        let t0 = std::time::Instant::now();
        let mut det = StreamingDetector::new(
            ts,
            cfg.clone(),
            StreamCfg {
                retain_horizon_mult: Some(2.0),
                ..StreamCfg::default()
            },
        );
        let mut s_last: Option<OnlineDetection> = None;
        let mut retained_max = 0usize;
        for i in 0..p.len() {
            det.push(p[i], us[i], um[i]);
            if (i + 1) % poll_stride == 0 {
                if let Some(v) = det.poll() {
                    s_last = v.detection;
                    retained_max = retained_max.max(det.retained_len());
                }
            }
        }
        let stream_wall_s = t0.elapsed().as_secs_f64();
        let (cache_hits, cache_misses) = det.cache_stats();

        // --- Batch pass: identical polls, no detector state.
        let t1 = std::time::Instant::now();
        let mut b_last: Option<OnlineDetection> = None;
        let mut b_evals = 0usize;
        let (mut bp, mut bus, mut bum) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..p.len() {
            bp.push(p[i]);
            bus.push(us[i]);
            bum.push(um[i]);
            if (i + 1) % poll_stride == 0 {
                let feat = composite_feature(&bp, &bus, &bum);
                b_last = online_detect(&feat, ts, &cfg);
                b_evals += 1;
            }
        }
        let batch_wall_s = t1.elapsed().as_secs_f64();

        rows.push(DetectBenchRow {
            app: app.name.clone(),
            aperiodic: app.aperiodic,
            true_period_s: truth,
            batch_wall_s,
            batch_evals: b_evals,
            batch_detected_s: b_last.map_or(f64::NAN, |d| d.estimate.t_iter),
            stream_wall_s,
            stream_evals: det.rounds(),
            stream_detected_s: s_last.map_or(f64::NAN, |d| d.estimate.t_iter),
            cache_hits,
            cache_misses,
            retained_max,
        });
    }

    let batch_total: f64 = rows.iter().map(|r| r.batch_wall_s).sum();
    let stream_total: f64 = rows.iter().map(|r| r.stream_wall_s).sum();
    let speedup = batch_total / stream_total.max(1e-12);

    let mut table = Table::new(
        &format!(
            "Detect-bench — streaming vs batch detection, {} apps, poll every {poll_s}s{}",
            rows.len(),
            if quick { ", --quick" } else { "" }
        ),
        &[
            "app", "true T", "stream ms", "batch ms", "speedup", "evals s/b", "cache hit%",
            "stream err", "batch err",
        ],
    );
    for r in &rows {
        let hitrate = 100.0 * r.cache_hits as f64 / (r.cache_hits + r.cache_misses).max(1) as f64;
        let fmt_err = |e: f64| {
            if e < 0.0 {
                "-".to_string()
            } else {
                format!("{:.1}%", e * 100.0)
            }
        };
        table.rowf(&[
            s(&r.app),
            Cell::F(r.true_period_s, 3),
            Cell::F(r.stream_wall_s * 1e3, 1),
            Cell::F(r.batch_wall_s * 1e3, 1),
            Cell::F(r.batch_wall_s / r.stream_wall_s.max(1e-12), 1),
            s(&format!("{}/{}", r.stream_evals, r.batch_evals)),
            Cell::F(hitrate, 0),
            s(&fmt_err(rel_err(r.stream_detected_s, r.true_period_s, r.aperiodic))),
            s(&fmt_err(rel_err(r.batch_detected_s, r.true_period_s, r.aperiodic))),
        ]);
    }

    let bench_path = args.opt_or("bench", "BENCH_detection.json");
    write_bench(bench_path, quick, poll_s, batch_total, stream_total, speedup, &rows)?;
    println!("bench record appended to {bench_path}");

    Ok(DetectBench {
        table,
        rows,
        batch_wall_s: batch_total,
        stream_wall_s: stream_total,
        speedup,
    })
}

/// Append one detect-bench record (`runs[]` keeps the history; `per_app`
/// holds the latest per-app numbers — the `BENCH_sweep.json` pattern).
fn write_bench(
    path: &str,
    quick: bool,
    poll_s: f64,
    batch_total: f64,
    stream_total: f64,
    speedup: f64,
    rows: &[DetectBenchRow],
) -> anyhow::Result<()> {
    let num = |x: f64| Json::Num(if x.is_finite() { x } else { -1.0 });
    let per_app: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("app", Json::Str(r.app.clone())),
                ("aperiodic", Json::Bool(r.aperiodic)),
                ("true_period_s", num(r.true_period_s)),
                ("batch_wall_s", num(r.batch_wall_s)),
                ("batch_evals", Json::Num(r.batch_evals as f64)),
                ("batch_detected_s", num(r.batch_detected_s)),
                (
                    "batch_err",
                    num(rel_err(r.batch_detected_s, r.true_period_s, r.aperiodic)),
                ),
                ("stream_wall_s", num(r.stream_wall_s)),
                ("stream_evals", Json::Num(r.stream_evals as f64)),
                ("stream_detected_s", num(r.stream_detected_s)),
                (
                    "stream_err",
                    num(rel_err(r.stream_detected_s, r.true_period_s, r.aperiodic)),
                ),
                ("cache_hits", Json::Num(r.cache_hits as f64)),
                ("cache_misses", Json::Num(r.cache_misses as f64)),
                ("retained_max", Json::Num(r.retained_max as f64)),
            ])
        })
        .collect();

    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let run = Json::obj(vec![
        ("unix_time_s", Json::Num(unix_s)),
        ("quick", Json::Bool(quick)),
        ("poll_s", Json::Num(poll_s)),
        ("apps", Json::Num(rows.len() as f64)),
        ("batch_wall_s", num(batch_total)),
        ("stream_wall_s", num(stream_total)),
        ("speedup", num(speedup)),
    ]);

    let mut runs = Json::bench_runs(path);
    runs.push(run);
    let doc = Json::obj(vec![
        ("runs", Json::Arr(runs)),
        ("per_app", Json::Arr(per_app)),
    ]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_odpp_locks_micro_period_at_all_clocks() {
        // Paper: ODPP errs ~100% on TSP_GatedGCN under every frequency;
        // GPOEO stays accurate.
        let spec = Arc::new(Spec::load_default().unwrap());
        let t = fig8(&spec);
        let mut gpoeo_ok = 0;
        let mut odpp_bad = 0;
        for row in &t.rows {
            let ge: f64 = row[1].trim_end_matches('%').parse().unwrap();
            let oe: f64 = row[2].trim_end_matches('%').parse().unwrap();
            if ge < 10.0 {
                gpoeo_ok += 1;
            }
            if oe > 50.0 {
                odpp_bad += 1;
            }
        }
        assert!(gpoeo_ok >= 5, "GPOEO accurate on most clocks: {gpoeo_ok}/7");
        assert!(odpp_bad >= 5, "ODPP fooled on most clocks: {odpp_bad}/7");
    }
}
