//! Shared machinery for the experiment harness: simulated-trace capture,
//! period-detection scoring (GPOEO vs ODPP), and policy comparisons.

use crate::coordinator::{
    default_iters, run_sim, savings, DefaultPolicy, Gpoeo, GpoeoCfg, Odpp, OdppCfg, Savings,
};
use crate::device::sim_device;
use crate::model::Predictor;
use crate::signal::{calc_period_fft_argmax, composite_feature, online_detect, PeriodCfg};
use crate::sim::{AppParams, Spec};
use std::sync::Arc;

/// Sample the three raw `Feature_dect` channels (power, SM util, mem
/// util) at the given clock config; returns the channels and the
/// ground-truth period. This is what streaming consumers push tick by
/// tick — the composite blend happens detector-side, over whatever
/// window is retained at evaluation time.
pub fn capture_channels(
    spec: &Arc<Spec>,
    app: &AppParams,
    sm_gear: usize,
    mem_gear: usize,
    ts: f64,
    duration_s: f64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64) {
    let mut gpu = sim_device(spec, app);
    gpu.set_sm_gear(sm_gear);
    gpu.set_mem_gear(mem_gear);
    let truth = gpu.true_period();
    let n = (duration_s / ts).ceil() as usize;
    let (mut p, mut us, mut um) = (
        Vec::with_capacity(n),
        Vec::with_capacity(n),
        Vec::with_capacity(n),
    );
    for _ in 0..n {
        gpu.advance(ts);
        let s = gpu.sample(ts);
        p.push(s.power_w);
        us.push(s.util_sm);
        um.push(s.util_mem);
    }
    (p, us, um, truth)
}

/// Sample a trace at the given clock config; returns the composite
/// `Feature_dect` channel and the ground-truth period.
pub fn capture_trace(
    spec: &Arc<Spec>,
    app: &AppParams,
    sm_gear: usize,
    mem_gear: usize,
    ts: f64,
    duration_s: f64,
) -> (Vec<f64>, f64) {
    let (p, us, um, truth) = capture_channels(spec, app, sm_gear, mem_gear, ts, duration_s);
    (composite_feature(&p, &us, &um), truth)
}

/// Detection errors (GPOEO, ODPP) on one app at one clock config.
/// Window is 12 true periods (min 8 s), matching the `detect` CLI.
pub fn detection_errors(
    spec: &Arc<Spec>,
    app: &AppParams,
    sm_gear: usize,
    mem_gear: usize,
) -> (f64, f64) {
    let ts = 0.025;
    let mut probe = sim_device(spec, app);
    probe.set_sm_gear(sm_gear);
    probe.set_mem_gear(mem_gear);
    let truth = probe.true_period();
    let dur = (12.0 * truth).clamp(8.0, 60.0);
    let (feat, truth) = capture_trace(spec, app, sm_gear, mem_gear, ts, dur);

    let gpoeo_err = online_detect(&feat, ts, &PeriodCfg::default())
        .map(|d| (d.estimate.t_iter - truth).abs() / truth)
        .unwrap_or(1.0);
    let odpp_err = calc_period_fft_argmax(&feat, ts)
        .map(|d| (d.t_iter - truth).abs() / truth)
        .unwrap_or(1.0);
    (gpoeo_err, odpp_err)
}

/// Full online-optimization comparison for one app: returns
/// (gpoeo savings, odpp savings, gpoeo stats).
pub fn compare_policies(
    spec: &Arc<Spec>,
    predictor: &Arc<Predictor>,
    app: &AppParams,
    iters: Option<u64>,
) -> (Savings, Savings, crate::coordinator::GpoeoStats) {
    let n = iters.unwrap_or_else(|| default_iters(app));
    let base = run_sim(spec, app, &mut DefaultPolicy { ts: 0.025 }, n);

    let mut g = Gpoeo::new(GpoeoCfg::default(), predictor.clone());
    let rg = run_sim(spec, app, &mut g, n);

    let mut o = Odpp::new(OdppCfg::default());
    let ro = run_sim(spec, app, &mut o, n);

    // A simulated run under a sane policy always completes iterations,
    // so a zero-work error here means the harness itself is broken.
    let sg = savings(&base, &rg).expect("gpoeo run completed zero iterations");
    let so = savings(&base, &ro).expect("odpp run completed zero iterations");
    (sg, so, g.stats.clone())
}

/// The paper's 71 evaluation apps (AIBench 14 + classical 2 + gnns 55)
/// — the suite every cross-app study (policies, detect-bench,
/// predict-bench, the bit-identity tests) iterates.
pub fn evaluation_apps(spec: &Spec) -> anyhow::Result<Vec<AppParams>> {
    let mut apps = Vec::new();
    for suite in ["aibench", "classical", "gnns"] {
        apps.extend(crate::sim::make_suite(spec, suite)?);
    }
    Ok(apps)
}

/// The 34 periodic apps used by the paper's period-detection study
/// (Fig. 5): all periodic AIBench apps plus periodic GNN apps, trimmed
/// to 34 in suite order.
pub fn detection_study_apps(spec: &Spec) -> Vec<AppParams> {
    let mut out = Vec::new();
    for suite in ["aibench", "gnns"] {
        for e in &spec.suites[suite].apps {
            let app = crate::sim::make_app(spec, suite, &e.name).unwrap();
            if !app.aperiodic {
                out.push(app);
            }
            if out.len() == 34 {
                return out;
            }
        }
    }
    out
}

/// SM gears swept in the sensitivity studies (Figs. 2/6/7/8).
pub fn sweep_gears() -> Vec<usize> {
    vec![40, 52, 64, 76, 88, 100, 114]
}

/// Fraction of entries ≤ threshold.
pub fn frac_within(xs: &[f64], thr: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x <= thr).count() as f64 / xs.len() as f64
}
