//! Experiment harness: one module per paper table/figure (DESIGN.md §5).
//!
//! `gpoeo experiment <id>` regenerates the corresponding artifact;
//! `gpoeo experiment all` runs the full evaluation. `--quick` shortens
//! the online runs (useful for smoke tests), `--save DIR` additionally
//! writes each table as markdown.

pub mod ablation;
pub mod apibench;
pub mod arbiterbench;
pub mod detection;
pub mod helpers;
pub mod motivation;
pub mod online;
pub mod policies;
pub mod prediction;
pub mod simbench;

use crate::model::Predictor;
use crate::sim::Spec;
use crate::util::cli::Args;
use crate::util::table::Table;
use std::sync::Arc;

pub const EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    "fig13", "table3", "fig14", "fig15", "headline", "ablation", "policies", "detect-bench",
    "predict-bench", "api-bench", "sim-bench", "arbiter-bench",
];

fn emit(t: &Table, args: &Args) -> anyhow::Result<()> {
    crate::cli::print_table(t, args);
    if let Some(dir) = args.opt("save") {
        std::fs::create_dir_all(dir)?;
        // Slug from the title's leading "Fig N"/"Table N" segment.
        let name: String = t
            .title
            .chars()
            .take_while(|&c| c != '—')
            .filter(|c| c.is_ascii_alphanumeric())
            .flat_map(|c| c.to_lowercase())
            .collect();
        std::fs::write(format!("{dir}/{name}.md"), t.to_markdown())?;
    }
    println!();
    Ok(())
}

pub fn cli_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("usage: gpoeo experiment <id|all> [--quick] [--save DIR]"))?;
    let spec = Arc::new(Spec::load_default()?);
    let quick = args.has_flag("quick");

    // The prediction/online experiments need the trained models; the
    // detection/motivation ones run on the simulator alone.
    let lazy_predictor = || -> anyhow::Result<Arc<Predictor>> {
        Ok(Arc::new(Predictor::load_best()?))
    };

    let ids: Vec<&str> = if id == "all" {
        EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };

    for id in ids {
        match id {
            "fig1" => emit(&motivation::fig1(&spec), args)?,
            "fig3" => emit(&motivation::fig3(&spec), args)?,
            "fig2" => {
                for t in detection::fig2(&spec) {
                    emit(&t, args)?;
                }
            }
            "fig5" => {
                let (t, summary) = detection::fig5(&spec);
                emit(&t, args)?;
                summary.print();
            }
            "fig6" => emit(&detection::fig6(&spec), args)?,
            "fig7" => emit(&detection::fig7(&spec), args)?,
            "fig8" => emit(&detection::fig8(&spec), args)?,
            "fig9" | "fig10" | "fig11" | "fig12" => {
                let p = lazy_predictor()?;
                let r = prediction::run(&spec, &p)?;
                match id {
                    "fig9" => emit(&r.fig9, args)?,
                    "fig10" => emit(&r.fig10, args)?,
                    "fig11" => emit(&r.fig11, args)?,
                    _ => emit(&r.fig12, args)?,
                }
                r.print_summary();
            }
            "fig13" => {
                let p = lazy_predictor()?;
                let r = online::fig13(&spec, &p, quick);
                emit(&r.table, args)?;
                r.print_summary("paper: GPOEO 14.7% saving / 4.6% slowdown / 6.8% ED2P");
            }
            "fig14" => {
                let p = lazy_predictor()?;
                let r = online::fig14(&spec, &p, quick);
                emit(&r.table, args)?;
                r.print_summary("paper: GPOEO 16.6% / 5.2% / 7.8%; ODPP 6.1% / 5.6% / -4.5%");
            }
            "table3" => {
                let p = lazy_predictor()?;
                emit(&online::table3(&spec, &p), args)?;
            }
            "fig15" => {
                let p = lazy_predictor()?;
                let (t, eo, to) = online::fig15(&spec, &p);
                emit(&t, args)?;
                println!(
                    "mean overhead: energy {:.1}%  time {:.1}%  (paper: all within 4%)",
                    eo * 100.0,
                    to * 100.0
                );
            }
            "ablation" => {
                let p = lazy_predictor()?;
                let (t, _) = ablation::run(&spec, &p);
                emit(&t, args)?;
            }
            "policies" => {
                // Dispatches through the registry + fleet; policies whose
                // models are unavailable show up as failure counts rather
                // than aborting the whole study.
                let r = policies::head_to_head(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
            }
            "detect-bench" => {
                // Model-free: runs on the simulator + signal stack alone,
                // so it can gate CI without AOT artifacts.
                let r = detection::detect_bench(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
                let min = args.opt_f64("min-speedup", 0.0)?;
                if min > 0.0 && r.speedup < min {
                    anyhow::bail!(
                        "detect-bench: streaming speedup {:.2}x below the required {min}x",
                        r.speedup
                    );
                }
            }
            "predict-bench" => {
                // Model-shape-only: falls back to a synthetic bundle
                // when the trained artifacts are absent, so it can gate
                // CI like detect-bench does.
                let r = prediction::predict_bench(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
                anyhow::ensure!(
                    r.max_abs_diff == 0.0,
                    "predict-bench: arena and legacy predictions diverge (max |diff| = {:e})",
                    r.max_abs_diff
                );
                let min = args.opt_f64("min-speedup", 0.0)?;
                if min > 0.0 && r.speedup < min {
                    anyhow::bail!(
                        "predict-bench: arena speedup {:.2}x below the required {min}x",
                        r.speedup
                    );
                }
            }
            "api-bench" => {
                // Control-plane scale: artifact-free (powercap policy),
                // so it gates CI alongside detect/predict-bench. Every
                // tier is appended to BENCH_api.json before any gate can
                // fail — a failed 10k attempt is recorded, not lost.
                let r = apibench::run(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
                let bench_path = args.opt_or("bench", "BENCH_api.json");
                apibench::append_bench(bench_path, &r, quick)?;
                println!("bench record appended to {bench_path}");
                let min_churn = args.opt_f64("min-churn", 0.0)?;
                let max_p99 = args.opt_f64("max-p99-ms", 0.0)?;
                let max_overhead = args.opt_f64("max-overhead-pct", 0.0)?;
                for t in &r.tiers {
                    if !t.ok {
                        // The 10k tier may fail on small machines (fd
                        // limits); the gated tiers must not.
                        anyhow::ensure!(
                            t.sessions > 1000,
                            "api-bench: {} sessions tier failed: {}",
                            t.sessions,
                            t.error
                        );
                        continue;
                    }
                    if min_churn > 0.0 && t.churn_per_s < min_churn {
                        anyhow::bail!(
                            "api-bench: {} sessions churned {:.0}/s, below the required {min_churn}/s",
                            t.sessions,
                            t.churn_per_s
                        );
                    }
                    if max_p99 > 0.0 && t.p99_ms > max_p99 {
                        anyhow::bail!(
                            "api-bench: {} sessions p99 {:.2}ms, above the allowed {max_p99}ms",
                            t.sessions,
                            t.p99_ms
                        );
                    }
                    // Telemetry must be near-free on the request path:
                    // attached p99 may exceed detached p99 by at most
                    // --max-overhead-pct, with a 1ms absolute floor so
                    // sub-ms noise can't fail the gate.
                    if max_overhead > 0.0
                        && t.p99_ms > t.p99_detached_ms * (1.0 + max_overhead / 100.0)
                        && t.p99_ms - t.p99_detached_ms > 1.0
                    {
                        anyhow::bail!(
                            "api-bench: {} sessions p99 {:.2}ms with telemetry vs {:.2}ms detached — over the {max_overhead}% overhead budget",
                            t.sessions,
                            t.p99_ms,
                            t.p99_detached_ms
                        );
                    }
                }
            }
            "arbiter-bench" => {
                // Fleet budget arbiter vs uncoordinated powercap
                // (DESIGN.md §14). Artifact-free (simulator + daemon), so
                // it gates CI. The bench record is appended before any
                // gate can fail.
                let r = arbiterbench::run(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
                let bench_path = args.opt_or("bench", "BENCH_arbiter.json");
                arbiterbench::append_bench(bench_path, &r, quick)?;
                println!("bench record appended to {bench_path}");
                anyhow::ensure!(
                    r.cap_violations == 0,
                    "arbiter-bench: {} epochs exceeded the budget in force (invariant: Σ caps ≤ budget, DESIGN.md §14)",
                    r.cap_violations
                );
                anyhow::ensure!(
                    r.epochs >= 3,
                    "arbiter-bench: only {} re-allocation epochs journaled; the shrinking-budget schedule must produce at least 3",
                    r.epochs
                );
                anyhow::ensure!(
                    r.coord_energy_j < r.uncoord_energy_j,
                    "arbiter-bench: coordinated arm used {:.0} J, not below the uncoordinated {:.0} J",
                    r.coord_energy_j,
                    r.uncoord_energy_j
                );
            }
            "sim-bench" => {
                // Model-free like detect-bench: the stepped-vs-fast-forward
                // comparison runs on the simulator alone, so it gates CI.
                // The bench record is appended before any gate can fail.
                let r = simbench::run(&spec, args, quick)?;
                emit(&r.table, args)?;
                r.print_summary();
                anyhow::ensure!(
                    r.max_divergence <= 1e-9,
                    "sim-bench: stepped and fast-forward paths diverge (max relative divergence {:e}, expected 0; see DESIGN.md §13)",
                    r.max_divergence
                );
                let min = args.opt_f64("min-speedup", 0.0)?;
                if min > 0.0 && r.speedup < min {
                    anyhow::bail!(
                        "sim-bench: fast-forward speedup {:.2}x below the required {min}x",
                        r.speedup
                    );
                }
            }
            "headline" => {
                let p = lazy_predictor()?;
                let h = online::headline(&spec, &p, quick);
                println!(
                    "headline over {} apps: mean energy saving {:.1}% (paper 16.2%), mean slowdown {:.1}% (paper 5.1%), mean ED2P saving {:.1}%",
                    h.n,
                    h.mean_saving * 100.0,
                    h.mean_slowdown * 100.0,
                    h.mean_ed2p * 100.0
                );
            }
            other => anyhow::bail!(
                "unknown experiment '{other}'; available: {} | all",
                EXPERIMENTS.join(" ")
            ),
        }
    }
    Ok(())
}
