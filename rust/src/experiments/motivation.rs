//! Motivation experiments: Fig. 1 (oracle savings for five apps) and
//! Fig. 3 (similar coarse features, different optimal SM clocks).

use crate::coordinator::oracle_full;
use crate::search::Objective;
use crate::sim::{find_app, make_suite, Spec};
use crate::util::table::{s, Cell, Table};

/// Fig. 1 — oracle energy/slowdown/ED²P for the five motivating apps
/// under the 5% slowdown constraint.
pub fn fig1(spec: &Spec) -> Table {
    let apps = ["AI_FE", "AI_S2T", "SBM_GIN", "CLB_MLP", "TSP_GatedGCN"];
    let obj = Objective::paper_default();
    let mut t = Table::new(
        "Fig 1 — Oracle savings of ML applications (slowdown ≤ 5%)",
        &["app", "class", "energy saving", "slowdown", "ED2P saving"],
    );
    for name in apps {
        let app = find_app(spec, name).unwrap();
        let r = oracle_full(&app, spec, obj);
        let class = if app.wc >= 0.5 { "compute" } else { "memory" };
        t.rowf(&[
            s(name),
            s(class),
            Cell::Pct(r.energy_saving),
            Cell::Pct(r.slowdown),
            Cell::Pct(r.ed2p_saving),
        ]);
    }
    t
}

/// Fig. 3 — pairs of applications with similar coarse-grained features
/// (average power, SM util, mem util at the reference clocks) whose
/// ED²P-optimal SM clocks differ substantially: the motivation for using
/// performance counters instead of NVML-level features (§2.2.4).
pub fn fig3(spec: &Spec) -> Table {
    // Collect (app, coarse features, optimal SM clock for ED2P).
    let mut rows = Vec::new();
    for suite in ["aibench", "gnns"] {
        for app in make_suite(spec, suite).unwrap() {
            let op = app.op_point(spec, spec.gears.reference_sm_gear, spec.gears.reference_mem_gear);
            let best = oracle_full(&app, spec, Objective::Ed2p);
            rows.push((app, op, best.sm_gear));
        }
    }
    let mut t = Table::new(
        "Fig 3 — similar coarse features, different optimal SM clocks (ED2P)",
        &[
            "app A", "app B", "powerA", "powerB", "utilA", "utilB", "optA(MHz)", "optB(MHz)",
            "Δgears",
        ],
    );
    let mut used = vec![false; rows.len()];
    for i in 0..rows.len() {
        if used[i] {
            continue;
        }
        for j in i + 1..rows.len() {
            if used[j] {
                continue;
            }
            let (a, oa, ga) = &rows[i];
            let (b, ob, gb) = &rows[j];
            let dp = (oa.power_w - ob.power_w).abs() / oa.power_w;
            let du = (oa.util_sm - ob.util_sm).abs();
            let dg = (*ga as i64 - *gb as i64).unsigned_abs() as usize;
            if dp < 0.04 && du < 0.06 && dg >= 12 {
                t.rowf(&[
                    s(&a.name),
                    s(&b.name),
                    Cell::F(oa.power_w, 0),
                    Cell::F(ob.power_w, 0),
                    Cell::F(oa.util_sm, 2),
                    Cell::F(ob.util_sm, 2),
                    Cell::F(spec.gears.sm_mhz(*ga), 0),
                    Cell::F(spec.gears.sm_mhz(*gb), 0),
                    Cell::U(dg),
                ]);
                used[i] = true;
                used[j] = true;
                break;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_reproduces_paper_shape() {
        let spec = Spec::load_default().unwrap();
        let t = fig1(&spec);
        assert_eq!(t.rows.len(), 5);
        // Every motivating app must show a double-digit-ish saving and
        // respect the slowdown cap — the paper's claim that both compute-
        // and memory-intensive apps have headroom.
        for row in &t.rows {
            let saving: f64 = row[2].trim_end_matches('%').parse().unwrap();
            let slow: f64 = row[3].trim_end_matches('%').parse().unwrap();
            assert!(saving > 8.0, "{row:?}");
            assert!(slow <= 5.1, "{row:?}");
        }
    }

    #[test]
    fn fig3_finds_confusable_pairs() {
        let spec = Spec::load_default().unwrap();
        let t = fig3(&spec);
        assert!(
            t.rows.len() >= 2,
            "need at least two confusable pairs, got {}",
            t.rows.len()
        );
    }
}
