//! Online-optimization experiments: Fig. 13 (AIBench + classical ML),
//! Table 3 (per-app optimization trace), Fig. 14 (benchmarking-gnns),
//! Fig. 15 (overhead) and the headline aggregate (§1/§7).

use crate::coordinator::{
    default_iters, oracle_ordered, run_sim, savings, DefaultPolicy, Gpoeo, GpoeoCfg,
};
use crate::experiments::helpers::compare_policies;
use crate::model::Predictor;
use crate::search::Objective;
use crate::sim::{make_suite, AppParams, Spec};
use crate::util::stats::mean;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// Apps of the "medium benchmark suite" (Fig. 13): AIBench + TSVM/TGBM.
fn medium_suite(spec: &Spec) -> Vec<AppParams> {
    let mut apps = make_suite(spec, "aibench").unwrap();
    apps.extend(make_suite(spec, "classical").unwrap());
    apps
}

pub struct OnlineReport {
    pub table: Table,
    pub gpoeo_mean_saving: f64,
    pub gpoeo_mean_slowdown: f64,
    pub gpoeo_mean_ed2p: f64,
    pub odpp_mean_saving: f64,
    pub odpp_mean_slowdown: f64,
    pub odpp_mean_ed2p: f64,
    pub gpoeo_meets_cap: usize,
    pub odpp_meets_cap: usize,
    pub gpoeo_wins_energy: usize,
    /// Apps where GPOEO's (energy, time) outcome scores better under the
    /// paper's capped objective than ODPP's.
    pub gpoeo_wins_score: usize,
    pub gpoeo_ed2p_positive: usize,
    pub odpp_ed2p_positive: usize,
    pub n: usize,
}

/// Run the full GPOEO-vs-ODPP-vs-default comparison over a set of apps.
pub fn online_comparison(
    spec: &Arc<Spec>,
    predictor: &Arc<Predictor>,
    apps: &[AppParams],
    title: &str,
    quick: bool,
) -> OnlineReport {
    let mut t = Table::new(
        title,
        &[
            "app", "GPOEO save", "GPOEO slow", "GPOEO ed2p", "ODPP save", "ODPP slow",
            "ODPP ed2p",
        ],
    );
    let (mut gs, mut gl, mut ge) = (Vec::new(), Vec::new(), Vec::new());
    let (mut os, mut ol, mut oe) = (Vec::new(), Vec::new(), Vec::new());
    let obj = Objective::paper_default();
    let mut score_wins = 0usize;
    for app in apps {
        let iters = if quick {
            Some(default_iters(app) / 3)
        } else {
            None
        };
        let (g, o, _) = compare_policies(spec, predictor, app, iters);
        gs.push(g.energy_saving);
        gl.push(g.slowdown);
        ge.push(g.ed2p_saving);
        os.push(o.energy_saving);
        ol.push(o.slowdown);
        oe.push(o.ed2p_saving);
        if obj.score(1.0 - g.energy_saving, 1.0 + g.slowdown)
            < obj.score(1.0 - o.energy_saving, 1.0 + o.slowdown)
        {
            score_wins += 1;
        }
        t.rowf(&[
            s(&app.name),
            Cell::Pct(g.energy_saving),
            Cell::Pct(g.slowdown),
            Cell::Pct(g.ed2p_saving),
            Cell::Pct(o.energy_saving),
            Cell::Pct(o.slowdown),
            Cell::Pct(o.ed2p_saving),
        ]);
    }
    OnlineReport {
        gpoeo_mean_saving: mean(&gs),
        gpoeo_mean_slowdown: mean(&gl),
        gpoeo_mean_ed2p: mean(&ge),
        odpp_mean_saving: mean(&os),
        odpp_mean_slowdown: mean(&ol),
        odpp_mean_ed2p: mean(&oe),
        gpoeo_meets_cap: gl.iter().filter(|&&x| x <= 0.05).count(),
        odpp_meets_cap: ol.iter().filter(|&&x| x <= 0.05).count(),
        gpoeo_wins_energy: gs.iter().zip(&os).filter(|(g, o)| g > o).count(),
        gpoeo_wins_score: score_wins,
        gpoeo_ed2p_positive: ge.iter().filter(|&&x| x > 0.0).count(),
        odpp_ed2p_positive: oe.iter().filter(|&&x| x > 0.0).count(),
        n: apps.len(),
        table: t,
    }
}

impl OnlineReport {
    pub fn print_summary(&self, paper: &str) {
        println!(
            "GPOEO: saving {:.1}%  slowdown {:.1}%  ED2P {:.1}%  (cap met {}/{}, ED2P>0 on {})",
            self.gpoeo_mean_saving * 100.0,
            self.gpoeo_mean_slowdown * 100.0,
            self.gpoeo_mean_ed2p * 100.0,
            self.gpoeo_meets_cap,
            self.n,
            self.gpoeo_ed2p_positive
        );
        println!(
            "ODPP : saving {:.1}%  slowdown {:.1}%  ED2P {:.1}%  (cap met {}/{}, ED2P>0 on {})",
            self.odpp_mean_saving * 100.0,
            self.odpp_mean_slowdown * 100.0,
            self.odpp_mean_ed2p * 100.0,
            self.odpp_meets_cap,
            self.n,
            self.odpp_ed2p_positive
        );
        println!(
            "GPOEO beats ODPP on raw energy for {}/{} apps; on the capped objective for {}/{}.  [{paper}]",
            self.gpoeo_wins_energy, self.n, self.gpoeo_wins_score, self.n
        );
    }
}

/// Fig. 13 — the medium suite.
pub fn fig13(spec: &Arc<Spec>, predictor: &Arc<Predictor>, quick: bool) -> OnlineReport {
    let apps = medium_suite(spec);
    online_comparison(
        spec,
        predictor,
        &apps,
        "Fig 13 — online optimization, AIBench + classical ML (vs NVIDIA default)",
        quick,
    )
}

/// Fig. 14 — the 55-app benchmarking-gnns suite.
pub fn fig14(spec: &Arc<Spec>, predictor: &Arc<Predictor>, quick: bool) -> OnlineReport {
    let apps = make_suite(spec, "gnns").unwrap();
    online_comparison(
        spec,
        predictor,
        &apps,
        "Fig 14 — online optimization, benchmarking-gnns (55 apps)",
        quick,
    )
}

/// Table 3 — per-app optimization trace on AIBench: oracle vs predicted
/// vs searched gears, and search step counts.
pub fn table3(spec: &Arc<Spec>, predictor: &Arc<Predictor>) -> Table {
    let apps = make_suite(spec, "aibench").unwrap();
    let obj = Objective::paper_default();
    let mut t = Table::new(
        "Table 3 — online optimization process for SM and memory clock (AIBench)",
        &[
            "app", "oracle SM", "pred err (gears)", "search err (gears)", "steps SM",
            "oracle Mem", "pred Mem", "searched Mem", "steps Mem",
        ],
    );
    for app in &apps {
        let oracle = oracle_ordered(app, spec, obj);
        let (_, _, stats) = compare_policies(spec, predictor, app, Some(default_iters(app) / 2));
        t.rowf(&[
            s(&app.name),
            Cell::U(oracle.sm_gear),
            Cell::I(stats.predicted_sm_gear as i64 - oracle.sm_gear as i64),
            Cell::I(stats.searched_sm_gear as i64 - oracle.sm_gear as i64),
            Cell::U(stats.search_steps_sm),
            Cell::F(spec.gears.mem_mhz_of(oracle.mem_gear), 0),
            Cell::F(spec.gears.mem_mhz_of(stats.predicted_mem_gear), 0),
            Cell::F(spec.gears.mem_mhz_of(stats.searched_mem_gear), 0),
            Cell::U(stats.search_steps_mem),
        ]);
    }
    t
}

/// Fig. 15 — measurement overhead: the full GPOEO pipeline with clock
/// actuation disabled, against the plain default run.
pub fn fig15(spec: &Arc<Spec>, predictor: &Arc<Predictor>) -> (Table, f64, f64) {
    let apps = make_suite(spec, "aibench").unwrap();
    let mut t = Table::new(
        "Fig 15 — GPOEO energy and time overhead on AIBench (no actuation)",
        &["app", "energy overhead", "time overhead"],
    );
    let (mut eo, mut to) = (Vec::new(), Vec::new());
    for app in &apps {
        let n = default_iters(app);
        let base = run_sim(spec, app, &mut DefaultPolicy { ts: 0.025 }, n);
        let mut g = Gpoeo::new(
            GpoeoCfg {
                actuate: false,
                ..GpoeoCfg::default()
            },
            predictor.clone(),
        );
        let r = run_sim(spec, app, &mut g, n);
        let s = savings(&base, &r).expect("online run completed zero iterations");
        eo.push(-s.energy_saving); // overhead = negative saving
        to.push(s.slowdown);
        t.rowf(&[
            s_cell(&app.name),
            Cell::Pct(-s.energy_saving),
            Cell::Pct(s.slowdown),
        ]);
    }
    (t, mean(&eo), mean(&to))
}

fn s_cell(v: &str) -> Cell {
    s(v)
}

/// Headline aggregate over all 71 evaluated apps (Figs. 13+14).
pub struct Headline {
    pub n: usize,
    pub mean_saving: f64,
    pub mean_slowdown: f64,
    pub mean_ed2p: f64,
}

pub fn headline(spec: &Arc<Spec>, predictor: &Arc<Predictor>, quick: bool) -> Headline {
    let mut apps = medium_suite(spec);
    apps.extend(make_suite(spec, "gnns").unwrap());
    let mut savings_all = Vec::new();
    let mut slow_all = Vec::new();
    let mut ed2p_all = Vec::new();
    for app in &apps {
        let iters = if quick {
            Some(default_iters(app) / 3)
        } else {
            None
        };
        let (g, _, _) = {
            // Only GPOEO needed for the headline number.
            let n = iters.unwrap_or_else(|| default_iters(app));
            let base = run_sim(spec, app, &mut DefaultPolicy { ts: 0.025 }, n);
            let mut p = Gpoeo::new(GpoeoCfg::default(), predictor.clone());
            let r = run_sim(spec, app, &mut p, n);
            (savings(&base, &r).expect("policy run completed zero iterations"), (), ())
        };
        savings_all.push(g.energy_saving);
        slow_all.push(g.slowdown);
        ed2p_all.push(g.ed2p_saving);
    }
    Headline {
        n: apps.len(),
        mean_saving: mean(&savings_all),
        mean_slowdown: mean(&slow_all),
        mean_ed2p: mean(&ed2p_all),
    }
}
