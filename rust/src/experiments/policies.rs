//! `gpoeo experiment policies` — the four-way head-to-head the policy
//! subsystem exists for: GPOEO (model-based) vs ODPP (baseline) vs the
//! switching-aware bandit vs the power-cap ladder, across the paper's 71
//! evaluation apps, all dispatched through one [`Fleet`] so every worker
//! compiles its predictor at most once for the whole comparison.
//!
//! Per policy the table reports mean energy saving / slowdown / ED²P
//! saving over the NVIDIA-default baseline plus the wall clock the fleet
//! spent; the same record is appended to `BENCH_policies.json` so the
//! cross-policy trajectory accumulates across runs (same pattern as
//! `BENCH_sweep.json`).

use crate::coordinator::{default_iters, Fleet, SweepJob};
use crate::policy::{PolicyConfig, PolicyRegistry, PolicySpec};
use crate::experiments::helpers::evaluation_apps;
use crate::sim::Spec;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::stats::mean;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// The contenders, in report order. All names resolve through the
/// registry — adding a policy there is all it takes to extend the study.
pub const CONTENDERS: &[&str] = &["gpoeo", "odpp", "bandit", "powercap"];

/// Aggregate row for one policy.
pub struct PolicyRow {
    pub policy: String,
    pub apps: usize,
    pub failures: usize,
    pub mean_saving: f64,
    pub mean_slowdown: f64,
    pub mean_ed2p: f64,
    pub wall_s: f64,
}

pub struct HeadToHead {
    pub table: Table,
    pub rows: Vec<PolicyRow>,
}

impl HeadToHead {
    pub fn print_summary(&self) {
        for r in &self.rows {
            println!(
                "{:<9} saving {:>5.1}%  slowdown {:>5.1}%  ED2P {:>5.1}%  ({} apps, {} failed, {:.2}s wall)",
                r.policy,
                r.mean_saving * 100.0,
                r.mean_slowdown * 100.0,
                r.mean_ed2p * 100.0,
                r.apps,
                r.failures,
                r.wall_s
            );
        }
        println!("paper reference: GPOEO 16.2% saving / 5.1% slowdown over the 71 workloads");
    }
}

pub fn head_to_head(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<HeadToHead> {
    let default_workers = std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2);
    let workers = args.opt_usize("parallel", default_workers)?.max(1);
    let cfg = PolicyConfig::from_args(args)?;
    let reg = PolicyRegistry::global();
    for name in CONTENDERS {
        reg.get(name)?; // fail fast before any simulation
    }

    let apps = evaluation_apps(spec)?;
    let fleet = Fleet::new(spec.clone(), workers);
    let mut rows = Vec::new();
    for &name in CONTENDERS {
        let jobs: Vec<SweepJob> = apps
            .iter()
            .map(|app| {
                let n = if quick {
                    (default_iters(app) / 3).max(60)
                } else {
                    default_iters(app)
                };
                SweepJob {
                    app: app.clone(),
                    policy: PolicySpec::new(name, cfg.clone()),
                    n_iters: n,
                }
            })
            .collect();
        let t0 = std::time::Instant::now();
        let outcomes = fleet.run_jobs(jobs);
        let wall_s = t0.elapsed().as_secs_f64();

        let (mut sv, mut sl, mut ed) = (Vec::new(), Vec::new(), Vec::new());
        let mut failures = 0usize;
        for (app, outcome) in apps.iter().zip(outcomes) {
            match outcome {
                Ok(o) => {
                    sv.push(o.savings.energy_saving);
                    sl.push(o.savings.slowdown);
                    ed.push(o.savings.ed2p_saving);
                }
                Err(e) => {
                    failures += 1;
                    // One representative notice per policy is enough;
                    // gpoeo without artifacts fails on every app.
                    if failures == 1 {
                        eprintln!("experiment policies: {name} on {}: {e}", app.name);
                    }
                }
            }
        }
        rows.push(PolicyRow {
            policy: name.to_string(),
            apps: sv.len(),
            failures,
            mean_saving: mean(&sv),
            mean_slowdown: mean(&sl),
            mean_ed2p: mean(&ed),
            wall_s,
        });
    }

    let mut table = Table::new(
        &format!(
            "Policy head-to-head — {} apps, {} workers{}",
            apps.len(),
            workers,
            if quick { ", --quick" } else { "" }
        ),
        &["policy", "mean saving", "mean slowdown", "mean ED2P", "apps", "failed", "wall s"],
    );
    for r in &rows {
        table.rowf(&[
            s(&r.policy),
            Cell::Pct(r.mean_saving),
            Cell::Pct(r.mean_slowdown),
            Cell::Pct(r.mean_ed2p),
            Cell::U(r.apps),
            Cell::U(r.failures),
            Cell::F(r.wall_s, 2),
        ]);
    }

    let bench_path = args.opt_or("bench", "BENCH_policies.json");
    write_bench(bench_path, workers, quick, &rows)?;
    println!("bench record appended to {bench_path}");

    Ok(HeadToHead { table, rows })
}

/// Append one head-to-head record to the bench file (`runs[]` keeps the
/// full history, like BENCH_sweep.json).
fn write_bench(path: &str, workers: usize, quick: bool, rows: &[PolicyRow]) -> anyhow::Result<()> {
    let policies: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("policy", Json::Str(r.policy.clone())),
                ("apps", Json::Num(r.apps as f64)),
                ("failures", Json::Num(r.failures as f64)),
                ("mean_saving", Json::Num(r.mean_saving)),
                ("mean_slowdown", Json::Num(r.mean_slowdown)),
                ("mean_ed2p", Json::Num(r.mean_ed2p)),
                ("wall_clock_s", Json::Num(r.wall_s)),
            ])
        })
        .collect();
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let run = Json::obj(vec![
        ("unix_time_s", Json::Num(unix_s)),
        ("workers", Json::Num(workers as f64)),
        ("quick", Json::Bool(quick)),
        ("policies", Json::Arr(policies)),
    ]);

    let mut runs = Json::bench_runs(path);
    runs.push(run);
    std::fs::write(path, Json::obj(vec![("runs", Json::Arr(runs))]).to_pretty())?;
    Ok(())
}
