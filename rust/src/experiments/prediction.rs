//! Prediction-accuracy experiments (Figs. 9–12): energy/time prediction
//! errors of the four GBT models on the 55 benchmarking-gnns apps, with
//! features measured online (one noisy counter period), grouped by clock
//! range (9/11) and by dataset (10/12) — plus the post-paper
//! `predict-bench` (arena vs legacy all-gears prediction cost over the
//! 71 evaluation apps, appended to `BENCH_predict.json`).

use crate::model::{NativeModels, Predictor};
use crate::sim::{make_suite, AppParams, Spec};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::{mean, percentile};
use crate::util::table::{s, Cell, Table};
use std::collections::BTreeMap;
use std::sync::Arc;

/// One (app, gear) prediction-error record.
struct Record {
    dataset: String,
    sm_mhz: f64,
    mem_mhz: f64,
    eng_ape: f64,
    time_ape: f64,
}

fn dataset_of(app: &AppParams) -> String {
    app.name.split('_').next().unwrap_or("?").to_string()
}

/// Collect prediction errors over the GNN suite (the paper's §5.3 setup:
/// 55 apps × 99 SM gears × 2 objectives → 11,660 SM predictions;
/// 55 × 5 × 2 → 550 memory predictions).
fn collect(spec: &Spec, predictor: &Predictor) -> anyhow::Result<(Vec<Record>, Vec<Record>)> {
    let mut sm_records = Vec::new();
    let mut mem_records = Vec::new();
    for app in make_suite(spec, "gnns")? {
        // Features as measured online: one counter period of noise.
        let mut rng = Pcg64::new(app.trace_seed ^ 0x00fe_a7, 0x5eed);
        let feats = app.measured_features(spec, &mut rng);

        let sm_pred = predictor.predict_sm(spec, &feats)?;
        for (i, g) in spec.gears.sm_gears().enumerate() {
            let (e, t) = app.ratios_vs_default(spec, g, spec.gears.default_mem_gear);
            sm_records.push(Record {
                dataset: dataset_of(&app),
                sm_mhz: spec.gears.sm_mhz(g),
                mem_mhz: 0.0,
                eng_ape: (sm_pred.energy_ratio[i] - e).abs() / e,
                time_ape: (sm_pred.time_ratio[i] - t).abs() / t,
            });
        }

        // Memory models assume the optimal SM gear (§4.3.2).
        let g_opt = crate::coordinator::oracle_ordered(
            &app,
            spec,
            crate::search::Objective::paper_default(),
        )
        .sm_gear;
        let mem_pred = predictor.predict_mem(spec, &feats)?;
        for m in 0..spec.gears.num_mem_gears() {
            let (e, t) = app.ratios_vs_default(spec, g_opt, m);
            mem_records.push(Record {
                dataset: dataset_of(&app),
                sm_mhz: 0.0,
                mem_mhz: spec.gears.mem_mhz_of(m),
                eng_ape: (mem_pred.energy_ratio[m] - e).abs() / e,
                time_ape: (mem_pred.time_ratio[m] - t).abs() / t,
            });
        }
    }
    Ok((sm_records, mem_records))
}

fn grouped_table(
    title: &str,
    records: &[Record],
    group_of: impl Fn(&Record) -> String,
) -> Table {
    let mut groups: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in records {
        let e = groups.entry(group_of(r)).or_default();
        e.0.push(r.eng_ape);
        e.1.push(r.time_ape);
    }
    let mut t = Table::new(
        title,
        &[
            "group", "n", "eng mean", "eng p50", "eng p90", "time mean", "time p50", "time p90",
        ],
    );
    for (g, (es, ts)) in groups {
        t.rowf(&[
            s(g),
            Cell::U(es.len()),
            Cell::Pct(mean(&es)),
            Cell::Pct(percentile(&es, 50.0)),
            Cell::Pct(percentile(&es, 90.0)),
            Cell::Pct(mean(&ts)),
            Cell::Pct(percentile(&ts, 50.0)),
            Cell::Pct(percentile(&ts, 90.0)),
        ]);
    }
    t
}

/// Grouping for Fig. 9: ~150 MHz SM clock ranges.
fn sm_range(mhz: f64) -> String {
    let lo = ((mhz - 450.0) / 150.0).floor() as usize * 150 + 450;
    format!("{:04}-{:04} MHz", lo, lo + 150)
}

pub struct PredictionReport {
    pub fig9: Table,
    pub fig10: Table,
    pub fig11: Table,
    pub fig12: Table,
    pub sm_mean_eng: f64,
    pub sm_mean_time: f64,
    pub mem_mean_eng: f64,
    pub mem_mean_time: f64,
    pub sm_n: usize,
    pub mem_n: usize,
}

pub fn run(spec: &Arc<Spec>, predictor: &Predictor) -> anyhow::Result<PredictionReport> {
    let (sm, mem) = collect(spec, predictor)?;
    let fig9 = grouped_table(
        "Fig 9 — SM-model prediction errors by clock range (55 gnn apps)",
        &sm,
        |r| sm_range(r.sm_mhz),
    );
    let fig10 = grouped_table(
        "Fig 10 — SM-model prediction errors by dataset",
        &sm,
        |r| r.dataset.clone(),
    );
    let fig11 = grouped_table(
        "Fig 11 — memory-model prediction errors by memory clock",
        &mem,
        |r| format!("{:>5.0} MHz", r.mem_mhz),
    );
    let fig12 = grouped_table(
        "Fig 12 — memory-model prediction errors by dataset",
        &mem,
        |r| r.dataset.clone(),
    );
    let report = PredictionReport {
        sm_mean_eng: mean(&sm.iter().map(|r| r.eng_ape).collect::<Vec<_>>()),
        sm_mean_time: mean(&sm.iter().map(|r| r.time_ape).collect::<Vec<_>>()),
        mem_mean_eng: mean(&mem.iter().map(|r| r.eng_ape).collect::<Vec<_>>()),
        mem_mean_time: mean(&mem.iter().map(|r| r.time_ape).collect::<Vec<_>>()),
        sm_n: sm.len(),
        mem_n: mem.len(),
        fig9,
        fig10,
        fig11,
        fig12,
    };
    Ok(report)
}

impl PredictionReport {
    pub fn print_summary(&self) {
        println!(
            "SM models: {} predictions/objective — mean APE eng {:.2}% (paper 3.05%), time {:.2}% (paper 2.09%)",
            self.sm_n,
            self.sm_mean_eng * 100.0,
            self.sm_mean_time * 100.0
        );
        println!(
            "mem models: {} predictions/objective — mean APE eng {:.2}% (paper 2.72%), time {:.2}% (paper 2.31%)",
            self.mem_n,
            self.mem_mean_eng * 100.0,
            self.mem_mean_time * 100.0
        );
    }
}

// ---------------------------------------------------------------------
// predict-bench: the arena-vs-legacy prediction cost study.
// ---------------------------------------------------------------------

/// Per-app outcome of one predict-bench pairing.
pub struct PredictBenchRow {
    pub app: String,
    pub arena_wall_s: f64,
    pub legacy_wall_s: f64,
    /// Max |arena − legacy| across both stages and both outputs; the
    /// bit-identity contract makes this exactly 0.0.
    pub max_abs_diff: f64,
}

pub struct PredictBench {
    pub table: Table,
    pub rows: Vec<PredictBenchRow>,
    pub backend: &'static str,
    pub reps: usize,
    pub arena_wall_s: f64,
    pub legacy_wall_s: f64,
    pub speedup: f64,
    pub rows_per_s_arena: f64,
    pub rows_per_s_legacy: f64,
    pub max_abs_diff: f64,
}

impl PredictBench {
    pub fn print_summary(&self) {
        println!(
            "all-gears prediction over {} apps ({} reps, {}): legacy {:.3}s  arena {:.3}s  speedup {:.1}x",
            self.rows.len(),
            self.reps,
            self.backend,
            self.legacy_wall_s,
            self.arena_wall_s,
            self.speedup
        );
        println!(
            "gear rows/sec: arena {:.0}  legacy {:.0}  max |arena - legacy| = {:e}",
            self.rows_per_s_arena, self.rows_per_s_legacy, self.max_abs_diff
        );
    }
}

/// `gpoeo experiment predict-bench [--quick] [--reps N] [--bench PATH]`
///
/// For every evaluation app, measures one optimization step's model
/// cost — `predict_sm` + `predict_mem` over all ~99 SM + 5 memory
/// gears — on both native inference paths:
///
/// - **legacy**: the pre-arena walk (feature vector rebuilt per gear,
///   `Vec`-of-`Vec` trees chased node by node);
/// - **arena**: one feature matrix per call, SoA node pools, tree-major
///   batched traversal ([`crate::model::GbtArena`]).
///
/// Outputs are compared (max-abs-diff; 0.0 by the bit-identity
/// contract) and wall-clock, rows/sec and speedup are tabulated and
/// appended to `BENCH_predict.json`. Runs on the trained artifacts when
/// present, else on a deterministic synthetic bundle of the same shape
/// — so the CI gate (`--min-speedup`) needs no `make artifacts`.
pub fn predict_bench(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<PredictBench> {
    let (models, backend) = NativeModels::load_default_or_synthetic()?;
    if backend == "native-synthetic" {
        println!("(artifacts missing: benchmarking the synthetic model bundle)");
    }
    let predictor = Predictor::Native(models.clone());

    let mut apps = crate::experiments::helpers::evaluation_apps(spec)?;
    if quick {
        apps = apps.into_iter().step_by(6).collect();
    }
    let reps = args.opt_f64("reps", if quick { 40.0 } else { 150.0 })? as usize;
    anyhow::ensure!(reps > 0, "--reps must be positive");

    let sm_rows = spec.gears.sm_gears().count();
    let mem_rows = spec.gears.num_mem_gears();
    let mut rows = Vec::new();
    for app in &apps {
        // Features as measured online (the Figs. 9–12 recipe).
        let mut rng = Pcg64::new(app.trace_seed ^ 0x00fe_a7, 0x5eed);
        let feats = app.measured_features(spec, &mut rng);

        // Correctness first: one paired evaluation, max-abs-diff.
        let sm_a = predictor.predict_sm(spec, &feats)?;
        let mem_a = predictor.predict_mem(spec, &feats)?;
        let sm_l = models.legacy_predict_sm(spec, &feats);
        let mem_l = models.legacy_predict_mem(spec, &feats);
        // Bit-compare, not float-compare: `f64::max` quietly drops a
        // NaN difference, which would let a NaN-producing regression
        // sail through the `max_abs_diff == 0.0` CI gate.
        let mut diff = 0.0f64;
        let mut note = |got: f64, want: f64| {
            if got.to_bits() != want.to_bits() {
                let d = (got - want).abs();
                diff = diff.max(if d.is_nan() { f64::INFINITY } else { d });
            }
        };
        for (a, l) in [(&sm_a, &sm_l), (&mem_a, &mem_l)] {
            for i in 0..a.gears.len() {
                note(a.energy_ratio[i], l.energy_ratio[i]);
                note(a.time_ratio[i], l.time_ratio[i]);
            }
        }

        // Timed passes (one unmeasured warmup each).
        let _ = std::hint::black_box(predictor.predict_sm(spec, &feats)?);
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(predictor.predict_sm(spec, &feats)?);
            std::hint::black_box(predictor.predict_mem(spec, &feats)?);
        }
        let arena_wall_s = t0.elapsed().as_secs_f64();

        let _ = std::hint::black_box(models.legacy_predict_sm(spec, &feats));
        let t1 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(models.legacy_predict_sm(spec, &feats));
            std::hint::black_box(models.legacy_predict_mem(spec, &feats));
        }
        let legacy_wall_s = t1.elapsed().as_secs_f64();

        rows.push(PredictBenchRow {
            app: app.name.clone(),
            arena_wall_s,
            legacy_wall_s,
            max_abs_diff: diff,
        });
    }

    let arena_total: f64 = rows.iter().map(|r| r.arena_wall_s).sum();
    let legacy_total: f64 = rows.iter().map(|r| r.legacy_wall_s).sum();
    let speedup = legacy_total / arena_total.max(1e-12);
    let gear_rows = (rows.len() * reps * (sm_rows + mem_rows)) as f64;
    let max_abs_diff = rows.iter().map(|r| r.max_abs_diff).fold(0.0, f64::max);

    let mut table = Table::new(
        &format!(
            "Predict-bench — arena vs legacy all-gears prediction, {} apps x {reps} reps, {backend}{}",
            rows.len(),
            if quick { ", --quick" } else { "" }
        ),
        &["app", "arena ms", "legacy ms", "speedup", "max |diff|"],
    );
    for r in &rows {
        table.rowf(&[
            s(&r.app),
            Cell::F(r.arena_wall_s * 1e3, 2),
            Cell::F(r.legacy_wall_s * 1e3, 2),
            Cell::F(r.legacy_wall_s / r.arena_wall_s.max(1e-12), 1),
            s(&format!("{:e}", r.max_abs_diff)),
        ]);
    }

    let report = PredictBench {
        table,
        backend,
        reps,
        arena_wall_s: arena_total,
        legacy_wall_s: legacy_total,
        speedup,
        rows_per_s_arena: gear_rows / arena_total.max(1e-12),
        rows_per_s_legacy: gear_rows / legacy_total.max(1e-12),
        max_abs_diff,
        rows,
    };
    let bench_path = args.opt_or("bench", "BENCH_predict.json");
    write_predict_bench(bench_path, quick, &report)?;
    println!("bench record appended to {bench_path}");
    Ok(report)
}

/// Append one predict-bench record (`runs[]` keeps the history;
/// `per_app` holds the latest per-app numbers — the
/// `BENCH_detection.json` pattern).
fn write_predict_bench(path: &str, quick: bool, r: &PredictBench) -> anyhow::Result<()> {
    let num = |x: f64| Json::Num(if x.is_finite() { x } else { -1.0 });
    let per_app: Vec<Json> = r
        .rows
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("app", Json::Str(row.app.clone())),
                ("arena_wall_s", num(row.arena_wall_s)),
                ("legacy_wall_s", num(row.legacy_wall_s)),
                (
                    "speedup",
                    num(row.legacy_wall_s / row.arena_wall_s.max(1e-12)),
                ),
                ("max_abs_diff", num(row.max_abs_diff)),
            ])
        })
        .collect();

    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let run = Json::obj(vec![
        ("unix_time_s", Json::Num(unix_s)),
        ("quick", Json::Bool(quick)),
        ("backend", Json::Str(r.backend.to_string())),
        ("apps", Json::Num(r.rows.len() as f64)),
        ("reps", Json::Num(r.reps as f64)),
        ("legacy_wall_s", num(r.legacy_wall_s)),
        ("arena_wall_s", num(r.arena_wall_s)),
        ("speedup", num(r.speedup)),
        ("rows_per_s_arena", num(r.rows_per_s_arena)),
        ("rows_per_s_legacy", num(r.rows_per_s_legacy)),
        ("max_abs_diff", num(r.max_abs_diff)),
    ]);

    let mut runs = Json::bench_runs(path);
    runs.push(run);
    let doc = Json::obj(vec![
        ("runs", Json::Arr(runs)),
        ("per_app", Json::Arr(per_app)),
    ]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeModels, Predictor};

    #[test]
    fn prediction_errors_in_paper_ballpark() {
        let Ok(native) = NativeModels::load_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let spec = Arc::new(Spec::load_default().unwrap());
        let r = run(&spec, &Predictor::Native(native)).unwrap();
        assert_eq!(r.sm_n, 55 * 99);
        assert_eq!(r.mem_n, 55 * 5);
        // Paper: ~2-3% mean APE. Gate generously at 8%.
        assert!(r.sm_mean_eng < 0.08, "sm eng APE {}", r.sm_mean_eng);
        assert!(r.sm_mean_time < 0.08, "sm time APE {}", r.sm_mean_time);
    }
}
