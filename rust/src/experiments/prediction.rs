//! Prediction-accuracy experiments (Figs. 9–12): energy/time prediction
//! errors of the four GBT models on the 55 benchmarking-gnns apps, with
//! features measured online (one noisy counter period), grouped by clock
//! range (9/11) and by dataset (10/12).

use crate::model::Predictor;
use crate::sim::{make_suite, AppParams, Spec};
use crate::util::rng::Pcg64;
use crate::util::stats::{mean, percentile};
use crate::util::table::{s, Cell, Table};
use std::collections::BTreeMap;
use std::sync::Arc;

/// One (app, gear) prediction-error record.
struct Record {
    dataset: String,
    sm_mhz: f64,
    mem_mhz: f64,
    eng_ape: f64,
    time_ape: f64,
}

fn dataset_of(app: &AppParams) -> String {
    app.name.split('_').next().unwrap_or("?").to_string()
}

/// Collect prediction errors over the GNN suite (the paper's §5.3 setup:
/// 55 apps × 99 SM gears × 2 objectives → 11,660 SM predictions;
/// 55 × 5 × 2 → 550 memory predictions).
fn collect(spec: &Spec, predictor: &Predictor) -> anyhow::Result<(Vec<Record>, Vec<Record>)> {
    let mut sm_records = Vec::new();
    let mut mem_records = Vec::new();
    for app in make_suite(spec, "gnns")? {
        // Features as measured online: one counter period of noise.
        let mut rng = Pcg64::new(app.trace_seed ^ 0x00fe_a7, 0x5eed);
        let feats = app.measured_features(spec, &mut rng);

        let sm_pred = predictor.predict_sm(spec, &feats)?;
        for (i, g) in spec.gears.sm_gears().enumerate() {
            let (e, t) = app.ratios_vs_default(spec, g, spec.gears.default_mem_gear);
            sm_records.push(Record {
                dataset: dataset_of(&app),
                sm_mhz: spec.gears.sm_mhz(g),
                mem_mhz: 0.0,
                eng_ape: (sm_pred.energy_ratio[i] - e).abs() / e,
                time_ape: (sm_pred.time_ratio[i] - t).abs() / t,
            });
        }

        // Memory models assume the optimal SM gear (§4.3.2).
        let g_opt = crate::coordinator::oracle_ordered(
            &app,
            spec,
            crate::search::Objective::paper_default(),
        )
        .sm_gear;
        let mem_pred = predictor.predict_mem(spec, &feats)?;
        for m in 0..spec.gears.num_mem_gears() {
            let (e, t) = app.ratios_vs_default(spec, g_opt, m);
            mem_records.push(Record {
                dataset: dataset_of(&app),
                sm_mhz: 0.0,
                mem_mhz: spec.gears.mem_mhz_of(m),
                eng_ape: (mem_pred.energy_ratio[m] - e).abs() / e,
                time_ape: (mem_pred.time_ratio[m] - t).abs() / t,
            });
        }
    }
    Ok((sm_records, mem_records))
}

fn grouped_table(
    title: &str,
    records: &[Record],
    group_of: impl Fn(&Record) -> String,
) -> Table {
    let mut groups: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for r in records {
        let e = groups.entry(group_of(r)).or_default();
        e.0.push(r.eng_ape);
        e.1.push(r.time_ape);
    }
    let mut t = Table::new(
        title,
        &[
            "group", "n", "eng mean", "eng p50", "eng p90", "time mean", "time p50", "time p90",
        ],
    );
    for (g, (es, ts)) in groups {
        t.rowf(&[
            s(g),
            Cell::U(es.len()),
            Cell::Pct(mean(&es)),
            Cell::Pct(percentile(&es, 50.0)),
            Cell::Pct(percentile(&es, 90.0)),
            Cell::Pct(mean(&ts)),
            Cell::Pct(percentile(&ts, 50.0)),
            Cell::Pct(percentile(&ts, 90.0)),
        ]);
    }
    t
}

/// Grouping for Fig. 9: ~150 MHz SM clock ranges.
fn sm_range(mhz: f64) -> String {
    let lo = ((mhz - 450.0) / 150.0).floor() as usize * 150 + 450;
    format!("{:04}-{:04} MHz", lo, lo + 150)
}

pub struct PredictionReport {
    pub fig9: Table,
    pub fig10: Table,
    pub fig11: Table,
    pub fig12: Table,
    pub sm_mean_eng: f64,
    pub sm_mean_time: f64,
    pub mem_mean_eng: f64,
    pub mem_mean_time: f64,
    pub sm_n: usize,
    pub mem_n: usize,
}

pub fn run(spec: &Arc<Spec>, predictor: &Predictor) -> anyhow::Result<PredictionReport> {
    let (sm, mem) = collect(spec, predictor)?;
    let fig9 = grouped_table(
        "Fig 9 — SM-model prediction errors by clock range (55 gnn apps)",
        &sm,
        |r| sm_range(r.sm_mhz),
    );
    let fig10 = grouped_table(
        "Fig 10 — SM-model prediction errors by dataset",
        &sm,
        |r| r.dataset.clone(),
    );
    let fig11 = grouped_table(
        "Fig 11 — memory-model prediction errors by memory clock",
        &mem,
        |r| format!("{:>5.0} MHz", r.mem_mhz),
    );
    let fig12 = grouped_table(
        "Fig 12 — memory-model prediction errors by dataset",
        &mem,
        |r| r.dataset.clone(),
    );
    let report = PredictionReport {
        sm_mean_eng: mean(&sm.iter().map(|r| r.eng_ape).collect::<Vec<_>>()),
        sm_mean_time: mean(&sm.iter().map(|r| r.time_ape).collect::<Vec<_>>()),
        mem_mean_eng: mean(&mem.iter().map(|r| r.eng_ape).collect::<Vec<_>>()),
        mem_mean_time: mean(&mem.iter().map(|r| r.time_ape).collect::<Vec<_>>()),
        sm_n: sm.len(),
        mem_n: mem.len(),
        fig9,
        fig10,
        fig11,
        fig12,
    };
    Ok(report)
}

impl PredictionReport {
    pub fn print_summary(&self) {
        println!(
            "SM models: {} predictions/objective — mean APE eng {:.2}% (paper 3.05%), time {:.2}% (paper 2.09%)",
            self.sm_n,
            self.sm_mean_eng * 100.0,
            self.sm_mean_time * 100.0
        );
        println!(
            "mem models: {} predictions/objective — mean APE eng {:.2}% (paper 2.72%), time {:.2}% (paper 2.31%)",
            self.mem_n,
            self.mem_mean_eng * 100.0,
            self.mem_mean_time * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NativeModels, Predictor};

    #[test]
    fn prediction_errors_in_paper_ballpark() {
        let Ok(native) = NativeModels::load_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let spec = Arc::new(Spec::load_default().unwrap());
        let r = run(&spec, &Predictor::Native(native)).unwrap();
        assert_eq!(r.sm_n, 55 * 99);
        assert_eq!(r.mem_n, 55 * 5);
        // Paper: ~2-3% mean APE. Gate generously at 8%.
        assert!(r.sm_mean_eng < 0.08, "sm eng APE {}", r.sm_mean_eng);
        assert!(r.sm_mean_time < 0.08, "sm time APE {}", r.sm_mean_time);
    }
}
