//! Sim-bench: stepped vs fast-forward simulation cost (DESIGN.md §13).
//!
//! For every evaluation app this drives two fresh simulated devices over
//! the same iteration target under the default policy's tick:
//!
//! - **reference** — the pre-segment-cache per-tick body
//!   (`advance_reference`), which recomputes the operating point, time
//!   factor and phase mix on every tick;
//! - **fast** — the segment fast-forward (`advance_until`), which
//!   revalidates one cached segment key per tick and integrates from
//!   cached constants.
//!
//! The two paths draw identical RNG streams in identical order, so the
//! end states must agree *bit for bit* — the reported divergence is
//! expected to be exactly 0.0 and is gated at ≤1e-9 in CI. Results are
//! appended to `BENCH_sim.json` (`runs[]` history + latest `per_app`,
//! the `BENCH_detection.json` pattern).

use crate::device::sim_device;
use crate::experiments::helpers::evaluation_apps;
use crate::sim::{run_budget_s, Spec};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{s, Cell, Table};
use std::sync::Arc;

/// Default-policy tick (matches `DefaultPolicy { ts: 0.025 }` everywhere).
const TS: f64 = 0.025;

#[derive(Debug, Clone)]
pub struct SimBenchRow {
    pub app: String,
    pub aperiodic: bool,
    pub iters: u64,
    /// Virtual seconds simulated (identical across both passes).
    pub sim_s: f64,
    pub ref_wall_s: f64,
    pub fast_wall_s: f64,
    /// Max relative end-state divergence (energy, time, iterations)
    /// between the two passes. Expected exactly 0.0.
    pub divergence: f64,
}

pub struct SimBench {
    pub table: Table,
    pub rows: Vec<SimBenchRow>,
    pub ref_wall_s: f64,
    pub fast_wall_s: f64,
    pub speedup: f64,
    /// Virtual sim seconds advanced per wall second on the fast path.
    pub sim_s_per_wall_s: f64,
    pub max_divergence: f64,
}

impl SimBench {
    pub fn print_summary(&self) {
        println!(
            "sim-bench over {} apps: stepped {:.3}s, fast-forward {:.3}s — {:.1}x speedup, {:.0} sim-s/s, max divergence {:e}",
            self.rows.len(),
            self.ref_wall_s,
            self.fast_wall_s,
            self.speedup,
            self.sim_s_per_wall_s,
            self.max_divergence
        );
    }
}

fn rel_div(a: f64, b: f64) -> f64 {
    if a == b {
        return 0.0; // bit-equal (covers 0==0 without the denominator guard)
    }
    (a - b).abs() / b.abs().max(1e-30)
}

/// Run the benchmark. `--quick` trims the suite and the per-app target;
/// `--reps N` takes best-of-N wall times (divergence is checked on every
/// rep); `--min-speedup` is gated by the caller, not here.
pub fn run(spec: &Arc<Spec>, args: &Args, quick: bool) -> anyhow::Result<SimBench> {
    let reps = args.opt_f64("reps", 1.0)?.max(1.0) as usize;
    let all = evaluation_apps(spec)?;
    let apps: Vec<_> = if quick {
        // Every 9th app keeps all three suites represented.
        all.into_iter().step_by(9).collect()
    } else {
        all
    };
    let iters: u64 = if quick { 80 } else { 400 };

    let mut rows: Vec<SimBenchRow> = Vec::with_capacity(apps.len());
    for app in &apps {
        let mut ref_wall = f64::INFINITY;
        let mut fast_wall = f64::INFINITY;
        let mut divergence: f64 = 0.0;
        let mut sim_s = 0.0;
        for _ in 0..reps {
            // Reference pass: the historical per-tick body, stepped.
            let mut r = sim_device(spec, app);
            let budget = run_budget_s(r.time_s(), iters, app.t_base);
            let t0 = std::time::Instant::now();
            while r.iterations() < iters && r.time_s() < budget {
                r.advance_reference(TS);
            }
            ref_wall = ref_wall.min(t0.elapsed().as_secs_f64());

            // Fast pass: segment fast-forward over the same target.
            let mut f = sim_device(spec, app);
            let t1 = std::time::Instant::now();
            f.advance_until(iters, budget, TS);
            fast_wall = fast_wall.min(t1.elapsed().as_secs_f64());

            divergence = divergence
                .max(rel_div(f.true_energy_j(), r.true_energy_j()))
                .max(rel_div(f.time_s(), r.time_s()))
                .max(rel_div(f.iterations() as f64, r.iterations() as f64));
            sim_s = r.time_s();
        }
        rows.push(SimBenchRow {
            app: app.name.clone(),
            aperiodic: app.aperiodic,
            iters,
            sim_s,
            ref_wall_s: ref_wall,
            fast_wall_s: fast_wall,
            divergence,
        });
    }

    let ref_total: f64 = rows.iter().map(|r| r.ref_wall_s).sum();
    let fast_total: f64 = rows.iter().map(|r| r.fast_wall_s).sum();
    let sim_total: f64 = rows.iter().map(|r| r.sim_s).sum();
    let speedup = ref_total / fast_total.max(1e-12);
    let sim_s_per_wall_s = sim_total / fast_total.max(1e-12);
    let max_divergence = rows.iter().map(|r| r.divergence).fold(0.0, f64::max);

    let mut table = Table::new(
        &format!(
            "Sim-bench — stepped vs segment fast-forward, {} apps x {iters} iters{}",
            rows.len(),
            if quick { ", --quick" } else { "" }
        ),
        &["app", "sim s", "stepped ms", "fast ms", "speedup", "sim-s/s", "divergence"],
    );
    for r in &rows {
        table.rowf(&[
            s(&r.app),
            Cell::F(r.sim_s, 1),
            Cell::F(r.ref_wall_s * 1e3, 2),
            Cell::F(r.fast_wall_s * 1e3, 2),
            Cell::F(r.ref_wall_s / r.fast_wall_s.max(1e-12), 1),
            Cell::F(r.sim_s / r.fast_wall_s.max(1e-12), 0),
            s(&format!("{:e}", r.divergence)),
        ]);
    }

    let bench_path = args.opt_or("bench", "BENCH_sim.json");
    write_bench(
        bench_path,
        quick,
        reps,
        ref_total,
        fast_total,
        speedup,
        sim_s_per_wall_s,
        max_divergence,
        &rows,
    )?;
    println!("bench record appended to {bench_path}");

    Ok(SimBench {
        table,
        rows,
        ref_wall_s: ref_total,
        fast_wall_s: fast_total,
        speedup,
        sim_s_per_wall_s,
        max_divergence,
    })
}

/// Append one sim-bench record (`runs[]` keeps the history; `per_app`
/// holds the latest per-app numbers — the `BENCH_detection.json` pattern).
#[allow(clippy::too_many_arguments)]
fn write_bench(
    path: &str,
    quick: bool,
    reps: usize,
    ref_total: f64,
    fast_total: f64,
    speedup: f64,
    sim_s_per_wall_s: f64,
    max_divergence: f64,
    rows: &[SimBenchRow],
) -> anyhow::Result<()> {
    let num = |x: f64| Json::Num(if x.is_finite() { x } else { -1.0 });
    let per_app: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("app", Json::Str(r.app.clone())),
                ("aperiodic", Json::Bool(r.aperiodic)),
                ("iters", Json::Num(r.iters as f64)),
                ("sim_s", num(r.sim_s)),
                ("stepped_wall_s", num(r.ref_wall_s)),
                ("fast_wall_s", num(r.fast_wall_s)),
                ("speedup", num(r.ref_wall_s / r.fast_wall_s.max(1e-12))),
                ("divergence", num(r.divergence)),
            ])
        })
        .collect();

    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let run = Json::obj(vec![
        ("unix_time_s", Json::Num(unix_s)),
        ("quick", Json::Bool(quick)),
        ("reps", Json::Num(reps as f64)),
        ("apps", Json::Num(rows.len() as f64)),
        ("stepped_wall_s", num(ref_total)),
        ("fast_wall_s", num(fast_total)),
        ("speedup", num(speedup)),
        ("sim_s_per_wall_s", num(sim_s_per_wall_s)),
        ("max_divergence", num(max_divergence)),
    ]);

    let mut runs = Json::bench_runs(path);
    runs.push(run);
    let doc = Json::obj(vec![
        ("runs", Json::Arr(runs)),
        ("per_app", Json::Arr(per_app)),
    ]);
    std::fs::write(path, doc.to_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The bench's own correctness invariant, cheap enough for tier-1:
    /// the two passes it compares must be bit-identical on a small run.
    #[test]
    fn bench_passes_agree_bitwise() {
        let spec = Arc::new(Spec::load_default().unwrap());
        for name in ["AI_I2T", "TSVM"] {
            let app = crate::sim::find_app(&spec, name).unwrap();
            let iters = 30;
            let mut r = sim_device(&spec, &app);
            let budget = run_budget_s(r.time_s(), iters, app.t_base);
            while r.iterations() < iters && r.time_s() < budget {
                r.advance_reference(TS);
            }
            let mut f = sim_device(&spec, &app);
            f.advance_until(iters, budget, TS);
            assert_eq!(f.true_energy_j(), r.true_energy_j(), "{name}: energy");
            assert_eq!(f.iterations(), r.iterations(), "{name}: iterations");
            assert_eq!(f.time_s(), r.time_s(), "{name}: time");
        }
    }
}
