//! # gpoeo — Dynamic GPU Energy Optimization for ML Training Workloads
//!
//! A full reproduction of **GPOEO** (Wang et al., IEEE TPDS 2022): an
//! online GPU energy-optimization framework that detects training-
//! iteration periods from power/utilization traces, profiles performance
//! counters for a single period, predicts the energy/time impact of every
//! SM and memory clock gear with gradient-boosted tree models, and golden-
//! section-searches around the predicted optimum.
//!
//! Because the paper's testbed (RTX3080Ti + NVML + CUPTI) is hardware we
//! do not have, the [`sim`] module provides a calibrated, deterministic
//! simulation of it, surfaced to the controller through the [`device`]
//! abstraction — the entire `coordinator` stack is written against
//! `dyn Device`, so an NVML-backed device slots in without touching the
//! control logic. Prediction models are trained offline in Python
//! (`python/compile/`), AOT-lowered to HLO, and executed at runtime by
//! the PJRT CPU client in `runtime` — Python is never on the request
//! path.
//!
//! Layer map (see DESIGN.md):
//! - L4: [`api`] — the control plane: protocol v1 (typed
//!   request/response/event enums over line-delimited JSON),
//!   `GpoeoClient`, legacy-compat client, `gpoeo ctl`
//! - L3: `coordinator` (controller, fleet, daemon), `policy` (registry
//!   + the bandit/power-cap families), [`arbiter`] (fleet power-budget
//!   allocation), `signal`, `search`, `experiments` — all
//!   device-agnostic via [`device`]
//! - Device backends: [`sim`] today; NVML tomorrow
//! - L2/L1 artifacts: built by `make artifacts`, loaded by `runtime`

pub mod api;
pub mod arbiter;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod experiments;
pub mod lint;
pub mod model;
pub mod policy;
pub mod search;
pub mod runtime;
pub mod signal;
pub mod sim;
pub mod telemetry;
pub mod util;
