//! Hand-rolled Rust lexer for the lint pass (DESIGN.md §12).
//!
//! The rule engines need exactly three things a grep cannot give them:
//! comments and string literals must not produce identifier matches
//! (`// calls unwrap()` is not a panic site), string literal *contents*
//! must survive as data (the policy-name and wire-literal rules match
//! on them), and every token must carry its source line. So this is a
//! token stream, not an AST: identifiers, string literals, numbers,
//! lifetimes and single-character punctuation, in source order, with
//! comments stripped but mined for `gpoeo-lint: allow(...)` waivers.
//!
//! Handled Rust lexical edge cases, because the tree uses them:
//! nested block comments, raw strings (`r"…"`, `r#"…"#`), byte and raw
//! byte strings, char literals vs lifetimes (`'a'` vs `'a`), raw
//! identifiers (`r#type`), and float literals vs method calls on
//! integers (`1.max(2)` lexes as number, dot, ident).

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String literal; `text` is the *content* (delimiters stripped,
    /// escapes left as written — rules match exact simple literals).
    Str,
    Num,
    Lifetime,
    /// Single-character punctuation (`::` is two consecutive `:`).
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// One `// gpoeo-lint: allow(RULE) reason` comment. Suppresses exactly
/// one finding of the named rule (or rule family) on the waiver's own
/// line or the line directly below it — so both trailing comments and
/// a standalone comment above the offending line work.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub line: u32,
    /// Rule id (`PF-INDEX`) or family keyword (`panic`, `layers`,
    /// `blocking`, `determinism`).
    pub rule: String,
    pub reason: String,
}

/// Lexed view of one source file.
#[derive(Debug)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub waivers: Vec<Waiver>,
}

const WAIVER_TAG: &str = "gpoeo-lint:";

/// Parse a waiver out of one comment's text, if present. Doc comments
/// (`///`, `//!`, `/**`, `/*!`) never carry waivers — they are prose
/// *about* the syntax (this module documents it), not directives.
fn parse_waiver(comment: &str, line: u32) -> Option<Waiver> {
    let doc = ["///", "//!", "/**", "/*!"]
        .iter()
        .any(|p| comment.starts_with(p));
    if doc {
        return None;
    }
    let at = comment.find(WAIVER_TAG)?;
    let rest = comment[at + WAIVER_TAG.len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() {
        return None;
    }
    let reason = rest[close + 1..].trim().to_string();
    Some(Waiver { line, rule, reason })
}

/// Tokenize `src`, stripping comments (mining them for waivers) and
/// converting string/char literals into single tokens.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut waivers = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    // Consume a quoted run starting at the opening `"` (index `i`),
    // returning (content, next index, lines crossed).
    fn take_string(b: &[char], mut i: usize, raw_hashes: Option<usize>) -> (String, usize, u32) {
        let n = b.len();
        let mut out = String::new();
        let mut crossed = 0u32;
        i += 1; // opening quote
        while i < n {
            let c = b[i];
            if c == '\n' {
                crossed += 1;
            }
            match raw_hashes {
                None => {
                    if c == '\\' && i + 1 < n {
                        out.push(c);
                        out.push(b[i + 1]);
                        if b[i + 1] == '\n' {
                            crossed += 1;
                        }
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        return (out, i + 1, crossed);
                    }
                }
                Some(h) => {
                    if c == '"' && b[i + 1..].iter().take(h).filter(|&&x| x == '#').count() == h {
                        return (out, i + 1 + h, crossed);
                    }
                }
            }
            out.push(c);
            i += 1;
        }
        (out, n, crossed)
    }

    while i < n {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                let start = i;
                while i < n && b[i] != '\n' {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                if let Some(w) = parse_waiver(&text, line) {
                    waivers.push(w);
                }
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                let start_line = line;
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                let text: String = b[start..i.min(n)].iter().collect();
                if let Some(w) = parse_waiver(&text, start_line) {
                    waivers.push(w);
                }
            }
            '"' => {
                let (s, j, crossed) = take_string(&b, i, None);
                toks.push(Tok { kind: TokKind::Str, text: s, line });
                line += crossed;
                i = j;
            }
            '\'' => {
                // Lifetime (`'a`, `'static`) vs char literal (`'a'`,
                // `'\n'`, `'\''`): an identifier run NOT followed by a
                // closing quote is a lifetime.
                let id_start = i + 1;
                let mut j = id_start;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                let is_lifetime = j > id_start && (j >= n || b[j] != '\'');
                if is_lifetime {
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: b[id_start..j].iter().collect(),
                        line,
                    });
                    i = j;
                } else {
                    // Char literal: consume to the closing quote,
                    // honoring `\'` and `\\`.
                    let mut j = i + 1;
                    while j < n {
                        if b[j] == '\\' {
                            j += 2;
                            continue;
                        }
                        if b[j] == '\'' {
                            j += 1;
                            break;
                        }
                        j += 1;
                    }
                    let end = j.saturating_sub(1).clamp(i + 1, n);
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: b[i + 1..end].iter().collect(),
                        line,
                    });
                    i = j.min(n);
                }
            }
            c if c.is_ascii_digit() => {
                let start = i;
                i += 1;
                while i < n {
                    let d = b[i];
                    if d.is_alphanumeric() || d == '_' {
                        i += 1;
                    } else if d == '.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                        // `1.5` continues the number; `1.max(2)` and
                        // `0..n` do not.
                        i += 2;
                    } else {
                        break;
                    }
                }
                toks.push(Tok {
                    kind: TokKind::Num,
                    text: b[start..i].iter().collect(),
                    line,
                });
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                let word: String = b[start..i].iter().collect();
                // Raw / byte string prefixes and raw identifiers.
                let mut hashes = 0usize;
                let mut j = i;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                let raw_str = matches!(word.as_str(), "r" | "br" | "rb")
                    && j < n
                    && b[j] == '"';
                let byte_str = word == "b" && hashes == 0 && i < n && b[i] == '"';
                if raw_str {
                    let (s, k, crossed) = take_string(&b, j, Some(hashes));
                    toks.push(Tok { kind: TokKind::Str, text: s, line });
                    line += crossed;
                    i = k;
                } else if byte_str {
                    let (s, k, crossed) = take_string(&b, i, None);
                    toks.push(Tok { kind: TokKind::Str, text: s, line });
                    line += crossed;
                    i = k;
                } else if word == "r" && hashes == 1 && j < n && (b[j].is_alphabetic() || b[j] == '_')
                {
                    // Raw identifier r#type → ident "type".
                    let start = j;
                    let mut k = j;
                    while k < n && (b[k].is_alphanumeric() || b[k] == '_') {
                        k += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Ident,
                        text: b[start..k].iter().collect(),
                        line,
                    });
                    i = k;
                } else {
                    toks.push(Tok { kind: TokKind::Ident, text: word, line });
                }
            }
            other => {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: other.to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    Lexed { toks, waivers }
}

/// Line ranges (inclusive) covered by `#[cfg(test)] mod … { … }`
/// blocks. Layer, panic and determinism contracts govern production
/// code; in-file test modules are exempt by construction (the
/// integration-test allowance of DESIGN.md §9).
pub fn test_mod_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        // #[cfg(test)]
        let is_cfg_test = toks[i].is_punct('#')
            && toks[i + 1].is_punct('[')
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is_punct('(')
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is_punct(')')
            && toks[i + 6].is_punct(']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // Skip any further attributes, then expect `mod name {`.
        let mut j = i + 7;
        while j + 1 < toks.len() && toks[j].is_punct('#') && toks[j + 1].is_punct('[') {
            let mut depth = 0usize;
            j += 1;
            while j < toks.len() {
                if toks[j].is_punct('[') {
                    depth += 1;
                } else if toks[j].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        if j < toks.len() && toks[j].is_ident("mod") {
            // `mod name {` — find the opening brace, then match it.
            let mut k = j + 1;
            while k < toks.len() && !toks[k].is_punct('{') && !toks[k].is_punct(';') {
                k += 1;
            }
            if k < toks.len() && toks[k].is_punct('{') {
                if let Some(end) = match_brace(toks, k) {
                    out.push((toks[i].line, toks[end].line));
                    i = end + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

/// Index of the `}` matching the `{` at `open`.
pub fn match_brace(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Token index ranges `(body_start, body_end)` (inclusive of braces)
/// for every `fn <name>` in `fns` (an empty list matches every fn).
/// Matches methods on any impl — a zone naming `emit` covers each
/// `fn emit` in the file.
pub fn fn_bodies(toks: &[Tok], fns: &[String]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].is_ident("fn")
            && toks[i + 1].kind == TokKind::Ident
            && (fns.is_empty() || fns.iter().any(|f| f == &toks[i + 1].text))
        {
            // Scan forward to the body's opening brace. Signatures
            // contain no braces; a `;` first means a trait declaration.
            let mut k = i + 2;
            while k < toks.len() && !toks[k].is_punct('{') && !toks[k].is_punct(';') {
                k += 1;
            }
            if k < toks.len() && toks[k].is_punct('{') {
                if let Some(end) = match_brace(toks, k) {
                    out.push((toks[i + 1].text.clone(), k, end));
                    i = end + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

/// Body token range of `impl <name> { … }` blocks (no generics walk:
/// matches `impl Name` and `impl Name for …` forms used in this tree).
pub fn impl_bodies(toks: &[Tok], name: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].is_ident("impl") && toks[i + 1].is_ident(name) {
            let mut k = i + 2;
            while k < toks.len() && !toks[k].is_punct('{') {
                k += 1;
            }
            if let Some(end) = match_brace(toks, k) {
                out.push((k, end));
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    out
}
