//! `lint.toml` — the DESIGN.md contracts as checked-in data.
//!
//! The rule engines are generic; *what* they enforce (the §0 layer DAG,
//! forbidden symbols, hot-path zones, determinism modules) lives in a
//! manifest next to `Cargo.toml`, so tightening a contract is a data
//! diff reviewers can read, not a code change. The parser covers the
//! TOML subset the manifest uses — `[section]` / `[[array-of-tables]]`
//! headers, `key = "string"`, `key = number`, and (possibly multi-line)
//! string arrays — and rejects anything else loudly rather than
//! guessing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One panic-freedom zone: the named fns of `file` must not contain the
/// listed panic classes (`unwrap`, `expect`, `panic`, `assert`,
/// `index`).
#[derive(Debug, Clone)]
pub struct PanicZone {
    pub file: String,
    pub fns: Vec<String>,
    pub checks: Vec<String>,
    /// Why this zone exists — carried into finding messages.
    pub contract: String,
}

/// One non-blocking zone: the named fns of `file` must not call any of
/// the banned identifiers (blocking I/O, lock acquisition, unbounded
/// sends, thread joins).
#[derive(Debug, Clone)]
pub struct NonblockZone {
    pub file: String,
    pub fns: Vec<String>,
    pub ban: Vec<String>,
    pub contract: String,
}

/// Lock-ordering check: inside `impl <imp>` in `file`, no single
/// statement may acquire two locks (the static shape of "holding one
/// shard while taking another").
#[derive(Debug, Clone)]
pub struct LockOrderZone {
    pub file: String,
    pub imp: String,
    pub contract: String,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from; file paths are relative
    /// to it.
    pub base: PathBuf,
    /// Source roots to scan, relative to `base`.
    pub roots: Vec<String>,
    /// §0 layer DAG: module → modules it may reference (itself always
    /// allowed).
    pub deps: BTreeMap<String, Vec<String>>,
    /// Modules allowed to name `SimGpu` (§0: the concrete simulator
    /// never leaks past the device boundary).
    pub simgpu_modules: Vec<String>,
    /// Registered policy names (§8: nothing outside `policy/` may match
    /// on them).
    pub policy_names: Vec<String>,
    /// Wire-protocol literals (§9: live in `api/` only).
    pub wire_literals: Vec<String>,
    /// Path prefixes where protocol symbols are allowed.
    pub proto_allowed: Vec<String>,
    /// `Telemetry::<ctor>` calls checked by LB-TEL…
    pub telemetry_ctors: Vec<String>,
    /// …and the files allowed to make them (§11: daemon/CLI edges).
    pub telemetry_allowed: Vec<String>,
    pub panic_zones: Vec<PanicZone>,
    pub nonblock_zones: Vec<NonblockZone>,
    pub lock_orders: Vec<LockOrderZone>,
    /// Determinism (§1): module path prefixes…
    pub det_modules: Vec<String>,
    /// …banned `A::b` clock calls (`Instant::now` — the bare ident
    /// `Instant` cannot be banned because `sim::Instant` is the
    /// simulator's own virtual-time sample)…
    pub det_clock_calls: Vec<String>,
    /// …banned bare clock identifiers (`SystemTime`, `UNIX_EPOCH`)…
    pub det_clock_idents: Vec<String>,
    /// …and banned OS-randomness identifiers (`thread_rng`,
    /// `RandomState`).
    pub det_random_idents: Vec<String>,
}

#[derive(Debug, Clone)]
enum Val {
    Str(String),
    Arr(Vec<String>),
}

fn parse_string(s: &str) -> Result<(String, &str), String> {
    let s = s.trim_start();
    let rest = s
        .strip_prefix('"')
        .ok_or_else(|| format!("expected string, got '{s}'"))?;
    let end = rest.find('"').ok_or("unterminated string")?;
    Ok((rest[..end].to_string(), &rest[end + 1..]))
}

fn parse_value(s: &str) -> Result<Val, String> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or("unterminated array")?
            .trim();
        let mut out = Vec::new();
        let mut rest = inner;
        while !rest.trim().is_empty() {
            let (v, r) = parse_string(rest)?;
            out.push(v);
            rest = r.trim_start();
            rest = rest.strip_prefix(',').unwrap_or(rest);
        }
        return Ok(Val::Arr(out));
    }
    if s.starts_with('"') {
        let (v, rest) = parse_string(s)?;
        if !rest.trim().is_empty() {
            return Err(format!("trailing input after string: '{rest}'"));
        }
        return Ok(Val::Str(v));
    }
    Err(format!("unsupported value '{s}' (string or string array)"))
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading lint manifest {}: {e}", path.display()))?;
        let base = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        Manifest::parse(&text, base)
            .map_err(|e| anyhow::anyhow!("parsing lint manifest {}: {e}", path.display()))
    }

    pub fn parse(text: &str, base: PathBuf) -> Result<Manifest, String> {
        let mut m = Manifest {
            base,
            ..Manifest::default()
        };
        let mut section = String::new();

        // Join multi-line arrays: buffer physical lines until brackets
        // balance outside strings.
        let mut logical: Vec<(usize, String)> = Vec::new();
        let mut buf = String::new();
        let mut buf_line = 0usize;
        let mut depth = 0i32;
        for (ln, raw) in text.lines().enumerate() {
            let stripped = strip_comment(raw);
            if buf.is_empty() {
                if stripped.trim().is_empty() {
                    continue;
                }
                buf_line = ln + 1;
            }
            depth += bracket_delta(&stripped);
            buf.push_str(&stripped);
            buf.push(' ');
            if depth <= 0 {
                logical.push((buf_line, std::mem::take(&mut buf)));
                depth = 0;
            }
        }
        if !buf.trim().is_empty() {
            return Err(format!("unterminated array starting at line {buf_line}"));
        }

        for (ln, line) in logical {
            let line = line.trim();
            let err = |msg: String| format!("line {ln}: {msg}");
            if let Some(h) = line.strip_prefix("[[") {
                let name = h
                    .strip_suffix("]]")
                    .ok_or_else(|| err("bad table header".into()))?
                    .trim();
                section = name.to_string();
                match name {
                    "zone.panic" => m.panic_zones.push(PanicZone {
                        file: String::new(),
                        fns: vec![],
                        checks: vec![],
                        contract: String::new(),
                    }),
                    "zone.nonblocking" => m.nonblock_zones.push(NonblockZone {
                        file: String::new(),
                        fns: vec![],
                        ban: vec![],
                        contract: String::new(),
                    }),
                    "zone.lock_order" => m.lock_orders.push(LockOrderZone {
                        file: String::new(),
                        imp: String::new(),
                        contract: String::new(),
                    }),
                    other => return Err(err(format!("unknown table '{other}'"))),
                }
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                section = h
                    .strip_suffix(']')
                    .ok_or_else(|| err("bad section header".into()))?
                    .trim()
                    .to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err(format!("expected key = value, got '{line}'")))?;
            let key = key.trim();
            let val = parse_value(val).map_err(err)?;
            let str_of = |v: &Val| -> Result<String, String> {
                match v {
                    Val::Str(s) => Ok(s.clone()),
                    Val::Arr(_) => Err(err(format!("'{key}' expects a string"))),
                }
            };
            let arr_of = |v: &Val| -> Result<Vec<String>, String> {
                match v {
                    Val::Arr(a) => Ok(a.clone()),
                    Val::Str(_) => Err(err(format!("'{key}' expects an array"))),
                }
            };
            match (section.as_str(), key) {
                ("files", "roots") => m.roots = arr_of(&val)?,
                ("layers.deps", module) => {
                    m.deps.insert(module.to_string(), arr_of(&val)?);
                }
                ("layers.symbols", "simgpu_modules") => m.simgpu_modules = arr_of(&val)?,
                ("layers.symbols", "policy_names") => m.policy_names = arr_of(&val)?,
                ("layers.symbols", "wire_literals") => m.wire_literals = arr_of(&val)?,
                ("layers.symbols", "proto_allowed") => m.proto_allowed = arr_of(&val)?,
                ("layers.symbols", "telemetry_ctors") => m.telemetry_ctors = arr_of(&val)?,
                ("layers.symbols", "telemetry_allowed") => m.telemetry_allowed = arr_of(&val)?,
                ("determinism", "modules") => m.det_modules = arr_of(&val)?,
                ("determinism", "clock_calls") => m.det_clock_calls = arr_of(&val)?,
                ("determinism", "clock_idents") => m.det_clock_idents = arr_of(&val)?,
                ("determinism", "random_idents") => m.det_random_idents = arr_of(&val)?,
                ("zone.panic", k) => {
                    let z = m
                        .panic_zones
                        .last_mut()
                        .ok_or_else(|| err("key outside [[zone.panic]]".into()))?;
                    match k {
                        "file" => z.file = str_of(&val)?,
                        "fns" => z.fns = arr_of(&val)?,
                        "checks" => z.checks = arr_of(&val)?,
                        "contract" => z.contract = str_of(&val)?,
                        other => return Err(err(format!("unknown zone.panic key '{other}'"))),
                    }
                }
                ("zone.nonblocking", k) => {
                    let z = m
                        .nonblock_zones
                        .last_mut()
                        .ok_or_else(|| err("key outside [[zone.nonblocking]]".into()))?;
                    match k {
                        "file" => z.file = str_of(&val)?,
                        "fns" => z.fns = arr_of(&val)?,
                        "ban" => z.ban = arr_of(&val)?,
                        "contract" => z.contract = str_of(&val)?,
                        other => {
                            return Err(err(format!("unknown zone.nonblocking key '{other}'")))
                        }
                    }
                }
                ("zone.lock_order", k) => {
                    let z = m
                        .lock_orders
                        .last_mut()
                        .ok_or_else(|| err("key outside [[zone.lock_order]]".into()))?;
                    match k {
                        "file" => z.file = str_of(&val)?,
                        "impl" => z.imp = str_of(&val)?,
                        "contract" => z.contract = str_of(&val)?,
                        other => return Err(err(format!("unknown zone.lock_order key '{other}'"))),
                    }
                }
                (sec, k) => return Err(err(format!("unknown key '{k}' in section '[{sec}]'"))),
            }
        }
        if m.roots.is_empty() {
            m.roots.push("src".to_string());
        }
        Ok(m)
    }
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> String {
    let mut out = String::new();
    let mut in_str = false;
    for c in line.chars() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

/// Net `[`/`]` nesting delta outside string literals.
fn bracket_delta(line: &str) -> i32 {
    let mut d = 0i32;
    let mut in_str = false;
    for c in line.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => d += 1,
            ']' if !in_str => d -= 1,
            _ => {}
        }
    }
    d
}
