//! `gpoeo lint` — machine-checked DESIGN.md contracts (§12).
//!
//! PRs 1–7 accumulated prose invariants: the §0 layer DAG, §1 simulator
//! determinism, §2/§3 bit-identity hot paths, §8 registry-only policy
//! dispatch, §9 protocol-string containment, §10 reactor-never-blocks,
//! §11 non-blocking-or-nothing telemetry. The api-bench gate catches a
//! blocking call only *after* it regresses p99; this pass catches the
//! code shape itself, before it ships. It is dependency-free by
//! construction (hand-rolled [`lexer`], no crates.io parsers — the
//! vendored-shim policy applies to the linter too) and data-driven: the
//! contracts live in `rust/lint.toml` ([`manifest`]), so tightening a
//! zone is a reviewable data diff.
//!
//! Waivers are explicit and budgeted: an inline
//! `// gpoeo-lint: allow(RULE) reason` suppresses exactly one finding
//! on its own or the following line, and every waiver is counted and
//! echoed in the report — silence is never free.

pub mod lexer;
pub mod manifest;
pub mod rules;

pub use manifest::Manifest;
pub use rules::Finding;

use crate::util::cli::Args;
use crate::util::json::Json;
use rules::FileCtx;
use std::path::{Path, PathBuf};

/// A finding suppressed by an inline waiver, with the written reason.
#[derive(Debug, Clone)]
pub struct Waived {
    pub finding: Finding,
    pub reason: String,
}

/// A waiver comment that suppressed nothing (stale or mistargeted).
#[derive(Debug, Clone)]
pub struct UnusedWaiver {
    pub file: String,
    pub line: u32,
    pub rule: String,
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub waived: Vec<Waived>,
    pub unused_waivers: Vec<UnusedWaiver>,
    pub files_scanned: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let fjson = |f: &Finding| {
            Json::obj(vec![
                ("rule", Json::Str(f.rule.clone())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("message", Json::Str(f.message.clone())),
            ])
        };
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            (
                "findings",
                Json::Arr(self.findings.iter().map(fjson).collect()),
            ),
            (
                "waived",
                Json::Arr(
                    self.waived
                        .iter()
                        .map(|w| {
                            let mut j = fjson(&w.finding);
                            if let Json::Obj(map) = &mut j {
                                map.insert("reason".into(), Json::Str(w.reason.clone()));
                            }
                            j
                        })
                        .collect(),
                ),
            ),
            (
                "unused_waivers",
                Json::Arr(
                    self.unused_waivers
                        .iter()
                        .map(|u| {
                            Json::obj(vec![
                                ("file", Json::Str(u.file.clone())),
                                ("line", Json::Num(u.line as f64)),
                                ("rule", Json::Str(u.rule.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{} {}:{}  {}\n", f.rule, f.file, f.line, f.message));
        }
        if !self.waived.is_empty() {
            out.push_str("waived:\n");
            for w in &self.waived {
                out.push_str(&format!(
                    "  {} {}:{}  {}\n",
                    w.finding.rule,
                    w.finding.file,
                    w.finding.line,
                    if w.reason.is_empty() { "(no reason)" } else { &w.reason }
                ));
            }
        }
        for u in &self.unused_waivers {
            out.push_str(&format!(
                "unused waiver: {}:{} allow({})\n",
                u.file, u.line, u.rule
            ));
        }
        out.push_str(&format!(
            "gpoeo lint: {} finding(s), {} waived, {} unused waiver(s), {} file(s) scanned\n",
            self.findings.len(),
            self.waived.len(),
            self.unused_waivers.len(),
            self.files_scanned
        ));
        out
    }
}

/// Does a waiver naming `rule` cover a finding of `finding_rule`? Exact
/// rule ids match themselves; the four family keywords match their
/// prefix.
fn waiver_covers(rule: &str, finding_rule: &str) -> bool {
    rule == finding_rule
        || match rule {
            "panic" => finding_rule.starts_with("PF-"),
            "layers" => finding_rule.starts_with("LB-"),
            "blocking" => finding_rule.starts_with("NB-"),
            "determinism" => finding_rule.starts_with("DT-"),
            _ => false,
        }
}

fn rule_selected(filter: Option<&str>, rule: &str) -> bool {
    match filter {
        None => true,
        Some(f) => f == rule || waiver_covers(f, rule),
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run every rule over every source file under the manifest's roots.
/// `rule_filter` restricts reporting to one rule id or family keyword.
pub fn run(m: &Manifest, rule_filter: Option<&str>) -> anyhow::Result<Report> {
    let mut files = Vec::new();
    for root in &m.roots {
        walk(&m.base.join(root), &mut files)?;
    }
    files.sort();

    let mut report = Report::default();
    for path in &files {
        let rel = path
            .strip_prefix(&m.base)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let lexed = lexer::lex(&src);
        let ctx = FileCtx {
            path: &rel,
            module: FileCtx::module_of(&rel),
            test_ranges: lexer::test_mod_ranges(&lexed.toks),
            lexed: &lexed,
        };

        let mut findings = Vec::new();
        rules::layer_rules(&ctx, m, &mut findings);
        rules::panic_rules(&ctx, m, &mut findings);
        rules::blocking_rules(&ctx, m, &mut findings);
        rules::determinism_rules(&ctx, m, &mut findings);
        findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));

        // Waiver application: each waiver suppresses the first
        // uncovered finding of its rule on the waiver's line or the
        // line below — exactly one, so waivers can't blanket a file.
        let mut suppressed = vec![false; findings.len()];
        for w in &lexed.waivers {
            let hit = findings.iter().enumerate().position(|(k, f)| {
                !suppressed[k]
                    && waiver_covers(&w.rule, &f.rule)
                    && (f.line == w.line || f.line == w.line + 1)
            });
            match hit {
                Some(k) => {
                    suppressed[k] = true;
                    if rule_selected(rule_filter, &findings[k].rule) {
                        report.waived.push(Waived {
                            finding: findings[k].clone(),
                            reason: w.reason.clone(),
                        });
                    }
                }
                None => report.unused_waivers.push(UnusedWaiver {
                    file: rel.clone(),
                    line: w.line,
                    rule: w.rule.clone(),
                }),
            }
        }
        for (k, f) in findings.into_iter().enumerate() {
            if !suppressed[k] && rule_selected(rule_filter, &f.rule) {
                report.findings.push(f);
            }
        }
        report.files_scanned += 1;
    }
    Ok(report)
}

/// Load the manifest at `path` and run the full pass.
pub fn run_manifest(path: &Path, rule_filter: Option<&str>) -> anyhow::Result<Report> {
    let m = Manifest::load(path)?;
    run(&m, rule_filter)
}

/// Locate `lint.toml`: `--manifest PATH`, else the working directory,
/// else `rust/` below it, else next to this crate's `Cargo.toml`.
fn find_manifest(args: &Args) -> anyhow::Result<PathBuf> {
    if let Some(p) = args.opt("manifest") {
        return Ok(PathBuf::from(p));
    }
    for cand in ["lint.toml", "rust/lint.toml"] {
        let p = PathBuf::from(cand);
        if p.exists() {
            return Ok(p);
        }
    }
    let baked = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint.toml");
    if baked.exists() {
        return Ok(baked);
    }
    anyhow::bail!("no lint.toml found (pass --manifest PATH)")
}

/// `gpoeo lint [--format text|json] [--rule ID] [--manifest PATH]
/// [--out PATH]` — non-zero exit on any non-waived finding.
pub fn cli_lint(args: &Args) -> anyhow::Result<()> {
    let manifest = find_manifest(args)?;
    let report = run_manifest(&manifest, args.opt("rule"))?;
    let rendered = match args.opt_or("format", "text") {
        "json" => report.to_json().to_pretty(),
        _ => report.to_text(),
    };
    println!("{rendered}");
    if let Some(out) = args.opt("out") {
        std::fs::write(out, &rendered)
            .map_err(|e| anyhow::anyhow!("writing report to {out}: {e}"))?;
    }
    if !report.ok() {
        anyhow::bail!(
            "{} contract violation(s) — see report above (DESIGN.md §12)",
            report.findings.len()
        );
    }
    Ok(())
}
