//! The four rule families (DESIGN.md §12), each a pure function from a
//! lexed file + manifest to findings.
//!
//! | family | rules | contract |
//! |---|---|---|
//! | layers | LB-DAG LB-SIMGPU LB-POLICY-MATCH LB-PROTO LB-TEL | §0 §8 §9 §11 |
//! | panic | PF-UNWRAP PF-EXPECT PF-PANIC PF-ASSERT PF-INDEX | §2 §3 §10 |
//! | blocking | NB-BLOCKING NB-LOCK-NEST | §10 §11 |
//! | determinism | DT-CLOCK DT-RANDOM | §1 |
//!
//! All layer/panic/determinism rules skip `#[cfg(test)]` modules —
//! production contracts govern production code; tests exercise the
//! forbidden shapes on purpose.

use crate::lint::lexer::{fn_bodies, impl_bodies, Lexed, Tok, TokKind};
use crate::lint::manifest::Manifest;
use std::collections::BTreeSet;

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    /// Manifest-relative path (`src/coordinator/fleet.rs`).
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// Per-file context shared by the rule engines.
pub struct FileCtx<'a> {
    pub path: &'a str,
    /// Top-level module: `src/coordinator/fleet.rs` → `coordinator`.
    pub module: String,
    pub lexed: &'a Lexed,
    pub test_ranges: Vec<(u32, u32)>,
}

impl<'a> FileCtx<'a> {
    pub fn module_of(path: &str) -> String {
        let rel = path.strip_prefix("src/").unwrap_or(path);
        match rel.split_once('/') {
            Some((dir, _)) => dir.to_string(),
            None => rel.trim_end_matches(".rs").to_string(),
        }
    }

    fn in_test(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| a <= line && line <= b)
    }

    fn toks(&self) -> &[Tok] {
        &self.lexed.toks
    }
}

fn finding(rule: &str, ctx: &FileCtx, line: u32, message: String) -> Finding {
    Finding {
        rule: rule.to_string(),
        file: ctx.path.to_string(),
        line,
        message,
    }
}

/// `toks[i..]` starts the path `root :: <ident>`; return that ident
/// index.
fn path_member(toks: &[Tok], i: usize) -> Option<usize> {
    if i + 3 < toks.len()
        && toks[i + 1].is_punct(':')
        && toks[i + 2].is_punct(':')
        && toks[i + 3].kind == TokKind::Ident
    {
        Some(i + 3)
    } else {
        None
    }
}

/// Collect the top-level member idents of a `root::{a, b::c, d}` group
/// starting at the `{` at index `open`.
fn group_members(toks: &[Tok], open: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut expect_member = true;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
            expect_member = depth == 1;
        } else if t.is_punct('}') {
            if depth == 0 {
                break;
            }
            depth -= 1;
            if depth == 0 {
                break;
            }
        } else if t.is_punct(',') && depth == 1 {
            expect_member = true;
        } else if expect_member && depth == 1 && t.kind == TokKind::Ident {
            out.push(k);
            expect_member = false;
        }
    }
    out
}

// ----------------------------------------------------------------------
// Family 1: layer boundaries (§0, §8, §9, §11)
// ----------------------------------------------------------------------

pub fn layer_rules(ctx: &FileCtx, m: &Manifest, out: &mut Vec<Finding>) {
    let toks = ctx.toks();
    let empty: Vec<String> = Vec::new();
    let allowed = m.deps.get(&ctx.module).unwrap_or(&empty);
    let check_dep = |out: &mut Vec<Finding>, k: usize| {
        let dep = &toks[k].text;
        // Self-references and root items (`crate::VERSION` — uppercase,
        // defined in lib.rs) are not layer edges.
        if dep == &ctx.module
            || dep == "self"
            || dep.chars().next().is_some_and(|c| c.is_uppercase())
        {
            return;
        }
        if !allowed.iter().any(|d| d == dep) {
            out.push(finding(
                "LB-DAG",
                ctx,
                toks[k].line,
                format!(
                    "module '{}' references 'crate::{dep}' — not an allowed \
                     §0 layer edge (allowed: {})",
                    ctx.module,
                    allowed.join(", ")
                ),
            ));
        }
    };

    for i in 0..toks.len() {
        let t = &toks[i];
        if ctx.in_test(t.line) {
            continue;
        }
        // --- LB-DAG: crate-path references against the layer DAG.
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "crate" | "gpoeo")
            && (i == 0 || !toks[i - 1].is_punct(':'))
        {
            if let Some(k) = path_member(toks, i) {
                check_dep(out, k);
            } else if i + 3 < toks.len()
                && toks[i + 1].is_punct(':')
                && toks[i + 2].is_punct(':')
                && toks[i + 3].is_punct('{')
            {
                for k in group_members(toks, i + 3) {
                    check_dep(out, k);
                }
            }
        }
        // --- LB-SIMGPU (§0): the concrete simulator type never leaks
        // past the device boundary.
        if t.is_ident("SimGpu") && !m.simgpu_modules.iter().any(|x| x == &ctx.module) {
            out.push(finding(
                "LB-SIMGPU",
                ctx,
                t.line,
                format!(
                    "'SimGpu' named in module '{}' — only {} may see the \
                     concrete simulator (everything else goes through dyn Device)",
                    ctx.module,
                    m.simgpu_modules.join("/")
                ),
            ));
        }
        // --- LB-POLICY-MATCH (§8): no policy-name string matching
        // outside the registry. Construction (`registered("gpoeo")`)
        // and labels are fine; comparison/match-arm adjacency is not.
        if t.kind == TokKind::Str
            && ctx.module != "policy"
            && m.policy_names.iter().any(|p| p == &t.text)
        {
            let two = |a: usize, b: usize, x: char, y: char| {
                a < toks.len() && b < toks.len() && toks[a].is_punct(x) && toks[b].is_punct(y)
            };
            let cmp_before = i >= 2
                && (two(i - 2, i - 1, '=', '=') || two(i - 2, i - 1, '!', '='));
            let cmp_after = two(i + 1, i + 2, '=', '=')
                || two(i + 1, i + 2, '!', '=')
                || two(i + 1, i + 2, '=', '>');
            if cmp_before || cmp_after {
                out.push(finding(
                    "LB-POLICY-MATCH",
                    ctx,
                    t.line,
                    format!(
                        "policy name \"{}\" matched outside policy/ — dispatch \
                         belongs to the PolicyRegistry (§8)",
                        t.text
                    ),
                ));
            }
        }
        // --- LB-PROTO (§9): protocol symbols live in api/ only.
        let proto_ok = m.proto_allowed.iter().any(|p| ctx.path.starts_with(p.as_str()));
        if !proto_ok {
            if t.is_ident("PROTOCOL_VERSION") {
                out.push(finding(
                    "LB-PROTO",
                    ctx,
                    t.line,
                    "'PROTOCOL_VERSION' referenced outside api/ — version logic \
                     belongs to the protocol layer (§9)"
                        .to_string(),
                ));
            }
            if t.kind == TokKind::Str && m.wire_literals.iter().any(|w| w == &t.text) {
                out.push(finding(
                    "LB-PROTO",
                    ctx,
                    t.line,
                    format!(
                        "wire literal \"{}\" outside api/ — all protocol strings \
                         live in the protocol layer (§9)",
                        t.text
                    ),
                ));
            }
        }
        // --- LB-TEL (§11): the real telemetry plane (queue + consumer
        // thread) is constructed at daemon/CLI edges only.
        if t.is_ident("Telemetry") {
            if let Some(k) = path_member(toks, i) {
                if m.telemetry_ctors.iter().any(|c| c == &toks[k].text)
                    && !m
                        .telemetry_allowed
                        .iter()
                        .any(|p| ctx.path.starts_with(p.as_str()))
                {
                    out.push(finding(
                        "LB-TEL",
                        ctx,
                        toks[k].line,
                        format!(
                            "'Telemetry::{}' called in {} — the plane is \
                             constructed at the daemon/CLI edges only (§11)",
                            toks[k].text, ctx.path
                        ),
                    ));
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Family 2: panic-freedom in designated hot paths (§2, §3, §10)
// ----------------------------------------------------------------------

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const ASSERT_MACROS: &[&str] = &[
    "assert",
    "assert_eq",
    "assert_ne",
    "debug_assert",
    "debug_assert_eq",
    "debug_assert_ne",
];

pub fn panic_rules(ctx: &FileCtx, m: &Manifest, out: &mut Vec<Finding>) {
    let toks = ctx.toks();
    for zone in m.panic_zones.iter().filter(|z| z.file == ctx.path) {
        // One finding per (rule, line): an expression like `x[i] +
        // y[j]` is one reviewable site, not two.
        let mut seen: BTreeSet<(String, u32)> = BTreeSet::new();
        for (fn_name, start, end) in fn_bodies(toks, &zone.fns) {
            for i in start..=end {
                let t = &toks[i];
                if ctx.in_test(t.line) {
                    continue;
                }
                let prev = i.checked_sub(1).map(|p| &toks[p]);
                let next = toks.get(i + 1);
                let hit: Option<(&str, String)> = if zone.checks.iter().any(|c| c == "unwrap")
                    && t.is_ident("unwrap")
                    && prev.is_some_and(|p| p.is_punct('.'))
                {
                    Some(("PF-UNWRAP", ".unwrap()".into()))
                } else if zone.checks.iter().any(|c| c == "expect")
                    && t.is_ident("expect")
                    && prev.is_some_and(|p| p.is_punct('.'))
                {
                    Some(("PF-EXPECT", ".expect()".into()))
                } else if zone.checks.iter().any(|c| c == "panic")
                    && t.kind == TokKind::Ident
                    && PANIC_MACROS.contains(&t.text.as_str())
                    && next.is_some_and(|x| x.is_punct('!'))
                {
                    Some(("PF-PANIC", format!("{}!", t.text)))
                } else if zone.checks.iter().any(|c| c == "assert")
                    && t.kind == TokKind::Ident
                    && ASSERT_MACROS.contains(&t.text.as_str())
                    && next.is_some_and(|x| x.is_punct('!'))
                {
                    Some(("PF-ASSERT", format!("{}!", t.text)))
                } else if zone.checks.iter().any(|c| c == "index")
                    && t.is_punct('[')
                    && prev.is_some_and(|p| {
                        // `expr[i]` — but `&mut [f64]` / `return [..]`
                        // start a slice type or array literal, not an
                        // index.
                        (p.kind == TokKind::Ident
                            && !matches!(
                                p.text.as_str(),
                                "mut" | "ref" | "dyn" | "return" | "break" | "in" | "else"
                                    | "match" | "if" | "move" | "box"
                            ))
                            || p.is_punct(')')
                            || p.is_punct(']')
                    })
                {
                    Some(("PF-INDEX", "slice/array indexing".into()))
                } else {
                    None
                };
                if let Some((rule, what)) = hit {
                    if seen.insert((rule.to_string(), t.line)) {
                        out.push(finding(
                            rule,
                            ctx,
                            t.line,
                            format!(
                                "{what} in panic-free zone fn '{fn_name}' ({})",
                                zone.contract
                            ),
                        ));
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Family 3: blocking calls + lock discipline (§10, §11)
// ----------------------------------------------------------------------

pub fn blocking_rules(ctx: &FileCtx, m: &Manifest, out: &mut Vec<Finding>) {
    let toks = ctx.toks();
    for zone in m.nonblock_zones.iter().filter(|z| z.file == ctx.path) {
        let mut seen: BTreeSet<(String, u32)> = BTreeSet::new();
        for (fn_name, start, end) in fn_bodies(toks, &zone.fns) {
            for i in start..=end {
                let t = &toks[i];
                if t.kind != TokKind::Ident || !zone.ban.iter().any(|b| b == &t.text) {
                    continue;
                }
                // Type names (uppercase: `File`, `OpenOptions`) match
                // bare; method/fn names only in call position, so a
                // local named `send` doesn't trip the rule.
                let is_type = t.text.chars().next().is_some_and(|c| c.is_uppercase());
                let callish = (i > 0 && (toks[i - 1].is_punct('.') || toks[i - 1].is_punct(':')))
                    || toks.get(i + 1).is_some_and(|x| x.is_punct('('));
                if (is_type || callish) && seen.insert((t.text.clone(), t.line)) {
                    out.push(finding(
                        "NB-BLOCKING",
                        ctx,
                        t.line,
                        format!(
                            "'{}' in non-blocking zone fn '{fn_name}' ({})",
                            t.text, zone.contract
                        ),
                    ));
                }
            }
        }
    }
    // Lock discipline: inside the named impl, no single statement may
    // acquire two locks (the static shape of shard-over-shard). Guards
    // in this impl are statement-local temporaries by §6 convention,
    // so per-statement counting is exact for the code it governs.
    for zone in m.lock_orders.iter().filter(|z| z.file == ctx.path) {
        for (start, end) in impl_bodies(toks, &zone.imp) {
            for (fn_name, fstart, fend) in fn_bodies(&toks[start..=end], &[]) {
                let body = &toks[start + fstart..=start + fend];
                let mut locks_in_stmt = 0usize;
                for (k, t) in body.iter().enumerate() {
                    if t.is_punct(';') {
                        locks_in_stmt = 0;
                    } else if t.is_ident("lock") && k > 0 && body[k - 1].is_punct('.') {
                        locks_in_stmt += 1;
                        if locks_in_stmt == 2 {
                            out.push(finding(
                                "NB-LOCK-NEST",
                                ctx,
                                t.line,
                                format!(
                                    "second lock acquired in one statement in \
                                     {}::{fn_name} ({})",
                                    zone.imp, zone.contract
                                ),
                            ));
                        }
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// Family 4: determinism (§1)
// ----------------------------------------------------------------------

pub fn determinism_rules(ctx: &FileCtx, m: &Manifest, out: &mut Vec<Finding>) {
    if !m.det_modules.iter().any(|p| ctx.path.starts_with(p.as_str())) {
        return;
    }
    let toks = ctx.toks();
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident || ctx.in_test(t.line) {
            continue;
        }
        if let Some(k) = path_member(toks, i) {
            let pair = format!("{}::{}", t.text, toks[k].text);
            if m.det_clock_calls.iter().any(|c| c == &pair) {
                out.push(finding(
                    "DT-CLOCK",
                    ctx,
                    t.line,
                    format!(
                        "'{pair}' in deterministic module — §1 promises \
                         parallel==serial bit-identity; wall clocks break replay"
                    ),
                ));
                continue;
            }
        }
        if m.det_clock_idents.iter().any(|c| c == &t.text) {
            out.push(finding(
                "DT-CLOCK",
                ctx,
                t.line,
                format!("'{}' (wall clock) in deterministic module (§1)", t.text),
            ));
        } else if m.det_random_idents.iter().any(|c| c == &t.text) {
            out.push(finding(
                "DT-RANDOM",
                ctx,
                t.line,
                format!(
                    "'{}' (OS randomness) in deterministic module — use the \
                     seeded Pcg64 (§1)",
                    t.text
                ),
            ));
        }
    }
}
