//! `gpoeo` — command-line entry point.
//!
//! Subcommands:
//! - `list`                      list suites and applications
//! - `policies`                  list the registered policy families
//! - `calibrate [--suite S]`     ground-truth model coefficients + oracle
//! - `detect --app A [...]`      run period detection on a simulated trace
//! - `run --app A [--policy P]`  online optimization on one app (any registered policy)
//! - `sweep [--parallel N]`      all-app sweep on a worker fleet (BENCH_sweep.json)
//! - `experiment <id>`           regenerate a paper table/figure (fig1..fig15, table3,
//!                               headline, policies) or run a bench gate (detect-bench,
//!                               predict-bench, api-bench, sim-bench)
//! - `daemon [--socket P]`       Begin/End API server (micro-intrusive mode, fleet-backed;
//!                               control-plane protocol v1 + legacy line protocol)
//! - `ctl <verb> [--socket P]`   control-plane client: apps/policies/begin/status/end/abort/
//!                               watch/run/parity/shutdown over `GpoeoClient`

use gpoeo::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match gpoeo::cli::dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}
