//! Arena-flattened GBT inference — the batched all-gears hot path.
//!
//! The legacy walk (`gbt::Tree::eval`) chases pointers through one
//! `Vec` quadruple per tree: for a four-model bundle that is ~4 × 100
//! trees × 4 allocations scattered across the heap, re-walked once per
//! gear row with a freshly rebuilt feature vector each time
//! (`clear/push/extend` per gear). This module flattens the whole
//! bundle into single SoA node pools (`feat`/`thr`/`left`/`right` as
//! one array each, children as **absolute** u32 indices, per-tree root
//! offsets) and evaluates **all gear rows in one call**: the feature
//! matrix is built once per prediction, and traversal iterates
//! tree-major so one tree's nodes stay cache-hot across the ~99 rows.
//!
//! **Bit-identity contract**: per row, leaf values are accumulated in
//! tree-index order within each model and finished as `base + lr · Σ`,
//! the exact float-op sequence of `GbtModel::predict`. The legacy walk
//! stays in the tree as the test oracle (`rust/tests/model_arena.rs`
//! asserts bit-identity on random ensembles and on all 71 apps).

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::model::gbt::GbtModel;

/// Which of the four bundled models to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArenaModelId {
    SmEnergy = 0,
    SmTime = 1,
    MemEnergy = 2,
    MemTime = 3,
}

/// Per-model slice of the shared pools: `[tree_start, tree_end)` into
/// `GbtArena::roots`, plus the ensemble combination constants.
#[derive(Debug, Clone)]
struct ModelMeta {
    base: f64,
    lr: f64,
    tree_start: usize,
    tree_end: usize,
}

/// Row-major feature matrix for one batched prediction: column 0 is the
/// per-row gear norm, columns 1.. are the shared Table-2 features —
/// built once per `predict_*` call instead of once per gear.
pub struct FeatureMatrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl FeatureMatrix {
    /// One row per gear norm; every row shares the same trailing
    /// feature block.
    pub fn build(gear_norms: &[f64], shared: &[f64]) -> FeatureMatrix {
        let cols = 1 + shared.len();
        let mut data = Vec::with_capacity(gear_norms.len() * cols);
        for &g in gear_norms {
            data.push(g);
            data.extend_from_slice(shared);
        }
        FeatureMatrix {
            data,
            rows: gear_norms.len(),
            cols,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Iterate rows as slices (contiguous, stride `cols`).
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.cols.max(1))
    }
}

/// The four-model bundle, flattened into contiguous SoA node pools.
#[derive(Debug, Clone)]
pub struct GbtArena {
    feat: Vec<i32>,
    thr: Vec<f64>,
    left: Vec<u32>,
    right: Vec<u32>,
    /// Absolute root node index of every tree, all models concatenated.
    roots: Vec<u32>,
    meta: [ModelMeta; 4],
    /// Highest feature index referenced + 1 — the minimum row width a
    /// `FeatureMatrix` must provide.
    n_features: usize,
}

impl GbtArena {
    /// Flatten `(sm_eng, sm_time, mem_eng, mem_time)` — every tree is
    /// re-validated (range, leaf self-loops, split acyclicity) before
    /// its nodes enter the pools, so a malformed model can never put an
    /// unterminating walk on the hot path.
    pub fn from_models(
        sm_eng: &GbtModel,
        sm_time: &GbtModel,
        mem_eng: &GbtModel,
        mem_time: &GbtModel,
    ) -> anyhow::Result<GbtArena> {
        let mut arena = GbtArena {
            feat: Vec::new(),
            thr: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
            roots: Vec::new(),
            meta: std::array::from_fn(|_| ModelMeta {
                base: 0.0,
                lr: 0.0,
                tree_start: 0,
                tree_end: 0,
            }),
            n_features: 0,
        };
        for (slot, m) in [sm_eng, sm_time, mem_eng, mem_time].into_iter().enumerate() {
            let tree_start = arena.roots.len();
            for t in &m.trees {
                t.validate()?;
                let off = arena.feat.len();
                anyhow::ensure!(
                    off + t.feat.len() <= u32::MAX as usize,
                    "arena node pool exceeds u32 addressing"
                );
                arena.roots.push(off as u32);
                arena.feat.extend_from_slice(&t.feat);
                arena.thr.extend_from_slice(&t.thr);
                // Children become absolute pool indices.
                arena.left.extend(t.left.iter().map(|&c| c + off as u32));
                arena.right.extend(t.right.iter().map(|&c| c + off as u32));
                for &f in &t.feat {
                    if f >= 0 {
                        arena.n_features = arena.n_features.max(f as usize + 1);
                    }
                }
            }
            arena.meta[slot] = ModelMeta {
                base: m.base,
                lr: m.lr,
                tree_start,
                tree_end: arena.roots.len(),
            };
        }
        Ok(arena)
    }

    /// Total nodes across the bundle (diagnostics).
    pub fn node_count(&self) -> usize {
        self.feat.len()
    }

    /// Minimum feature-matrix width this bundle indexes into.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Evaluate one model over every row of `m`, tree-major, writing
    /// into `out` (`out.len() == m.rows()`). Accumulation order per row
    /// is tree-index order — bit-identical to `GbtModel::predict`.
    pub fn eval_into(&self, id: ArenaModelId, m: &FeatureMatrix, out: &mut [f64]) {
        // gpoeo-lint: allow(PF-ASSERT) caller-contract check: a mis-sized output buffer is a build bug, not a runtime state
        assert_eq!(out.len(), m.rows(), "output/rows mismatch");
        // gpoeo-lint: allow(PF-ASSERT) caller-contract check: matrix narrower than the bundle's max feature id cannot be scored
        assert!(
            m.cols() >= self.n_features,
            "feature matrix has {} columns, bundle indexes {}",
            m.cols(),
            self.n_features
        );
        out.fill(0.0);
        // gpoeo-lint: allow(PF-INDEX) ArenaModelId has exactly 4 variants; meta is [ModelMeta; 4]
        let meta = &self.meta[id as usize];
        // gpoeo-lint: allow(PF-INDEX) tree_start..tree_end recorded by from_models against this roots vec
        for &root in &self.roots[meta.tree_start..meta.tree_end] {
            for (acc, x) in out.iter_mut().zip(m.iter_rows()) {
                let mut i = root as usize;
                loop {
                    // gpoeo-lint: allow(PF-INDEX) node ids validated < len at load time (GbtModel::validate, DESIGN.md §3)
                    let f = self.feat[i];
                    if f < 0 {
                        // gpoeo-lint: allow(PF-INDEX) same validated node id as feat[i] above
                        *acc += self.thr[i];
                        break;
                    }
                    // gpoeo-lint: allow(PF-INDEX) f >= 0 here and f < n_features <= m.cols() by the assert above
                    i = if x[f as usize] <= self.thr[i] {
                        // gpoeo-lint: allow(PF-INDEX) child ids range-checked against node count at load time
                        self.left[i] as usize
                    } else {
                        // gpoeo-lint: allow(PF-INDEX) child ids range-checked against node count at load time
                        self.right[i] as usize
                    };
                }
            }
        }
        for acc in out.iter_mut() {
            *acc = meta.base + meta.lr * *acc;
        }
    }

    /// Batched (energy, time) prediction sharing one feature matrix —
    /// the shape every consumer wants: both models of a stage in a
    /// single call over all gear rows.
    pub fn predict_pair(
        &self,
        eng: ArenaModelId,
        time: ArenaModelId,
        m: &FeatureMatrix,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut e = vec![0.0; m.rows()];
        let mut t = vec![0.0; m.rows()];
        self.eval_into(eng, m, &mut e);
        self.eval_into(time, m, &mut t);
        (e, t)
    }
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn bundle(seed: u64) -> [GbtModel; 4] {
        std::array::from_fn(|i| GbtModel::random_ensemble(seed ^ (i as u64 + 1), 17, 24))
    }

    #[test]
    fn matrix_layout() {
        let m = FeatureMatrix::build(&[0.25, 0.5], &[1.0, 2.0, 3.0]);
        assert_eq!((m.rows(), m.cols()), (2, 4));
        let rows: Vec<&[f64]> = m.iter_rows().collect();
        assert_eq!(rows[0], &[0.25, 1.0, 2.0, 3.0]);
        assert_eq!(rows[1], &[0.5, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn arena_matches_legacy_walk_bitwise() {
        let [a, b, c, d] = bundle(0x1234);
        let arena = GbtArena::from_models(&a, &b, &c, &d).unwrap();
        let mut rng = Pcg64::new(0xfeed, 3);
        let shared: Vec<f64> = (0..16).map(|_| rng.uniform(0.0, 1.05)).collect();
        let norms: Vec<f64> = (0..99).map(|i| 0.2 + 0.8 * i as f64 / 98.0).collect();
        let m = FeatureMatrix::build(&norms, &shared);
        for (id, model) in [
            (ArenaModelId::SmEnergy, &a),
            (ArenaModelId::SmTime, &b),
            (ArenaModelId::MemEnergy, &c),
            (ArenaModelId::MemTime, &d),
        ] {
            let mut out = vec![0.0; m.rows()];
            arena.eval_into(id, &m, &mut out);
            for (row, got) in m.iter_rows().zip(&out) {
                let want = model.predict(row);
                assert_eq!(want.to_bits(), got.to_bits(), "model {id:?}");
            }
        }
    }

    #[test]
    fn rejects_malformed_model() {
        let [a, b, c, mut d] = bundle(0x77);
        // Corrupt one tree into a split self-loop.
        d.trees[0].feat[0] = 0;
        d.trees[0].left[0] = 0;
        d.trees[0].right[0] = 0;
        assert!(GbtArena::from_models(&a, &b, &c, &d).is_err());
    }

    #[test]
    fn n_features_tracks_max_index() {
        let [a, b, c, d] = bundle(0x9);
        let arena = GbtArena::from_models(&a, &b, &c, &d).unwrap();
        assert!(arena.n_features() <= 17);
        assert!(arena.node_count() > 0);
    }
}
