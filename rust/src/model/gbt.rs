//! Native gradient-boosted-tree inference — the Rust twin of the
//! AOT-compiled predictor modules.
//!
//! Loads the dense-array JSON written by `python/compile/gbt.py`
//! (`artifacts/gbt_*.json`). Used (a) as a cross-check oracle against the
//! PJRT path in `rust/tests/runtime_crosscheck.rs` and (b) as the
//! fallback predictor when `artifacts/` has no compiled HLO.

use crate::util::json::Json;
use std::path::Path;

/// One flattened regression tree (leaves: `feat < 0`, value in `thr`,
/// children self-loop).
#[derive(Debug, Clone)]
pub struct Tree {
    pub feat: Vec<i32>,
    pub thr: Vec<f64>,
    pub left: Vec<u32>,
    pub right: Vec<u32>,
}

impl Tree {
    /// Evaluate one input row.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            let f = self.feat[i];
            if f < 0 {
                return self.thr[i];
            }
            i = if x[f as usize] <= self.thr[i] {
                self.left[i] as usize
            } else {
                self.right[i] as usize
            };
        }
    }

    /// Structural validation: children in range, leaves self-looping,
    /// no split cycles within a bounded depth.
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.feat.len();
        anyhow::ensure!(n > 0, "empty tree");
        anyhow::ensure!(
            self.thr.len() == n && self.left.len() == n && self.right.len() == n,
            "ragged tree arrays"
        );
        for i in 0..n {
            anyhow::ensure!((self.left[i] as usize) < n, "left child out of range");
            anyhow::ensure!((self.right[i] as usize) < n, "right child out of range");
            if self.feat[i] < 0 {
                anyhow::ensure!(
                    self.left[i] as usize == i && self.right[i] as usize == i,
                    "leaf must self-loop"
                );
            }
        }
        Ok(())
    }
}

/// A trained ensemble: `base + lr · Σ trees`.
#[derive(Debug, Clone)]
pub struct GbtModel {
    pub base: f64,
    pub lr: f64,
    pub trees: Vec<Tree>,
}

impl GbtModel {
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base + self.lr * self.trees.iter().map(|t| t.eval(x)).sum::<f64>()
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GbtModel> {
        let base = j.req_f64("base")?;
        let lr = j.req_f64("lr")?;
        let mut trees = Vec::new();
        for t in j.req_arr("trees")? {
            let feat: Vec<i32> = t
                .req_f64_arr("feat")?
                .into_iter()
                .map(|v| v as i32)
                .collect();
            let thr = t.req_f64_arr("thr")?;
            let left: Vec<u32> = t
                .req_f64_arr("left")?
                .into_iter()
                .map(|v| v as u32)
                .collect();
            let right: Vec<u32> = t
                .req_f64_arr("right")?
                .into_iter()
                .map(|v| v as u32)
                .collect();
            let tree = Tree {
                feat,
                thr,
                left,
                right,
            };
            tree.validate()?;
            trees.push(tree);
        }
        anyhow::ensure!(!trees.is_empty(), "model has no trees");
        Ok(GbtModel { base, lr, trees })
    }

    pub fn load(path: &Path) -> anyhow::Result<GbtModel> {
        GbtModel::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_tree() -> Tree {
        // x[0] <= 0.5 ? 1.0 : (x[1] <= 0.2 ? 2.0 : 3.0)
        Tree {
            feat: vec![0, -1, 1, -1, -1],
            thr: vec![0.5, 1.0, 0.2, 2.0, 3.0],
            left: vec![1, 1, 3, 3, 4],
            right: vec![2, 1, 4, 3, 4],
        }
    }

    #[test]
    fn tree_eval_follows_splits() {
        let t = toy_tree();
        assert_eq!(t.eval(&[0.3, 0.9]), 1.0);
        assert_eq!(t.eval(&[0.7, 0.1]), 2.0);
        assert_eq!(t.eval(&[0.7, 0.9]), 3.0);
        // Boundary: <= goes left.
        assert_eq!(t.eval(&[0.5, 0.0]), 1.0);
    }

    #[test]
    fn model_combines_trees() {
        let m = GbtModel {
            base: 1.0,
            lr: 0.5,
            trees: vec![toy_tree(), toy_tree()],
        };
        assert_eq!(m.predict(&[0.3, 0.0]), 1.0 + 0.5 * 2.0);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{
            "base": 0.9, "lr": 0.1,
            "trees": [{"feat": [0, -1, -1], "thr": [0.5, 1.0, 2.0],
                       "left": [1, 1, 2], "right": [2, 1, 2]}]
        }"#;
        let m = GbtModel::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(m.trees.len(), 1);
        assert!((m.predict(&[0.4]) - (0.9 + 0.1)).abs() < 1e-12);
        assert!((m.predict(&[0.6]) - (0.9 + 0.2)).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_trees() {
        let bad = Tree {
            feat: vec![0],
            thr: vec![0.5],
            left: vec![7],
            right: vec![0],
        };
        assert!(bad.validate().is_err());
        let bad_leaf = Tree {
            feat: vec![-1],
            thr: vec![1.0],
            left: vec![0],
            right: vec![0],
        };
        assert!(bad_leaf.validate().is_ok());
    }
}
