//! Native gradient-boosted-tree inference — the Rust twin of the
//! AOT-compiled predictor modules.
//!
//! Loads the dense-array JSON written by `python/compile/gbt.py`
//! (`artifacts/gbt_*.json`). Used (a) as a cross-check oracle against the
//! PJRT path in `rust/tests/runtime_crosscheck.rs` and (b) as the
//! fallback predictor when `artifacts/` has no compiled HLO.

use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::path::Path;

/// One flattened regression tree (leaves: `feat < 0`, value in `thr`,
/// children self-loop).
#[derive(Debug, Clone)]
pub struct Tree {
    pub feat: Vec<i32>,
    pub thr: Vec<f64>,
    pub left: Vec<u32>,
    pub right: Vec<u32>,
}

impl Tree {
    /// Evaluate one input row.
    pub fn eval(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            let f = self.feat[i];
            if f < 0 {
                return self.thr[i];
            }
            i = if x[f as usize] <= self.thr[i] {
                self.left[i] as usize
            } else {
                self.right[i] as usize
            };
        }
    }

    /// Structural validation: children in range, leaves self-looping,
    /// no split cycles — the split edges must form a DAG, so every
    /// `eval` walk terminates within `n` hops. Leaves' self-loops are
    /// terminal by construction and exempt.
    pub fn validate(&self) -> anyhow::Result<()> {
        let n = self.feat.len();
        anyhow::ensure!(n > 0, "empty tree");
        anyhow::ensure!(
            self.thr.len() == n && self.left.len() == n && self.right.len() == n,
            "ragged tree arrays"
        );
        for i in 0..n {
            anyhow::ensure!((self.left[i] as usize) < n, "left child out of range");
            anyhow::ensure!((self.right[i] as usize) < n, "right child out of range");
            if self.feat[i] < 0 {
                anyhow::ensure!(
                    self.left[i] as usize == i && self.right[i] as usize == i,
                    "leaf must self-loop"
                );
            }
        }
        // Cycle check over the split graph (iterative 3-color DFS; a
        // gray→gray edge is a cycle that would hang `eval` forever).
        const WHITE: u8 = 0;
        const GRAY: u8 = 1;
        const BLACK: u8 = 2;
        let mut color = vec![WHITE; n];
        let mut stack: Vec<(usize, u8)> = Vec::new();
        for start in 0..n {
            if color[start] != WHITE {
                continue;
            }
            stack.push((start, 0));
            while let Some((i, phase)) = stack.pop() {
                if phase == 0 {
                    if color[i] == BLACK {
                        continue; // reached again via a shared subtree
                    }
                    color[i] = GRAY;
                    if self.feat[i] < 0 {
                        color[i] = BLACK; // leaf: terminal
                        continue;
                    }
                } else if phase == 2 {
                    color[i] = BLACK;
                    continue;
                }
                stack.push((i, phase + 1));
                let c = (if phase == 0 { self.left[i] } else { self.right[i] }) as usize;
                match color[c] {
                    GRAY => anyhow::bail!("split cycle through node {c}"),
                    WHITE => stack.push((c, 0)),
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Deterministic random valid tree (property tests, synthetic
    /// benchmark models). Nodes are appended depth-first, so children
    /// always have larger indices; leaves self-loop; `feat` indices are
    /// drawn from `[0, n_features)` and thresholds/leaf values from the
    /// normalized feature range the trained models see.
    pub fn random(rng: &mut Pcg64, n_features: usize, max_depth: usize) -> Tree {
        assert!(n_features > 0);
        let mut t = Tree {
            feat: Vec::new(),
            thr: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
        };
        fn grow(t: &mut Tree, rng: &mut Pcg64, n_features: usize, depth: usize) -> u32 {
            let i = t.feat.len() as u32;
            let split = depth > 0 && rng.next_f64() < 0.85;
            if split {
                t.feat.push(rng.below(n_features as u64) as i32);
                t.thr.push(rng.uniform(0.0, 1.05));
                t.left.push(0); // patched below
                t.right.push(0);
                let l = grow(t, rng, n_features, depth - 1);
                let r = grow(t, rng, n_features, depth - 1);
                t.left[i as usize] = l;
                t.right[i as usize] = r;
            } else {
                t.feat.push(-1);
                t.thr.push(rng.uniform(-0.5, 0.5));
                t.left.push(i);
                t.right.push(i);
            }
            i
        }
        grow(&mut t, rng, n_features, max_depth);
        t
    }
}

/// A trained ensemble: `base + lr · Σ trees`.
#[derive(Debug, Clone)]
pub struct GbtModel {
    pub base: f64,
    pub lr: f64,
    pub trees: Vec<Tree>,
}

impl GbtModel {
    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base + self.lr * self.trees.iter().map(|t| t.eval(x)).sum::<f64>()
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GbtModel> {
        let base = j.req_f64("base")?;
        let lr = j.req_f64("lr")?;
        // Index arrays must hold exact integers in range: an `as` cast
        // would silently zero NaN and saturate garbage floats into
        // plausible-looking (and cycle-prone) node ids before
        // `validate` ever sees them.
        fn req_index_arr(t: &Json, key: &str, min: i64, max: i64) -> anyhow::Result<Vec<i64>> {
            t.req_f64_arr(key)?
                .into_iter()
                .enumerate()
                .map(|(i, v)| {
                    anyhow::ensure!(
                        v.is_finite() && v.fract() == 0.0,
                        "'{key}'[{i}] = {v} is not an integral index"
                    );
                    let n = v as i64;
                    anyhow::ensure!(
                        (min..=max).contains(&n),
                        "'{key}'[{i}] = {n} outside [{min}, {max}]"
                    );
                    Ok(n)
                })
                .collect()
        }
        let mut trees = Vec::new();
        for t in j.req_arr("trees")? {
            // Leaves are written as feat = -1 (python/compile/gbt.py);
            // any other negative value is a writer bug, not a leaf.
            let feat: Vec<i32> = req_index_arr(t, "feat", -1, i32::MAX as i64)?
                .into_iter()
                .map(|v| v as i32)
                .collect();
            let thr = t.req_f64_arr("thr")?;
            let left: Vec<u32> = req_index_arr(t, "left", 0, u32::MAX as i64)?
                .into_iter()
                .map(|v| v as u32)
                .collect();
            let right: Vec<u32> = req_index_arr(t, "right", 0, u32::MAX as i64)?
                .into_iter()
                .map(|v| v as u32)
                .collect();
            let tree = Tree {
                feat,
                thr,
                left,
                right,
            };
            tree.validate()?;
            trees.push(tree);
        }
        anyhow::ensure!(!trees.is_empty(), "model has no trees");
        Ok(GbtModel { base, lr, trees })
    }

    /// Deterministic synthetic ensemble with the shape of the trained
    /// artifacts (~100 trees, depth ≤ 7, 17 inputs = gear norm +
    /// 16 Table-2 features). Lets the prediction benchmarks and the
    /// arena bit-identity tests run on machines without `make
    /// artifacts` (CI), where only the *relative* cost and the exact
    /// agreement of the two inference paths matter — not the trained
    /// weights.
    pub fn random_ensemble(seed: u64, n_features: usize, n_trees: usize) -> GbtModel {
        let mut rng = Pcg64::new(seed, 0x6b7);
        let trees = (0..n_trees)
            .map(|_| Tree::random(&mut rng, n_features, 7))
            .collect();
        GbtModel {
            base: 1.0,
            lr: 0.05,
            trees,
        }
    }

    pub fn load(path: &Path) -> anyhow::Result<GbtModel> {
        GbtModel::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_tree() -> Tree {
        // x[0] <= 0.5 ? 1.0 : (x[1] <= 0.2 ? 2.0 : 3.0)
        Tree {
            feat: vec![0, -1, 1, -1, -1],
            thr: vec![0.5, 1.0, 0.2, 2.0, 3.0],
            left: vec![1, 1, 3, 3, 4],
            right: vec![2, 1, 4, 3, 4],
        }
    }

    #[test]
    fn tree_eval_follows_splits() {
        let t = toy_tree();
        assert_eq!(t.eval(&[0.3, 0.9]), 1.0);
        assert_eq!(t.eval(&[0.7, 0.1]), 2.0);
        assert_eq!(t.eval(&[0.7, 0.9]), 3.0);
        // Boundary: <= goes left.
        assert_eq!(t.eval(&[0.5, 0.0]), 1.0);
    }

    #[test]
    fn model_combines_trees() {
        let m = GbtModel {
            base: 1.0,
            lr: 0.5,
            trees: vec![toy_tree(), toy_tree()],
        };
        assert_eq!(m.predict(&[0.3, 0.0]), 1.0 + 0.5 * 2.0);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{
            "base": 0.9, "lr": 0.1,
            "trees": [{"feat": [0, -1, -1], "thr": [0.5, 1.0, 2.0],
                       "left": [1, 1, 2], "right": [2, 1, 2]}]
        }"#;
        let m = GbtModel::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(m.trees.len(), 1);
        assert!((m.predict(&[0.4]) - (0.9 + 0.1)).abs() < 1e-12);
        assert!((m.predict(&[0.6]) - (0.9 + 0.2)).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_split_cycles() {
        // A split node pointing back at itself used to pass validation
        // and hang `eval` forever.
        let self_loop = Tree {
            feat: vec![0],
            thr: vec![0.5],
            left: vec![0],
            right: vec![0],
        };
        assert!(self_loop.validate().unwrap_err().to_string().contains("cycle"));
        // Two splits pointing at each other, with reachable leaves so
        // every per-node check passes.
        let mutual = Tree {
            feat: vec![0, 1, -1, -1],
            thr: vec![0.5, 0.5, 1.0, 2.0],
            left: vec![1, 0, 2, 3],
            right: vec![2, 3, 2, 3],
        };
        assert!(mutual.validate().unwrap_err().to_string().contains("cycle"));
        // A diamond (shared subtree) is acyclic and stays legal.
        let diamond = Tree {
            feat: vec![0, 1, -1, -1],
            thr: vec![0.5, 0.25, 1.0, 2.0],
            left: vec![1, 2, 2, 3],
            right: vec![3, 3, 2, 3],
        };
        assert!(diamond.validate().is_ok());
    }

    #[test]
    fn from_json_rejects_non_integral_indices() {
        let make = |feat: &str, left: &str, right: &str| {
            format!(
                r#"{{"base": 0.0, "lr": 1.0,
                     "trees": [{{"feat": {feat}, "thr": [0.5, 1.0, 2.0],
                                 "left": {left}, "right": {right}}}]}}"#
            )
        };
        let ok = make("[0, -1, -1]", "[1, 1, 2]", "[2, 1, 2]");
        assert!(GbtModel::from_json(&Json::parse(&ok).unwrap()).is_ok());
        for (feat, left, right, what) in [
            ("[0.5, -1, -1]", "[1, 1, 2]", "[2, 1, 2]", "fractional feat"),
            ("[0, -1, -1]", "[1.25, 1, 2]", "[2, 1, 2]", "fractional left"),
            ("[0, -1, -1]", "[1, 1, 2]", "[2e12, 1, 2]", "right > u32"),
            ("[-3, -1, -1]", "[1, 1, 2]", "[2, 1, 2]", "feat < -1"),
            ("[0, -1, -1]", "[-1, 1, 2]", "[2, 1, 2]", "negative left"),
        ] {
            let j = Json::parse(&make(feat, left, right)).unwrap();
            assert!(GbtModel::from_json(&j).is_err(), "accepted {what}");
        }
        // NaN can't appear in JSON text, but a programmatic document can
        // carry it; `v as u32` used to quietly turn it into node 0.
        let mut j = Json::parse(&ok).unwrap();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(trees)) = o.get_mut("trees") {
                if let Json::Obj(t) = &mut trees[0] {
                    t.insert(
                        "left".into(),
                        Json::Arr(vec![Json::Num(f64::NAN), Json::Num(1.0), Json::Num(2.0)]),
                    );
                }
            }
        }
        assert!(GbtModel::from_json(&j).is_err(), "accepted NaN left");
    }

    #[test]
    fn random_trees_validate_and_eval() {
        let mut rng = crate::util::rng::Pcg64::new(0xa11e, 7);
        for _ in 0..50 {
            let t = Tree::random(&mut rng, 17, 7);
            t.validate().unwrap();
            let x: Vec<f64> = (0..17).map(|_| rng.uniform(0.0, 1.05)).collect();
            assert!(t.eval(&x).is_finite());
        }
    }

    #[test]
    fn validation_rejects_bad_trees() {
        let bad = Tree {
            feat: vec![0],
            thr: vec![0.5],
            left: vec![7],
            right: vec![0],
        };
        assert!(bad.validate().is_err());
        let bad_leaf = Tree {
            feat: vec![-1],
            thr: vec![1.0],
            left: vec![0],
            right: vec![0],
        };
        assert!(bad_leaf.validate().is_ok());
    }
}
