//! Prediction models (§4.3): native GBT inference (arena-flattened
//! batched hot path + legacy walk as oracle) and the unified predictor
//! over HLO/native backends.

pub mod arena;
pub mod gbt;
pub mod predictor;

pub use arena::{ArenaModelId, FeatureMatrix, GbtArena};
pub use gbt::GbtModel;
pub use predictor::{gear_norm_mem, gear_norm_sm, GearPredictions, NativeModels, Predictor};
