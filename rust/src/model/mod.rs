//! Prediction models (§4.3): native GBT inference and the unified
//! predictor over HLO/native backends.

pub mod gbt;
pub mod predictor;

pub use gbt::GbtModel;
pub use predictor::{gear_norm_mem, gear_norm_sm, GearPredictions, NativeModels, Predictor};
