//! The four prediction models of Equation (1)/(2), behind one interface.
//!
//! Two backends:
//! - **Hlo** — the AOT-compiled PJRT modules (production path; Pallas
//!   kernels inside, Python nowhere).
//! - **Native** — Rust GBT inference over the same trained trees
//!   (`artifacts/gbt_*.json`). Twin/cross-check path and the fallback
//!   when the compiled artifacts are absent.

use crate::model::gbt::GbtModel;
use crate::runtime::{default_artifacts_dir, Runtime};
use crate::sim::Spec;

/// Per-gear predictions relative to the NVIDIA default strategy.
#[derive(Debug, Clone)]
pub struct GearPredictions {
    /// Gear id of row i (SM gear index or memory gear index).
    pub gears: Vec<usize>,
    pub energy_ratio: Vec<f64>,
    pub time_ratio: Vec<f64>,
}

impl GearPredictions {
    /// Best gear under an objective.
    pub fn best(&self, obj: crate::search::Objective) -> usize {
        let scores: Vec<f64> = self
            .energy_ratio
            .iter()
            .zip(&self.time_ratio)
            .map(|(&e, &t)| obj.score(e, t))
            .collect();
        self.gears[crate::util::stats::argmin(&scores).unwrap()]
    }
}

/// Normalized SM-gear model input — must match `simdata.gear_norm_sm`.
pub fn gear_norm_sm(spec: &Spec, gear: usize) -> f64 {
    spec.gears.sm_mhz(gear) / spec.power.f_max_mhz
}

/// Normalized memory-gear model input — must match `simdata.gear_norm_mem`.
pub fn gear_norm_mem(spec: &Spec, gear: usize) -> f64 {
    let max = spec
        .gears
        .mem_mhz
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    spec.gears.mem_mhz_of(gear) / max
}

/// Native four-model bundle.
pub struct NativeModels {
    pub sm_eng: GbtModel,
    pub sm_time: GbtModel,
    pub mem_eng: GbtModel,
    pub mem_time: GbtModel,
}

impl NativeModels {
    pub fn load_default() -> anyhow::Result<NativeModels> {
        let dir = default_artifacts_dir();
        Ok(NativeModels {
            sm_eng: GbtModel::load(&dir.join("gbt_sm_eng.json"))?,
            sm_time: GbtModel::load(&dir.join("gbt_sm_time.json"))?,
            mem_eng: GbtModel::load(&dir.join("gbt_mem_eng.json"))?,
            mem_time: GbtModel::load(&dir.join("gbt_mem_time.json"))?,
        })
    }
}

/// Prediction backend.
pub enum Predictor {
    Hlo(Runtime),
    Native(NativeModels),
}

impl Predictor {
    /// Prefer the compiled HLO path; fall back to native trees.
    pub fn load_best() -> anyhow::Result<Predictor> {
        if let Some(rt) = Runtime::try_default() {
            return Ok(Predictor::Hlo(rt));
        }
        Ok(Predictor::Native(NativeModels::load_default()?))
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Predictor::Hlo(_) => "hlo-pjrt",
            Predictor::Native(_) => "native-gbt",
        }
    }

    /// SM-clock models: (energy, time) ratio per SM gear.
    pub fn predict_sm(&self, spec: &Spec, features: &[f64]) -> anyhow::Result<GearPredictions> {
        let gears: Vec<usize> = spec.gears.sm_gears().collect();
        match self {
            Predictor::Hlo(rt) => {
                let f32s: Vec<f32> = features.iter().map(|&v| v as f32).collect();
                let (e, t) = rt.predict_sm(&f32s)?;
                Ok(GearPredictions {
                    gears,
                    energy_ratio: e.into_iter().map(|v| v as f64).collect(),
                    time_ratio: t.into_iter().map(|v| v as f64).collect(),
                })
            }
            Predictor::Native(m) => {
                let mut x = Vec::with_capacity(1 + features.len());
                let mut eng = Vec::with_capacity(gears.len());
                let mut tim = Vec::with_capacity(gears.len());
                for &g in &gears {
                    x.clear();
                    x.push(gear_norm_sm(spec, g));
                    x.extend_from_slice(features);
                    eng.push(m.sm_eng.predict(&x));
                    tim.push(m.sm_time.predict(&x));
                }
                Ok(GearPredictions {
                    gears,
                    energy_ratio: eng,
                    time_ratio: tim,
                })
            }
        }
    }

    /// Memory-clock models: (energy, time) ratio per memory gear.
    pub fn predict_mem(&self, spec: &Spec, features: &[f64]) -> anyhow::Result<GearPredictions> {
        let gears: Vec<usize> = (0..spec.gears.num_mem_gears()).collect();
        match self {
            Predictor::Hlo(rt) => {
                let f32s: Vec<f32> = features.iter().map(|&v| v as f32).collect();
                let (e, t) = rt.predict_mem(&f32s)?;
                Ok(GearPredictions {
                    gears,
                    energy_ratio: e.into_iter().map(|v| v as f64).collect(),
                    time_ratio: t.into_iter().map(|v| v as f64).collect(),
                })
            }
            Predictor::Native(m) => {
                let mut eng = Vec::new();
                let mut tim = Vec::new();
                for &g in &gears {
                    let mut x = vec![gear_norm_mem(spec, g)];
                    x.extend_from_slice(features);
                    eng.push(m.mem_eng.predict(&x));
                    tim.push(m.mem_time.predict(&x));
                }
                Ok(GearPredictions {
                    gears,
                    energy_ratio: eng,
                    time_ratio: tim,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Objective;

    #[test]
    fn gear_norms_match_contract() {
        let spec = Spec::load_default().unwrap();
        assert!((gear_norm_sm(&spec, 114) - 1.0).abs() < 1e-12);
        assert!((gear_norm_sm(&spec, 16) - 450.0 / 1920.0).abs() < 1e-12);
        assert!((gear_norm_mem(&spec, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn best_gear_respects_objective() {
        let p = GearPredictions {
            gears: vec![10, 11, 12],
            energy_ratio: vec![0.8, 0.7, 0.9],
            time_ratio: vec![1.04, 1.20, 1.01],
        };
        // Min-energy-capped: gear 11 is infeasible, 10 beats 12 on energy.
        assert_eq!(p.best(Objective::paper_default()), 10);
        // Unconstrained energy: gear 11 wins.
        assert_eq!(p.best(Objective::Energy), 11);
    }
}
