//! The four prediction models of Equation (1)/(2), behind one interface.
//!
//! Two backends:
//! - **Hlo** — the AOT-compiled PJRT modules (production path; Pallas
//!   kernels inside, Python nowhere).
//! - **Native** — Rust GBT inference over the same trained trees
//!   (`artifacts/gbt_*.json`). Twin/cross-check path and the fallback
//!   when the compiled artifacts are absent. Since the arena rewrite
//!   the native hot path is [`crate::model::GbtArena`]: one feature
//!   matrix per call, all gear rows batched, bit-identical to the
//!   legacy per-gear `Tree::eval` walk (kept below as the test oracle
//!   and benchmark comparator).

use crate::model::arena::{ArenaModelId, FeatureMatrix, GbtArena};
use crate::model::gbt::GbtModel;
use crate::runtime::{default_artifacts_dir, Runtime};
use crate::sim::Spec;

/// Per-gear predictions relative to the NVIDIA default strategy.
#[derive(Debug, Clone)]
pub struct GearPredictions {
    /// Gear id of row i (SM gear index or memory gear index).
    pub gears: Vec<usize>,
    pub energy_ratio: Vec<f64>,
    pub time_ratio: Vec<f64>,
}

impl GearPredictions {
    /// Best gear under an objective: fused score+argmin, no
    /// intermediate allocation. First index wins ties; NaN scores
    /// never win (matching `stats::argmin`'s total order). An empty or
    /// ragged gear table is a caller bug surfaced as an error — a
    /// fleet worker must not panic mid-session on a degenerate
    /// prediction.
    pub fn best(&self, obj: crate::search::Objective) -> anyhow::Result<usize> {
        anyhow::ensure!(!self.gears.is_empty(), "empty gear prediction table");
        anyhow::ensure!(
            self.energy_ratio.len() == self.gears.len()
                && self.time_ratio.len() == self.gears.len(),
            "ragged gear prediction table"
        );
        let mut best_i = 0usize;
        let mut best_s = f64::INFINITY;
        for (i, (&e, &t)) in self.energy_ratio.iter().zip(&self.time_ratio).enumerate() {
            let s = obj.score(e, t);
            if s < best_s {
                best_s = s;
                best_i = i;
            }
        }
        Ok(self.gears[best_i])
    }
}

/// Normalized SM-gear model input — must match `simdata.gear_norm_sm`.
pub fn gear_norm_sm(spec: &Spec, gear: usize) -> f64 {
    spec.gears.sm_mhz(gear) / spec.power.f_max_mhz
}

/// Normalized memory-gear model input — must match `simdata.gear_norm_mem`.
pub fn gear_norm_mem(spec: &Spec, gear: usize) -> f64 {
    let max = spec
        .gears
        .mem_mhz
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    spec.gears.mem_mhz_of(gear) / max
}

/// Native four-model bundle: the trained trees plus their
/// arena-flattened twin. The arena is built (and re-validated) at
/// construction time, so the hot path never pays flattening or
/// validation costs.
#[derive(Clone)]
pub struct NativeModels {
    pub sm_eng: GbtModel,
    pub sm_time: GbtModel,
    pub mem_eng: GbtModel,
    pub mem_time: GbtModel,
    arena: GbtArena,
}

impl NativeModels {
    pub fn new(
        sm_eng: GbtModel,
        sm_time: GbtModel,
        mem_eng: GbtModel,
        mem_time: GbtModel,
    ) -> anyhow::Result<NativeModels> {
        let arena = GbtArena::from_models(&sm_eng, &sm_time, &mem_eng, &mem_time)?;
        Ok(NativeModels {
            sm_eng,
            sm_time,
            mem_eng,
            mem_time,
            arena,
        })
    }

    pub fn load_default() -> anyhow::Result<NativeModels> {
        let dir = default_artifacts_dir();
        NativeModels::new(
            GbtModel::load(&dir.join("gbt_sm_eng.json"))?,
            GbtModel::load(&dir.join("gbt_sm_time.json"))?,
            GbtModel::load(&dir.join("gbt_mem_eng.json"))?,
            GbtModel::load(&dir.join("gbt_mem_time.json"))?,
        )
    }

    /// Deterministic synthetic bundle with the trained artifacts'
    /// shape (17 inputs, ~100 trees per model) — the benchmark/test
    /// stand-in on machines without `make artifacts`.
    pub fn synthetic(seed: u64) -> NativeModels {
        NativeModels::new(
            GbtModel::random_ensemble(seed ^ 0x51, 17, 100),
            GbtModel::random_ensemble(seed ^ 0x52, 17, 100),
            GbtModel::random_ensemble(seed ^ 0x53, 17, 100),
            GbtModel::random_ensemble(seed ^ 0x54, 17, 100),
        )
        .expect("synthetic trees are valid by construction")
    }

    /// Trained bundle when the artifacts exist, synthetic when they are
    /// *absent* — for consumers (benches, bit-identity tests) that only
    /// care about the *paths*, not the weights. Artifacts that exist
    /// but fail to load are an error, not a fallback: silently
    /// downgrading to synthetic trees would let a corrupt bundle pass
    /// every gate that claims to exercise the trained models.
    pub fn load_default_or_synthetic() -> anyhow::Result<(NativeModels, &'static str)> {
        let dir = default_artifacts_dir();
        let any_present = [
            "gbt_sm_eng.json",
            "gbt_sm_time.json",
            "gbt_mem_eng.json",
            "gbt_mem_time.json",
        ]
        .iter()
        .any(|f| dir.join(f).exists());
        if any_present {
            Ok((NativeModels::load_default()?, "native-trained"))
        } else {
            Ok((NativeModels::synthetic(0x9b7d), "native-synthetic"))
        }
    }

    pub fn arena(&self) -> &GbtArena {
        &self.arena
    }

    /// The pre-arena per-gear walk, verbatim: rebuilds the feature
    /// vector per gear and chases `Vec`-of-`Vec` trees node by node.
    /// Kept as the bit-identity oracle and the `predict-bench`
    /// comparator — NOT a production path.
    pub fn legacy_predict_sm(&self, spec: &Spec, features: &[f64]) -> GearPredictions {
        let gears: Vec<usize> = spec.gears.sm_gears().collect();
        let mut x = Vec::with_capacity(1 + features.len());
        let mut eng = Vec::with_capacity(gears.len());
        let mut tim = Vec::with_capacity(gears.len());
        for &g in &gears {
            x.clear();
            x.push(gear_norm_sm(spec, g));
            x.extend_from_slice(features);
            eng.push(self.sm_eng.predict(&x));
            tim.push(self.sm_time.predict(&x));
        }
        GearPredictions {
            gears,
            energy_ratio: eng,
            time_ratio: tim,
        }
    }

    /// Legacy memory-gear walk (see [`Self::legacy_predict_sm`]).
    pub fn legacy_predict_mem(&self, spec: &Spec, features: &[f64]) -> GearPredictions {
        let gears: Vec<usize> = (0..spec.gears.num_mem_gears()).collect();
        let mut eng = Vec::new();
        let mut tim = Vec::new();
        for &g in &gears {
            let mut x = vec![gear_norm_mem(spec, g)];
            x.extend_from_slice(features);
            eng.push(self.mem_eng.predict(&x));
            tim.push(self.mem_time.predict(&x));
        }
        GearPredictions {
            gears,
            energy_ratio: eng,
            time_ratio: tim,
        }
    }
}

/// Prediction backend.
pub enum Predictor {
    Hlo(Runtime),
    Native(NativeModels),
}

impl Predictor {
    /// Prefer the compiled HLO path; fall back to native trees.
    pub fn load_best() -> anyhow::Result<Predictor> {
        if let Some(rt) = Runtime::try_default() {
            return Ok(Predictor::Hlo(rt));
        }
        Ok(Predictor::Native(NativeModels::load_default()?))
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Predictor::Hlo(_) => "hlo-pjrt",
            Predictor::Native(_) => "native-gbt",
        }
    }

    /// SM-clock models: (energy, time) ratio per SM gear, both models
    /// batched over one feature matrix.
    pub fn predict_sm(&self, spec: &Spec, features: &[f64]) -> anyhow::Result<GearPredictions> {
        let gears: Vec<usize> = spec.gears.sm_gears().collect();
        match self {
            Predictor::Hlo(rt) => {
                let f32s: Vec<f32> = features.iter().map(|&v| v as f32).collect();
                let (e, t) = rt.predict_sm(&f32s)?;
                Ok(GearPredictions {
                    gears,
                    energy_ratio: e.into_iter().map(|v| v as f64).collect(),
                    time_ratio: t.into_iter().map(|v| v as f64).collect(),
                })
            }
            Predictor::Native(m) => {
                let norms: Vec<f64> = gears.iter().map(|&g| gear_norm_sm(spec, g)).collect();
                let mat = FeatureMatrix::build(&norms, features);
                let (eng, tim) =
                    m.arena
                        .predict_pair(ArenaModelId::SmEnergy, ArenaModelId::SmTime, &mat);
                Ok(GearPredictions {
                    gears,
                    energy_ratio: eng,
                    time_ratio: tim,
                })
            }
        }
    }

    /// Memory-clock models: (energy, time) ratio per memory gear.
    pub fn predict_mem(&self, spec: &Spec, features: &[f64]) -> anyhow::Result<GearPredictions> {
        let gears: Vec<usize> = (0..spec.gears.num_mem_gears()).collect();
        match self {
            Predictor::Hlo(rt) => {
                let f32s: Vec<f32> = features.iter().map(|&v| v as f32).collect();
                let (e, t) = rt.predict_mem(&f32s)?;
                Ok(GearPredictions {
                    gears,
                    energy_ratio: e.into_iter().map(|v| v as f64).collect(),
                    time_ratio: t.into_iter().map(|v| v as f64).collect(),
                })
            }
            Predictor::Native(m) => {
                let norms: Vec<f64> = gears.iter().map(|&g| gear_norm_mem(spec, g)).collect();
                let mat = FeatureMatrix::build(&norms, features);
                let (eng, tim) =
                    m.arena
                        .predict_pair(ArenaModelId::MemEnergy, ArenaModelId::MemTime, &mat);
                Ok(GearPredictions {
                    gears,
                    energy_ratio: eng,
                    time_ratio: tim,
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Objective;

    #[test]
    fn gear_norms_match_contract() {
        let spec = Spec::load_default().unwrap();
        assert!((gear_norm_sm(&spec, 114) - 1.0).abs() < 1e-12);
        assert!((gear_norm_sm(&spec, 16) - 450.0 / 1920.0).abs() < 1e-12);
        assert!((gear_norm_mem(&spec, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn best_gear_respects_objective() {
        let p = GearPredictions {
            gears: vec![10, 11, 12],
            energy_ratio: vec![0.8, 0.7, 0.9],
            time_ratio: vec![1.04, 1.20, 1.01],
        };
        // Min-energy-capped: gear 11 is infeasible, 10 beats 12 on energy.
        assert_eq!(p.best(Objective::paper_default()).unwrap(), 10);
        // Unconstrained energy: gear 11 wins.
        assert_eq!(p.best(Objective::Energy).unwrap(), 11);
    }

    #[test]
    fn best_rejects_degenerate_tables() {
        let empty = GearPredictions {
            gears: vec![],
            energy_ratio: vec![],
            time_ratio: vec![],
        };
        assert!(empty.best(Objective::Energy).is_err());
        let ragged = GearPredictions {
            gears: vec![1, 2],
            energy_ratio: vec![0.9],
            time_ratio: vec![1.0, 1.0],
        };
        assert!(ragged.best(Objective::Energy).is_err());
    }

    #[test]
    fn best_ignores_nan_scores() {
        let p = GearPredictions {
            gears: vec![5, 6, 7],
            energy_ratio: vec![f64::NAN, 0.8, 0.9],
            time_ratio: vec![1.0, 1.0, 1.0],
        };
        assert_eq!(p.best(Objective::Energy).unwrap(), 6);
    }

    #[test]
    fn native_predictions_match_legacy_walk() {
        let spec = Spec::load_default().unwrap();
        let m = NativeModels::synthetic(0xabc);
        let p = Predictor::Native(m.clone());
        let feats: Vec<f64> = (0..16).map(|i| 0.1 + 0.05 * i as f64).collect();
        let sm = p.predict_sm(&spec, &feats).unwrap();
        let sm_legacy = m.legacy_predict_sm(&spec, &feats);
        assert_eq!(sm.gears, sm_legacy.gears);
        for i in 0..sm.gears.len() {
            assert_eq!(
                sm.energy_ratio[i].to_bits(),
                sm_legacy.energy_ratio[i].to_bits()
            );
            assert_eq!(sm.time_ratio[i].to_bits(), sm_legacy.time_ratio[i].to_bits());
        }
        let mem = p.predict_mem(&spec, &feats).unwrap();
        let mem_legacy = m.legacy_predict_mem(&spec, &feats);
        assert_eq!(mem.gears, mem_legacy.gears);
        for i in 0..mem.gears.len() {
            assert_eq!(
                mem.energy_ratio[i].to_bits(),
                mem_legacy.energy_ratio[i].to_bits()
            );
            assert_eq!(
                mem.time_ratio[i].to_bits(),
                mem_legacy.time_ratio[i].to_bits()
            );
        }
    }
}
