//! The `arbiter` policy family — the session-side member of the fleet
//! power-budget arbiter (DESIGN.md §14).
//!
//! The policy itself makes no clock decisions: caps arrive from the
//! daemon's [`crate::arbiter::BudgetArbiter`] through worker-side
//! `SessionHandle` dispatch, not from this tick loop. What the member
//! contributes is the *telemetry signal* the arbiter allocates on: it
//! runs the model-free streaming detector over the device's sampling
//! channel and emits one `Detect` event once the workload classifies as
//! periodic (latency-critical) or aperiodic (throughput-insensitive,
//! i.e. a cap donor). Iteration-rate signals need no help here — the
//! fleet's slice-cadence `Tick` events already carry them.
//!
//! The daemon-level knobs (`budget_w`, `period_s`, `min_cap_w`,
//! `max_cap_w`, `hysteresis_w`) ride in the same `set_policy {name,
//! config}` wire message; [`arbiter_config`] is how the reactor reads
//! them, keeping every policy-name match inside this module (§8).

use super::{PolicyBuilder, PolicyConfig, PolicyCtx, PolicySpec};
use crate::arbiter::ArbiterCfg;
use crate::coordinator::Policy;
use crate::device::Device;
use crate::signal::{PeriodCfg, StreamCfg, StreamVerdict, StreamingDetector};
use crate::telemetry::{Telemetry, TelemetryEvent};
use std::sync::Arc;

/// The registry key. Matching on this string anywhere outside the
/// policy module violates the §8 single-construction-point contract —
/// use [`is_arbiter`]/[`arbiter_config`] instead.
const ARBITER_NAME: &str = "arbiter";

/// Detection gives up and classifies aperiodic past these limits —
/// mirroring the controller's `max_detect_rounds`/`max_window_s`/
/// `aperiodic_err` defaults so both stacks agree on what "periodic"
/// means.
const APERIODIC_ERR: f64 = 0.35;
const MAX_DETECT_ROUNDS: usize = 6;
const MAX_WINDOW_S: f64 = 45.0;
const FALLBACK_PERIOD_S: f64 = 2.5;

/// Does this spec select the arbiter family? (The reactor uses this to
/// decide enrollment without touching the name string.)
pub fn is_arbiter(spec: &PolicySpec) -> bool {
    spec.name == ARBITER_NAME
}

/// The daemon-level [`ArbiterCfg`] carried by an arbiter spec: `None`
/// for any other family, `Some(Err)` when the knobs are malformed (the
/// control plane answers a typed error before the session runs).
pub fn arbiter_config(spec: &PolicySpec) -> Option<anyhow::Result<ArbiterCfg>> {
    is_arbiter(spec).then(|| cfg_from(&spec.cfg))
}

/// Parse the wire knobs into an [`ArbiterCfg`]. Underscore-named per
/// the v1 wire convention for daemon-level options.
pub fn cfg_from(cfg: &PolicyConfig) -> anyhow::Result<ArbiterCfg> {
    let d = ArbiterCfg::default();
    let budget_w = cfg.opt_f64("budget_w", d.budget_w)?;
    anyhow::ensure!(
        budget_w.is_finite() && budget_w > 0.0,
        "budget_w must be a positive number of watts, got {budget_w}"
    );
    let min_cap_w = cfg.opt_f64("min_cap_w", d.min_cap_w)?.max(0.0);
    let max_cap_w = cfg.opt_f64("max_cap_w", d.max_cap_w)?;
    anyhow::ensure!(
        max_cap_w >= min_cap_w,
        "max_cap_w ({max_cap_w}) must be >= min_cap_w ({min_cap_w})"
    );
    Ok(ArbiterCfg {
        budget_w,
        period_s: cfg.opt_f64("period_s", d.period_s)?.max(0.0),
        min_cap_w,
        max_cap_w,
        hysteresis_w: cfg.opt_f64("hysteresis_w", d.hysteresis_w)?.max(0.0),
        rate_alpha: cfg.opt_f64("rate_alpha", d.rate_alpha)?,
        donor_ratio: cfg.opt_f64("donor_ratio", d.donor_ratio)?.clamp(0.0, 1.0),
    })
}

/// Session-side arbiter member. Implements
/// [`crate::coordinator::Policy`]; registered as `arbiter`.
pub struct ArbiterPolicy {
    ts: f64,
    det: StreamingDetector,
    /// `Some(aperiodic)` once the workload classified.
    classified: Option<bool>,
    tel: Option<(Arc<Telemetry>, u64)>,
}

impl ArbiterPolicy {
    pub fn new(ts: f64) -> ArbiterPolicy {
        ArbiterPolicy {
            ts,
            det: StreamingDetector::new(ts, PeriodCfg::default(), StreamCfg::default()),
            classified: None,
            tel: None,
        }
    }

    /// `Some(true)` = aperiodic (donor), `Some(false)` = periodic,
    /// `None` = still detecting.
    pub fn classification(&self) -> Option<bool> {
        self.classified
    }
}

/// Turn a streaming verdict into a final classification, or `None` to
/// keep listening. Same thresholds as the GPOEO controller.
fn classify(v: &StreamVerdict) -> Option<(f64, bool)> {
    match &v.detection {
        Some(d) if d.next_sampling_s.is_none() && d.estimate.err <= APERIODIC_ERR => {
            Some((d.estimate.t_iter, false))
        }
        det => {
            let stable_high_err = matches!(det, Some(d) if d.next_sampling_s.is_none());
            if v.round >= MAX_DETECT_ROUNDS || v.window_s >= MAX_WINDOW_S || stable_high_err {
                Some((FALLBACK_PERIOD_S, true))
            } else {
                None
            }
        }
    }
}

impl Policy for ArbiterPolicy {
    fn name(&self) -> &'static str {
        "arbiter"
    }

    fn attach_telemetry(&mut self, tel: Arc<Telemetry>, session: u64) {
        self.det.attach_metrics(tel.metrics().clone());
        self.tel = Some((tel, session));
    }

    fn tick(&mut self, dev: &mut dyn Device) {
        dev.advance(self.ts);
        if self.classified.is_some() {
            return;
        }
        let inst = dev.sample(self.ts);
        self.det.push(inst.power_w, inst.util_sm, inst.util_mem);
        let Some(v) = self.det.poll() else {
            return;
        };
        let Some((period_s, aperiodic)) = classify(&v) else {
            return;
        };
        self.classified = Some(aperiodic);
        if let Some((tel, session)) = &self.tel {
            if tel.enabled() {
                tel.emit(TelemetryEvent::Detect {
                    session: *session,
                    period_s,
                    aperiodic,
                    round: v.round as u64,
                });
            }
        }
    }
}

pub struct ArbiterBuilder;

impl PolicyBuilder for ArbiterBuilder {
    fn name(&self) -> &'static str {
        ARBITER_NAME
    }

    fn describe(&self) -> &'static str {
        "fleet budget-arbiter member: streaming periodic/aperiodic classification; caps arrive from the daemon's BudgetArbiter"
    }

    fn default_config(&self) -> String {
        let c = ArbiterCfg::default();
        format!(
            "budget_w={} period_s={} min_cap_w={} max_cap_w={} hysteresis_w={} (daemon-level) ts=0.025",
            c.budget_w, c.period_s, c.min_cap_w, c.max_cap_w, c.hysteresis_w
        )
    }

    fn build(&self, _ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        // Validate the daemon-level knobs even worker-side, so a bad
        // config fails the begin/set_policy instead of running silently
        // with defaults.
        let _ = cfg_from(cfg)?;
        Ok(Box::new(ArbiterPolicy::new(cfg.opt_f64("ts", 0.025)?)))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::device::sim_device;
    use crate::sim::{find_app, Spec};

    fn classify_app(name: &str) -> Option<bool> {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, name).unwrap();
        let mut dev = sim_device(&spec, &app);
        let mut pol = ArbiterPolicy::new(0.025);
        // 60 virtual seconds: past the detector's give-up window, so
        // every workload classifies one way or the other.
        for _ in 0..2400 {
            pol.tick(&mut dev);
            if pol.classification().is_some() {
                break;
            }
        }
        pol.classification()
    }

    #[test]
    fn periodic_and_aperiodic_workloads_classify() {
        assert_eq!(classify_app("AI_TS"), Some(false), "AI_TS is periodic");
        assert_eq!(classify_app("TSVM"), Some(true), "TSVM is aperiodic");
    }

    #[test]
    fn wire_knobs_parse_and_validate() {
        let spec = PolicySpec::registered("arbiter");
        assert!(is_arbiter(&spec));
        let cfg = arbiter_config(&spec).unwrap().unwrap();
        assert_eq!(cfg, ArbiterCfg::default());
        assert!(arbiter_config(&PolicySpec::registered("powercap")).is_none());

        let mut pc = PolicyConfig::default();
        pc.opts.insert("budget_w".into(), "600".into());
        pc.opts.insert("period_s".into(), "0.05".into());
        pc.opts.insert("min_cap_w".into(), "60".into());
        pc.opts.insert("hysteresis_w".into(), "5".into());
        let c = cfg_from(&pc).unwrap();
        assert_eq!(c.budget_w, 600.0);
        assert_eq!(c.period_s, 0.05);
        assert_eq!(c.min_cap_w, 60.0);
        assert_eq!(c.hysteresis_w, 5.0);

        pc.opts.insert("budget_w".into(), "-5".into());
        assert!(cfg_from(&pc).is_err(), "negative budget rejected");
        pc.opts.insert("budget_w".into(), "600".into());
        pc.opts.insert("max_cap_w".into(), "10".into());
        assert!(cfg_from(&pc).is_err(), "max below min rejected");
    }
}
