//! Switching-aware multi-armed bandit over a pruned SM-gear ladder.
//!
//! Xu et al. (2024) show that when gear changes are costly, online
//! energy optimization is better framed as a bandit with an explicit
//! switching-cost term than as model-based search: the learner only
//! needs the *noisy meters* (energy counter + IPS proxy), no performance
//! counters, no trained models, no period detection. That makes this
//! family the model-free counterpoint to GPOEO in `gpoeo experiment
//! policies`:
//!
//! - **Arms** are SM gears pruned to a ladder (`bandit-stride` apart,
//!   from the floor gear up to the entry gear — the NVIDIA-default boost
//!   point). Pruning keeps the pull budget proportional to the run
//!   length instead of the 99-gear space; the memory clock is left at
//!   the entry gear (a wrong memory clock is catastrophic, §4.3.4).
//! - **Rewards** come from one decision period per pull: average power
//!   from the noisy energy-meter delta and work rate from the noisy IPS
//!   proxy, turned into (energy, time) ratios against a baseline
//!   measured at the entry clocks, scored by the configured objective.
//! - **Switching cost** is charged onto the observed loss whenever a
//!   pull changes gears, and (for UCB) onto the selection index of every
//!   non-current arm, so the learner settles instead of thrashing.
//!
//! Two algorithms share the harness: UCB1 (`bandit-algo=ucb`, default)
//! and EXP3 (`bandit-algo=exp3`, adversarial-style updates). Both are
//! deterministic given the device's noise stream — EXP3's sampling runs
//! on a fixed-seed PCG64 — so fleet sweeps stay bit-reproducible.

use super::{MeterWindow, PolicyBuilder, PolicyConfig, PolicyCtx};
use crate::coordinator::Policy;
use crate::device::Device;
use crate::search::Objective;
use crate::telemetry::{Gauge, Telemetry, TelemetryEvent};
use crate::util::rng::Pcg64;
use std::sync::Arc;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BanditAlgo {
    Ucb,
    Exp3,
}

#[derive(Clone)]
pub struct BanditCfg {
    pub objective: Objective,
    /// NVML sampling interval (seconds) — one tick advances this far.
    pub ts: f64,
    pub algo: BanditAlgo,
    /// Decision-period length per pull, seconds (0 = auto: ~3 nominal
    /// iterations, clamped to [1, 6] s).
    pub period_s: f64,
    /// Gear distance between neighboring arms.
    pub stride: usize,
    /// Loss charged when a pull switches gears.
    pub switch_cost: f64,
    /// UCB exploration weight.
    pub explore: f64,
    /// EXP3 exploration/learning rate γ.
    pub exp3_gamma: f64,
    /// Decision periods spent measuring the baseline before pulling.
    pub baseline_periods: usize,
}

impl Default for BanditCfg {
    fn default() -> Self {
        BanditCfg {
            objective: Objective::paper_default(),
            ts: 0.025,
            algo: BanditAlgo::Ucb,
            period_s: 0.0,
            stride: 8,
            switch_cost: 0.02,
            explore: 0.18,
            exp3_gamma: 0.15,
            baseline_periods: 2,
        }
    }
}

impl BanditCfg {
    pub fn from_config(cfg: &PolicyConfig) -> anyhow::Result<BanditCfg> {
        let d = BanditCfg::default();
        let algo = match cfg.opt("bandit-algo").unwrap_or("ucb") {
            "ucb" => BanditAlgo::Ucb,
            "exp3" => BanditAlgo::Exp3,
            other => anyhow::bail!("--bandit-algo expects ucb|exp3, got '{other}'"),
        };
        Ok(BanditCfg {
            objective: cfg.objective,
            ts: cfg.opt_f64("ts", d.ts)?,
            algo,
            period_s: cfg.opt_f64("bandit-period", d.period_s)?,
            stride: cfg.opt_usize("bandit-stride", d.stride)?.max(1),
            switch_cost: cfg.opt_f64("switch-cost", d.switch_cost)?,
            explore: cfg.opt_f64("bandit-explore", d.explore)?,
            exp3_gamma: cfg.opt_f64("exp3-gamma", d.exp3_gamma)?.clamp(0.01, 1.0),
            baseline_periods: cfg.opt_usize("bandit-baseline", d.baseline_periods)?.max(1),
        })
    }
}

/// Losses above this are treated as "maximally bad" when mapping to
/// EXP3's [0, 1] reward scale (infeasible configs score 10+).
const LOSS_CLIP: f64 = 2.0;

enum Phase {
    /// Waiting for the first tick (arms depend on the entry gear).
    Boot,
    /// Accumulating the baseline at the entry clocks.
    Baseline { done: usize },
    /// One arm pulled, measuring its decision period. `prob` is the
    /// probability the selector played this arm with (1.0 for UCB) —
    /// EXP3's importance weighting needs the true value.
    Pull {
        arm: usize,
        switched: bool,
        prob: f64,
    },
}

/// The switching-aware bandit policy. Implements
/// [`crate::coordinator::Policy`]; registered as `bandit`.
pub struct Bandit {
    pub cfg: BanditCfg,
    phase: Phase,
    window: Option<MeterWindow>,
    period_s: f64,
    /// Pruned SM-gear arms, ascending; `arms[current]` is live.
    arms: Vec<usize>,
    current: usize,
    /// Per-arm pull count and mean observed loss (UCB state).
    pulls: Vec<u64>,
    mean_loss: Vec<f64>,
    total_pulls: u64,
    /// EXP3 log-weights (kept in log space for numeric safety).
    log_w: Vec<f64>,
    /// Baseline power/IPS at the entry clocks.
    p_base: f64,
    ips_base: f64,
    base_acc: (f64, f64),
    rng: Pcg64,
    /// Total switch events (telemetry; exercised by tests).
    pub switches: u64,
    /// Telemetry plane + fleet session id; pure observation.
    tel: Option<(Arc<Telemetry>, u64)>,
}

impl Bandit {
    pub fn new(cfg: BanditCfg) -> Bandit {
        Bandit {
            cfg,
            phase: Phase::Boot,
            window: None,
            period_s: 0.0,
            arms: Vec::new(),
            current: 0,
            pulls: Vec::new(),
            mean_loss: Vec::new(),
            total_pulls: 0,
            log_w: Vec::new(),
            p_base: 0.0,
            ips_base: 0.0,
            base_acc: (0.0, 0.0),
            // Fixed seed: selection must be reproducible run-to-run so
            // parallel fleet sweeps stay bit-identical to serial ones.
            rng: Pcg64::new(0xbad_d17 ^ 0x5eed, 0x0b5e55),
            switches: 0,
            tel: None,
        }
    }

    fn boot(&mut self, dev: &mut dyn Device) {
        let spec = dev.spec().clone();
        let entry = dev.sm_gear();
        let floor = spec.gears.sm_gear_min;
        // Ladder from the floor gear up in `stride` steps; the entry
        // gear (the "do nothing" arm) is always the top rung, so both
        // ends of the range are reachable whatever the stride.
        let mut arms: Vec<usize> = (floor..=entry).step_by(self.cfg.stride).collect();
        if arms.last() != Some(&entry) {
            arms.push(entry);
        }
        let n = arms.len();
        self.current = n - 1; // entry gear
        self.arms = arms;
        self.pulls = vec![0; n];
        self.mean_loss = vec![0.0; n];
        self.log_w = vec![0.0; n];
        self.period_s = if self.cfg.period_s > 0.0 {
            self.cfg.period_s
        } else {
            (3.0 * dev.nominal_iter_s()).clamp(1.0, 6.0)
        };
        self.phase = Phase::Baseline { done: 0 };
    }

    /// Open a measurement window of one decision period.
    fn open_window(&mut self, dev: &mut dyn Device) {
        self.window = Some(MeterWindow::open(dev, self.period_s));
    }

    /// Close the window: (average power, IPS), both meter-noisy.
    fn close_window(&mut self, dev: &mut dyn Device) -> Option<(f64, f64)> {
        self.window.take()?.close(dev)
    }

    /// Loss of one pull from measured (power, IPS) against the baseline.
    fn loss_of(&self, p: f64, ips: f64, switched: bool) -> f64 {
        let t_ratio = self.ips_base / ips.max(1e-9);
        let e_ratio = (p / ips.max(1e-9)) / (self.p_base / self.ips_base);
        let mut loss = self.cfg.objective.score(e_ratio, t_ratio);
        if switched {
            loss += self.cfg.switch_cost;
        }
        loss
    }

    /// Pick the next arm and the probability it was played with (1.0
    /// for the deterministic UCB). UCB: argmin of (mean loss −
    /// exploration bonus + switching penalty for non-current arms);
    /// every arm is primed once first, nearest-to-entry first. EXP3:
    /// sample from the exponential-weights distribution mixed with
    /// uniform exploration.
    fn select(&mut self) -> (usize, f64) {
        let n = self.arms.len();
        match self.cfg.algo {
            BanditAlgo::Ucb => {
                // Prime unpulled arms from the top of the ladder down —
                // high gears are the safe (feasible) end.
                if let Some(i) = (0..n).rev().find(|&i| self.pulls[i] == 0) {
                    return (i, 1.0);
                }
                let t = (self.total_pulls as f64).max(2.0);
                let mut best = self.current;
                let mut best_idx = f64::INFINITY;
                for i in 0..n {
                    let bonus = self.cfg.explore * (t.ln() / self.pulls[i] as f64).sqrt();
                    let mut idx = self.mean_loss[i] - bonus;
                    if i != self.current {
                        idx += self.cfg.switch_cost;
                    }
                    if idx < best_idx {
                        best_idx = idx;
                        best = i;
                    }
                }
                (best, 1.0)
            }
            BanditAlgo::Exp3 => {
                let g = self.cfg.exp3_gamma;
                let max = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let ws: Vec<f64> = self.log_w.iter().map(|&l| (l - max).exp()).collect();
                let wsum: f64 = ws.iter().sum();
                let probs: Vec<f64> = ws
                    .iter()
                    .map(|&w| (1.0 - g) * w / wsum + g / n as f64)
                    .collect();
                let mut u = self.rng.next_f64();
                for (i, &p) in probs.iter().enumerate() {
                    if u < p {
                        return (i, p);
                    }
                    u -= p;
                }
                (n - 1, probs[n - 1])
            }
        }
    }

    /// Account one observed pull. `prob` is the probability the selector
    /// played this arm with — the unbiased EXP3 importance weight.
    fn update(&mut self, arm: usize, loss: f64, prob: f64) {
        self.total_pulls += 1;
        self.pulls[arm] += 1;
        let k = self.pulls[arm] as f64;
        self.mean_loss[arm] += (loss - self.mean_loss[arm]) / k;
        if self.cfg.algo == BanditAlgo::Exp3 {
            let n = self.arms.len() as f64;
            let g = self.cfg.exp3_gamma;
            // Reward in [0,1], importance-weighted by the true play
            // probability (floored defensively; the γ/K exploration term
            // already bounds it from below).
            let reward = (1.0 - loss.min(LOSS_CLIP) / LOSS_CLIP).clamp(0.0, 1.0);
            let p = prob.max(g / (2.0 * n));
            self.log_w[arm] += g * (reward / p) / n;
            // Keep log-weights bounded.
            let max = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if max > 40.0 {
                for l in &mut self.log_w {
                    *l -= max - 40.0;
                }
            }
        }
    }

    fn start_pull(&mut self, dev: &mut dyn Device) {
        let (next, prob) = self.select();
        let switched = next != self.current;
        if switched {
            self.switches += 1;
            dev.set_sm_gear(self.arms[next]);
            if let Some((tel, session)) = &self.tel {
                tel.metrics().gear_switch("bandit");
                tel.metrics().set_gauge(Gauge::SmGear, dev.sm_gear() as f64);
                tel.metrics().set_gauge(Gauge::MemGear, dev.mem_gear() as f64);
                tel.emit(TelemetryEvent::GearSwitch {
                    session: *session,
                    policy: "bandit".into(),
                    sm_gear: dev.sm_gear(),
                    mem_gear: dev.mem_gear(),
                    time_s: dev.time_s(),
                });
            }
        }
        self.current = next;
        self.phase = Phase::Pull {
            arm: next,
            switched,
            prob,
        };
        self.open_window(dev);
    }
}

impl Policy for Bandit {
    fn name(&self) -> &'static str {
        "bandit"
    }

    fn attach_telemetry(&mut self, tel: Arc<Telemetry>, session: u64) {
        self.tel = Some((tel, session));
    }

    fn tick(&mut self, dev: &mut dyn Device) {
        if matches!(self.phase, Phase::Boot) {
            self.boot(dev);
            self.open_window(dev);
        }
        dev.advance(self.cfg.ts);
        let done = self
            .window
            .as_ref()
            .map(|w| w.done(dev.time_s()))
            .unwrap_or(true);
        if !done {
            return;
        }
        match self.phase {
            Phase::Boot => unreachable!("boot handled above"),
            Phase::Baseline { done } => {
                if let Some((p, ips)) = self.close_window(dev) {
                    self.base_acc.0 += p;
                    self.base_acc.1 += ips;
                    let done = done + 1;
                    if done >= self.cfg.baseline_periods {
                        self.p_base = self.base_acc.0 / done as f64;
                        self.ips_base = self.base_acc.1 / done as f64;
                        self.start_pull(dev);
                    } else {
                        self.phase = Phase::Baseline { done };
                        self.open_window(dev);
                    }
                } else {
                    // Meter glitch: re-measure the same baseline window.
                    self.open_window(dev);
                }
            }
            Phase::Pull {
                arm,
                switched,
                prob,
            } => {
                if let Some((p, ips)) = self.close_window(dev) {
                    let loss = self.loss_of(p, ips, switched);
                    self.update(arm, loss, prob);
                    self.start_pull(dev);
                } else {
                    self.open_window(dev);
                }
            }
        }
    }
}

pub struct BanditBuilder;

impl PolicyBuilder for BanditBuilder {
    fn name(&self) -> &'static str {
        "bandit"
    }

    fn describe(&self) -> &'static str {
        "switching-aware UCB/EXP3 bandit over a pruned SM-gear ladder (model-free: noisy energy meter + IPS only)"
    }

    fn default_config(&self) -> String {
        let c = BanditCfg::default();
        format!(
            "bandit-algo=ucb bandit-stride={} switch-cost={} bandit-explore={} bandit-period=auto",
            c.stride, c.switch_cost, c.explore
        )
    }

    fn build(&self, _ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        Ok(Box::new(Bandit::new(BanditCfg::from_config(cfg)?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sim, savings, DefaultPolicy};
    use crate::sim::{find_app, Spec};
    use std::sync::Arc;

    #[test]
    fn cfg_parses_and_rejects() {
        let mut pc = PolicyConfig::default();
        pc.opts.insert("bandit-algo".into(), "exp3".into());
        pc.opts.insert("bandit-stride".into(), "12".into());
        let c = BanditCfg::from_config(&pc).unwrap();
        assert_eq!(c.algo, BanditAlgo::Exp3);
        assert_eq!(c.stride, 12);
        pc.opts.insert("bandit-algo".into(), "thompson".into());
        assert!(BanditCfg::from_config(&pc).is_err());
    }

    #[test]
    fn bandit_completes_and_is_deterministic() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "SBM_GIN").unwrap();
        let r1 = run_sim(&spec, &app, &mut Bandit::new(BanditCfg::default()), 120);
        let r2 = run_sim(&spec, &app, &mut Bandit::new(BanditCfg::default()), 120);
        assert!(r1.iterations >= 120);
        assert_eq!(r1.energy_j, r2.energy_j, "bandit must be reproducible");
        assert_eq!(r1.time_s, r2.time_s);
    }

    #[test]
    fn bandit_saves_energy_within_the_envelope() {
        // Long-horizon run: the bandit should end below baseline energy
        // per work unit without catastrophic slowdown. Model-free, so no
        // artifacts are required — this exercises the whole loop in CI.
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "CLB_MLP").unwrap();
        let n = crate::coordinator::default_iters(&app);
        let base = run_sim(&spec, &app, &mut DefaultPolicy { ts: 0.025 }, n);
        let mut b = Bandit::new(BanditCfg::default());
        let run = run_sim(&spec, &app, &mut b, n);
        let s = savings(&base, &run).unwrap();
        assert!(b.switches > 0, "bandit never explored");
        assert!(
            s.energy_saving > -0.02,
            "bandit must not burn extra energy: {:.3}",
            s.energy_saving
        );
        assert!(s.slowdown < 0.25, "slowdown {:.3}", s.slowdown);
    }

    #[test]
    fn exp3_variant_completes() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_TS").unwrap();
        let cfg = BanditCfg {
            algo: BanditAlgo::Exp3,
            ..BanditCfg::default()
        };
        let r = run_sim(&spec, &app, &mut Bandit::new(cfg), 80);
        assert!(r.iterations >= 80);
    }
}
