//! The policy subsystem (DESIGN.md §8).
//!
//! Every online clock-management policy is constructed through the
//! [`PolicyRegistry`] — the single place that maps policy *names* to
//! builders. The CLI (`run`/`sweep`), the fleet workers, the daemon's
//! `POLICY` command and the `experiment policies` head-to-head all
//! resolve names here; nothing outside this module matches on
//! policy-name strings.
//!
//! Registered families:
//!
//! | name       | description                                            |
//! |------------|--------------------------------------------------------|
//! | `default`  | NVIDIA default scheduling (no controller; the baseline)|
//! | `gpoeo`    | the paper's online controller (needs trained models)   |
//! | `odpp`     | the ODPP baseline                                      |
//! | `bandit`   | switching-aware UCB/EXP3 over a pruned gear ladder     |
//! | `powercap` | Zeus-style power-cap ladder over `Device` power limits |
//! | `arbiter`  | fleet budget-arbiter member (caps arrive daemon-side)  |
//!
//! Construction is split in two so non-`Send` predictors stay worker-
//! local: a [`PolicySpec`] (name + [`PolicyConfig`]) is `Send + Clone`
//! and crosses threads freely; [`PolicyRegistry::build_spec`] turns it
//! into a live `Box<dyn Policy>` *on the thread that will drive it*,
//! pulling the thread's predictor through [`PolicyCtx`] only if the
//! policy actually needs one (the bandit and power-cap families are
//! model-free).

pub mod arbiter;
pub mod bandit;
pub mod powercap;

pub use arbiter::ArbiterPolicy;
pub use bandit::{Bandit, BanditAlgo, BanditCfg};
pub use powercap::{PowerCap, PowerCapCfg};

use crate::coordinator::{DefaultPolicy, Gpoeo, GpoeoCfg, Odpp, OdppCfg, Policy};
use crate::device::Device;
use crate::model::Predictor;
use crate::search::Objective;
use crate::sim::Spec;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

/// Thread-crossing policy configuration: the objective plus free-form
/// `key=value` options (the CLI forwards all `--key value` options, so
/// each builder picks up its own knobs and ignores the rest).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    pub objective: Objective,
    pub opts: BTreeMap<String, String>,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            objective: Objective::paper_default(),
            opts: BTreeMap::new(),
        }
    }
}

impl PolicyConfig {
    pub fn new(objective: Objective) -> PolicyConfig {
        PolicyConfig {
            objective,
            opts: BTreeMap::new(),
        }
    }

    /// Build from CLI arguments: the objective from `--objective`/
    /// `--slowdown-cap`, and every other option forwarded verbatim.
    pub fn from_args(args: &Args) -> anyhow::Result<PolicyConfig> {
        Ok(PolicyConfig {
            objective: crate::coordinator::parse_objective(args)?,
            opts: args.options.clone(),
        })
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{s}'")),
        }
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{s}'")),
        }
    }

    /// Control-plane wire encoding (DESIGN.md §9):
    /// `{"objective": "capped", "max_time_ratio": 1.05, "opts": {...}}`.
    /// Fields with default values are omitted; `decode(encode(c)) == c`
    /// bit-exactly.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![(
            "objective",
            Json::Str(self.objective.wire_name().to_string()),
        )];
        if let Some(r) = self.objective.max_time_ratio() {
            fields.push(("max_time_ratio", Json::Num(r)));
        }
        if !self.opts.is_empty() {
            fields.push((
                "opts",
                Json::Obj(
                    self.opts
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Decode the wire encoding. Unknown fields are rejected (the
    /// control plane answers a typed error instead of silently running a
    /// config the client never asked for); option values may be strings,
    /// numbers or bools — non-strings are stringified, since builders
    /// parse options from text exactly as they do for CLI `--key value`.
    pub fn from_json(j: &Json) -> anyhow::Result<PolicyConfig> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("policy config must be a json object"))?;
        for k in obj.keys() {
            if !matches!(k.as_str(), "objective" | "max_time_ratio" | "opts") {
                anyhow::bail!("unknown policy config field '{k}'");
            }
        }
        let name = match j.get("objective") {
            Json::Null => "capped",
            v => v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("'objective' must be a string"))?,
        };
        let objective = Objective::from_wire(name, j.opt_f64("max_time_ratio", 1.05))?;
        let mut opts = BTreeMap::new();
        match j.get("opts") {
            Json::Null => {}
            Json::Obj(o) => {
                for (k, v) in o {
                    let s = match v {
                        Json::Str(s) => s.clone(),
                        Json::Num(_) | Json::Bool(_) => v.to_string(),
                        _ => anyhow::bail!("option '{k}' must be a string, number or bool"),
                    };
                    opts.insert(k.clone(), s);
                }
            }
            _ => anyhow::bail!("'opts' must be a json object"),
        }
        Ok(PolicyConfig { objective, opts })
    }
}

/// A named policy selection that can cross threads (fleet jobs, daemon
/// sessions). Built into a live policy worker-side via
/// [`PolicyRegistry::build_spec`].
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    pub name: String,
    pub cfg: PolicyConfig,
}

impl PolicySpec {
    pub fn new(name: &str, cfg: PolicyConfig) -> PolicySpec {
        PolicySpec {
            name: name.to_string(),
            cfg,
        }
    }

    /// Selection by name with the default (paper) configuration.
    pub fn registered(name: &str) -> PolicySpec {
        PolicySpec::new(name, PolicyConfig::default())
    }

    /// Control-plane wire encoding: `{"name": "bandit", "config": {...}}`
    /// (the `config` field is omitted when it is all defaults).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("name", Json::Str(self.name.clone()))];
        if self.cfg != PolicyConfig::default() {
            fields.push(("config", self.cfg.to_json()));
        }
        Json::obj(fields)
    }

    /// Decode the wire encoding. A bare string is shorthand for a name
    /// with the default config (`"policy": "bandit"`).
    pub fn from_json(j: &Json) -> anyhow::Result<PolicySpec> {
        if let Some(name) = j.as_str() {
            return Ok(PolicySpec::registered(name));
        }
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("policy must be a name string or a json object"))?;
        for k in obj.keys() {
            if !matches!(k.as_str(), "name" | "config") {
                anyhow::bail!("unknown policy field '{k}'");
            }
        }
        let name = j.req_str("name")?;
        let cfg = match j.get("config") {
            Json::Null => PolicyConfig::default(),
            c => PolicyConfig::from_json(c)?,
        };
        Ok(PolicySpec::new(name, cfg))
    }
}

/// One measurement window over the device's noisy meters, shared by the
/// model-free policies: average power from the energy-counter delta over
/// the window plus the IPS proxy at close. `close` reports `None` on a
/// meter glitch (non-finite or non-positive readings) — callers re-open
/// and re-measure.
pub(crate) struct MeterWindow {
    end_s: f64,
    e0: f64,
    t0: f64,
}

impl MeterWindow {
    pub(crate) fn open(dev: &mut dyn Device, dur_s: f64) -> MeterWindow {
        MeterWindow {
            end_s: dev.time_s() + dur_s,
            e0: dev.energy_j(),
            t0: dev.time_s(),
        }
    }

    pub(crate) fn done(&self, now_s: f64) -> bool {
        now_s >= self.end_s
    }

    /// (average power, IPS), both meter-noisy; `None` on a glitch.
    pub(crate) fn close(self, dev: &mut dyn Device) -> Option<(f64, f64)> {
        let p = (dev.energy_j() - self.e0) / (dev.time_s() - self.t0).max(1e-9);
        let ips = dev.ips();
        (p > 0.0 && ips > 0.0 && p.is_finite() && ips.is_finite()).then_some((p, ips))
    }
}

/// Thread-local construction context. `predictor` is a lazy provider —
/// typically a closure over a fleet worker's `OnceCell` — invoked only
/// by builders whose policy needs the trained models, so model-free
/// policies never pay (or fail on) predictor loading.
pub struct PolicyCtx<'a> {
    pub spec: &'a Arc<Spec>,
    pub predictor: &'a dyn Fn() -> anyhow::Result<Arc<Predictor>>,
}

/// One registered policy family: metadata plus the builder.
pub trait PolicyBuilder: Send + Sync {
    /// Registry key (`--policy <name>`, daemon `POLICY <name>`).
    fn name(&self) -> &'static str;

    /// One-line description for `gpoeo policies`.
    fn describe(&self) -> &'static str;

    /// One-line default-configuration summary (knob names double as the
    /// CLI options each builder understands).
    fn default_config(&self) -> String;

    fn build(&self, ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>>;
}

/// Name → builder table. Use [`PolicyRegistry::global`] for the standard
/// registry; `standard()` builds a fresh one (tests).
pub struct PolicyRegistry {
    builders: Vec<Box<dyn PolicyBuilder>>,
}

impl PolicyRegistry {
    /// The standard registry with every built-in policy family.
    pub fn standard() -> PolicyRegistry {
        PolicyRegistry {
            builders: vec![
                Box::new(DefaultBuilder),
                Box::new(GpoeoBuilder),
                Box::new(OdppBuilder),
                Box::new(bandit::BanditBuilder),
                Box::new(powercap::PowerCapBuilder),
                Box::new(arbiter::ArbiterBuilder),
            ],
        }
    }

    /// Process-wide standard registry.
    pub fn global() -> &'static PolicyRegistry {
        static REG: OnceLock<PolicyRegistry> = OnceLock::new();
        REG.get_or_init(PolicyRegistry::standard)
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn PolicyBuilder> {
        self.builders.iter().map(|b| b.as_ref())
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.builders.iter().map(|b| b.name()).collect()
    }

    /// Look a builder up by name. The error text starts with
    /// `unknown policy` — the daemon protocol relies on that prefix.
    pub fn get(&self, name: &str) -> anyhow::Result<&dyn PolicyBuilder> {
        self.builders
            .iter()
            .map(|b| b.as_ref())
            .find(|b| b.name() == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown policy '{name}' (registered: {})",
                    self.names().join(" ")
                )
            })
    }

    /// Build a policy by name.
    pub fn build(
        &self,
        name: &str,
        ctx: &PolicyCtx,
        cfg: &PolicyConfig,
    ) -> anyhow::Result<Box<dyn Policy>> {
        self.get(name)?.build(ctx, cfg)
    }

    /// Build from a thread-crossing [`PolicySpec`].
    pub fn build_spec(
        &self,
        spec: &PolicySpec,
        ctx: &PolicyCtx,
    ) -> anyhow::Result<Box<dyn Policy>> {
        self.build(&spec.name, ctx, &spec.cfg)
    }
}

// ---------------------------------------------------------------------
// Builders for the pre-existing policy families. The bandit and
// power-cap builders live next to their policies.
// ---------------------------------------------------------------------

struct DefaultBuilder;

impl PolicyBuilder for DefaultBuilder {
    fn name(&self) -> &'static str {
        "default"
    }

    fn describe(&self) -> &'static str {
        "NVIDIA default scheduling strategy (no controller; the baseline itself)"
    }

    fn default_config(&self) -> String {
        "ts=0.025".to_string()
    }

    fn build(&self, _ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        Ok(Box::new(DefaultPolicy {
            ts: cfg.opt_f64("ts", 0.025)?,
        }))
    }
}

struct GpoeoBuilder;

impl PolicyBuilder for GpoeoBuilder {
    fn name(&self) -> &'static str {
        "gpoeo"
    }

    fn describe(&self) -> &'static str {
        "the paper's online controller: period detection + counter profiling + GBT prediction + golden-section search"
    }

    fn default_config(&self) -> String {
        let c = GpoeoCfg::default();
        format!(
            "ts={} initial-window={} slowdown-cap=0.05 (needs trained model artifacts)",
            c.ts, c.initial_window_s
        )
    }

    fn build(&self, ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        let predictor = (ctx.predictor)()?;
        let mut c = GpoeoCfg {
            objective: cfg.objective,
            ..GpoeoCfg::default()
        };
        c.ts = cfg.opt_f64("ts", c.ts)?;
        c.initial_window_s = cfg.opt_f64("initial-window", c.initial_window_s)?;
        Ok(Box::new(Gpoeo::new(c, predictor)))
    }
}

struct OdppBuilder;

impl PolicyBuilder for OdppBuilder {
    fn name(&self) -> &'static str {
        "odpp"
    }

    fn describe(&self) -> &'static str {
        "ODPP baseline: FFT-argmax period detection + piecewise-linear clock models (counter-free)"
    }

    fn default_config(&self) -> String {
        let c = OdppCfg::default();
        format!("ts={} window={} probe={}", c.ts, c.window_s, c.probe_s)
    }

    fn build(&self, _ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        let mut c = OdppCfg {
            objective: cfg.objective,
            ..OdppCfg::default()
        };
        c.ts = cfg.opt_f64("ts", c.ts)?;
        c.window_s = cfg.opt_f64("window", c.window_s)?;
        Ok(Box::new(Odpp::new(c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_complete() {
        let reg = PolicyRegistry::standard();
        let names = reg.names();
        for expect in ["default", "gpoeo", "odpp", "bandit", "powercap", "arbiter"] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate registry names");
    }

    #[test]
    fn unknown_name_error_has_the_protocol_prefix() {
        let err = PolicyRegistry::global().get("warpdrive").unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("unknown policy"), "{msg}");
        assert!(msg.contains("bandit"), "must list registered names: {msg}");
    }

    #[test]
    fn config_opts_parse_and_reject() {
        let mut cfg = PolicyConfig::default();
        cfg.opts.insert("switch-cost".into(), "0.5".into());
        cfg.opts.insert("bad".into(), "zzz".into());
        assert_eq!(cfg.opt_f64("switch-cost", 0.0).unwrap(), 0.5);
        assert_eq!(cfg.opt_f64("absent", 1.5).unwrap(), 1.5);
        assert!(cfg.opt_f64("bad", 0.0).is_err());
        assert!(cfg.opt_usize("bad", 0).is_err());
    }

    #[test]
    fn config_wire_roundtrip_is_exact() {
        let mut cfg = PolicyConfig::new(Objective::Ed2p);
        cfg.opts.insert("switch-cost".into(), "0.25".into());
        cfg.opts.insert("bandit-algo".into(), "exp3".into());
        for c in [PolicyConfig::default(), cfg] {
            let back = PolicyConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back, c);
            // And through a serialize/parse cycle (the wire is text).
            let text = c.to_json().to_string();
            let back = PolicyConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, c);
        }
    }

    #[test]
    fn spec_wire_roundtrip_and_shorthand() {
        let mut cfg = PolicyConfig::default();
        cfg.opts.insert("switch-cost".into(), "2".into());
        let spec = PolicySpec::new("bandit", cfg);
        assert_eq!(PolicySpec::from_json(&spec.to_json()).unwrap(), spec);

        let plain = PolicySpec::registered("odpp");
        let j = plain.to_json();
        assert_eq!(j.get("config"), &Json::Null, "default config is omitted");
        assert_eq!(PolicySpec::from_json(&j).unwrap(), plain);
        assert_eq!(
            PolicySpec::from_json(&Json::Str("powercap".into())).unwrap(),
            PolicySpec::registered("powercap")
        );
    }

    #[test]
    fn config_wire_rejects_malformed_input() {
        for bad in [
            r#"{"objective": "warp"}"#,
            r#"{"objective": 3}"#,
            r#"{"surprise": 1}"#,
            r#"{"opts": [1]}"#,
            r#"{"opts": {"k": [1]}}"#,
            r#"{"objective": "capped", "max_time_ratio": 0.5}"#,
            r#""s""#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(PolicyConfig::from_json(&j).is_err(), "{bad}");
        }
        // Numeric/bool option values are coerced to the text the CLI
        // would have passed.
        let j = Json::parse(r#"{"opts": {"switch-cost": 0.5, "flag": true}}"#).unwrap();
        let cfg = PolicyConfig::from_json(&j).unwrap();
        assert_eq!(cfg.opt("switch-cost"), Some("0.5"));
        assert_eq!(cfg.opt("flag"), Some("true"));

        assert!(PolicySpec::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(PolicySpec::from_json(&Json::parse(r#"{"name":"x","zz":1}"#).unwrap()).is_err());
    }
}
