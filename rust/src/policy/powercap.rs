//! Power-cap ladder policy — the Zeus-style alternative to clock gears.
//!
//! Zeus (You et al., 2022) trades energy against time by searching over
//! *power limits* instead of clock pairs: the driver's power manager
//! does the gear bookkeeping, the optimizer just walks a one-dimensional
//! ladder of caps. This policy reproduces that control surface on top of
//! the [`Device::set_power_limit_w`] extension (the simulator throttles
//! its effective SM clock under the cap, like real power management):
//!
//! 1. **Baseline** — one dwell window at the entry clocks, uncapped:
//!    average power from the noisy energy meter, work rate from the IPS
//!    proxy.
//! 2. **Descend** — step the cap down from just under the baseline power
//!    in `cap-step` watt decrements, one dwell window per rung, scoring
//!    each rung's (energy, time) ratios under the configured objective.
//!    Stop after `cap-patience` consecutive worsening rungs or at the
//!    `cap-floor` fraction of baseline power (the ladder is near-unimodal
//!    — patience absorbs meter noise).
//! 3. **Hold** — pin the best-scoring cap (possibly "uncapped" when no
//!    rung beat the baseline) and keep monitoring nothing: like ODPP,
//!    the policy is counter-free and needs no trained models.

use super::{MeterWindow, PolicyBuilder, PolicyConfig, PolicyCtx};
use crate::coordinator::Policy;
use crate::device::Device;
use crate::search::Objective;
use crate::telemetry::{Gauge, Telemetry, TelemetryEvent};
use std::sync::Arc;

#[derive(Clone)]
pub struct PowerCapCfg {
    pub objective: Objective,
    /// NVML sampling interval (seconds).
    pub ts: f64,
    /// Dwell per ladder rung, seconds (0 = auto: ~2 nominal iterations,
    /// clamped to [1.5, 8] s).
    pub dwell_s: f64,
    /// Ladder decrement, watts.
    pub step_w: f64,
    /// Lowest cap as a fraction of the measured baseline power.
    pub floor_frac: f64,
    /// Consecutive worsening rungs tolerated before settling.
    pub patience: usize,
}

impl Default for PowerCapCfg {
    fn default() -> Self {
        PowerCapCfg {
            objective: Objective::paper_default(),
            ts: 0.025,
            dwell_s: 0.0,
            step_w: 15.0,
            floor_frac: 0.45,
            patience: 2,
        }
    }
}

impl PowerCapCfg {
    pub fn from_config(cfg: &PolicyConfig) -> anyhow::Result<PowerCapCfg> {
        let d = PowerCapCfg::default();
        Ok(PowerCapCfg {
            objective: cfg.objective,
            ts: cfg.opt_f64("ts", d.ts)?,
            dwell_s: cfg.opt_f64("cap-dwell", d.dwell_s)?,
            step_w: cfg.opt_f64("cap-step", d.step_w)?.max(1.0),
            floor_frac: cfg.opt_f64("cap-floor", d.floor_frac)?.clamp(0.1, 0.95),
            patience: cfg.opt_usize("cap-patience", d.patience)?.max(1),
        })
    }
}

enum Phase {
    Boot,
    Baseline,
    Descend { worse_streak: usize },
    Hold,
}

/// The power-cap ladder policy. Implements
/// [`crate::coordinator::Policy`]; registered as `powercap`.
pub struct PowerCap {
    pub cfg: PowerCapCfg,
    phase: Phase,
    window: Option<MeterWindow>,
    dwell_s: f64,
    p_base: f64,
    ips_base: f64,
    /// Cap currently being measured (watts).
    cap_w: f64,
    /// Best (score, cap) seen; `f64::INFINITY` cap = stay uncapped.
    best: (f64, f64),
    /// Final cap once settled (telemetry; exercised by tests).
    pub chosen_cap_w: f64,
    /// Rungs measured (telemetry).
    pub rungs: usize,
    /// Telemetry plane + fleet session id; pure observation.
    tel: Option<(Arc<Telemetry>, u64)>,
}

impl PowerCap {
    pub fn new(cfg: PowerCapCfg) -> PowerCap {
        PowerCap {
            cfg,
            phase: Phase::Boot,
            window: None,
            dwell_s: 0.0,
            p_base: 0.0,
            ips_base: 0.0,
            cap_w: 0.0,
            best: (f64::INFINITY, f64::INFINITY),
            chosen_cap_w: f64::INFINITY,
            rungs: 0,
            tel: None,
        }
    }

    /// Apply a cap and mirror the *applied* (range-clamped) value to
    /// the power-limit gauge. An uncapped cap reports the measured
    /// baseline power (gauges stay finite).
    fn apply_cap(&mut self, dev: &mut dyn Device, cap_w: f64) {
        let applied = dev.set_power_limit_w(cap_w);
        if let Some((tel, _)) = &self.tel {
            let shown = if applied.is_finite() { applied } else { self.p_base };
            tel.metrics().set_gauge(Gauge::PowerLimitW, shown);
        }
    }

    fn open_window(&mut self, dev: &mut dyn Device) {
        self.window = Some(MeterWindow::open(dev, self.dwell_s));
    }

    fn close_window(&mut self, dev: &mut dyn Device) -> Option<(f64, f64)> {
        self.window.take()?.close(dev)
    }

    fn score_of(&self, p: f64, ips: f64) -> f64 {
        let t_ratio = self.ips_base / ips.max(1e-9);
        let e_ratio = (p / ips.max(1e-9)) / (self.p_base / self.ips_base);
        self.cfg.objective.score(e_ratio, t_ratio)
    }

    fn settle(&mut self, dev: &mut dyn Device) {
        self.chosen_cap_w = self.best.1;
        self.apply_cap(dev, self.chosen_cap_w);
        if let Some((tel, session)) = &self.tel {
            tel.metrics().gear_switch("powercap");
            tel.emit(TelemetryEvent::GearSwitch {
                session: *session,
                policy: "powercap".into(),
                sm_gear: dev.sm_gear(),
                mem_gear: dev.mem_gear(),
                time_s: dev.time_s(),
            });
        }
        self.phase = Phase::Hold;
    }
}

impl Policy for PowerCap {
    fn name(&self) -> &'static str {
        "powercap"
    }

    fn attach_telemetry(&mut self, tel: Arc<Telemetry>, session: u64) {
        self.tel = Some((tel, session));
    }

    fn tick(&mut self, dev: &mut dyn Device) {
        if matches!(self.phase, Phase::Boot) {
            self.dwell_s = if self.cfg.dwell_s > 0.0 {
                self.cfg.dwell_s
            } else {
                (2.0 * dev.nominal_iter_s()).clamp(1.5, 8.0)
            };
            self.phase = Phase::Baseline;
            self.open_window(dev);
        }
        dev.advance(self.cfg.ts);
        if matches!(self.phase, Phase::Hold) {
            return;
        }
        let done = self
            .window
            .as_ref()
            .map(|w| w.done(dev.time_s()))
            .unwrap_or(true);
        if !done {
            return;
        }
        match self.phase {
            Phase::Boot | Phase::Hold => unreachable!("handled above"),
            Phase::Baseline => {
                let Some((p, ips)) = self.close_window(dev) else {
                    self.open_window(dev);
                    return;
                };
                self.p_base = p;
                self.ips_base = ips;
                // The baseline itself scores objective(1, 1) = 1 with an
                // "uncapped" cap — the rung every real cap must beat.
                self.best = (self.cfg.objective.score(1.0, 1.0), f64::INFINITY);
                self.cap_w = p - self.cfg.step_w;
                if self.cap_w <= p * self.cfg.floor_frac {
                    self.settle(dev);
                    return;
                }
                let cap = self.cap_w;
                self.apply_cap(dev, cap);
                self.phase = Phase::Descend { worse_streak: 0 };
                self.open_window(dev);
            }
            Phase::Descend { worse_streak } => {
                let Some((p, ips)) = self.close_window(dev) else {
                    self.open_window(dev);
                    return;
                };
                self.rungs += 1;
                let score = self.score_of(p, ips);
                let streak = if score < self.best.0 {
                    self.best = (score, self.cap_w);
                    0
                } else {
                    worse_streak + 1
                };
                let next = self.cap_w - self.cfg.step_w;
                if streak >= self.cfg.patience || next <= self.p_base * self.cfg.floor_frac {
                    self.settle(dev);
                    return;
                }
                self.cap_w = next;
                self.apply_cap(dev, next);
                self.phase = Phase::Descend {
                    worse_streak: streak,
                };
                self.open_window(dev);
            }
        }
    }
}

pub struct PowerCapBuilder;

impl PolicyBuilder for PowerCapBuilder {
    fn name(&self) -> &'static str {
        "powercap"
    }

    fn describe(&self) -> &'static str {
        "Zeus-style power-cap ladder descent over Device::set_power_limit_w (counter- and model-free)"
    }

    fn default_config(&self) -> String {
        let c = PowerCapCfg::default();
        format!(
            "cap-step={} cap-floor={} cap-patience={} cap-dwell=auto",
            c.step_w, c.floor_frac, c.patience
        )
    }

    fn build(&self, _ctx: &PolicyCtx, cfg: &PolicyConfig) -> anyhow::Result<Box<dyn Policy>> {
        Ok(Box::new(PowerCap::new(PowerCapCfg::from_config(cfg)?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sim, savings, DefaultPolicy};
    use crate::sim::{find_app, Spec};
    use std::sync::Arc;

    #[test]
    fn powercap_descends_settles_and_saves() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "AI_I2T").unwrap();
        let n = crate::coordinator::default_iters(&app);
        let base = run_sim(&spec, &app, &mut DefaultPolicy { ts: 0.025 }, n);
        let mut p = PowerCap::new(PowerCapCfg::default());
        let run = run_sim(&spec, &app, &mut p, n);
        assert!(run.iterations >= n);
        assert!(p.rungs > 0, "never measured a rung");
        assert!(
            p.chosen_cap_w.is_finite(),
            "a capped rung should beat the uncapped baseline here"
        );
        let s = savings(&base, &run).unwrap();
        assert!(
            s.energy_saving > 0.0,
            "power capping must save energy on AI_I2T: {:.3}",
            s.energy_saving
        );
    }

    #[test]
    fn powercap_is_deterministic() {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, "SBM_GIN").unwrap();
        let a = run_sim(&spec, &app, &mut PowerCap::new(PowerCapCfg::default()), 100);
        let b = run_sim(&spec, &app, &mut PowerCap::new(PowerCapCfg::default()), 100);
        assert!(a.iterations >= 100);
        assert_eq!(a.energy_j, b.energy_j);
        assert_eq!(a.time_s, b.time_s);
    }

    #[test]
    fn cfg_knobs_parse() {
        let mut pc = PolicyConfig::default();
        pc.opts.insert("cap-step".into(), "25".into());
        pc.opts.insert("cap-floor".into(), "0.6".into());
        let c = PowerCapCfg::from_config(&pc).unwrap();
        assert_eq!(c.step_w, 25.0);
        assert_eq!(c.floor_frac, 0.6);
        pc.opts.insert("cap-step".into(), "fast".into());
        assert!(PowerCapCfg::from_config(&pc).is_err());
    }
}
