//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them on the CPU PJRT client
//! from the L3 hot path. Python never runs here.
//!
//! Artifacts are HLO *text* — the interchange format that survives the
//! jax≥0.5 / xla_extension 0.5.1 proto-id mismatch (see
//! /opt/xla-example/README.md). `HloModuleProto::from_text_file`
//! reassigns instruction ids during parsing.
//!
//! The whole backend sits behind the `pjrt` cargo feature: the `xla`
//! bindings crate only exists in the offline seed environment. Without
//! the feature, [`Runtime`] is a stub whose loaders fail cleanly, so
//! `model::Predictor` degrades to the native GBT twin and the
//! controller's periodogram falls back to the native FFT.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Locate the artifact directory: `$GPOEO_ARTIFACTS`, else `artifacts/`
/// under the crate root, else `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("GPOEO_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let candidates = [
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string(),
        "artifacts".to_string(),
    ];
    for c in &candidates {
        let p = PathBuf::from(c);
        if p.join("meta.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// One compiled module.
#[cfg(feature = "pjrt")]
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl LoadedExe {
    fn load(client: &xla::PjRtClient, path: &Path) -> anyhow::Result<LoadedExe> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(LoadedExe { exe })
    }

    fn run1(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        let x = xla::Literal::vec1(input);
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    fn run2(&self, input: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let x = xla::Literal::vec1(input);
        let result = self.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let (a, b) = result.to_tuple2()?;
        Ok((a.to_vec::<f32>()?, b.to_vec::<f32>()?))
    }
}

/// The runtime: a PJRT CPU client plus the three compiled modules.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    _client: xla::PjRtClient,
    periodogram: LoadedExe,
    predictor_sm: LoadedExe,
    predictor_mem: LoadedExe,
    /// From meta.json — sanity metadata written at AOT time.
    pub meta: Json,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load all artifacts from `dir`. Fails if any artifact is missing —
    /// callers that want graceful degradation use [`Runtime::try_default`]
    /// and fall back to the native twin paths.
    pub fn load(dir: &Path) -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        let periodogram = LoadedExe::load(&client, &dir.join("periodogram_1024.hlo.txt"))?;
        let predictor_sm = LoadedExe::load(&client, &dir.join("predictor_sm.hlo.txt"))?;
        let predictor_mem = LoadedExe::load(&client, &dir.join("predictor_mem.hlo.txt"))?;
        let meta = Json::parse_file(&dir.join("meta.json"))?;
        Ok(Runtime {
            _client: client,
            periodogram,
            predictor_sm,
            predictor_mem,
            meta,
        })
    }

    /// Load from the default artifact location; `None` if unavailable.
    pub fn try_default() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        match Runtime::load(&dir) {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!(
                    "runtime: artifacts unavailable ({e}); falling back to native paths"
                );
                None
            }
        }
    }

    /// Amplitude spectrum of a 1024-sample trace (bins 1..=512).
    pub fn periodogram_1024(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(x.len() == 1024, "periodogram_1024 expects 1024 samples");
        self.periodogram.run1(x)
    }

    /// SM-clock models: features[16] → (energy ratios, time ratios) over
    /// the 99 SM gears (gear 16 first).
    pub fn predict_sm(&self, features: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(features.len() == 16, "predict_sm expects 16 features");
        self.predictor_sm.run2(features)
    }

    /// Memory-clock models: features[16] → ratios over the 5 memory gears.
    pub fn predict_mem(&self, features: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(features.len() == 16, "predict_mem expects 16 features");
        self.predictor_mem.run2(features)
    }
}

/// Stub runtime for builds without the `pjrt` feature: the type exists
/// (so `Predictor::Hlo` and call sites compile unchanged) but can never
/// be constructed — `load` reports the backend as unavailable and the
/// callers take their native fallbacks.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Mirrors the real field so downstream metadata probes compile.
    pub meta: Json,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn load(_dir: &Path) -> anyhow::Result<Runtime> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }

    pub fn try_default() -> Option<Runtime> {
        None
    }

    pub fn periodogram_1024(&self, _x: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }

    pub fn predict_sm(&self, _features: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }

    pub fn predict_mem(&self, _features: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }
}
