//! Golden-section local search over integer clock gears (§4.3.4).
//!
//! The paper's procedure: (1) bracket the predicted optimum by finding a
//! worse gear on each side, (2) golden-section within the bracket,
//! (3) fit the probed points with a parabola and let the convex fit pick
//! the final gear, which absorbs noise in the per-probe energy/period
//! measurements.

use crate::util::stats::{argmin, parabola_argmin};
use std::collections::BTreeMap;

/// Result of a local search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best_gear: usize,
    /// Number of *new* measurements taken (the paper's "# of search steps").
    pub steps: usize,
    /// All probed (gear, score) pairs, in probe order.
    pub probes: Vec<(usize, f64)>,
}

const GOLDEN: f64 = 0.618_033_988_749_894_8;

/// Search for the gear minimizing `eval` around `predicted` in
/// `[lo, hi]`. `eval` is called at most once per gear (results are
/// memoized); each fresh call counts as one search step.
pub fn local_search(
    predicted: usize,
    lo: usize,
    hi: usize,
    eval: &mut dyn FnMut(usize) -> f64,
) -> SearchResult {
    assert!(lo <= hi);
    let predicted = predicted.clamp(lo, hi);
    let mut cache: BTreeMap<usize, f64> = BTreeMap::new();
    let mut steps = 0usize;
    let mut probes: Vec<(usize, f64)> = Vec::new();

    let mut probe = |g: usize, cache: &mut BTreeMap<usize, f64>,
                     steps: &mut usize,
                     probes: &mut Vec<(usize, f64)>|
     -> f64 {
        if let Some(&v) = cache.get(&g) {
            return v;
        }
        let v = eval(g);
        cache.insert(g, v);
        *steps += 1;
        probes.push((g, v));
        v
    };

    let f0 = probe(predicted, &mut cache, &mut steps, &mut probes);

    // --- Phase 1: bracket. Expand geometrically on each side until a
    // worse point than the incumbent is seen (or the bound is hit).
    let mut best = (predicted, f0);
    let mut left = predicted;
    let mut stride = 1usize;
    while left > lo {
        let g = left.saturating_sub(stride).max(lo);
        let v = probe(g, &mut cache, &mut steps, &mut probes);
        if v < best.1 {
            best = (g, v);
        }
        left = g;
        if v > best.1 || g == lo {
            break;
        }
        stride *= 2;
    }
    let mut right = predicted;
    stride = 1;
    while right < hi {
        let g = (right + stride).min(hi);
        let v = probe(g, &mut cache, &mut steps, &mut probes);
        if v < best.1 {
            best = (g, v);
        }
        right = g;
        if v > best.1 || g == hi {
            break;
        }
        stride *= 2;
    }

    // --- Phase 2: golden-section on [a, b].
    let (mut a, mut b) = (left as f64, right as f64);
    while b - a > 2.0 {
        let x1 = (b - GOLDEN * (b - a)).round() as usize;
        let x2 = (a + GOLDEN * (b - a)).round() as usize;
        let (x1, x2) = if x1 >= x2 {
            ((a as usize + 1).min(hi), (b as usize).saturating_sub(1).max(lo))
        } else {
            (x1, x2)
        };
        if x1 >= x2 {
            break;
        }
        let f1 = probe(x1, &mut cache, &mut steps, &mut probes);
        let f2 = probe(x2, &mut cache, &mut steps, &mut probes);
        if f1 < best.1 {
            best = (x1, f1);
        }
        if f2 < best.1 {
            best = (x2, f2);
        }
        if f1 <= f2 {
            b = x2 as f64;
        } else {
            a = x1 as f64;
        }
    }

    // --- Phase 3: convex fit over the feasible probes near the incumbent.
    // Infeasible probes carry the +10 offset (see Objective::score) and
    // would wreck the parabola, so only fit scores in the feasible band.
    let fit_pts: Vec<(usize, f64)> = cache
        .iter()
        .filter(|(_, &v)| v < 9.0)
        .map(|(&g, &v)| (g, v))
        .collect();
    if fit_pts.len() >= 4 {
        let xs: Vec<f64> = fit_pts.iter().map(|(g, _)| *g as f64).collect();
        let ys: Vec<f64> = fit_pts.iter().map(|(_, v)| *v).collect();
        let vertex = parabola_argmin(&xs, &ys, lo as f64, hi as f64).round() as usize;
        let v = probe(vertex, &mut cache, &mut steps, &mut probes);
        if v < best.1 {
            best = (vertex, v);
        }
    }

    SearchResult {
        best_gear: best.0,
        steps,
        probes,
    }
}

/// Exhaustive argmin over a gear range — used by the oracle and for small
/// gear sets (memory clock has only 5 gears, where golden-section would
/// just be a sweep anyway).
pub fn sweep(lo: usize, hi: usize, eval: &mut dyn FnMut(usize) -> f64) -> SearchResult {
    let scores: Vec<f64> = (lo..=hi).map(|g| eval(g)).collect();
    let k = argmin(&scores).unwrap();
    SearchResult {
        best_gear: lo + k,
        steps: scores.len(),
        probes: scores.iter().enumerate().map(|(i, &v)| (lo + i, v)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_exact_minimum_of_convex_function() {
        let f = |g: usize| ((g as f64) - 73.0).powi(2) * 0.001 + 0.8;
        for start in [20usize, 50, 73, 90, 114] {
            let mut eval = |g: usize| f(g);
            let r = local_search(start, 16, 114, &mut eval);
            assert!(
                (r.best_gear as i64 - 73).abs() <= 1,
                "start {start} -> {}",
                r.best_gear
            );
        }
    }

    #[test]
    fn step_count_is_modest_near_prediction() {
        // Prediction within a few gears of the optimum -> few steps (the
        // paper's Table 3 reports 3-9 steps).
        let f = |g: usize| ((g as f64) - 94.0).powi(2) * 0.0005 + 0.7;
        let mut eval = |g: usize| f(g);
        let r = local_search(92, 16, 114, &mut eval);
        assert_eq!(r.best_gear, 94);
        assert!(r.steps <= 12, "steps {}", r.steps);
    }

    #[test]
    fn noisy_convex_function_lands_close() {
        // Deterministic pseudo-noise, ~1% of range.
        let f = |g: usize| {
            let x = g as f64;
            let noise = ((g * 2654435761) % 1000) as f64 / 1000.0 - 0.5;
            (x - 60.0).powi(2) * 0.0004 + 0.8 + 0.004 * noise
        };
        let mut eval = |g: usize| f(g);
        let r = local_search(50, 16, 114, &mut eval);
        assert!(
            (r.best_gear as i64 - 60).abs() <= 4,
            "got {}",
            r.best_gear
        );
    }

    #[test]
    fn respects_bounds() {
        // Minimum at the boundary.
        let mut eval = |g: usize| -(g as f64);
        let r = local_search(20, 16, 114, &mut eval);
        assert_eq!(r.best_gear, 114);
        let mut eval2 = |g: usize| g as f64;
        let r2 = local_search(100, 16, 114, &mut eval2);
        assert_eq!(r2.best_gear, 16);
    }

    #[test]
    fn memoizes_probes() {
        let mut calls = 0usize;
        let mut eval = |g: usize| {
            calls += 1;
            ((g as f64) - 40.0).powi(2)
        };
        let r = local_search(40, 16, 114, &mut eval);
        assert_eq!(r.steps, calls);
        // Each probe is unique.
        let mut gears: Vec<usize> = r.probes.iter().map(|(g, _)| *g).collect();
        gears.sort_unstable();
        gears.dedup();
        assert_eq!(gears.len(), r.probes.len());
    }

    #[test]
    fn sweep_finds_min() {
        let mut eval = |g: usize| (g as f64 - 2.0).abs();
        let r = sweep(0, 4, &mut eval);
        assert_eq!(r.best_gear, 2);
        assert_eq!(r.steps, 5);
    }

    #[test]
    fn infeasible_band_excluded_from_fit() {
        // Scores: feasible convex valley around 70, infeasible below 40.
        let f = |g: usize| {
            if g < 40 {
                10.0 + (40 - g) as f64 * 0.01
            } else {
                (g as f64 - 70.0).powi(2) * 0.001 + 0.6
            }
        };
        let mut eval = |g: usize| f(g);
        let r = local_search(45, 16, 114, &mut eval);
        assert!((r.best_gear as i64 - 70).abs() <= 2, "got {}", r.best_gear);
    }
}
