//! Online local search (§4.3.4): objective functions over (energy, time)
//! ratios and a golden-section search over clock gears with a convex-fit
//! finish to absorb measurement noise.

pub mod golden;
pub mod objective;

pub use golden::{local_search, SearchResult};
pub use objective::Objective;
