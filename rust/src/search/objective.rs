//! Energy-efficiency objective functions. All operate on ratios relative
//! to the NVIDIA default scheduling strategy (energy ratio, time ratio),
//! matching the paper's model outputs. Lower scores are better.

/// The optimization objective `f_obj` of Equation (1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Minimize energy subject to a slowdown cap (paper's evaluation
    /// setting: cap = 1.05, i.e. ≤5% execution-time increase).
    EnergyCapped { max_time_ratio: f64 },
    /// Minimize Energy × Delay (EDP).
    Edp,
    /// Minimize Energy × Delay² (ED²P — the paper's headline metric).
    Ed2p,
    /// Minimize energy unconditionally.
    Energy,
}

impl Objective {
    /// The paper's evaluation objective.
    pub fn paper_default() -> Objective {
        Objective::EnergyCapped {
            max_time_ratio: 1.05,
        }
    }

    /// Score a configuration; lower is better. Infeasible configurations
    /// (slowdown-cap violations) are pushed above any feasible score but
    /// remain ordered by time ratio so a search can climb back toward the
    /// feasible region.
    pub fn score(&self, energy_ratio: f64, time_ratio: f64) -> f64 {
        match *self {
            Objective::EnergyCapped { max_time_ratio } => {
                if time_ratio <= max_time_ratio {
                    energy_ratio
                } else {
                    // Feasible energy ratios live in ~(0, ~2); offset 10
                    // dominates them while preserving gradient direction.
                    10.0 + (time_ratio - max_time_ratio)
                }
            }
            Objective::Edp => energy_ratio * time_ratio,
            Objective::Ed2p => energy_ratio * time_ratio * time_ratio,
            Objective::Energy => energy_ratio,
        }
    }

    pub fn is_feasible(&self, time_ratio: f64) -> bool {
        match *self {
            Objective::EnergyCapped { max_time_ratio } => time_ratio <= max_time_ratio,
            _ => true,
        }
    }

    /// The objective's name as it appears on the CLI (`--objective`) and
    /// the control-plane wire (`"objective"` field).
    pub fn wire_name(&self) -> &'static str {
        match self {
            Objective::EnergyCapped { .. } => "capped",
            Objective::Edp => "edp",
            Objective::Ed2p => "ed2p",
            Objective::Energy => "energy",
        }
    }

    /// The cap parameter, present only for `capped` (serialized as
    /// `max_time_ratio` so decode(encode(o)) is bit-exact).
    pub fn max_time_ratio(&self) -> Option<f64> {
        match *self {
            Objective::EnergyCapped { max_time_ratio } => Some(max_time_ratio),
            _ => None,
        }
    }

    /// Inverse of [`wire_name`](Objective::wire_name)/
    /// [`max_time_ratio`](Objective::max_time_ratio): the single decode
    /// point shared by the CLI (`--objective`/`--slowdown-cap`) and the
    /// control-plane wire. `max_time_ratio` only applies to `capped`.
    pub fn from_wire(name: &str, max_time_ratio: f64) -> anyhow::Result<Objective> {
        Ok(match name {
            "edp" => Objective::Edp,
            "ed2p" => Objective::Ed2p,
            "energy" => Objective::Energy,
            "capped" => {
                if !max_time_ratio.is_finite() || max_time_ratio < 1.0 {
                    anyhow::bail!("max_time_ratio must be finite and >= 1, got {max_time_ratio}");
                }
                Objective::EnergyCapped { max_time_ratio }
            }
            other => anyhow::bail!("unknown objective '{other}' (capped|edp|ed2p|energy)"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capped_objective_orders_feasible_first() {
        let obj = Objective::paper_default();
        let good = obj.score(0.85, 1.03);
        let bad_energy = obj.score(0.99, 1.04);
        let infeasible = obj.score(0.5, 1.2);
        assert!(good < bad_energy);
        assert!(bad_energy < infeasible);
    }

    #[test]
    fn infeasible_scores_order_by_time() {
        let obj = Objective::paper_default();
        assert!(obj.score(0.5, 1.10) < obj.score(0.5, 1.50));
    }

    #[test]
    fn ed2p_weights_delay_quadratically() {
        let o = Objective::Ed2p;
        // 10% energy saving at 10% slowdown is a net ED2P loss.
        assert!(o.score(0.9, 1.1) > 1.0 * 0.9 * 1.0 + 0.18 - 0.1); // 0.9*1.21 = 1.089 > 1
        assert!(o.score(0.9, 1.1) > o.score(1.0, 1.0) - 1e-12 || true);
        assert!((o.score(1.0, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn feasibility() {
        let obj = Objective::paper_default();
        assert!(obj.is_feasible(1.05));
        assert!(!obj.is_feasible(1.0501));
        assert!(Objective::Ed2p.is_feasible(9.0));
    }

    #[test]
    fn wire_roundtrip_is_exact() {
        for o in [
            Objective::paper_default(),
            Objective::EnergyCapped { max_time_ratio: 1.125 },
            Objective::Edp,
            Objective::Ed2p,
            Objective::Energy,
        ] {
            let back =
                Objective::from_wire(o.wire_name(), o.max_time_ratio().unwrap_or(1.05)).unwrap();
            assert_eq!(back, o, "{} must roundtrip bit-exactly", o.wire_name());
        }
        assert!(Objective::from_wire("warp", 1.05).is_err());
        assert!(Objective::from_wire("capped", 0.9).is_err());
        assert!(Objective::from_wire("capped", f64::NAN).is_err());
    }
}
