//! Radix-2 iterative Cooley–Tukey FFT and the amplitude periodogram used
//! by period detection (§4.1.1).
//!
//! This is the *native* spectral path; the AOT-compiled Pallas kernel
//! (`artifacts/periodogram_1024.hlo.txt`, executed via `runtime`) is the
//! hot-path twin. `rust/tests/runtime_crosscheck.rs` pins the two to each
//! other.

use std::f64::consts::PI;

/// In-place iterative radix-2 FFT over interleaved complex (re, im) pairs.
/// `n` (pair count) must be a power of two.
pub fn fft_inplace(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }

    // Butterflies.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cr - im[i + k + len / 2] * ci,
                    re[i + k + len / 2] * ci + im[i + k + len / 2] * cr,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Next power of two ≥ n.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Reusable FFT scratch buffers — keeps the rolling-detection hot loop
/// allocation-free (see EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct FftScratch {
    re: Vec<f64>,
    im: Vec<f64>,
}

/// One-sided amplitude spectrum of a real signal sampled at interval `ts`.
///
/// The signal is mean-detrended and zero-padded to the next power of two.
/// Returns (frequencies Hz, amplitudes) for bins 1..n/2 (DC excluded —
/// period detection never wants the zero-frequency bin).
pub fn periodogram(samples: &[f64], ts: f64) -> (Vec<f64>, Vec<f64>) {
    let mut scratch = FftScratch::default();
    periodogram_with(samples, ts, &mut scratch)
}

/// `periodogram` with caller-provided scratch buffers.
pub fn periodogram_with(
    samples: &[f64],
    ts: f64,
    scratch: &mut FftScratch,
) -> (Vec<f64>, Vec<f64>) {
    let n = samples.len();
    if n < 4 {
        return (Vec::new(), Vec::new());
    }
    let m = next_pow2(n);
    let mean = samples.iter().sum::<f64>() / n as f64;

    scratch.re.clear();
    scratch.re.extend(samples.iter().map(|s| s - mean));
    scratch.re.resize(m, 0.0);
    scratch.im.clear();
    scratch.im.resize(m, 0.0);

    fft_inplace(&mut scratch.re, &mut scratch.im);

    // Frequency resolution is based on the padded length (standard DFT
    // bin spacing); the true signal duration governs what is resolvable.
    let df = 1.0 / (m as f64 * ts);
    let half = m / 2;
    let mut freqs = Vec::with_capacity(half - 1);
    let mut ampls = Vec::with_capacity(half - 1);
    for k in 1..half {
        freqs.push(k as f64 * df);
        ampls.push((scratch.re[k].powi(2) + scratch.im[k].powi(2)).sqrt());
    }
    (freqs, ampls)
}

/// The spectral front-end signature used by period detection so the
/// PJRT-compiled periodogram can be swapped in for the native FFT.
pub type SpectrumFn<'a> = &'a mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_of_impulse_is_flat() {
        let n = 16;
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[0] = 1.0;
        fft_inplace(&mut re, &mut im);
        for k in 0..n {
            let mag = (re[k] * re[k] + im[k] * im[k]).sqrt();
            assert!((mag - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn fft_matches_naive_dft() {
        let n = 64;
        let sig: Vec<f64> = (0..n)
            .map(|i| (i as f64 * 0.37).sin() + 0.5 * (i as f64 * 1.1).cos())
            .collect();
        let mut re = sig.clone();
        let mut im = vec![0.0; n];
        fft_inplace(&mut re, &mut im);
        for k in 0..n {
            let (mut dr, mut di) = (0.0, 0.0);
            for (t, x) in sig.iter().enumerate() {
                let ang = -2.0 * PI * k as f64 * t as f64 / n as f64;
                dr += x * ang.cos();
                di += x * ang.sin();
            }
            assert!((re[k] - dr).abs() < 1e-8, "k={k} re {} vs {}", re[k], dr);
            assert!((im[k] - di).abs() < 1e-8, "k={k} im {} vs {}", im[k], di);
        }
    }

    #[test]
    fn periodogram_finds_dominant_frequency() {
        let ts = 0.02;
        let f0 = 1.25; // Hz
        let sig: Vec<f64> = (0..1000)
            .map(|i| 3.0 + 2.0 * (2.0 * PI * f0 * i as f64 * ts).sin())
            .collect();
        let (freqs, ampls) = periodogram(&sig, ts);
        let k = crate::util::stats::argmax(&ampls).unwrap();
        assert!((freqs[k] - f0).abs() < 0.05, "peak at {}", freqs[k]);
    }

    #[test]
    fn periodogram_excludes_dc() {
        // Pure offset has no non-DC content.
        let sig = vec![5.0; 256];
        let (_, ampls) = periodogram(&sig, 0.01);
        assert!(ampls.iter().all(|a| a.abs() < 1e-9));
    }

    #[test]
    fn scratch_reuse_is_equivalent() {
        let sig: Vec<f64> = (0..300).map(|i| (i as f64 * 0.21).sin()).collect();
        let mut scratch = FftScratch::default();
        let a = periodogram(&sig, 0.05);
        let b = periodogram_with(&sig, 0.05, &mut scratch);
        let c = periodogram_with(&sig, 0.05, &mut scratch);
        assert_eq!(a.1, b.1);
        assert_eq!(b.1, c.1);
    }
}
