//! One-dimensional Gaussian-mixture clustering (EM), the grouping step of
//! the feature-sequence-similarity algorithm (Algorithm 2, line 8).
//!
//! The paper clusters each sub-curve's samples into `NumG` amplitude
//! groups so that group-mean comparisons cancel high-frequency
//! interference. Initialization is deterministic (quantile-spread means)
//! so the whole detection pipeline stays reproducible.

/// Result of clustering: per-sample hard assignment plus the model.
#[derive(Debug, Clone)]
pub struct GmmResult {
    pub assignments: Vec<usize>,
    pub means: Vec<f64>,
    pub vars: Vec<f64>,
    pub weights: Vec<f64>,
    pub iterations: usize,
}

/// Fit a 1-D GMM with `k` components via EM with deterministic quantile
/// initialization. Returns hard assignments by maximum responsibility.
pub fn cluster_1d(xs: &[f64], k: usize, max_iter: usize) -> GmmResult {
    assert!(k >= 1);
    let n = xs.len();
    if n == 0 {
        return GmmResult {
            assignments: Vec::new(),
            means: vec![0.0; k],
            vars: vec![1.0; k],
            weights: vec![1.0 / k as f64; k],
            iterations: 0,
        };
    }

    // Deterministic init: means at spread quantiles, shared variance.
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut means: Vec<f64> = (0..k)
        .map(|j| {
            let q = (j as f64 + 0.5) / k as f64;
            sorted[((q * (n - 1) as f64).round() as usize).min(n - 1)]
        })
        .collect();
    let global_mean = xs.iter().sum::<f64>() / n as f64;
    let global_var = (xs.iter().map(|x| (x - global_mean).powi(2)).sum::<f64>() / n as f64)
        .max(1e-12);
    let mut vars = vec![global_var; k];
    let mut weights = vec![1.0 / k as f64; k];

    let mut resp = vec![0.0f64; n * k];
    let mut iterations = 0;
    let mut prev_ll = f64::NEG_INFINITY;

    for it in 0..max_iter {
        iterations = it + 1;

        // E-step: responsibilities (log-space for stability).
        let mut ll = 0.0;
        for i in 0..n {
            let mut logp = [0.0f64; 16];
            assert!(k <= 16, "k too large");
            let mut maxlp = f64::NEG_INFINITY;
            for j in 0..k {
                let v = vars[j].max(1e-12);
                let d = xs[i] - means[j];
                let lp = weights[j].max(1e-300).ln()
                    - 0.5 * (2.0 * std::f64::consts::PI * v).ln()
                    - 0.5 * d * d / v;
                logp[j] = lp;
                maxlp = maxlp.max(lp);
            }
            let mut z = 0.0;
            for j in 0..k {
                z += (logp[j] - maxlp).exp();
            }
            ll += maxlp + z.ln();
            for j in 0..k {
                resp[i * k + j] = (logp[j] - maxlp).exp() / z;
            }
        }

        // M-step.
        for j in 0..k {
            let nj: f64 = (0..n).map(|i| resp[i * k + j]).sum();
            let nj_safe = nj.max(1e-9);
            let mu = (0..n).map(|i| resp[i * k + j] * xs[i]).sum::<f64>() / nj_safe;
            let var = (0..n)
                .map(|i| resp[i * k + j] * (xs[i] - mu).powi(2))
                .sum::<f64>()
                / nj_safe;
            means[j] = mu;
            vars[j] = var.max(global_var * 1e-6).max(1e-12);
            weights[j] = nj / n as f64;
        }

        if (ll - prev_ll).abs() < 1e-8 * (1.0 + ll.abs()) {
            break;
        }
        prev_ll = ll;
    }

    let assignments = (0..n)
        .map(|i| {
            let row = &resp[i * k..(i + 1) * k];
            crate::util::stats::argmax(row).unwrap_or(0)
        })
        .collect();

    GmmResult {
        assignments,
        means,
        vars,
        weights,
        iterations,
    }
}

/// Group sample *indices* by cluster (Algorithm 2's `GaGrp` sets).
/// Empty groups are dropped.
pub fn group_indices(assignments: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut groups = vec![Vec::new(); k];
    for (i, &a) in assignments.iter().enumerate() {
        groups[a].push(i);
    }
    groups.retain(|g| !g.is_empty());
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_clear_clusters() {
        let mut xs = Vec::new();
        for i in 0..50 {
            xs.push(1.0 + 0.01 * (i % 7) as f64);
        }
        for i in 0..50 {
            xs.push(10.0 + 0.01 * (i % 5) as f64);
        }
        let r = cluster_1d(&xs, 2, 100);
        // All low samples in one group, all high in the other.
        let g0 = r.assignments[0];
        assert!(r.assignments[..50].iter().all(|&a| a == g0));
        assert!(r.assignments[50..].iter().all(|&a| a != g0));
    }

    #[test]
    fn deterministic() {
        let xs: Vec<f64> = (0..200).map(|i| ((i * 37) % 101) as f64 / 10.0).collect();
        let a = cluster_1d(&xs, 4, 60);
        let b = cluster_1d(&xs, 4, 60);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn handles_constant_signal() {
        let xs = vec![2.5; 64];
        let r = cluster_1d(&xs, 3, 50);
        assert_eq!(r.assignments.len(), 64);
        // No NaNs anywhere.
        assert!(r.means.iter().all(|m| m.is_finite()));
        assert!(r.vars.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn group_indices_partition() {
        let assignments = vec![0, 1, 0, 2, 1, 0];
        let g = group_indices(&assignments, 3);
        let total: usize = g.iter().map(|v| v.len()).sum();
        assert_eq!(total, 6);
        assert_eq!(g[0], vec![0, 2, 5]);
    }

    #[test]
    fn empty_input() {
        let r = cluster_1d(&[], 3, 10);
        assert!(r.assignments.is_empty());
    }
}
