//! Robust period detection (paper §4.1): FFT periodogram, peak
//! extraction, 1-D GMM clustering, feature-sequence similarity
//! (Algorithm 2), period calculation (Algorithm 1) and the online
//! rolling framework (Algorithm 3).

pub mod fft;
pub mod gmm;
pub mod online;
pub mod peaks;
pub mod period;
pub mod similarity;

pub use fft::{periodogram, FftScratch};
pub use online::{composite_feature, online_detect, online_detect_with, OnlineDetection};
pub use peaks::{candidate_periods, find_peaks, Peak};
pub use period::{calc_period, calc_period_fft_argmax, calc_period_with, PeriodCfg, PeriodEstimate};
pub use similarity::{sequence_similarity_error, SimilarityCfg};
