//! Robust period detection (paper §4.1): FFT periodogram, peak
//! extraction, 1-D GMM clustering, feature-sequence similarity
//! (Algorithm 2), period calculation (Algorithm 1) and the online
//! rolling framework (Algorithm 3) — both as the stateless batch
//! wrapper [`online_detect_with`] and as the incremental
//! [`StreamingDetector`] long-lived consumers hold (DESIGN.md §2).

pub mod fft;
pub mod gmm;
pub mod online;
pub mod peaks;
pub mod period;
pub mod similarity;
pub mod streaming;

pub use fft::{periodogram, FftScratch};
pub use online::{
    composite_feature, composite_feature_into, online_detect, online_detect_with,
    rolling_start_index, OnlineDetection,
};
pub use peaks::{candidate_periods, find_peaks, Peak};
pub use period::{
    calc_period, calc_period_fft_argmax, calc_period_scratch, calc_period_with, PeriodCfg,
    PeriodEstimate, PeriodScratch,
};
pub use similarity::{sequence_similarity_error, SimilarityCfg};
pub use streaming::{detections_bit_equal, StreamCfg, StreamVerdict, StreamingDetector};
