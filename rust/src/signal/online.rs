//! Online robust period detection (Algorithm 3): rolling re-estimation of
//! the period over a growing sample window until the estimate stabilizes.
//!
//! The caller keeps sampling `Feature_dect` (the composite power/util
//! channel) and invokes [`online_detect`] after each requested extension;
//! a returned `next_sampling_s == None` means the period is stable and
//! feature measurement (§4.2) can proceed.

use crate::signal::period::{calc_period_scratch, PeriodCfg, PeriodEstimate, PeriodScratch};
use crate::util::stats::{argmin, mean};

/// Outcome of one Algorithm-3 evaluation.
#[derive(Debug, Clone, Copy)]
pub struct OnlineDetection {
    pub estimate: PeriodEstimate,
    /// `Some(d)`: sample for `d` more seconds and call again.
    /// `None`: the period is stable — proceed to feature measurement.
    pub next_sampling_s: Option<f64>,
}

/// First sample index at or after the advancing start line `t_start`.
///
/// The previous derivation (`floor + 1` for any positive `t_start`) is
/// identical whenever the line falls strictly between sample ticks, but
/// when it landed *exactly on* a tick it skipped that perfectly valid
/// sample — reaching one step further into the stale past than the
/// window boundary allows. A single `ceil` includes the on-line sample
/// and never admits one from before the line.
pub fn rolling_start_index(t_start: f64, ts: f64) -> usize {
    (t_start / ts).ceil() as usize
}

/// The Algorithm-3 evaluation loop over a pluggable per-window
/// estimator: `eval_window(istart)` must return the Algorithm-1 estimate
/// over `smp[istart..]` of the `n`-sample window. Shared verbatim by the
/// batch wrapper [`online_detect_with`] and the caching
/// [`crate::signal::StreamingDetector`], so the two paths cannot drift —
/// the streaming engine's memoization only ever short-circuits calls the
/// batch path would answer identically.
pub(crate) fn online_detect_loop(
    n: usize,
    ts: f64,
    cfg: &PeriodCfg,
    eval_window: &mut dyn FnMut(usize) -> Option<PeriodEstimate>,
) -> Option<OnlineDetection> {
    // Line 1: initial estimate over the whole window.
    let init = eval_window(0)?;
    let smp_dur = (n - 1) as f64 * ts;

    // Lines 2–6: window shorter than c_measure periods — ask for more.
    if smp_dur < cfg.c_measure * init.t_iter {
        return Some(OnlineDetection {
            estimate: init,
            next_sampling_s: Some(cfg.c_measure * init.t_iter - smp_dur),
        });
    }

    // Lines 7–14: rolling re-estimation with an advancing start line;
    // early samples may predate a clock change and are progressively
    // excluded.
    let mut t_start = (smp_dur - (2.0 + cfg.c_eval * cfg.step) * init.t_iter).max(0.0);
    let mut periods = Vec::new();
    let mut errs = Vec::new();
    // Sub-3-period windows are kept out of the stability vote: their
    // refinement resolution is too coarse and their scatter would keep a
    // perfectly stable workload "unstable" forever.
    while (smp_dur - t_start) / init.t_iter >= cfg.c_measure.max(3.0) {
        let istart = rolling_start_index(t_start, ts);
        if istart + 16 >= n {
            break;
        }
        if let Some(est) = eval_window(istart) {
            periods.push(est.t_iter);
            errs.push(est.err);
        }
        t_start += cfg.step * init.t_iter;
    }
    if periods.len() < 2 {
        // Fewer than two rolling estimates — a single agreeing window is
        // no evidence of stability; extend and re-evaluate.
        return Some(OnlineDetection {
            estimate: init,
            next_sampling_s: Some(init.t_iter.max(smp_dur * 0.5)),
        });
    }

    // Line 15: best = minimum similarity error.
    let k = argmin(&errs).unwrap();
    let best = PeriodEstimate {
        t_iter: periods[k],
        err: errs[k],
    };

    // Lines 16–21: stability check on the rolling spread.
    let pmax = periods.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let pmin = periods.iter().cloned().fold(f64::INFINITY, f64::min);
    let diff = (pmax - pmin) / mean(&periods);
    let next = if diff < cfg.diff_threshold {
        None
    } else {
        // Extend to the next whole multiple of the largest rolling period.
        let d = (smp_dur / pmax).ceil() * pmax - smp_dur;
        Some(if d > 1e-9 { d } else { pmax })
    };

    Some(OnlineDetection {
        estimate: best,
        next_sampling_s: next,
    })
}

/// Algorithm 3 with a pluggable spectral front-end — the batch
/// compatibility wrapper over [`online_detect_loop`]: one fresh,
/// stateless evaluation of the full window. Long-lived consumers should
/// hold a [`crate::signal::StreamingDetector`] instead and push samples
/// as they arrive.
pub fn online_detect_with(
    smp: &[f64],
    ts: f64,
    cfg: &PeriodCfg,
    spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
) -> Option<OnlineDetection> {
    let mut scratch = PeriodScratch::default();
    let mut eval = |istart: usize| {
        calc_period_scratch(&smp[istart..], ts, cfg, &mut *spectrum, &mut scratch)
    };
    online_detect_loop(smp.len(), ts, cfg, &mut eval)
}

/// Algorithm 3 with the native FFT front-end.
pub fn online_detect(smp: &[f64], ts: f64, cfg: &PeriodCfg) -> Option<OnlineDetection> {
    let mut scratch = crate::signal::fft::FftScratch::default();
    let mut spectrum = move |s: &[f64], ts: f64| -> (Vec<f64>, Vec<f64>) {
        crate::signal::fft::periodogram_with(s, ts, &mut scratch)
    };
    online_detect_with(smp, ts, cfg, &mut spectrum)
}

/// Build the composite `Feature_dect` channel from NVML samples: the
/// paper combines power, SM utilization and memory utilization because
/// the blend shows the most pronounced periodicity (§4.2). Channels are
/// variance-normalized before blending so no single unit dominates.
pub fn composite_feature(power: &[f64], util_sm: &[f64], util_mem: &[f64]) -> Vec<f64> {
    let mut out = Vec::new();
    composite_feature_into(&mut out, power, util_sm, util_mem);
    out
}

/// [`composite_feature`] into a caller-provided buffer — the streaming
/// detector's allocation-free path. There is exactly one copy of the
/// blend arithmetic, so the streaming/batch bit-identity contract cannot
/// drift when the blend is tuned.
pub fn composite_feature_into(
    out: &mut Vec<f64>,
    power: &[f64],
    util_sm: &[f64],
    util_mem: &[f64],
) {
    assert_eq!(power.len(), util_sm.len());
    assert_eq!(power.len(), util_mem.len());
    let norm = |xs: &[f64]| -> (f64, f64) {
        let m = mean(xs);
        let s = crate::util::stats::std(xs).max(1e-9);
        (m, s)
    };
    let (mp, sp) = norm(power);
    let (ms, ss) = norm(util_sm);
    let (mm, sm) = norm(util_mem);
    out.clear();
    out.reserve(power.len());
    for i in 0..power.len() {
        out.push(
            (power[i] - mp) / sp + 0.5 * (util_sm[i] - ms) / ss + 0.5 * (util_mem[i] - mm) / sm,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Phase-structured waveform resembling a real training-iteration
    /// trace (data-load dip / fwd plateau / bwd plateau / optimizer dip).
    /// Smooth sines have too flat a similarity landscape for the short
    /// rolling windows of Algorithm 3 — and real traces are not sines.
    fn signal(period_s: f64, ts: f64, dur_s: f64) -> Vec<f64> {
        let n = (dur_s / ts) as usize;
        (0..n)
            .map(|i| {
                let t = i as f64 * ts;
                let ph = (t / period_s).fract();
                let base = if ph < 0.10 {
                    0.4
                } else if ph < 0.50 {
                    0.95
                } else if ph < 0.85 {
                    1.05
                } else {
                    0.6
                };
                // Incoherent ripple (hash noise): a pure sine here would be
                // a real periodic component the detector could honestly lock.
                let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15);
                let noise = ((h >> 11) as f64 / (1u64 << 53) as f64) - 0.5;
                base + 0.04 * noise
            })
            .collect()
    }

    #[test]
    fn stable_signal_converges() {
        let ts = 0.025;
        let p = 1.7;
        let smp = signal(p, ts, 18.0);
        let det = online_detect(&smp, ts, &PeriodCfg::default()).unwrap();
        assert!(det.next_sampling_s.is_none(), "should be stable");
        let rel = (det.estimate.t_iter - p).abs() / p;
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn short_window_never_confidently_wrong() {
        // Only 1.5 true periods in view: the detector cannot possibly see
        // the 3.0 s period (max verifiable period is half the window). The
        // contract is weaker but still essential: whatever it reports must
        // either ask for more samples or be a self-consistent sub-period —
        // never a confident estimate close to, but wrong about, the truth.
        let ts = 0.025;
        let p = 3.0;
        let smp = signal(p, ts, 4.5);
        if let Some(d) = online_detect(&smp, ts, &PeriodCfg::default()) {
            if d.next_sampling_s.is_none() && d.estimate.err < 0.35 {
                // Declared stable AND below the controller's aperiodic
                // acceptance threshold: the claim must then be sound.
                // (High-self-err stables are routed to the aperiodic path
                // downstream, which is safe.)
                assert!(d.estimate.t_iter <= 2.3, "cannot exceed window/2");
                assert!(
                    d.estimate.err < 0.2,
                    "confident but bad: {:?}",
                    d.estimate
                );
            }
        }
    }

    #[test]
    fn pure_noise_is_never_a_confident_period() {
        let ts = 0.025;
        let n = 720;
        let smp: Vec<f64> = (0..n)
            .map(|i| {
                let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15) ^ 0xabcdef;
                let h = h.wrapping_mul(0xff51afd7ed558ccd);
                1.0 + 0.3 * (((h >> 11) as f64 / (1u64 << 53) as f64) - 0.5)
            })
            .collect();
        if let Some(d) = online_detect(&smp, ts, &PeriodCfg::default()) {
            // Incoherent noise: any detection must carry a high self-error
            // (the controller's aperiodic threshold catches these).
            assert!(
                d.estimate.err > 0.2 || d.next_sampling_s.is_some(),
                "noise must not produce a confident stable period: {:?}",
                d.estimate
            );
        }
    }

    #[test]
    fn recent_period_change_is_flagged_unstable() {
        let ts = 0.025;
        // A clock change *near the end* of the window: the rolling
        // sub-windows straddle both periods → unstable spread.
        let mut smp = signal(1.2, ts, 12.0);
        smp.extend(signal(2.0, ts, 2.5));
        let det = online_detect(&smp, ts, &PeriodCfg::default());
        if let Some(d) = det {
            assert!(
                d.next_sampling_s.is_some(),
                "mixed-period window must not be declared stable (got {:?})",
                d.estimate
            );
        }
    }

    #[test]
    fn old_period_change_is_forgotten() {
        let ts = 0.025;
        // Change long before the end: Algorithm 3 deliberately excludes
        // outdated samples, so the recent stable regime should win.
        let mut smp = signal(1.2, ts, 4.0);
        smp.extend(signal(2.0, ts, 20.0));
        let det = online_detect(&smp, ts, &PeriodCfg::default()).unwrap();
        assert!(det.next_sampling_s.is_none(), "recent window is stable");
        let rel = (det.estimate.t_iter - 2.0).abs() / 2.0;
        assert!(rel < 0.06, "should report the NEW period, rel {rel}");
    }

    #[test]
    fn start_index_on_exact_tick_keeps_the_boundary_sample() {
        // t_start exactly on a sample tick: 0.5 / 0.25 == 2.0 exactly in
        // binary floating point. The old `floor + 1` derivation skipped
        // sample 2 even though it sits ON the start line; `ceil` keeps it.
        assert_eq!(rolling_start_index(0.5, 0.25), 2);
        // Strictly between ticks: identical to the old derivation.
        assert_eq!(rolling_start_index(0.51, 0.25), 3);
        assert_eq!(rolling_start_index(0.74, 0.25), 3);
        // At the origin nothing is excluded.
        assert_eq!(rolling_start_index(0.0, 0.25), 0);
    }

    #[test]
    fn nan_samples_never_panic_detection() {
        // A single poisoned NVML reading must degrade ("no detection" or
        // a high-error estimate), never panic the detection thread.
        let ts = 0.025;
        let mut smp = signal(1.5, ts, 12.0);
        smp[120] = f64::NAN;
        let _ = online_detect(&smp, ts, &PeriodCfg::default());
        let all_nan = vec![f64::NAN; 400];
        assert!(online_detect(&all_nan, ts, &PeriodCfg::default()).is_none());
    }

    #[test]
    fn composite_feature_blends_channels() {
        let n = 100;
        let power: Vec<f64> = (0..n).map(|i| 200.0 + (i as f64 * 0.3).sin() * 30.0).collect();
        let usm: Vec<f64> = (0..n).map(|i| 0.8 + (i as f64 * 0.3).sin() * 0.1).collect();
        let umem: Vec<f64> = (0..n).map(|i| 0.5 + (i as f64 * 0.3).cos() * 0.1).collect();
        let c = composite_feature(&power, &usm, &umem);
        assert_eq!(c.len(), n);
        // Normalized blend: mean ~0.
        assert!(mean(&c).abs() < 1e-6);
    }
}
