//! Amplitude-peak extraction from a spectrum (Algorithm 1, lines 3–5):
//! local maxima, filtered to those within `c_peak` of the global maximum,
//! become candidate periods.

/// A detected spectral peak.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Peak {
    pub index: usize,
    pub freq_hz: f64,
    pub period_s: f64,
    pub amplitude: f64,
}

/// Find strict local maxima (plateau-tolerant: the first sample of a
/// plateau wins) in the amplitude spectrum.
pub fn find_peaks(freqs: &[f64], ampls: &[f64]) -> Vec<Peak> {
    let n = ampls.len();
    let mut peaks = Vec::new();
    for i in 0..n {
        let left = if i == 0 { f64::NEG_INFINITY } else { ampls[i - 1] };
        let right = if i + 1 == n { f64::NEG_INFINITY } else { ampls[i + 1] };
        if ampls[i] > left && ampls[i] >= right && ampls[i] > 0.0 {
            peaks.push(Peak {
                index: i,
                freq_hz: freqs[i],
                period_s: 1.0 / freqs[i],
                amplitude: ampls[i],
            });
        }
    }
    peaks
}

/// Candidate periods: peaks with amplitude ≥ `c_peak · max`, sorted by
/// amplitude descending and capped at `max_candidates`. Periods longer
/// than `max_period` (unverifiable: fewer than two sub-curves fit in the
/// sampling window) are dropped.
pub fn candidate_periods(
    peaks: &[Peak],
    c_peak: f64,
    max_candidates: usize,
    max_period: f64,
) -> Vec<Peak> {
    let max_ampl = peaks
        .iter()
        .map(|p| p.amplitude)
        .fold(f64::NEG_INFINITY, f64::max);
    if !max_ampl.is_finite() {
        return Vec::new();
    }
    let mut cands: Vec<Peak> = peaks
        .iter()
        .copied()
        .filter(|p| p.amplitude >= c_peak * max_ampl && p.period_s <= max_period)
        .collect();
    cands.sort_by(|a, b| b.amplitude.total_cmp(&a.amplitude));
    cands.truncate(max_candidates);
    cands
}

/// Prominence-scored candidates: each peak's amplitude is normalized by
/// the local spectral background (median over a neighborhood of bins).
/// A jitter-broadened micro-oscillation raises its own background, so it
/// scores low; a coherent iteration period is a sharp line over a quiet
/// background and scores high. This is what keeps GPOEO's candidate set
/// useful on TSP-style traces where the raw arg-max (ODPP) locks onto
/// the micro period (§2.2.3).
pub fn candidate_periods_prominence(
    freqs: &[f64],
    ampls: &[f64],
    c_peak: f64,
    max_candidates: usize,
    max_period: f64,
) -> Vec<Peak> {
    let n = ampls.len();
    if n == 0 {
        return Vec::new();
    }
    let peaks = find_peaks(freqs, ampls);
    let mut scored: Vec<(f64, Peak)> = peaks
        .iter()
        .filter(|p| p.period_s <= max_period)
        .map(|p| {
            let k = p.index;
            let w = (k / 3).clamp(4, 48);
            let lo = k.saturating_sub(w);
            let hi = (k + w + 1).min(n);
            let mut window: Vec<f64> = ampls[lo..hi].to_vec();
            window.sort_by(|a, b| a.total_cmp(b));
            let med = window[window.len() / 2].max(1e-12);
            (p.amplitude / med, *p)
        })
        .collect();
    let max_score = scored.iter().map(|(s, _)| *s).fold(f64::NEG_INFINITY, f64::max);
    if !max_score.is_finite() {
        return Vec::new();
    }
    // Union of the two criteria: absolute amplitude (the paper's c_peak
    // cut) OR local prominence. Sharp-but-spurious lines admitted by the
    // prominence side are cheap: the similarity stage rejects anything
    // whose sub-curves don't actually repeat, and sub-Nyquist periods are
    // unevaluable by construction.
    let max_ampl = scored
        .iter()
        .map(|(_, p)| p.amplitude)
        .fold(f64::NEG_INFINITY, f64::max);
    scored.retain(|(s, p)| *s >= c_peak * max_score || p.amplitude >= c_peak * max_ampl);
    // Rank by amplitude so the cap keeps the spectrally dominant set, with
    // prominence deciding admission.
    scored.sort_by(|a, b| b.1.amplitude.total_cmp(&a.1.amplitude));
    scored.truncate(max_candidates);
    scored.into_iter().map(|(_, p)| p).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_interior_peaks() {
        let freqs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let ampls = vec![0.1, 1.0, 0.1, 0.5, 0.2, 0.9, 0.3, 0.05, 0.2];
        let peaks = find_peaks(&freqs, &ampls);
        let idx: Vec<usize> = peaks.iter().map(|p| p.index).collect();
        assert_eq!(idx, vec![1, 3, 5, 8]);
    }

    #[test]
    fn candidates_filter_and_sort() {
        let freqs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let ampls = vec![0.1, 1.0, 0.1, 0.5, 0.2, 0.9, 0.3, 0.05, 0.2];
        let peaks = find_peaks(&freqs, &ampls);
        let c = candidate_periods(&peaks, 0.6, 8, 10.0);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].index, 1); // strongest first
        assert_eq!(c[1].index, 5);
    }

    #[test]
    fn max_period_cap_applies() {
        let freqs = vec![0.01, 0.5, 1.0]; // periods 100s, 2s, 1s
        let ampls = vec![1.0, 0.2, 0.9]; // peaks at index 0 and 2
        let peaks = find_peaks(&freqs, &ampls);
        assert_eq!(peaks.len(), 2);
        let c = candidate_periods(&peaks, 0.5, 8, 10.0);
        assert_eq!(c.len(), 1, "100s period exceeds the cap");
        assert_eq!(c[0].period_s, 1.0);
    }

    #[test]
    fn prominence_prefers_sharp_line_over_broad_bump() {
        // Broad bump: large amplitude spread over many bins around k=40.
        // Sharp line: single-bin spike at k=150 with lower absolute height.
        let n = 256;
        let freqs: Vec<f64> = (0..n).map(|i| (i + 1) as f64 * 0.05).collect();
        let mut ampls = vec![1.0; n];
        for k in 20..60 {
            let d = (k as f64 - 40.0) / 10.0;
            ampls[k] += 30.0 * (-d * d).exp();
        }
        ampls[150] = 12.0;
        let c = candidate_periods_prominence(&freqs, &ampls, 0.6, 4, 1e9);
        assert!(!c.is_empty());
        assert!(
            c.iter().any(|p| p.index == 150),
            "sharp line must be admitted despite the broad bump's height"
        );
    }

    #[test]
    fn empty_input_no_panic() {
        assert!(find_peaks(&[], &[]).is_empty());
        assert!(candidate_periods(&[], 0.6, 8, 10.0).is_empty());
        assert!(candidate_periods_prominence(&[], &[], 0.6, 8, 10.0).is_empty());
    }
}
