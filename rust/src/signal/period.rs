//! Period calculation (Algorithm 1): FFT candidates → feature-sequence
//! similarity scoring → local refinement around the best candidate.

use crate::signal::fft::{periodogram_with, FftScratch};
use crate::signal::peaks::candidate_periods_prominence;
use crate::signal::similarity::{sequence_similarity_error, SimilarityCfg, UNEVALUABLE};
use crate::util::stats::argmin;

/// Configuration of the period-detection stack (Algorithms 1–3).
#[derive(Debug, Clone)]
pub struct PeriodCfg {
    /// Peak-amplitude coefficient `c_peak` (paper: 0.6–0.7).
    pub c_peak: f64,
    /// Maximum number of FFT candidates evaluated.
    pub max_candidates: usize,
    /// Local-refinement grid points around the best candidate.
    pub refine_steps: usize,
    /// Algorithm 2 knobs.
    pub similarity: SimilarityCfg,
    /// Algorithm 3: minimum window in periods before rolling (`c_measure`).
    pub c_measure: f64,
    /// Algorithm 3: rolling-start step in periods (`step`).
    pub step: f64,
    /// Algorithm 3: rolling-window factor (`c_eval`).
    pub c_eval: f64,
    /// Algorithm 3: stability threshold on rolling-period spread.
    pub diff_threshold: f64,
}

impl Default for PeriodCfg {
    fn default() -> Self {
        PeriodCfg {
            c_peak: 0.65,
            max_candidates: 8,
            refine_steps: 12,
            similarity: SimilarityCfg::default(),
            c_measure: 2.0,
            step: 0.5,
            c_eval: 6.5,
            diff_threshold: 0.08,
        }
    }
}

/// Outcome of one period calculation.
#[derive(Debug, Clone, Copy)]
pub struct PeriodEstimate {
    pub t_iter: f64,
    pub err: f64,
}

/// Reusable buffers for [`calc_period_scratch`]: the moving-average
/// filtered copy of the window is the one O(n) allocation Algorithm 1
/// used to make per call, which the rolling hot loop (Algorithm 3 runs
/// Algorithm 1 once per sub-window per evaluation) pays dozens of times
/// per detector tick. Owning the buffer caller-side makes the hot path
/// allocation-free without changing a single arithmetic operation.
#[derive(Debug, Default)]
pub struct PeriodScratch {
    smooth: Vec<f64>,
}

/// Algorithm 1 with the native FFT front-end.
pub fn calc_period(smp: &[f64], ts: f64, cfg: &PeriodCfg) -> Option<PeriodEstimate> {
    let mut scratch = FftScratch::default();
    let mut spectrum =
        move |s: &[f64], ts: f64| -> (Vec<f64>, Vec<f64>) { periodogram_with(s, ts, &mut scratch) };
    calc_period_with(smp, ts, cfg, &mut spectrum)
}

/// Algorithm 1 with a pluggable spectral front-end (the PJRT-compiled
/// Pallas periodogram is injected here by the runtime-backed controller).
pub fn calc_period_with(
    smp: &[f64],
    ts: f64,
    cfg: &PeriodCfg,
    spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
) -> Option<PeriodEstimate> {
    let mut scratch = PeriodScratch::default();
    calc_period_scratch(smp, ts, cfg, spectrum, &mut scratch)
}

/// [`calc_period_with`] with caller-provided scratch buffers — the
/// allocation-free variant the streaming detector drives. Results are
/// bit-identical to the allocating path.
pub fn calc_period_scratch(
    smp: &[f64],
    ts: f64,
    cfg: &PeriodCfg,
    spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
    scratch: &mut PeriodScratch,
) -> Option<PeriodEstimate> {
    if smp.len() < 16 {
        return None;
    }
    let duration = (smp.len() - 1) as f64 * ts;

    // Lines 1–5: FFT → peaks → candidate periods. A candidate must leave
    // at least two full sub-curves in the window to be scoreable.
    let (freqs, ampls) = spectrum(smp, ts);
    let cands =
        candidate_periods_prominence(&freqs, &ampls, cfg.c_peak, cfg.max_candidates, duration / 2.0);
    if cands.is_empty() {
        return None;
    }

    // Similarity evaluation runs on a moving-average-filtered copy: the
    // ~150 ms MA kills jittered micro-oscillations (which shuffle the
    // GMM's amplitude groups chaotically) while leaving the much longer
    // iteration phase structure intact. The FFT above runs on the RAW
    // signal — candidate extraction must see the same spectrum ODPP does.
    let w = ((0.15 / ts).round() as usize).clamp(1, smp.len() / 16);
    let smp: &[f64] = if w <= 1 {
        smp
    } else {
        scratch.smooth.clear();
        scratch.smooth.reserve(smp.len());
        let mut acc = 0.0;
        for (i, &x) in smp.iter().enumerate() {
            acc += x;
            if i >= w {
                acc -= smp[i - w];
            }
            scratch.smooth.push(acc / w.min(i + 1) as f64);
        }
        &scratch.smooth
    };

    // Harmonic completion: when the waveform's 2nd/3rd harmonic dominates
    // the spectrum (near-symmetric fwd/bwd iterations), the fundamental
    // may fall below the c_peak cut. Add 2× and 3× of the strongest
    // candidates so the similarity check can still recover the true
    // period; ties resolve toward the shortest period below.
    let mut periods: Vec<f64> = cands.iter().map(|c| c.period_s).collect();
    for c in cands.iter().take(2) {
        for mult in [2.0, 3.0] {
            let t = c.period_s * mult;
            if t <= duration / 2.0 {
                periods.push(t);
            }
        }
    }
    periods.sort_by(|a, b| a.total_cmp(b));
    periods.dedup_by(|a, b| (*a - *b).abs() / *b < 0.05);

    // Lines 6–10: score each candidate with Algorithm 2.
    let errs: Vec<f64> = periods
        .iter()
        .map(|&t| sequence_similarity_error(t, smp, ts, &cfg.similarity))
        .collect();
    let best = argmin(&errs)?;
    if errs[best] == UNEVALUABLE {
        return None;
    }
    // Lines 11–18: local refinement. The FFT bin quantization bounds the
    // candidate's relative error by ±1/(N_T ∓ 1) where N_T is the number
    // of periods in the window; search an arithmetic grid over that band.
    let refine = |t_cand: f64, anchor_e: f64| -> (f64, f64) {
        let n_t = (duration / t_cand).max(2.0);
        // Clamp the band to ±10%: for very short windows the paper's
        // formula opens up to ±50% and the refinement wanders off the
        // candidate on a flat similarity landscape.
        let t_low = t_cand * (1.0 - (1.0 / (n_t + 1.0)).min(0.10));
        let t_up = t_cand * (1.0 + (1.0 / (n_t - 1.0)).min(0.10));
        let mut best_t = t_cand;
        let mut best_e = anchor_e;
        for q in 0..=cfg.refine_steps {
            let t = t_low + (t_up - t_low) * q as f64 / cfg.refine_steps as f64;
            let e = sequence_similarity_error(t, smp, ts, &cfg.similarity);
            // Move off the FFT-bin candidate only for a *material* gain:
            // on a noise-flat landscape, chasing 1-2% score wobbles walks
            // the estimate to the band edge (≫ the bin-quantization error
            // the refinement is meant to remove).
            if e < best_e && e < anchor_e * 0.95 {
                best_e = e;
                best_t = t;
            }
        }
        (best_t, best_e)
    };

    let (mut best_t, mut best_e) = refine(periods[best], errs[best]);

    // Divisor preference: a k-fold multiple of the true period often
    // scores *better* than the fundamental before refinement (bin
    // quantization misaligns k× fewer window boundaries), so compare
    // against the REFINED divisors and walk down whenever one explains
    // the signal nearly as well. Genuine harmonics (T/2 of a symmetric
    // waveform) fail the closeness test: their error is categorically
    // worse, not marginally worse.
    'divisor: loop {
        let tol = (best_e * 1.3).max(best_e + 0.05);
        for k in [2.0, 3.0, 4.0] {
            let t_div = best_t / k;
            if t_div < 8.0 * ts {
                continue;
            }
            let e0 = sequence_similarity_error(t_div, smp, ts, &cfg.similarity);
            // Only pay for refinement when the raw divisor score is at
            // least in the neighborhood of acceptance (§Perf).
            if e0 > 3.0 * tol {
                continue;
            }
            let (t_ref, e_ref) = refine(t_div, e0);
            if e_ref <= tol {
                best_t = t_ref;
                best_e = e_ref;
                continue 'divisor;
            }
        }
        break;
    }

    Some(PeriodEstimate {
        t_iter: best_t,
        err: best_e,
    })
}

/// The ODPP baseline's period detector: plain FFT arg-max (no similarity
/// verification, no refinement). Implemented from the description in
/// [11]; exhibits the harmonic/micro-period failure modes of §2.2.3.
pub fn calc_period_fft_argmax(smp: &[f64], ts: f64) -> Option<PeriodEstimate> {
    if smp.len() < 16 {
        return None;
    }
    let (freqs, ampls) = crate::signal::fft::periodogram(smp, ts);
    let k = crate::util::stats::argmax(&ampls)?;
    // NaN-poisoned spectra (a bad NVML reading anywhere in the window)
    // must degrade to "no detection", not report a garbage period.
    if ampls[k].is_nan() || ampls[k] <= 0.0 {
        return None;
    }
    Some(PeriodEstimate {
        t_iter: 1.0 / freqs[k],
        err: f64::NAN, // ODPP reports no self-assessed error
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    fn asym_periodic(period_samples: usize, cycles: usize, hf: f64, harm2: f64) -> Vec<f64> {
        let n = period_samples * cycles;
        (0..n)
            .map(|i| {
                let ph = 2.0 * PI * (i % period_samples) as f64 / period_samples as f64;
                1.0 * ph.sin() + harm2 * (2.0 * ph).sin()
                    + 0.3 * (3.0 * ph).cos()
                    + hf * (2.0 * PI * 0.43 * i as f64).sin()
            })
            .collect()
    }

    #[test]
    fn detects_simple_period() {
        let ts = 0.02;
        let p = 75;
        let smp = asym_periodic(p, 8, 0.05, 0.4);
        let est = calc_period(&smp, ts, &PeriodCfg::default()).unwrap();
        let rel = (est.t_iter - p as f64 * ts).abs() / (p as f64 * ts);
        assert!(rel < 0.05, "rel err {rel}, got {}", est.t_iter);
    }

    #[test]
    fn beats_fft_argmax_when_harmonic_dominates() {
        let ts = 0.02;
        let p = 96;
        // 2nd harmonic much stronger than fundamental, but the composite
        // waveform still repeats only at the fundamental.
        let n = p * 8;
        let smp: Vec<f64> = (0..n)
            .map(|i| {
                let ph = 2.0 * PI * (i % p) as f64 / p as f64;
                0.35 * ph.sin() + 1.0 * (2.0 * ph).sin() + 0.45 * (3.0 * ph).cos()
            })
            .collect();
        let odpp = calc_period_fft_argmax(&smp, ts).unwrap();
        let gpoeo = calc_period(&smp, ts, &PeriodCfg::default()).unwrap();
        let truth = p as f64 * ts;
        let odpp_err = (odpp.t_iter - truth).abs() / truth;
        let gpoeo_err = (gpoeo.t_iter - truth).abs() / truth;
        assert!(odpp_err > 0.4, "odpp should lock the harmonic, err {odpp_err}");
        assert!(gpoeo_err < 0.05, "gpoeo err {gpoeo_err}");
    }

    #[test]
    fn too_short_window_returns_none() {
        let smp = vec![1.0; 8];
        assert!(calc_period(&smp, 0.02, &PeriodCfg::default()).is_none());
    }

    #[test]
    fn constant_signal_returns_none() {
        let smp = vec![3.0; 512];
        assert!(calc_period(&smp, 0.02, &PeriodCfg::default()).is_none());
    }

    #[test]
    fn refinement_improves_on_bin_quantization() {
        let ts = 0.02;
        // Non-integer period in samples: 83.4
        let n = 800;
        let period_s = 83.4 * ts;
        let smp: Vec<f64> = (0..n)
            .map(|i| {
                let t = i as f64 * ts;
                let ph = 2.0 * PI * t / period_s;
                ph.sin() + 0.5 * (2.0 * ph).sin() + 0.2 * (5.0 * ph).cos()
            })
            .collect();
        let est = calc_period(&smp, ts, &PeriodCfg::default()).unwrap();
        let rel = (est.t_iter - period_s).abs() / period_s;
        assert!(rel < 0.03, "rel {rel}");
    }
}
