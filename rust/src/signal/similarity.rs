//! Feature-sequence similarity (Algorithm 2): score how well a candidate
//! period explains the sampled trace.
//!
//! The trace is cut into sub-curves of one candidate period each. For
//! every adjacent pair, the first sub-curve's samples are GMM-clustered
//! into amplitude groups; the *same sample indices* are then compared
//! across the pair via group-mean relative amplitudes and SMAPE. Averaging
//! within groups cancels the high-frequency interference that defeats
//! pointwise Euclidean distance (§4.1.2).

use crate::signal::gmm::{cluster_1d, group_indices};
use crate::util::stats::{mean, weighted_mean};

/// Tuning knobs for Algorithm 2 (paper defaults in `Default`).
#[derive(Debug, Clone)]
pub struct SimilarityCfg {
    /// Number of GMM amplitude groups per sub-curve.
    pub num_groups: usize,
    /// EM iteration cap.
    pub gmm_max_iter: usize,
}

impl Default for SimilarityCfg {
    fn default() -> Self {
        SimilarityCfg {
            num_groups: 4,
            // EM on ~30 one-dimensional samples converges in a handful of
            // iterations; 22 is indistinguishable from 40 on every app in
            // the suite and nearly halves Algorithm 2's cost (§Perf).
            gmm_max_iter: 22,
        }
    }
}

/// Error returned when a candidate period cannot be evaluated (fewer than
/// two full sub-curves fit in the window). Treated as "infinitely bad".
pub const UNEVALUABLE: f64 = f64::INFINITY;

/// Algorithm 2: similarity error of candidate period `t_iter` against the
/// sample sequence `smp` taken at interval `ts`. Lower is better; 0 means
/// adjacent sub-curves are identical under the grouping.
pub fn sequence_similarity_error(
    t_iter: f64,
    smp: &[f64],
    ts: f64,
    cfg: &SimilarityCfg,
) -> f64 {
    let n = smp.len();
    if t_iter <= 0.0 || n < 8 {
        return UNEVALUABLE;
    }
    let num_s = (t_iter / ts).floor() as usize; // samples per sub-curve
    // A sub-curve needs enough samples for amplitude grouping to mean
    // anything; below ~8 the GMM degenerates and scores are luck. This
    // also floors the detectable period at 8·ts, rejecting sub-Nyquist
    // micro-oscillation periods outright.
    if num_s < 8 {
        return UNEVALUABLE;
    }
    // Sub-curve i starts at the sample nearest i·T (NOT i·num_s: integer
    // window lengths accumulate sub-sample drift across windows, which
    // systematically penalizes true periods that are not integer multiples
    // of the sampling interval while sparing their k-fold multiples).
    let start_of = |i: usize| -> usize { (i as f64 * t_iter / ts).round() as usize };
    let num_t = {
        let mut k = 0usize;
        while start_of(k + 1) + num_s <= n + 1 && start_of(k) + num_s <= n {
            k += 1;
        }
        k
    };
    if num_t < 2 {
        return UNEVALUABLE;
    }

    // Score a pair of sub-curves given the leading curve's grouping —
    // the GMM is the expensive part, so each leading sub-curve is
    // clustered once and reused for both its lag-1 and lag-2 comparisons
    // (EXPERIMENTS.md §Perf).
    let pair_err = |groups: &[Vec<usize>], i: usize, lag: usize| -> Option<f64> {
        let s_prev = start_of(i);
        let s_back = start_of(i + lag);
        if s_prev + num_s > n || s_back + num_s > n {
            return None;
        }
        let prev = &smp[s_prev..s_prev + num_s];
        let back = &smp[s_back..s_back + num_s];
        let mean_prev = mean(prev);
        let mean_back = mean(back);
        if groups.is_empty() {
            return None;
        }
        // Group-relative amplitudes. Plain SMAPE of (rel_prev, rel_back)
        // blows up when a group's relative mean is near zero (SMAPE(≈0,≈0)
        // = 2), which systematically inflates the error of short windows
        // and biases selection toward k-fold multiples of the period.
        // Normalize group differences by the overall amplitude scale of
        // the grouping instead.
        let mut diffs = Vec::with_capacity(groups.len());
        let mut scales = Vec::with_capacity(groups.len());
        let mut weights = Vec::with_capacity(groups.len());
        for g in groups {
            let gp: Vec<f64> = g.iter().map(|&j| prev[j]).collect();
            let gb: Vec<f64> = g.iter().map(|&j| back[j]).collect();
            let rel_prev = mean(&gp) - mean_prev;
            let rel_back = mean(&gb) - mean_back;
            diffs.push((rel_prev - rel_back).abs());
            scales.push(rel_prev.abs().max(rel_back.abs()));
            weights.push(g.len() as f64);
        }
        let scale = weighted_mean(&scales, &weights).max(1e-12);
        let rel_errs: Vec<f64> = diffs.iter().map(|d| d / scale).collect();
        let e = weighted_mean(&rel_errs, &weights);
        // A poisoned pair (non-finite samples in a sub-curve) is excluded
        // outright. The guard must sit *before* the clamp: `f64::min`
        // ignores NaN, so `NaN.min(2.0)` would silently count the pair as
        // worst-case evidence against the period hypothesis.
        if !e.is_finite() {
            return None;
        }
        Some(e.min(2.0))
    };

    // Adjacent pairs (the paper's Algorithm 2) plus lag-2 pairs: a false
    // short period can luck into similar *adjacent* windows when they fall
    // inside the same long phase of the true iteration, but windows two
    // candidate-periods apart then land in different phases and expose it.
    let mut errs = Vec::with_capacity(2 * num_t);
    for i in 0..num_t - 1 {
        let s_prev = start_of(i);
        if s_prev + num_s > n {
            break;
        }
        let prev = &smp[s_prev..s_prev + num_s];
        let k = cfg.num_groups.min(prev.len());
        let gmm = cluster_1d(prev, k, cfg.gmm_max_iter);
        let groups = group_indices(&gmm.assignments, k);
        if let Some(e) = pair_err(&groups, i, 1) {
            errs.push(e);
        }
        if i + 2 < num_t {
            if let Some(e) = pair_err(&groups, i, 2) {
                errs.push(e);
            }
        }
    }

    if errs.is_empty() {
        return UNEVALUABLE;
    }
    // Lightly trimmed mean: drop the worst ~12% of pair scores so a single
    // abnormal (eval/checkpoint) iteration does not poison an otherwise
    // clean period hypothesis.
    errs.sort_by(|a, b| a.total_cmp(b));
    let keep = ((errs.len() as f64 * 0.88).ceil() as usize).max(1);
    mean(&errs[..keep])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    /// Asymmetric periodic signal with additive high-frequency noise.
    fn make_signal(period_samples: usize, cycles: usize, hf_amp: f64) -> Vec<f64> {
        let n = period_samples * cycles;
        (0..n)
            .map(|i| {
                let ph = (i % period_samples) as f64 / period_samples as f64;
                // Sawtooth + plateau: clearly asymmetric within a period.
                let base = if ph < 0.3 {
                    1.0 + ph * 3.0
                } else if ph < 0.7 {
                    2.5
                } else {
                    0.8
                };
                base + hf_amp * (2.0 * PI * 11.7 * i as f64 / period_samples as f64).sin()
            })
            .collect()
    }

    #[test]
    fn true_period_scores_better_than_wrong_ones() {
        let p = 50;
        let smp = make_signal(p, 8, 0.15);
        let ts = 0.02;
        let cfg = SimilarityCfg::default();
        let e_true = sequence_similarity_error(p as f64 * ts, &smp, ts, &cfg);
        let e_half = sequence_similarity_error(p as f64 * ts / 2.0, &smp, ts, &cfg);
        let e_off = sequence_similarity_error(p as f64 * ts * 1.37, &smp, ts, &cfg);
        assert!(e_true < e_half, "true {e_true} vs half {e_half}");
        assert!(e_true < e_off, "true {e_true} vs off {e_off}");
    }

    #[test]
    fn robust_to_high_frequency_interference() {
        let p = 64;
        let ts = 0.02;
        let cfg = SimilarityCfg::default();
        let clean = make_signal(p, 6, 0.0);
        let noisy = make_signal(p, 6, 0.4);
        let e_clean = sequence_similarity_error(p as f64 * ts, &clean, ts, &cfg);
        let e_noisy = sequence_similarity_error(p as f64 * ts, &noisy, ts, &cfg);
        // Group averaging keeps the true-period error low despite the HF ride.
        assert!(e_clean < 0.05, "clean {e_clean}");
        assert!(e_noisy < 0.35, "noisy {e_noisy}");
    }

    #[test]
    fn unevaluable_cases() {
        let smp = vec![1.0; 100];
        let cfg = SimilarityCfg::default();
        // Period longer than half the window: only one sub-curve fits.
        assert_eq!(
            sequence_similarity_error(60.0 * 0.02, &smp, 0.02, &cfg),
            UNEVALUABLE
        );
        // Period shorter than 8 samples.
        assert_eq!(
            sequence_similarity_error(0.14, &smp, 0.02, &cfg),
            UNEVALUABLE
        );
        assert_eq!(sequence_similarity_error(-1.0, &smp, 0.02, &cfg), UNEVALUABLE);
    }

    #[test]
    fn perfect_repetition_scores_near_zero() {
        let p = 40;
        let smp = make_signal(p, 10, 0.0);
        let e = sequence_similarity_error(p as f64 * 0.02, &smp, 0.02, &SimilarityCfg::default());
        assert!(e < 1e-6, "e={e}");
    }
}
