//! Streaming detection engine: the online robust period detection
//! (Algorithm 3) as a long-lived, push-based detector instead of a
//! function consumers re-run over ever-growing sample `Vec`s.
//!
//! The batch wrapper ([`online_detect_with`]) recomputes everything from
//! scratch on every call: a consumer that wants a fresh verdict per poll
//! pays O(window) per poll and O(session²) over a session. The detector
//! owns the whole per-session state instead:
//!
//! - a **bounded sample window** of the three `Feature_dect` channels
//!   (power / SM util / mem util), trimmed behind the paper's advancing
//!   start line (outdated samples are *dropped*, not just skipped) and
//!   hard-capped at `max_retain_s` — detector memory is O(1) in session
//!   length;
//! - the **evaluation schedule**: Algorithm 3's own contract is "sample
//!   `d` more seconds, then call again", so [`StreamingDetector::poll`]
//!   answers from the standing verdict until the requested extension has
//!   actually arrived, and only then re-evaluates. Consumers stop
//!   reimplementing deadline bookkeeping (and naive ones stop paying for
//!   evaluations the algorithm itself declares void);
//! - **reusable scratch** (FFT buffers, the Algorithm-1 moving-average
//!   copy) and a **per-sub-window estimate cache** keyed by
//!   `(istart, len)`, so repeated window evaluations inside one tick are
//!   answered once.
//!
//! Every evaluation runs the exact [`online_detect_loop`] the batch
//! wrapper runs, over the retained window — the results are
//! bit-identical to `online_detect_with` on the same samples, which
//! `rust/tests/detection_streaming.rs` enforces across all 71 apps.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use crate::signal::fft::{periodogram_with, FftScratch};
use crate::signal::online::{composite_feature_into, online_detect_loop, OnlineDetection};
use crate::signal::period::{calc_period_scratch, PeriodCfg, PeriodEstimate, PeriodScratch};
use crate::telemetry::{Counter, Metrics};
use std::collections::HashMap;
use std::sync::Arc;

/// Per-sub-window Algorithm-1 results, keyed by `(istart, len)` relative
/// to the current feature window.
type EstimateCache = HashMap<(usize, usize), Option<PeriodEstimate>>;

/// Cadence and retention knobs of the streaming engine. The defaults
/// mirror the GPOEO controller's sampling schedule (§4.3.1).
#[derive(Debug, Clone)]
pub struct StreamCfg {
    /// The first evaluation is due after this much signal (SmpDur_init).
    pub initial_window_s: f64,
    /// Clamp on the extension Algorithm 3 may request between
    /// evaluations.
    pub min_ext_s: f64,
    pub max_ext_s: f64,
    /// Extension used when an evaluation yields no detection at all
    /// (window too short / no spectral candidates).
    pub none_ext_s: f64,
    /// Advancing start line (§4.1.3): with `Some(m)`, samples older than
    /// `m × (2 + c_eval·step) × T̂` behind the window end are dropped
    /// before the next evaluation — the paper's progressive exclusion of
    /// outdated samples, made literal. `None` retains the whole window
    /// (up to `max_retain_s`), which is bit-compatible with the historic
    /// grow-only controller behavior.
    pub retain_horizon_mult: Option<f64>,
    /// Hard cap on retained signal, seconds — bounds detector memory
    /// regardless of session length or estimate quality.
    pub max_retain_s: f64,
}

impl Default for StreamCfg {
    fn default() -> Self {
        StreamCfg {
            initial_window_s: 6.0,
            min_ext_s: 0.5,
            max_ext_s: 12.0,
            none_ext_s: 3.0,
            retain_horizon_mult: None,
            max_retain_s: 60.0,
        }
    }
}

/// One evaluation the detector actually performed.
#[derive(Debug, Clone, Copy)]
pub struct StreamVerdict {
    /// The Algorithm-3 outcome over the retained window (`None`: the
    /// window was unusable — too short or no spectral candidates).
    pub detection: Option<OnlineDetection>,
    /// Retained-window duration at evaluation time, seconds.
    pub window_s: f64,
    /// 1-based evaluation ordinal since construction/reset.
    pub round: usize,
}

/// Bit-level equality of two detection outcomes (NaN-safe: raw f64 bit
/// patterns) — the contract the property suite enforces between the
/// streaming and batch paths.
pub fn detections_bit_equal(a: Option<OnlineDetection>, b: Option<OnlineDetection>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => {
            x.estimate.t_iter.to_bits() == y.estimate.t_iter.to_bits()
                && x.estimate.err.to_bits() == y.estimate.err.to_bits()
                && match (x.next_sampling_s, y.next_sampling_s) {
                    (None, None) => true,
                    (Some(p), Some(q)) => p.to_bits() == q.to_bits(),
                    _ => false,
                }
        }
        _ => false,
    }
}

/// The streaming Algorithm-3 engine. See the module docs for the
/// contract; see [`StreamCfg`] for the knobs.
pub struct StreamingDetector {
    ts: f64,
    cfg: PeriodCfg,
    stream: StreamCfg,
    // Retained Feature_dect channels (the window the next evaluation
    // sees). `origin` is the absolute index of element 0 in the full
    // pushed stream.
    power: Vec<f64>,
    util_sm: Vec<f64>,
    util_mem: Vec<f64>,
    origin: usize,
    /// Total samples pushed since construction/reset.
    pushed: usize,
    // Composite blend of the retained window, rebuilt lazily: the
    // variance normalization is window-global, so any push or trim
    // invalidates it (and the estimate cache with it).
    feat: Vec<f64>,
    feature_dirty: bool,
    scratch: PeriodScratch,
    fft: FftScratch,
    cache: EstimateCache,
    cache_hits: u64,
    cache_misses: u64,
    rounds: usize,
    last: Option<StreamVerdict>,
    /// Absolute pushed-sample count at which the next evaluation is due;
    /// `usize::MAX` once the period is stable.
    next_eval_at: usize,
    max_retained: usize,
    /// Telemetry tap (DESIGN.md §11): counts evaluations and
    /// re-detections. Pure observation — never consulted by the
    /// detection math, so the streaming↔batch bit-identity holds with
    /// or without it.
    metrics: Option<Arc<Metrics>>,
}

impl StreamingDetector {
    pub fn new(ts: f64, cfg: PeriodCfg, stream: StreamCfg) -> StreamingDetector {
        let first_due = ((stream.initial_window_s / ts).ceil() as usize).max(1);
        let max_retained = ((stream.max_retain_s / ts).ceil() as usize).max(32);
        StreamingDetector {
            ts,
            cfg,
            stream,
            power: Vec::new(),
            util_sm: Vec::new(),
            util_mem: Vec::new(),
            origin: 0,
            pushed: 0,
            feat: Vec::new(),
            feature_dirty: true,
            scratch: PeriodScratch::default(),
            fft: FftScratch::default(),
            cache: EstimateCache::new(),
            cache_hits: 0,
            cache_misses: 0,
            rounds: 0,
            last: None,
            next_eval_at: first_due,
            max_retained,
            metrics: None,
        }
    }

    /// Route evaluation/re-detection counters to a metrics registry.
    pub fn attach_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    /// Push one NVML sampling tick (the three Feature_dect channels).
    pub fn push(&mut self, power_w: f64, util_sm: f64, util_mem: f64) {
        self.power.push(power_w);
        self.util_sm.push(util_sm);
        self.util_mem.push(util_mem);
        self.pushed += 1;
        self.feature_dirty = true;
        if !self.cache.is_empty() {
            // The composite blend renormalizes over the new window: every
            // cached sub-window estimate is stale.
            self.cache.clear();
        }
        if self.power.len() > self.max_retained {
            let excess = self.power.len() - self.max_retained;
            self.drop_front(excess);
        }
    }

    /// Gated evaluation with the native FFT front-end: answers `None`
    /// (keep sampling — the standing verdict is [`Self::last`]) until the
    /// extension Algorithm 3 requested has arrived, then re-evaluates.
    pub fn poll(&mut self) -> Option<StreamVerdict> {
        if self.pushed < self.next_eval_at {
            return None;
        }
        Some(self.evaluate())
    }

    /// [`Self::poll`] with a pluggable spectral front-end.
    pub fn poll_with(
        &mut self,
        spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
    ) -> Option<StreamVerdict> {
        if self.pushed < self.next_eval_at {
            return None;
        }
        Some(self.evaluate_with(spectrum))
    }

    /// Unconditional evaluation with the native FFT front-end.
    pub fn evaluate(&mut self) -> StreamVerdict {
        self.apply_start_line();
        self.ensure_feature();
        let fft = &mut self.fft;
        let mut spectrum =
            |s: &[f64], t: f64| -> (Vec<f64>, Vec<f64>) { periodogram_with(s, t, &mut *fft) };
        let det = Self::detect(
            &self.feat,
            self.ts,
            &self.cfg,
            &mut self.scratch,
            &mut self.cache,
            &mut self.cache_hits,
            &mut self.cache_misses,
            &mut spectrum,
        );
        self.finish_evaluation(det)
    }

    /// Unconditional evaluation with a pluggable spectral front-end.
    /// Callers must inject the same front-end for the detector's whole
    /// lifetime — the estimate cache is keyed by window, not by spectrum.
    pub fn evaluate_with(
        &mut self,
        spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
    ) -> StreamVerdict {
        self.apply_start_line();
        self.ensure_feature();
        let det = Self::detect(
            &self.feat,
            self.ts,
            &self.cfg,
            &mut self.scratch,
            &mut self.cache,
            &mut self.cache_hits,
            &mut self.cache_misses,
            spectrum,
        );
        self.finish_evaluation(det)
    }

    /// Forget everything and restart the detection phase (workload
    /// change). Cache hit/miss counters are cumulative across resets.
    pub fn reset(&mut self) {
        if let Some(m) = &self.metrics {
            m.inc(Counter::DetectorRedetections);
        }
        self.power.clear();
        self.util_sm.clear();
        self.util_mem.clear();
        self.feat.clear();
        self.cache.clear();
        self.origin = 0;
        self.pushed = 0;
        self.rounds = 0;
        self.feature_dirty = true;
        self.last = None;
        self.next_eval_at = ((self.stream.initial_window_s / self.ts).ceil() as usize).max(1);
    }

    // ------------------------------------------------------ accessors --

    /// The last verdict, whether or not this poll re-evaluated.
    pub fn last(&self) -> Option<StreamVerdict> {
        self.last
    }

    /// Total signal pushed since construction/reset, seconds.
    pub fn pushed_s(&self) -> f64 {
        self.pushed as f64 * self.ts
    }

    /// Retained-window duration, seconds.
    pub fn retained_s(&self) -> f64 {
        self.power.len() as f64 * self.ts
    }

    /// Retained sample count (per channel).
    pub fn retained_len(&self) -> usize {
        self.power.len()
    }

    /// Absolute index of the first retained sample (> 0 once the start
    /// line has advanced past dropped history).
    pub fn origin(&self) -> usize {
        self.origin
    }

    /// Evaluations performed since construction/reset.
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Cumulative sub-window estimate cache (hits, misses).
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache_hits, self.cache_misses)
    }

    /// The retained raw channels `(power, util_sm, util_mem)` — what the
    /// next evaluation will blend and detect over. The property suite
    /// feeds these to the batch wrapper to prove bit-identity.
    pub fn channels(&self) -> (&[f64], &[f64], &[f64]) {
        (&self.power, &self.util_sm, &self.util_mem)
    }

    // ------------------------------------------------------- internals --

    /// Drop retained history behind the advancing start line: no future
    /// rolling window of Algorithm 3 reaches further back than
    /// `(2 + c_eval·step) × T̂` behind the window end, so (with margin
    /// `retain_horizon_mult`) older samples can never influence a verdict
    /// again. Runs *before* an evaluation so the verdict and the retained
    /// window always correspond.
    fn apply_start_line(&mut self) {
        let Some(mult) = self.stream.retain_horizon_mult else {
            return;
        };
        let Some(StreamVerdict {
            detection: Some(d), ..
        }) = self.last
        else {
            return;
        };
        let horizon_s = ((2.0 + self.cfg.c_eval * self.cfg.step) * d.estimate.t_iter * mult)
            .max(self.stream.initial_window_s);
        let keep = ((horizon_s / self.ts).ceil() as usize).max(32);
        if self.power.len() > keep {
            let excess = self.power.len() - keep;
            self.drop_front(excess);
        }
    }

    fn drop_front(&mut self, k: usize) {
        let k = k.min(self.power.len());
        if k == 0 {
            return;
        }
        self.power.drain(..k);
        self.util_sm.drain(..k);
        self.util_mem.drain(..k);
        self.origin += k;
        self.feature_dirty = true;
        self.cache.clear();
    }

    /// Rebuild the composite `Feature_dect` blend of the retained window
    /// into the reusable buffer (the one copy of the blend arithmetic
    /// lives in [`composite_feature_into`]).
    fn ensure_feature(&mut self) {
        if !self.feature_dirty {
            return;
        }
        composite_feature_into(&mut self.feat, &self.power, &self.util_sm, &self.util_mem);
        self.feature_dirty = false;
    }

    /// One Algorithm-3 evaluation over the blended window: the shared
    /// [`online_detect_loop`] with a memoizing per-sub-window estimator.
    #[allow(clippy::too_many_arguments)]
    fn detect(
        feat: &[f64],
        ts: f64,
        cfg: &PeriodCfg,
        scratch: &mut PeriodScratch,
        cache: &mut EstimateCache,
        hits: &mut u64,
        misses: &mut u64,
        spectrum: &mut dyn FnMut(&[f64], f64) -> (Vec<f64>, Vec<f64>),
    ) -> Option<OnlineDetection> {
        let n = feat.len();
        let mut eval = |istart: usize| -> Option<PeriodEstimate> {
            let key = (istart, n - istart);
            if let Some(&est) = cache.get(&key) {
                *hits += 1;
                return est;
            }
            *misses += 1;
            // gpoeo-lint: allow(PF-INDEX) online_detect_loop only probes istart < n = feat.len(), so the range start is always in bounds
            let est = calc_period_scratch(&feat[istart..], ts, cfg, &mut *spectrum, &mut *scratch);
            cache.insert(key, est);
            est
        };
        online_detect_loop(n, ts, cfg, &mut eval)
    }

    /// Record the verdict and schedule the next evaluation per the
    /// Algorithm-3 contract.
    fn finish_evaluation(&mut self, det: Option<OnlineDetection>) -> StreamVerdict {
        if let Some(m) = &self.metrics {
            m.inc(Counter::DetectorEvaluations);
        }
        self.rounds += 1;
        let verdict = StreamVerdict {
            detection: det,
            window_s: self.retained_s(),
            round: self.rounds,
        };
        self.next_eval_at = match det.and_then(|d| d.next_sampling_s) {
            Some(ext) => {
                let ext = ext.clamp(self.stream.min_ext_s, self.stream.max_ext_s);
                self.pushed + ((ext / self.ts).ceil() as usize).max(1)
            }
            None => match det {
                // Stable: Algorithm 3 is done; the consumer moves on (or
                // resets on a workload change).
                Some(_) => usize::MAX,
                // No detection at all: extend by the fallback window.
                None => {
                    self.pushed + ((self.stream.none_ext_s / self.ts).ceil() as usize).max(1)
                }
            },
        };
        self.last = Some(verdict);
        verdict
    }
}

#[allow(clippy::unwrap_used, clippy::expect_used)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::signal::{composite_feature, online_detect};

    /// Phase-structured waveform matching the online.rs test harness.
    fn signal(period_s: f64, ts: f64, dur_s: f64) -> Vec<f64> {
        let n = (dur_s / ts) as usize;
        (0..n)
            .map(|i| {
                let t = i as f64 * ts;
                let ph = (t / period_s).fract();
                let base = if ph < 0.10 {
                    0.4
                } else if ph < 0.50 {
                    0.95
                } else if ph < 0.85 {
                    1.05
                } else {
                    0.6
                };
                let h = (i as u64).wrapping_mul(0x9e3779b97f4a7c15);
                let noise = ((h >> 11) as f64 / (1u64 << 53) as f64) - 0.5;
                base + 0.04 * noise
            })
            .collect()
    }

    fn push_as_channels(det: &mut StreamingDetector, sig: &[f64]) {
        for &x in sig {
            det.push(200.0 + 40.0 * x, 0.6 + 0.2 * x, 0.4 + 0.1 * x);
        }
    }

    #[test]
    fn evaluate_matches_batch_wrapper_bitwise() {
        let ts = 0.025;
        let sig = signal(1.7, ts, 18.0);
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), StreamCfg::default());
        push_as_channels(&mut det, &sig);
        let (p, us, um) = det.channels();
        let feat = composite_feature(p, us, um);
        let batch = online_detect(&feat, ts, &PeriodCfg::default());
        let v = det.evaluate();
        assert!(
            detections_bit_equal(v.detection, batch),
            "streaming {v:?} vs batch {batch:?}"
        );
        assert!(v.detection.is_some());
    }

    #[test]
    fn poll_gates_on_the_extension_schedule() {
        let ts = 0.025;
        let sig = signal(1.7, ts, 24.0);
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), StreamCfg::default());
        let mut evals = Vec::new();
        for (i, &x) in sig.iter().enumerate() {
            det.push(200.0 + 40.0 * x, 0.6 + 0.2 * x, 0.4 + 0.1 * x);
            if let Some(v) = det.poll() {
                evals.push((i, v));
            }
        }
        // First evaluation exactly when the initial window fills (same
        // ceil derivation as the detector, so FP rounding cancels).
        let first_due = (6.0 / ts).ceil() as usize;
        assert_eq!(evals.first().map(|(i, _)| i + 1), Some(first_due));
        // The contract gates evaluations to a handful per session — a
        // poll-per-tick consumer must not trigger one per tick.
        assert!(
            evals.len() < sig.len() / 20,
            "{} evaluations for {} ticks",
            evals.len(),
            sig.len()
        );
        // A stable signal converges, after which polls stop evaluating.
        let last = evals.last().unwrap().1;
        assert!(last.detection.is_some());
        assert!(last.detection.unwrap().next_sampling_s.is_none());
        assert_eq!(det.last().unwrap().round, evals.len());
    }

    #[test]
    fn repeated_evaluate_is_answered_from_the_cache() {
        let ts = 0.025;
        let sig = signal(1.3, ts, 14.0);
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), StreamCfg::default());
        push_as_channels(&mut det, &sig);
        let v1 = det.evaluate();
        let (_, misses1) = det.cache_stats();
        let v2 = det.evaluate();
        let (hits2, misses2) = det.cache_stats();
        assert!(detections_bit_equal(v1.detection, v2.detection));
        assert_eq!(
            misses1, misses2,
            "no new samples: second evaluation must be all cache hits"
        );
        assert!(hits2 > 0);
    }

    #[test]
    fn retention_is_bounded() {
        let ts = 0.025;
        let cfg = StreamCfg {
            max_retain_s: 2.0,
            ..StreamCfg::default()
        };
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), cfg);
        let sig = signal(0.9, ts, 100.0);
        push_as_channels(&mut det, &sig);
        assert!(det.retained_len() <= (2.0 / ts).ceil() as usize);
        assert!(det.origin() > 0);
        assert!((det.pushed_s() - 100.0).abs() < 0.1);
    }

    #[test]
    fn start_line_trims_and_stays_bitwise_consistent() {
        let ts = 0.025;
        let cfg = StreamCfg {
            retain_horizon_mult: Some(1.0),
            ..StreamCfg::default()
        };
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), cfg);
        push_as_channels(&mut det, &signal(1.7, ts, 18.0));
        let _ = det.evaluate();
        push_as_channels(&mut det, &signal(1.7, ts, 2.0));
        let v = det.evaluate();
        assert!(
            det.origin() > 0,
            "advancing start line must have dropped stale history"
        );
        // The verdict corresponds to the post-trim retained window.
        let (p, us, um) = det.channels();
        let feat = composite_feature(p, us, um);
        let batch = online_detect(&feat, ts, &PeriodCfg::default());
        assert!(detections_bit_equal(v.detection, batch));
    }

    #[test]
    fn reset_restarts_the_phase() {
        let ts = 0.025;
        let mut det = StreamingDetector::new(ts, PeriodCfg::default(), StreamCfg::default());
        push_as_channels(&mut det, &signal(1.1, ts, 8.0));
        let _ = det.evaluate();
        det.reset();
        assert_eq!(det.retained_len(), 0);
        assert_eq!(det.rounds(), 0);
        assert!(det.last().is_none());
        assert!(det.poll().is_none(), "fresh phase: nothing due yet");
    }
}
