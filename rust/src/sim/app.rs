//! Synthetic application materialization and the analytic DVFS model.
//!
//! Each benchmark app is generated deterministically from
//! (global_seed, suite salt, app name) — see `util::rng::app_rng`. The
//! generation *order of RNG draws* is part of the cross-language contract
//! with `python/compile/simdata.py`; do not reorder draws without updating
//! the Python twin and `artifacts/crosscheck.json`.
//!
//! The analytic model maps (SM gear, mem gear) → (iteration time, average
//! power, energy). It is the "real hardware" the online controller probes,
//! and — with per-app hidden coefficient noise removed — the ground truth
//! the offline GBT models are trained on.

use crate::sim::spec::{PhaseSpec, Spec, NUM_FEATURES};
use crate::util::rng::{app_rng, Pcg64};

/// A fully materialized synthetic application.
#[derive(Debug, Clone)]
pub struct AppParams {
    pub name: String,
    pub suite: String,
    pub archetype: String,
    /// True performance-counter signature (Table 2), each in (0, 1].
    pub features: Vec<f64>,
    /// Iteration period at the reference clock config, seconds. For
    /// aperiodic apps this is the mean phase-segment length instead.
    pub t_base: f64,
    /// Normalized time-decomposition weights: compute / memory / other.
    pub wc: f64,
    pub wm: f64,
    pub wo: f64,
    /// SM-clock scaling exponent for the compute term.
    pub gamma: f64,
    /// Fraction of the memory term that scales with DRAM clock.
    pub s_m: f64,
    /// Power-model coefficients.
    pub k_sm: f64,
    pub k_mem: f64,
    pub a_sm: f64,
    pub a_mem: f64,
    /// Trace-shape parameters.
    pub phases: Vec<PhaseSpec>,
    pub trace_noise: f64,
    pub micro_amp: f64,
    pub micro_period_s: f64,
    pub micro_jitter: f64,
    pub abnormal_every: usize,
    pub abnormal_scale: f64,
    pub aperiodic: bool,
    /// Seed for the per-run trace noise stream.
    pub trace_seed: u64,
}

/// Metrics of one app at one clock configuration (noise-free ground truth).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpPoint {
    pub t_iter_s: f64,
    pub power_w: f64,
    pub energy_j: f64,
    pub util_sm: f64,
    pub util_mem: f64,
}

impl AppParams {
    /// Materialize an app. `overrides` come from the suite entry.
    pub fn materialize(
        spec: &Spec,
        suite: &str,
        name: &str,
        archetype_name: &str,
        abnormal_every: Option<usize>,
        abnormal_scale: Option<f64>,
        aperiodic: Option<bool>,
    ) -> AppParams {
        let arch = &spec.archetypes[archetype_name];
        let salt = spec.suites[suite].seed_salt;
        let mut rng = app_rng(spec.global_seed, salt, name);

        // Draw order is the cross-language contract — see module docs.
        let mut features = Vec::with_capacity(NUM_FEATURES);
        for i in 0..NUM_FEATURES {
            let v = arch.features_mean[i] + arch.features_std * rng.gauss();
            features.push(v.clamp(0.01, 1.0));
        }
        let t_base = if arch.period_s.1 > 0.0 {
            rng.uniform(arch.period_s.0, arch.period_s.1)
        } else {
            // Aperiodic archetypes draw the mean segment length instead;
            // the draw still happens so the stream stays aligned.
            rng.uniform(0.4, 1.2)
        };
        let h = spec.noise.hidden_coeff_std;
        let h_wc = rng.normal(0.0, h).exp();
        let h_wm = rng.normal(0.0, h).exp();
        let h_ksm = rng.normal(0.0, h).exp();
        let h_kmem = rng.normal(0.0, h).exp();
        let h_gamma = rng.normal(0.0, h / 2.0);

        let mut phases: Vec<PhaseSpec> = arch.phases.clone();
        for ph in &mut phases {
            ph.frac *= rng.normal(0.0, 0.08).exp();
        }
        let fsum: f64 = phases.iter().map(|p| p.frac).sum();
        for ph in &mut phases {
            ph.frac /= fsum;
        }
        let micro_period_s = arch.micro_period_s * rng.uniform(0.8, 1.25);
        let trace_seed = rng.next_u64();

        let cm = &spec.coeff_maps;
        let wc_raw = cm.w_compute.eval(&features) * h_wc;
        let wm_raw = cm.w_memory.eval(&features) * h_wm;
        let wo_raw = cm.w_other.eval(&features);
        let s = wc_raw + wm_raw + wo_raw;
        let gamma =
            (cm.gamma_sm.eval(&features) + h_gamma).clamp(cm.gamma_sm.lo, cm.gamma_sm.hi);
        let s_m = cm.mem_sens.eval(&features);
        let k_sm = cm.k_sm_power.eval(&features) * h_ksm;
        let k_mem = cm.k_mem_power.eval(&features) * h_kmem;
        let a_sm = cm.sm_activity.eval(&features);
        let a_mem = cm.mem_activity.eval(&features);

        AppParams {
            name: name.to_string(),
            suite: suite.to_string(),
            archetype: archetype_name.to_string(),
            features,
            t_base,
            wc: wc_raw / s,
            wm: wm_raw / s,
            wo: wo_raw / s,
            gamma,
            s_m,
            k_sm,
            k_mem,
            a_sm,
            a_mem,
            phases,
            trace_noise: arch.trace_noise,
            micro_amp: arch.micro_amp,
            micro_period_s,
            micro_jitter: arch.micro_jitter,
            abnormal_every: abnormal_every.unwrap_or(arch.abnormal_every),
            abnormal_scale: abnormal_scale.unwrap_or(arch.abnormal_scale),
            aperiodic: aperiodic.unwrap_or(arch.aperiodic),
            trace_seed,
        }
    }

    /// Relative iteration-time factor R = t/t_base at a clock config.
    pub fn time_factor(&self, spec: &Spec, sm_gear: usize, mem_gear: usize) -> f64 {
        let fs = spec.gears.sm_mhz(sm_gear);
        let fm = spec.gears.mem_mhz_of(mem_gear);
        let f_ref_s = spec.gears.sm_mhz(spec.gears.reference_sm_gear);
        let f_ref_m = spec.gears.mem_mhz_of(spec.gears.reference_mem_gear);
        let r_s = (f_ref_s / fs).powf(self.gamma);
        let r_m = (f_ref_m / fm).powf(spec.time_model.mem_exponent);
        let rme = (1.0 - self.s_m) + self.s_m * r_m;
        self.wo + self.wc * r_s + self.wm * rme
    }

    /// Noise-free operating point at a clock configuration.
    pub fn op_point(&self, spec: &Spec, sm_gear: usize, mem_gear: usize) -> OpPoint {
        let fs = spec.gears.sm_mhz(sm_gear);
        let fm = spec.gears.mem_mhz_of(mem_gear);
        let f_ref_s = spec.gears.sm_mhz(spec.gears.reference_sm_gear);
        let f_ref_m = spec.gears.mem_mhz_of(spec.gears.reference_mem_gear);
        let r_s = (f_ref_s / fs).powf(self.gamma);
        let r_m = (f_ref_m / fm).powf(spec.time_model.mem_exponent);
        let rme = (1.0 - self.s_m) + self.s_m * r_m;
        let r = self.wo + self.wc * r_s + self.wm * rme;
        let t_iter = self.t_base * r;

        // Busy-fraction utilization: downclocking the bottleneck unit
        // raises its utilization; the other unit's utilization falls.
        let util_sm = (self.a_sm * (self.wc * r_s + 0.5 * self.wo)
            / (r * (self.wc + 0.5 * self.wo)))
            .clamp(0.02, 1.0);
        let util_mem = (self.a_mem * (self.wm * rme + 0.4 * self.wo)
            / (r * (self.wm + 0.4 * self.wo)))
            .clamp(0.02, 1.0);

        let p = &spec.power;
        let v = p.voltage(fs);
        let p_sm = p.c_sm * self.k_sm * util_sm * v * v * (fs / 1000.0);
        let p_mem = (p.c_mem_static + p.c_mem * self.k_mem * util_mem)
            * p.mem_v2_factor[mem_gear]
            * (fm / 1000.0);
        let power = p.p_idle_w + p_sm + p_mem;

        OpPoint {
            t_iter_s: t_iter,
            power_w: power,
            energy_j: power * t_iter,
            util_sm,
            util_mem,
        }
    }

    /// The SM gear the NVIDIA default scheduling strategy settles on for
    /// this app: power-capped boost — the highest gear whose average
    /// power stays under the TDP (at the default memory clock). Hot
    /// compute workloads are therefore already throttled by the default
    /// strategy and have little energy-saving headroom (the paper's
    /// AI_I2IC/AI_T2T cases), while low-power workloads boost to the top
    /// gear wastefully.
    pub fn default_sm_gear(&self, spec: &Spec) -> usize {
        let mem = spec.gears.default_mem_gear;
        for g in (spec.gears.sm_gear_min..=spec.gears.default_sm_gear).rev() {
            if self.op_point(spec, g, mem).power_w <= spec.power.tdp_w {
                return g;
            }
        }
        spec.gears.sm_gear_min
    }

    /// Operating point under the NVIDIA default scheduling strategy.
    pub fn default_op(&self, spec: &Spec) -> (usize, usize, OpPoint) {
        let sm = self.default_sm_gear(spec);
        let mem = spec.gears.default_mem_gear;
        (sm, mem, self.op_point(spec, sm, mem))
    }

    /// Energy and time ratios relative to the NVIDIA-default config —
    /// the quantities the paper's four prediction models are trained on.
    pub fn ratios_vs_default(&self, spec: &Spec, sm_gear: usize, mem_gear: usize) -> (f64, f64) {
        let (_, _, dflt) = self.default_op(spec);
        let pt = self.op_point(spec, sm_gear, mem_gear);
        (pt.energy_j / dflt.energy_j, pt.t_iter_s / dflt.t_iter_s)
    }

    /// Measured counter features: truth + one-period measurement noise.
    /// `rng` is the measurement stream (not the materialization stream).
    pub fn measured_features(&self, spec: &Spec, rng: &mut Pcg64) -> Vec<f64> {
        self.features
            .iter()
            .map(|f| {
                (f * rng.normal(0.0, spec.noise.counter_meas_std).exp()).clamp(0.005, 1.05)
            })
            .collect()
    }

    /// Instructions-per-second proxy for the aperiodic path (§4.3.5):
    /// work-rate is inversely proportional to the time factor.
    pub fn ips(&self, spec: &Spec, sm_gear: usize, mem_gear: usize) -> f64 {
        1.0 / (self.time_factor(spec, sm_gear, mem_gear) * self.t_base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::Spec;

    fn spec() -> Spec {
        Spec::load_default().unwrap()
    }

    fn app(spec: &Spec, suite: &str, name: &str) -> AppParams {
        let e = spec.suites[suite]
            .apps
            .iter()
            .find(|a| a.name == name)
            .unwrap()
            .clone();
        AppParams::materialize(
            spec,
            suite,
            &e.name,
            &e.archetype,
            e.abnormal_every,
            e.abnormal_scale,
            e.aperiodic,
        )
    }

    #[test]
    fn materialization_is_deterministic() {
        let s = spec();
        let a = app(&s, "aibench", "AI_I2T");
        let b = app(&s, "aibench", "AI_I2T");
        assert_eq!(a.features, b.features);
        assert_eq!(a.t_base, b.t_base);
        assert_eq!(a.trace_seed, b.trace_seed);
    }

    #[test]
    fn weights_normalized_and_positive() {
        let s = spec();
        for suite in ["aibench", "gnns", "pytorch_train", "classical"] {
            for e in &s.suites[suite].apps {
                let a = app(&s, suite, &e.name);
                assert!((a.wc + a.wm + a.wo - 1.0).abs() < 1e-9, "{}", a.name);
                assert!(a.wc > 0.0 && a.wm > 0.0 && a.wo > 0.0, "{}", a.name);
                assert!(a.t_base > 0.0);
                assert!((0.55..=1.0).contains(&a.gamma));
            }
        }
    }

    #[test]
    fn reference_point_is_t_base() {
        let s = spec();
        let a = app(&s, "aibench", "AI_FE");
        let r = a.time_factor(&s, s.gears.reference_sm_gear, s.gears.reference_mem_gear);
        assert!((r - 1.0).abs() < 1e-12);
    }

    #[test]
    fn time_monotone_in_sm_clock() {
        let s = spec();
        let a = app(&s, "aibench", "AI_OBJ");
        let mut prev = f64::INFINITY;
        for g in s.gears.sm_gears() {
            let t = a.op_point(&s, g, 3).t_iter_s;
            assert!(t <= prev + 1e-12, "time must not increase with clock");
            prev = t;
        }
    }

    #[test]
    fn power_monotone_in_sm_clock_at_fixed_mem() {
        let s = spec();
        let a = app(&s, "aibench", "AI_I2T");
        // Power should broadly rise with SM clock (V^2 f dominates util drift).
        let lo = a.op_point(&s, 30, 3).power_w;
        let hi = a.op_point(&s, 114, 3).power_w;
        assert!(hi > lo * 1.3, "lo={lo} hi={hi}");
    }

    #[test]
    fn energy_is_convexish_with_interior_min_for_some_app() {
        let s = spec();
        // At least one AIBench app should have an interior-optimum SM gear
        // (that is the whole premise of the paper).
        let mut found_interior = false;
        for e in &s.suites["aibench"].apps {
            let a = app(&s, "aibench", &e.name);
            let e_of: Vec<f64> = s.gears.sm_gears().map(|g| a.op_point(&s, g, 4).energy_j).collect();
            let i = crate::util::stats::argmin(&e_of).unwrap();
            if i > 0 && i < e_of.len() - 1 {
                found_interior = true;
            }
        }
        assert!(found_interior);
    }

    #[test]
    fn ratios_vs_default_identity() {
        let s = spec();
        let a = app(&s, "gnns", "SBM_GIN");
        let (sm, mem, _) = a.default_op(&s);
        let (e, t) = a.ratios_vs_default(&s, sm, mem);
        assert!((e - 1.0).abs() < 1e-12 && (t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn default_gear_is_power_capped() {
        let s = spec();
        for e in &s.suites["aibench"].apps {
            let a = app(&s, "aibench", &e.name);
            let (sm, mem, op) = a.default_op(&s);
            assert!(op.power_w <= s.power.tdp_w + 1e-9, "{} {}W", a.name, op.power_w);
            if sm < s.gears.default_sm_gear {
                // One gear higher must exceed the TDP (tightness).
                let above = a.op_point(&s, sm + 1, mem);
                assert!(above.power_w > s.power.tdp_w, "{}", a.name);
            }
        }
    }

    #[test]
    fn measured_features_are_noisy_but_close() {
        let s = spec();
        let a = app(&s, "aibench", "AI_TS");
        let mut rng = crate::util::rng::Pcg64::new(9, 9);
        let m = a.measured_features(&s, &mut rng);
        assert_eq!(m.len(), NUM_FEATURES);
        for (t, m) in a.features.iter().zip(&m) {
            assert!(((m / t) - 1.0).abs() < 0.2, "truth {t} meas {m}");
        }
    }

    #[test]
    fn aperiodic_flag_propagates() {
        let s = spec();
        assert!(app(&s, "classical", "TSVM").aperiodic);
        assert!(app(&s, "gnns", "CSL_GCN").aperiodic);
        assert!(!app(&s, "gnns", "SBM_GCN").aperiodic);
    }
}
