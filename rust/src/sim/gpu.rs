//! `SimGpu` — the simulated GPU device.
//!
//! Exposes the same surface the paper's framework uses on real hardware:
//!
//! - **NVML-like**: set SM / memory clock gears; sample instantaneous
//!   power and SM/memory utilization; read accumulated energy.
//! - **CUPTI-like**: start/stop a performance-counter profiling session
//!   and collect the Table-2 feature vector. While a session is active the
//!   device pays the profiling tax (iterations slow down, power rises) —
//!   the overhead that motivates the paper's "profile one period only".
//!
//! Time is virtual: `advance(dt)` moves the simulation clock, accumulates
//! energy and progresses the workload trace. The controller is driven by
//! ticks, so experiments over 71 apps × hundreds of iterations run in
//! milliseconds of wall time.

use crate::sim::app::AppParams;
use crate::sim::segment::{SegmentCache, SegmentKey};
use crate::sim::spec::Spec;
use crate::sim::trace::{Instant, TraceState};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// `read_counters()` was called without an active counter session — on
/// real hardware the CUPTI read would fail the same way. Typed (not a
/// panic) so the fast-forward hot zone stays panic-free (DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterSessionError;

impl std::fmt::Display for CounterSessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "read_counters() requires an active counter session")
    }
}

impl std::error::Error for CounterSessionError {}

/// Virtual-time cutoff for driving a device toward `n_iters` further
/// iterations: generous (50× the nominal run length plus an hour of
/// virtual slack) so it never binds on a healthy run, but finite so an
/// errant policy that stops making progress cannot hang a sweep. The
/// single source of truth for every driver — `run_policy`, the fleet's
/// session budgets, and `SimGpu::run_iterations` all call this.
pub fn run_budget_s(now_s: f64, n_iters: u64, nominal_iter_s: f64) -> f64 {
    now_s + 50.0 * n_iters as f64 * nominal_iter_s + 3600.0
}

#[derive(Debug, Clone)]
pub struct SimGpu {
    pub spec: Arc<Spec>,
    pub app: AppParams,
    sm_gear: usize,
    mem_gear: usize,
    /// Board power limit, watts (`f64::INFINITY` = uncapped).
    power_limit_w: f64,
    /// Highest gear ≤ `sm_gear` whose steady power fits the limit —
    /// recomputed on every clock/limit change, used by every
    /// time/power/trace path so the cap behaves like real power
    /// management (clocks throttle, the requested gear is remembered).
    eff_sm_gear: usize,
    profiling: bool,
    /// Virtual time since run start, seconds.
    vtime_s: f64,
    /// Total accumulated energy, joules.
    energy_j: f64,
    trace: TraceState,
    meas_rng: Pcg64,
    /// Constant-op segment constants (DESIGN.md §13): revalidated by key
    /// compare on every advance/sample, recomputed only when the
    /// (eff_sm_gear, mem_gear, profiling, app_epoch) tuple changes.
    seg: SegmentCache,
    /// Bumped by `swap_app` so segment keys from the old workload can
    /// never validate against the new one.
    app_epoch: u64,
    /// Counts of control actions, for overhead accounting / debugging.
    pub clock_sets: u64,
    pub counter_sessions: u64,
}

impl SimGpu {
    /// Create a device running `app` at the NVIDIA-default configuration.
    pub fn new(spec: Arc<Spec>, app: AppParams) -> SimGpu {
        let meas_rng = Pcg64::new(app.trace_seed ^ 0x5eed_0bad, 0xf00d);
        let trace = TraceState::new(&app);
        // Boot under the NVIDIA default scheduling strategy (power-capped
        // boost), exactly like a real training job before GPOEO attaches.
        let (sm, mem, _) = app.default_op(&spec);
        SimGpu {
            spec,
            app,
            sm_gear: sm,
            mem_gear: mem,
            power_limit_w: f64::INFINITY,
            eff_sm_gear: sm,
            profiling: false,
            vtime_s: 0.0,
            energy_j: 0.0,
            trace,
            meas_rng,
            seg: SegmentCache::new(),
            app_epoch: 0,
            clock_sets: 0,
            counter_sessions: 0,
        }
    }

    fn segment_key(&self) -> SegmentKey {
        SegmentKey {
            eff_sm_gear: self.eff_sm_gear,
            mem_gear: self.mem_gear,
            profiling: self.profiling,
            app_epoch: self.app_epoch,
        }
    }

    /// Revalidate the segment cache against the current device tuple
    /// (one key compare in the steady state).
    fn refresh_segment(&mut self) {
        let key = self.segment_key();
        self.seg.ensure(&self.app, &self.spec, key);
    }

    // ------------------------------------------------------- NVML-like --

    /// Set the SM clock gear (clamped to the valid range).
    pub fn set_sm_gear(&mut self, gear: usize) {
        let g = gear.clamp(self.spec.gears.sm_gear_min, self.spec.gears.sm_gear_max);
        if g != self.sm_gear {
            self.sm_gear = g;
            self.clock_sets += 1;
        }
        self.recompute_throttle();
    }

    /// Set the memory clock gear.
    pub fn set_mem_gear(&mut self, gear: usize) {
        let g = gear.min(self.spec.gears.num_mem_gears() - 1);
        if g != self.mem_gear {
            self.mem_gear = g;
            self.clock_sets += 1;
        }
        self.recompute_throttle();
    }

    /// Set the board power limit (watts), clamped to the device's
    /// supported [`SimGpu::power_limit_range_w`] and returning the
    /// applied value — mirroring `nvmlDeviceSetPowerManagementLimit`,
    /// which bounds requests by the board's management-limit
    /// constraints (we clamp instead of erroring). `f64::INFINITY` (or
    /// NaN, or any non-positive value) lifts the cap and is stored as
    /// `f64::INFINITY` unclamped, keeping the uncapped path bit-
    /// identical to a device that never touched this API. The
    /// effective SM clock throttles immediately; the requested gear is
    /// kept and restored when the limit allows.
    pub fn set_power_limit_w(&mut self, limit_w: f64) -> f64 {
        self.power_limit_w = if !limit_w.is_finite() || limit_w <= 0.0 {
            f64::INFINITY
        } else {
            let (lo, hi) = self.power_limit_range_w();
            limit_w.clamp(lo, hi)
        };
        self.recompute_throttle();
        self.power_limit_w
    }

    /// Current board power limit (`f64::INFINITY` when uncapped).
    pub fn power_limit_w(&self) -> f64 {
        self.power_limit_w
    }

    /// The meaningful cap range `[lo, hi]` for this device+workload:
    /// `lo` is the lowest steady power any operating point can reach
    /// (floor SM gear, best memory gear) and `hi` the highest (top SM
    /// gear, worst memory gear). Caps below `lo` cannot throttle any
    /// deeper than the floor gear already does, and caps above `hi`
    /// never throttle at all — so clamping to this range preserves the
    /// throttle walk bit-for-bit (see the property test).
    pub fn power_limit_range_w(&self) -> (f64, f64) {
        let gears = &self.spec.gears;
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for mem in 0..gears.num_mem_gears() {
            let floor = self.app.op_point(&self.spec, gears.sm_gear_min, mem).power_w;
            let top = self.app.op_point(&self.spec, gears.sm_gear_max, mem).power_w;
            if floor < lo {
                lo = floor;
            }
            if top > hi {
                hi = top;
            }
        }
        (lo, hi)
    }

    /// The SM gear the hardware actually runs at: the requested gear,
    /// throttled down until steady power fits under the power limit (or
    /// the floor gear is reached — at the floor the limit may still be
    /// exceeded, like real silicon at its minimum voltage/clock).
    pub fn effective_sm_gear(&self) -> usize {
        self.eff_sm_gear
    }

    fn recompute_throttle(&mut self) {
        let mut g = self.sm_gear;
        if self.power_limit_w.is_finite() {
            while g > self.spec.gears.sm_gear_min
                && self.app.op_point(&self.spec, g, self.mem_gear).power_w > self.power_limit_w
            {
                g -= 1;
            }
        }
        self.eff_sm_gear = g;
    }

    /// Reset to the NVIDIA default scheduling configuration (power-capped
    /// boost for this app).
    pub fn set_default_clocks(&mut self) {
        let (sm, mem, _) = self.app.default_op(&self.spec);
        self.set_sm_gear(sm);
        self.set_mem_gear(mem);
    }

    pub fn sm_gear(&self) -> usize {
        self.sm_gear
    }

    pub fn mem_gear(&self) -> usize {
        self.mem_gear
    }

    /// Instantaneous (power, SM util, mem util) with measurement noise —
    /// the NVML sampling channel used for period detection. Hot path:
    /// the op point and phase-duration constants come from the segment
    /// cache; results are bit-identical to [`SimGpu::sample_reference`].
    pub fn sample(&mut self, dt_since_last: f64) -> Instant {
        self.refresh_segment();
        let inst = self.trace.sample_with(
            &self.app,
            &self.spec,
            dt_since_last,
            &self.seg.op,
            &self.seg.durs,
            self.seg.weight_norm,
            self.seg.cw_mean,
            self.seg.mw_mean,
        );
        let pmul = self.seg.pmul;
        let noise = self
            .meas_rng
            .normal(0.0, self.spec.noise.power_meas_std);
        Instant {
            power_w: inst.power_w * pmul * (1.0 + noise),
            util_sm: inst.util_sm,
            util_mem: inst.util_mem,
        }
    }

    /// Recomputing twin of [`SimGpu::sample`]: the historical per-call
    /// body, kept as the parity oracle and `sim-bench` comparator
    /// (DESIGN.md §13). Must stay operand-for-operand in sync with the
    /// constants `SegmentCache::refresh` caches.
    pub fn sample_reference(&mut self, dt_since_last: f64) -> Instant {
        let inst = self.trace.sample(
            &self.app,
            &self.spec,
            self.eff_sm_gear,
            self.mem_gear,
            dt_since_last,
        );
        let pmul = if self.profiling {
            self.spec.profiling_tax.counter_power_mult
        } else {
            1.0
        };
        let noise = self
            .meas_rng
            .normal(0.0, self.spec.noise.power_meas_std);
        Instant {
            power_w: inst.power_w * pmul * (1.0 + noise),
            util_sm: inst.util_sm,
            util_mem: inst.util_mem,
        }
    }

    /// Accumulated energy counter (joules), with meter noise — mirrors
    /// `nvmlDeviceGetTotalEnergyConsumption`.
    pub fn energy_j(&mut self) -> f64 {
        let noise = self
            .meas_rng
            .normal(0.0, self.spec.noise.energy_meas_std / 10.0);
        self.energy_j * (1.0 + noise)
    }

    /// Noise-free totals, for experiment bookkeeping (not visible to the
    /// controller, which must use `energy_j()`/`time_s()`).
    pub fn true_energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn time_s(&self) -> f64 {
        self.vtime_s
    }

    pub fn iterations(&self) -> u64 {
        self.trace.iterations
    }

    /// Instructions-per-second proxy (aperiodic path, §4.3.5).
    pub fn ips(&mut self) -> f64 {
        let speed = if self.profiling {
            1.0 / self.spec.profiling_tax.counter_time_mult
        } else {
            1.0
        };
        let noise = self.meas_rng.normal(0.0, 0.01);
        self.app.ips(&self.spec, self.eff_sm_gear, self.mem_gear) * speed * (1.0 + noise)
    }

    // ------------------------------------------------------ CUPTI-like --

    /// Begin a performance-counter session. While active, the workload
    /// pays `profiling_tax` (slower iterations, higher power).
    pub fn start_counter_session(&mut self) {
        if !self.profiling {
            self.profiling = true;
            self.counter_sessions += 1;
        }
    }

    pub fn stop_counter_session(&mut self) {
        self.profiling = false;
    }

    pub fn profiling_active(&self) -> bool {
        self.profiling
    }

    /// Collect the Table-2 feature vector measured over the session window.
    /// Errors without an active session (on hardware the CUPTI read
    /// would fail the same way).
    pub fn read_counters(&mut self) -> Result<Vec<f64>, CounterSessionError> {
        if !self.profiling {
            return Err(CounterSessionError);
        }
        Ok(self.app.measured_features(&self.spec, &mut self.meas_rng))
    }

    /// Replace the running workload mid-flight (a new training job takes
    /// the GPU, or the current job changes phase) — the scenario that
    /// exercises the controller's fluctuation monitor (Fig. 4 step ⑧).
    pub fn swap_app(&mut self, app: AppParams) {
        self.trace = TraceState::new(&app);
        self.app = app;
        // Old-workload segment keys must never validate against the new
        // app, even at identical gears (DESIGN.md §13).
        self.app_epoch += 1;
        // A new workload draws different power at the same clocks, so the
        // throttle point moves.
        self.recompute_throttle();
    }

    // ------------------------------------------------------- simulation --

    /// Advance virtual time by `dt` seconds: progress the workload and
    /// integrate energy at the current operating point. Hot path: the
    /// operating point, profiling tax and time factor come from the
    /// segment cache — bit-identical to [`SimGpu::advance_reference`].
    pub fn advance(&mut self, dt: f64) {
        self.refresh_segment();
        self.energy_j += self.seg.power_eff_w * dt;
        self.trace.advance_with(
            &self.app,
            dt,
            self.seg.speed,
            self.seg.time_factor,
            self.seg.micro_rate0,
        );
        self.vtime_s += dt;
    }

    /// Recomputing twin of [`SimGpu::advance`]: the historical per-tick
    /// body that re-derives the op point and time factor on every call.
    /// Kept as the parity oracle and the `sim-bench` baseline
    /// (DESIGN.md §13) — must stay operand-for-operand in sync with
    /// `SegmentCache::refresh`.
    pub fn advance_reference(&mut self, dt: f64) {
        let (speed, pmul) = if self.profiling {
            (
                1.0 / self.spec.profiling_tax.counter_time_mult,
                self.spec.profiling_tax.counter_power_mult,
            )
        } else {
            (1.0, 1.0)
        };
        let op = self.app.op_point(&self.spec, self.eff_sm_gear, self.mem_gear);
        self.energy_j += op.power_w * pmul * dt;
        self.trace
            .advance(&self.app, &self.spec, self.eff_sm_gear, self.mem_gear, dt, speed);
        self.vtime_s += dt;
    }

    /// Fast-forward in `tick` increments until `target_iters` total
    /// iterations complete or virtual time reaches `t_limit_s`,
    /// whichever comes first. Semantically exactly
    /// `while iterations < target && time < limit { advance(tick) }` —
    /// same tick quantization, same overshoot — but with the segment
    /// revalidated once and the per-tick body run as a tight
    /// monomorphic loop, which is where the sim-bench speedup lives.
    pub fn advance_until(&mut self, target_iters: u64, t_limit_s: f64, tick: f64) {
        if !(tick > 0.0) {
            return; // zero/negative/NaN tick would never terminate
        }
        self.refresh_segment();
        while self.trace.iterations < target_iters && self.vtime_s < t_limit_s {
            self.energy_j += self.seg.power_eff_w * tick;
            self.trace.advance_with(
                &self.app,
                tick,
                self.seg.speed,
                self.seg.time_factor,
                self.seg.micro_rate0,
            );
            self.vtime_s += tick;
        }
    }

    /// Run until `n` further iterations complete (convenience for tests
    /// and the oracle; steps in `tick` increments). The cutoff is the
    /// shared `run_budget_s` — the same errant-policy guard every other
    /// driver uses.
    pub fn run_iterations(&mut self, n: u64, tick: f64) {
        let target = self.trace.iterations + n;
        let budget = run_budget_s(self.vtime_s, n, self.app.t_base);
        self.advance_until(target, budget, tick);
    }

    /// Ground-truth current iteration period (virtual seconds), including
    /// the profiling dilation if a session is active.
    pub fn true_period(&self) -> f64 {
        let speed = if self.profiling {
            1.0 / self.spec.profiling_tax.counter_time_mult
        } else {
            1.0
        };
        TraceState::true_period(&self.app, &self.spec, self.eff_sm_gear, self.mem_gear, speed)
    }
}

/// Materialize one app from a suite by name.
pub fn make_app(spec: &Spec, suite: &str, name: &str) -> anyhow::Result<AppParams> {
    let s = spec
        .suites
        .get(suite)
        .ok_or_else(|| anyhow::anyhow!("unknown suite '{suite}'"))?;
    let e = s
        .apps
        .iter()
        .find(|a| a.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown app '{name}' in suite '{suite}'"))?;
    Ok(AppParams::materialize(
        spec,
        suite,
        &e.name,
        &e.archetype,
        e.abnormal_every,
        e.abnormal_scale,
        e.aperiodic,
    ))
}

/// Materialize every app in a suite, in spec order.
pub fn make_suite(spec: &Spec, suite: &str) -> anyhow::Result<Vec<AppParams>> {
    let s = spec
        .suites
        .get(suite)
        .ok_or_else(|| anyhow::anyhow!("unknown suite '{suite}'"))?;
    s.apps
        .iter()
        .map(|e| make_app(spec, suite, &e.name))
        .collect()
}

/// Find an app by name across all suites (for the CLI).
pub fn find_app(spec: &Spec, name: &str) -> anyhow::Result<AppParams> {
    for suite in spec.suites.keys() {
        if spec.suites[suite].apps.iter().any(|a| a.name == name) {
            return make_app(spec, suite, name);
        }
    }
    anyhow::bail!("app '{name}' not found in any suite")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu(name: &str) -> SimGpu {
        let spec = Arc::new(Spec::load_default().unwrap());
        let app = find_app(&spec, name).unwrap();
        SimGpu::new(spec, app)
    }

    #[test]
    fn energy_integrates_power() {
        let mut g = gpu("AI_I2T");
        let op = g.app.op_point(&g.spec, g.sm_gear(), g.mem_gear());
        for _ in 0..1000 {
            g.advance(0.01);
        }
        let expect = op.power_w * 10.0;
        assert!((g.true_energy_j() - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn profiling_costs_energy_and_time() {
        let mut a = gpu("AI_FE");
        let mut b = gpu("AI_FE");
        b.start_counter_session();
        for _ in 0..6000 {
            a.advance(0.01);
            b.advance(0.01);
        }
        assert!(b.true_energy_j() > a.true_energy_j() * 1.05);
        assert!(b.iterations() < a.iterations());
    }

    #[test]
    fn gear_setting_clamps_and_counts() {
        let mut g = gpu("AI_TS");
        g.set_sm_gear(5);
        assert_eq!(g.sm_gear(), 16);
        g.set_sm_gear(500);
        assert_eq!(g.sm_gear(), 114);
        g.set_mem_gear(99);
        assert_eq!(g.mem_gear(), 4);
        assert!(g.clock_sets >= 2);
    }

    #[test]
    fn downclock_reduces_power_increases_period() {
        let mut g = gpu("SBM_GIN");
        let p_hi = g.app.op_point(&g.spec, 114, 4);
        g.set_sm_gear(60);
        let p_lo = g.app.op_point(&g.spec, 60, 4);
        assert!(p_lo.power_w < p_hi.power_w);
        assert!(p_lo.t_iter_s > p_hi.t_iter_s);
    }

    #[test]
    fn counters_require_session() {
        let mut g = gpu("AI_OBJ");
        assert_eq!(g.read_counters(), Err(CounterSessionError));
        // The failed read must not perturb the measurement RNG stream:
        // a session opened afterwards reads the same features as one
        // opened on a fresh device.
        let mut fresh = gpu("AI_OBJ");
        g.start_counter_session();
        fresh.start_counter_session();
        assert_eq!(g.read_counters().unwrap(), fresh.read_counters().unwrap());
    }

    #[test]
    fn counters_noisy_copy_of_truth() {
        let mut g = gpu("AI_OBJ");
        g.start_counter_session();
        let m = g.read_counters().unwrap();
        g.stop_counter_session();
        for (t, m) in g.app.features.clone().iter().zip(&m) {
            assert!((m / t - 1.0).abs() < 0.15);
        }
    }

    #[test]
    fn cached_advance_is_bit_identical_to_reference() {
        // Drive two clones of the same device through an adversarial
        // schedule of gear switches, profiling toggles and a power cap —
        // one through the segment-cached hot path, one through the
        // recomputing reference twin. Every observable must match to the
        // last bit (DESIGN.md §13).
        for name in ["AI_I2T", "AI_TS", "TSVM", "SBM_GIN"] {
            let mut fast = gpu(name);
            let mut refr = gpu(name);
            for step in 0..3000u32 {
                if step % 400 == 0 {
                    let gear = 40 + ((step / 400) * 17 % 75) as usize;
                    fast.set_sm_gear(gear);
                    refr.set_sm_gear(gear);
                }
                if step % 700 == 0 {
                    fast.start_counter_session();
                    refr.start_counter_session();
                } else if step % 700 == 350 {
                    fast.stop_counter_session();
                    refr.stop_counter_session();
                }
                if step == 1500 {
                    fast.set_power_limit_w(200.0);
                    refr.set_power_limit_w(200.0);
                }
                fast.advance(0.01);
                refr.advance_reference(0.01);
                let (sf, sr) = (fast.sample(0.01), refr.sample_reference(0.01));
                assert_eq!(sf.power_w, sr.power_w, "{name} step {step}");
                assert_eq!(sf.util_sm, sr.util_sm, "{name} step {step}");
                assert_eq!(sf.util_mem, sr.util_mem, "{name} step {step}");
            }
            assert_eq!(fast.true_energy_j(), refr.true_energy_j(), "{name}");
            assert_eq!(fast.iterations(), refr.iterations(), "{name}");
            assert_eq!(fast.time_s(), refr.time_s(), "{name}");
        }
    }

    #[test]
    fn advance_until_matches_stepped_loop_bitwise() {
        for name in ["AI_FE", "TSVM"] {
            let mut fast = gpu(name);
            let mut stepped = gpu(name);
            let target = 40;
            let limit = 1e6;
            fast.advance_until(target, limit, 0.025);
            while stepped.iterations() < target && stepped.time_s() < limit {
                stepped.advance_reference(0.025);
            }
            assert_eq!(fast.iterations(), stepped.iterations(), "{name}");
            assert_eq!(fast.true_energy_j(), stepped.true_energy_j(), "{name}");
            assert_eq!(fast.time_s(), stepped.time_s(), "{name}");
        }
    }

    #[test]
    fn advance_until_honors_the_time_limit() {
        let mut g = gpu("AI_I2T");
        g.advance_until(u64::MAX, 1.0, 0.01);
        // Tick-quantized: stops on the first tick at or past the limit.
        assert!(g.time_s() >= 1.0 && g.time_s() < 1.0 + 0.011);
        // Degenerate ticks must return rather than spin.
        g.advance_until(u64::MAX, 2.0, 0.0);
        g.advance_until(u64::MAX, 2.0, -1.0);
        g.advance_until(u64::MAX, 2.0, f64::NAN);
        assert!(g.time_s() < 1.0 + 0.011);
    }

    #[test]
    fn swap_app_invalidates_the_segment_cache() {
        // Warm the cache on app A, swap to app B *without* touching the
        // gears (so only the epoch bump separates the segment keys), and
        // compare against the recomputing twin. A stale cache would keep
        // integrating app A's power and diverge immediately.
        let spec = Arc::new(Spec::load_default().unwrap());
        let a = find_app(&spec, "AI_I2T").unwrap();
        let b = find_app(&spec, "AI_FE").unwrap();
        let mut fast = SimGpu::new(spec.clone(), a.clone());
        let mut refr = SimGpu::new(spec, a);
        fast.advance(0.01);
        refr.advance_reference(0.01);
        fast.swap_app(b.clone());
        refr.swap_app(b);
        for _ in 0..500 {
            fast.advance(0.01);
            refr.advance_reference(0.01);
        }
        assert_eq!(fast.true_energy_j(), refr.true_energy_j());
        assert_eq!(fast.iterations(), refr.iterations());
    }

    #[test]
    fn power_cap_throttles_under_the_limit() {
        // Property: under any finite cap, the effective operating point
        // never draws more than the limit (unless already at the floor
        // gear), and is never throttled further than necessary.
        for name in ["AI_I2T", "SBM_GIN", "AI_TS", "TSVM"] {
            let mut g = gpu(name);
            for cap in [320.0, 260.0, 200.0, 140.0, 90.0] {
                g.set_power_limit_w(cap);
                for gear in [114usize, 96, 70, 40, 16] {
                    g.set_sm_gear(gear);
                    let eff = g.effective_sm_gear();
                    assert!(eff <= g.sm_gear());
                    let op = g.app.op_point(&g.spec, eff, g.mem_gear());
                    assert!(
                        op.power_w <= cap + 1e-9 || eff == g.spec.gears.sm_gear_min,
                        "{name} cap {cap}: eff gear {eff} draws {:.1} W",
                        op.power_w
                    );
                    if eff < g.sm_gear() {
                        let above = g.app.op_point(&g.spec, eff + 1, g.mem_gear());
                        assert!(above.power_w > cap, "{name}: throttled too deep");
                    }
                }
            }
            // Lifting the cap restores the requested gear.
            g.set_sm_gear(114);
            g.set_power_limit_w(f64::INFINITY);
            assert_eq!(g.effective_sm_gear(), 114);
        }
    }

    #[test]
    fn uncapped_behavior_is_bit_identical() {
        // Setting an infinite limit must not change a single bit of the
        // trajectory relative to a device that never touched the API.
        let mut a = gpu("AI_FE");
        let mut b = gpu("AI_FE");
        b.set_power_limit_w(f64::INFINITY);
        for _ in 0..2000 {
            a.advance(0.01);
            b.advance(0.01);
            let (sa, sb) = (a.sample(0.01), b.sample(0.01));
            assert_eq!(sa.power_w, sb.power_w);
        }
        assert_eq!(a.true_energy_j(), b.true_energy_j());
        assert_eq!(a.iterations(), b.iterations());
        assert_eq!(a.true_period(), b.true_period());
    }

    #[test]
    fn capping_saves_energy_and_slows_iterations() {
        let mut free = gpu("AI_I2T");
        let mut capped = gpu("AI_I2T");
        let (_, _, dflt) = free.app.default_op(&free.spec);
        let cap = dflt.power_w * 0.7;
        capped.set_power_limit_w(cap);
        assert!(capped.effective_sm_gear() < capped.sm_gear());
        for _ in 0..6000 {
            free.advance(0.01);
            capped.advance(0.01);
        }
        assert!(capped.true_energy_j() < free.true_energy_j());
        assert!(capped.iterations() <= free.iterations());
        // The integral form of the cap: E ≤ limit × time.
        assert!(capped.true_energy_j() <= cap * capped.time_s() + 1e-6);
        assert!(capped.true_period() > free.true_period());
    }

    #[test]
    fn clamped_caps_apply_and_preserve_the_throttle_walk() {
        // Property (DESIGN.md §14): set_power_limit_w clamps to the
        // device's supported range and returns the applied value, and
        // the clamp never changes the effective SM gear a raw
        // (unclamped) throttle walk would pick — out-of-range requests
        // were already saturated at the floor/top gear, so clamping
        // preserves the PR 2 capped/uncapped behavior bit-for-bit.
        for name in ["AI_I2T", "AI_TS", "TSVM", "SBM_GIN"] {
            let mut g = gpu(name);
            let (lo, hi) = g.power_limit_range_w();
            assert!(lo > 0.0 && lo <= hi, "{name}: range ({lo}, {hi})");
            for mem in 0..g.spec.gears.num_mem_gears() {
                g.set_mem_gear(mem);
                for sm in [114usize, 96, 70, 40, 16] {
                    g.set_sm_gear(sm);
                    for req in [1.0, lo * 0.5, lo, 0.5 * (lo + hi), hi, hi * 2.0, 1e6] {
                        let applied = g.set_power_limit_w(req);
                        assert_eq!(applied, req.clamp(lo, hi), "{name} req {req}");
                        assert_eq!(g.power_limit_w(), applied);
                        // The raw-request reference walk (the PR 2
                        // contract, pre-clamping).
                        let mut eff = g.sm_gear();
                        while eff > g.spec.gears.sm_gear_min
                            && g.app.op_point(&g.spec, eff, mem).power_w > req
                        {
                            eff -= 1;
                        }
                        assert_eq!(
                            g.effective_sm_gear(),
                            eff,
                            "{name} mem {mem} sm {sm} req {req}"
                        );
                    }
                }
            }
            // Lifting requests store INFINITY unclamped — bit-identical
            // to never capping (uncapped_behavior_is_bit_identical).
            for req in [f64::INFINITY, f64::NAN, 0.0, -5.0, f64::NEG_INFINITY] {
                assert_eq!(g.set_power_limit_w(req), f64::INFINITY);
            }
            assert_eq!(g.power_limit_w(), f64::INFINITY);
        }
    }

    #[test]
    fn run_iterations_terminates() {
        let mut g = gpu("CLB_MLP");
        g.run_iterations(5, 0.01);
        assert!(g.iterations() >= 5);
    }

    #[test]
    fn suite_materialization_counts() {
        let spec = Spec::load_default().unwrap();
        assert_eq!(make_suite(&spec, "aibench").unwrap().len(), 14);
        assert_eq!(make_suite(&spec, "gnns").unwrap().len(), 55);
        assert!(find_app(&spec, "TSVM").unwrap().aperiodic);
        assert!(find_app(&spec, "NOPE").is_err());
    }
}
