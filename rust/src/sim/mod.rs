//! Simulated GPU testbed: the hardware substrate the paper ran on real
//! silicon (RTX3080Ti + NVML + CUPTI), rebuilt as a deterministic
//! discrete-event model. See DESIGN.md §1 for the substitution rationale.

pub mod app;
pub mod gpu;
pub mod segment;
pub mod spec;
pub mod trace;

pub use app::{AppParams, OpPoint};
pub use gpu::{find_app, make_app, make_suite, run_budget_s, CounterSessionError, SimGpu};
pub use segment::{SegmentCache, SegmentKey};
pub use spec::{Spec, NUM_FEATURES};
pub use trace::{Instant, TraceState};
